"""Two-phase DES scale-out (DESIGN.md Sec. 12).

Fast tier: seeded property tests that the two-phase ``des`` backend
(phase 1 :mod:`repro.core.desgraph` + phase 2
:mod:`repro.core.desreplay`) is bit-identical to the legacy ``des-loop``
— reports, delivery logs, latency percentiles, cost extras — across
heterogeneous stacked subgroups, null-send on/off and the full flag
lattice corners; graph-vs-des conformance at N ∈ {256, 1024}; the
deterministic ``(time, node, seq)`` event tie-break under permuted
subgroup declaration order; and the vectorized egress-link chain vs a
reference sequential loop.

Soak tier (``-m soak``): the N=4096 fleet — two-phase des against the
stacked graph program on the same schedule.
"""

import dataclasses

import numpy as np
import pytest

from repro import api
from repro.core import desgraph, desreplay
from repro.core import group as group_mod
from repro.core import simulator as sim

fast = pytest.mark.fast
soak = pytest.mark.soak


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------

def _eq(a, b, path=""):
    """Bit-exact structural equality (NaN == NaN, numpy vs scalar)."""
    if isinstance(a, np.ndarray) or isinstance(b, np.ndarray):
        assert np.array_equal(np.asarray(a), np.asarray(b),
                              equal_nan=True), path
    elif isinstance(a, dict):
        assert set(a) == set(b), path
        for k in a:
            if k in ("wall_s", "backend"):
                continue
            _eq(a[k], b[k], f"{path}.{k}")
    elif isinstance(a, (list, tuple)):
        assert len(a) == len(b), path
        for i, (x, y) in enumerate(zip(a, b)):
            _eq(x, y, f"{path}[{i}]")
    elif isinstance(a, float) and isinstance(b, float) \
            and np.isnan(a) and np.isnan(b):
        pass
    else:
        assert a == b, (path, a, b)


def _run(cfg, backend):
    g = api.Group(cfg)
    report = g.run(backend=backend)
    return report, g.delivery_logs


def _assert_identical(cfg, ctx=""):
    """des (two-phase) == des-loop (legacy), bit for bit."""
    r1, l1 = _run(cfg, "des-loop")
    r2, l2 = _run(cfg, "des")
    _eq(dataclasses.asdict(r1), dataclasses.asdict(r2), f"{ctx}:report")
    assert set(l1) == set(l2), ctx
    for gid in l1:
        _eq(vars(l1[gid]), vars(l2[gid]), f"{ctx}:log{gid}")


def _rand_stack(rng, n_nodes, n_groups):
    """A random heterogeneous stacked-subgroup scenario."""
    nodes = np.arange(n_nodes)
    specs = []
    for _ in range(n_groups):
        n_m = int(rng.integers(2, min(n_nodes, 7) + 1))
        members = tuple(int(m) for m in
                        rng.choice(nodes, size=n_m, replace=False))
        n_s = int(rng.integers(1, n_m + 1))
        senders = tuple(int(s) for s in
                        rng.choice(members, size=n_s, replace=False))
        specs.append(api.SubgroupSpec(
            members=members, senders=senders,
            window=int(rng.integers(2, 7)),
            msg_size=int(rng.choice([64, 512, 4096])),
            n_messages=int(rng.integers(1, 9))))
    return api.GroupConfig(members=tuple(range(n_nodes)),
                           subgroups=tuple(specs))


def _big_cfg(n_nodes, n_senders=8, n_messages=4, window=16,
             rounds=None):
    spec = api.SubgroupSpec(members=tuple(range(n_nodes)),
                            senders=tuple(range(n_senders)),
                            window=window, msg_size=1024,
                            n_messages=n_messages)
    return api.GroupConfig(members=tuple(range(n_nodes)),
                           subgroups=(spec,), rounds=rounds)


def _digest(logs):
    """Order-sensitive per-member delivery digest for graph-vs-des
    conformance: the delivered sequence of (rank, idx, is_app)."""
    out = {}
    for gid, log in sorted(logs.items()):
        for node in sorted(log.delivered_seq):
            out[(gid, node)] = log.sequence(node)
    return out


# ---------------------------------------------------------------------------
# des2 == des-loop, bit-identical (fast)
# ---------------------------------------------------------------------------

@fast
def test_two_phase_identical_heterogeneous_stacks():
    rng = np.random.default_rng(1234)
    for case in range(8):
        cfg = _rand_stack(rng, n_nodes=int(rng.integers(4, 9)),
                          n_groups=int(rng.integers(1, 4)))
        _assert_identical(cfg, ctx=f"case{case}")


@fast
def test_two_phase_identical_null_send_on_off():
    rng = np.random.default_rng(77)
    for case in range(4):
        base = _rand_stack(rng, n_nodes=6, n_groups=2)
        for null_send in (True, False):
            cfg = dataclasses.replace(
                base, flags=dataclasses.replace(base.flags,
                                                null_send=null_send))
            _assert_identical(cfg, ctx=f"case{case}:null={null_send}")


@fast
def test_two_phase_identical_flag_corners():
    base = _rand_stack(np.random.default_rng(9), n_nodes=7, n_groups=3)
    corners = [
        api.SpindleFlags(batch_receive=False, batch_delivery=False,
                         batch_send=False, null_send=False,
                         early_lock_release=False, batched_upcall=False,
                         wait_stability=False),
        dataclasses.replace(api.SpindleFlags(), memcpy_delivery=True,
                            memcpy_send=True, disk_append=True),
        dataclasses.replace(api.SpindleFlags(),
                            early_lock_release=False),
        dataclasses.replace(api.SpindleFlags(), batch_send=False,
                            wait_stability=False),
    ]
    for i, flags in enumerate(corners):
        _assert_identical(dataclasses.replace(base, flags=flags),
                          ctx=f"corner{i}")


@fast
def test_two_phase_identical_n64():
    _assert_identical(_big_cfg(64, n_messages=6), ctx="n64")


# ---------------------------------------------------------------------------
# graph-vs-des conformance at fleet scale (fast: 256 and 1024)
# ---------------------------------------------------------------------------

def _conformance(n_nodes, rounds, n_messages, n_senders=8):
    cfg = _big_cfg(n_nodes, n_senders=n_senders, n_messages=n_messages,
                   rounds=rounds)
    r_des, l_des = _run(cfg, "des")
    r_g, l_g = _run(cfg, "graph")
    assert not r_des.stalled and not r_g.stalled
    assert r_des.delivered_app_msgs == r_g.delivered_app_msgs
    assert _digest(l_des) == _digest(l_g)


@fast
def test_graph_vs_des_conformance_n256():
    _conformance(256, rounds=24, n_messages=4)


@fast
def test_graph_vs_des_conformance_n1024():
    _conformance(1024, rounds=16, n_messages=2)


@soak
def test_graph_vs_des_conformance_n4096():
    _conformance(4096, rounds=24, n_messages=2, n_senders=2)


# ---------------------------------------------------------------------------
# deterministic event tie-breaking (the (time, node, seq) heap key)
# ---------------------------------------------------------------------------

@fast
def test_event_graph_invariant_under_subgroup_permutation():
    """Permuting the declaration order of disjoint subgroups must not
    reorder same-timestamp events: the per-subgroup slices of the event
    graph are unchanged (the explicit ``(time, node, seq)`` key breaks
    ties by node, never by arrival order of heap pushes)."""
    sa = api.SubgroupSpec(members=(0, 1, 2), senders=(0, 1),
                          window=3, msg_size=512, n_messages=6)
    sb = api.SubgroupSpec(members=(3, 4, 5, 6), senders=(3, 5, 6),
                          window=4, msg_size=256, n_messages=5)
    members = tuple(range(7))
    cfg_ab = api.GroupConfig(members=members, subgroups=(sa, sb))
    cfg_ba = api.GroupConfig(members=members, subgroups=(sb, sa))
    graphs = {}
    for tag, cfg in (("ab", cfg_ab), ("ba", cfg_ba)):
        counts = {g: np.full(len(s.senders), s.n_messages, np.int64)
                  for g, s in enumerate(cfg.subgroups)}
        graphs[tag] = desgraph.simulate(
            group_mod.DESLoopBackend._lower(cfg, counts))
    ga, gb = graphs["ab"], graphs["ba"]
    # the global sweep timeline is identical (gids don't enter the key)
    _eq(ga.sweep_node, gb.sweep_node, "sweep_node")
    _eq(ga.sweep_time, gb.sweep_time, "sweep_time")
    _eq(ga.sweep_dur, gb.sweep_dur, "sweep_dur")
    # per-subgroup event slices match under the gid permutation
    perm = {0: 1, 1: 0}                   # ab gid -> ba gid
    for key in ("deliv", "pub"):
        gid_a = getattr(ga, f"{key}_gid")
        gid_b = getattr(gb, f"{key}_gid")
        for g_a, g_b in perm.items():
            ma, mb = gid_a == g_a, gid_b == g_b
            fields = {"deliv": ("member", "lo", "hi", "napp", "time"),
                      "pub": ("rank", "count", "is_null", "time")}[key]
            for f in fields:
                _eq(getattr(ga, f"{key}_{f}")[ma],
                    getattr(gb, f"{key}_{f}")[mb],
                    f"{key}_{f}:g{g_a}")


@fast
def test_two_phase_identical_under_subgroup_permutation():
    """End to end: the permuted-declaration scenario still replays
    bit-identically to the legacy loop (per-subgroup logs match under
    the gid relabeling)."""
    sa = api.SubgroupSpec(members=(0, 1, 2), senders=(0, 1),
                          window=3, msg_size=512, n_messages=6)
    sb = api.SubgroupSpec(members=(3, 4, 5, 6), senders=(3, 5, 6),
                          window=4, msg_size=256, n_messages=5)
    members = tuple(range(7))
    _assert_identical(api.GroupConfig(members=members,
                                      subgroups=(sa, sb)), "ab")
    _assert_identical(api.GroupConfig(members=members,
                                      subgroups=(sb, sa)), "ba")


# ---------------------------------------------------------------------------
# the vectorized egress-link chain (phase 1's only float refactor)
# ---------------------------------------------------------------------------

@fast
def test_post_chain_matches_sequential_reference():
    """The two cumsum regimes of ``Phase1._post_record`` reproduce the
    sequential ``L_i = fl(max(L_{i-1}, t_i) + ser)`` recurrence bit for
    bit, for serialization both above and below the post cost."""
    rng = np.random.default_rng(3)
    cfg = api.single_group(5, n_senders=2, n_messages=1)
    counts = {0: np.ones(2, np.int64)}
    for size in (64, 700, 4096, 65536):
        for link0_off in (-3.0, 0.0, 2.5, 1000.0):
            p1 = desgraph.Phase1(
                group_mod.DESLoopBackend._lower(cfg, counts))
            net = p1.cfg.net
            t0 = float(rng.uniform(5.0, 50.0))
            src = 0
            p1.link_free[src] = t0 + link0_off
            link0 = p1.link_free[src]
            g = p1.groups[0]
            st = p1._stream_for(g, 0, src)
            n = len(st.dsts)
            # reference: the legacy sequential chain
            ser = net.serialization(size)
            ref, link, t = [], link0, t0
            for _ in range(n):
                t += net.post_us
                link = max(link, t) + ser
                ref.append(link)
            p1._post_record(src, t0, st, size, 7, g.recv_seen, 0)
            wl = net.wire_latency(min(size, 4096))
            got = np.asarray(st.arrs[-1])
            expect = np.maximum(np.asarray(ref) + wl, 0.0)
            np.testing.assert_array_equal(got, expect)
            assert p1.link_free[src] == ref[-1]


# ---------------------------------------------------------------------------
# the des stream mirror (sweep arithmetic host-side)
# ---------------------------------------------------------------------------

@fast
def test_numpy_sweep_mirror_matches_jax_rounds():
    """:func:`repro.core.desreplay.sweep_np` steps produce the same
    int32 state trajectory as the compiled stream program."""
    rng = np.random.default_rng(21)
    s1 = api.SubgroupSpec(members=(0, 1, 2, 3), senders=(0, 2),
                          window=3, msg_size=512, n_messages=10)
    s2 = api.SubgroupSpec(members=(2, 3, 4, 5, 6), senders=(3, 4, 5, 6),
                          window=5, msg_size=128, n_messages=10)
    cfg = api.GroupConfig(members=tuple(range(7)), subgroups=(s1, s2))
    streams = {be: api.Group(cfg).stream(backend=be)
               for be in ("graph", "des")}
    assert streams["des"]._numpy and not streams["graph"]._numpy
    for _ in range(10):
        ready = rng.integers(0, 3, size=(2, 4)).astype(np.int32)
        ready[0, 2:] = 0
        va = streams["graph"].step(ready.copy())
        vb = streams["des"].step(ready.copy())
        _eq(np.asarray(va.delivered_num), np.asarray(vb.delivered_num))
        _eq(np.asarray(va.published), np.asarray(vb.published))
        _eq(np.asarray(va.backlog), np.asarray(vb.backlog))
        _eq(np.asarray(va.app_pub), np.asarray(vb.app_pub))
        _eq(np.asarray(va.nulls), np.asarray(vb.nulls))
    ra, la = streams["graph"].finish()
    rb, lb = streams["des"].finish()
    _eq(dataclasses.asdict(ra), dataclasses.asdict(rb), "report")
    _eq({k: vars(v) for k, v in la.items()},
        {k: vars(v) for k, v in lb.items()}, "logs")


@fast
def test_des_loop_backend_still_runs_and_rejects_streaming():
    cfg = api.single_group(3, n_senders=2, n_messages=4)
    report = api.Group(cfg).run(backend="des-loop")
    assert report.backend == "des-loop"
    assert report.delivered_app_msgs == 2 * 4 * 3
    with pytest.raises(ValueError, match="graph/pallas"):
        api.Group(cfg).stream(backend="des-loop")


@fast
def test_des_batch_runs_sequentially_per_point():
    """DESBackend.run_batch must bypass the inherited compiled grid."""
    cfg = api.single_group(3, n_senders=2, n_messages=3)
    g = api.Group(cfg)
    sizes = [64, 1024]
    cfgs = [dataclasses.replace(
        cfg, subgroups=(dataclasses.replace(cfg.subgroups[0],
                                            msg_size=s),))
        for s in sizes]
    reports = [api.Group(c).run(backend="des") for c in cfgs]
    loop = [api.Group(c).run(backend="des-loop") for c in cfgs]
    for r2, r1 in zip(reports, loop):
        _eq(dataclasses.asdict(r1), dataclasses.asdict(r2))
