"""Property + unit tests for the Spindle protocol core.

These check the paper's stated invariants:
  * round-robin sequence arithmetic is self-consistent (Sec. 2.1),
  * the null-send rule implies no-stall / <=1-round skew / quiescence
    (Sec. 3.3's proof, checked mechanically),
  * monotone merge safety (Sec. 3.4's lock-release argument),
  * the fused sweep delivers the same total order at every node.

Property tests draw cases from seeded numpy generators (one fixed seed
per parametrized case) instead of hypothesis — the container doesn't
ship it, and the suite's skip budget is ~0 (tests/conftest.py).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import nullsend, smc, sst, sweep

pytestmark = pytest.mark.fast

jax.config.update("jax_platform_name", "cpu")

_BASE_SEED = 20_000


def _rng(case: int) -> np.random.Generator:
    return np.random.default_rng(_BASE_SEED + case)


# ---------------------------------------------------------------------------
# sst: round-robin arithmetic
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("case", range(30))
def test_rr_prefix_definition(case):
    """rr_prefix(counts) = largest N s.t. every message of the first N in
    round-robin order is present — checked against brute force."""
    rng = _rng(case)
    counts = rng.integers(0, 201, size=int(rng.integers(1, 17)))
    s = len(counts)
    n = 0
    while counts[n % s] >= n // s + 1:
        n += 1
    assert sst.rr_prefix(counts) == n


@pytest.mark.parametrize("case", range(30))
def test_sender_counts_roundtrip(case):
    rng = _rng(case)
    prefix = int(rng.integers(0, 10_001))
    s = int(rng.integers(1, 17))
    counts = sst.sender_counts(np.array(prefix), s)
    assert counts.sum() == prefix
    # the counts of a complete prefix reproduce the prefix
    assert sst.rr_prefix(counts) >= prefix


@pytest.mark.parametrize("case", range(30))
def test_rr_prefix_monotone(case):
    rng = _rng(case)
    counts = rng.integers(0, 51, size=int(rng.integers(1, 13)))
    bumped = counts + 1
    assert sst.rr_prefix(bumped) >= sst.rr_prefix(counts)


def test_rr_prefix_jnp_matches_np():
    counts = np.array([[3, 5, 2], [7, 7, 7], [0, 9, 9]])
    got = np.asarray(sst.rr_prefix(jnp.asarray(counts)))
    want = np.array([sst.rr_prefix(c) for c in counts])
    np.testing.assert_array_equal(got, want)


def test_update_own_row_rejects_non_monotonic():
    schema = sst.SSTSchema(columns=(sst.SSTColumn("c", ()),))
    table = schema.make_table(3)
    table = sst.update_own_row(table, 0, "c", 5)
    with pytest.raises(ValueError):
        sst.update_own_row(table, 0, "c", 4)


def test_merge_tables_is_monotone_join():
    a = {"c": np.array([3, 1, 4])}
    b = {"c": np.array([2, 7, 4])}
    m = sst.merge_tables(a, b)
    np.testing.assert_array_equal(m["c"], [3, 7, 4])
    # idempotent + commutative
    np.testing.assert_array_equal(
        sst.merge_tables(m, a)["c"], m["c"])
    np.testing.assert_array_equal(
        sst.merge_tables(b, a)["c"], m["c"])


# ---------------------------------------------------------------------------
# smc: ring buffer
# ---------------------------------------------------------------------------

def test_smc_region_bytes_matches_paper_formula():
    # Sec. 4.1.2: 16 members, 10KB messages, w=100 -> ~16MB per subgroup
    cfg = smc.SMCConfig(window=100, max_msg_size=10240)
    assert cfg.region_bytes(16) == 16 * 100 * (10240 + 8)
    assert abs(cfg.region_bytes(16) / 2**20 - 16) < 0.7


@pytest.mark.parametrize("case", range(30))
def test_slot_counter_identity(case):
    rng = _rng(case)
    index = int(rng.integers(0, 1001))
    window = int(rng.integers(1, 65))
    # message k lives in slot k % w with counter k // w
    slot = smc.slot_of(index, window)
    ctr = smc.counter_for(index, window)
    assert ctr * window + slot == index


@pytest.mark.parametrize("case", range(30))
def test_visible_from_counters(case):
    rng = _rng(case)
    window = int(rng.integers(1, 9))
    received = int(rng.integers(0, 41))
    published = int(rng.integers(0, 81))
    published = max(received, min(published, received + window))
    counters = np.full(window, -1, dtype=np.int64)
    for k in range(published):
        counters[k % window] = k // window
    got = smc.visible_from_counters(counters, np.int64(received), window)
    assert got == published


# ---------------------------------------------------------------------------
# nullsend: the Sec. 3.3 rule
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("case", range(40))
def test_null_target_is_minimal_non_preceding(case):
    """target is the smallest own index that does not precede M(j, k)."""
    rng = _rng(case)
    i = int(rng.integers(0, 8))
    k = int(rng.integers(0, 101))
    j = int(rng.integers(0, 8))
    tgt = int(nullsend.null_target(i, k, j))
    assert not nullsend.precedes(tgt, i, k, j)
    if tgt > 0:
        assert nullsend.precedes(tgt - 1, i, k, j)


@pytest.mark.parametrize("case", range(30))
def test_nulls_needed_never_responds_to_self(case):
    rng = _rng(case)
    s = int(rng.integers(2, 9))
    rank = int(rng.integers(0, s))
    counts = np.zeros(s, dtype=np.int64)
    counts[rank] = int(rng.integers(0, 51))
    assert nullsend.nulls_needed(rank, 0, counts) == 0


@pytest.mark.parametrize("case", range(40))
def test_nulls_needed_covers_delivery(case):
    """After sending the prescribed nulls, every message received so far is
    deliverable once others catch up: our next message no longer precedes
    any received message."""
    rng = _rng(case)
    s = int(rng.integers(2, 9))
    rank = int(rng.integers(0, s))
    counts = rng.integers(0, 31, size=s)
    own_next = int(rng.integers(0, 31))
    n = int(nullsend.nulls_needed(rank, own_next, counts))
    new_next = own_next + n
    for j in range(s):
        if j == rank or counts[j] == 0:
            continue
        assert not nullsend.precedes(new_next, rank, counts[j] - 1, j)
    # and it is minimal: one fewer null would leave a preceding message
    if n > 0:
        assert any(
            nullsend.precedes(new_next - 1, rank, counts[j] - 1, j)
            for j in range(s) if j != rank and counts[j] > 0)


def test_nulls_needed_quiescent_when_caught_up():
    counts = np.array([10, 10, 10, 10])
    assert nullsend.nulls_needed(0, 10, counts) == 0
    # rank 3 at index 9 does not precede anyone's round-9 message...
    assert nullsend.nulls_needed(3, 9, counts) == 0
    # ...but at index 8 it precedes M(0..2, 9): one null
    assert nullsend.nulls_needed(3, 8, counts) == 1
    # rank 0 must cover round 9 itself (M(0,9) precedes M(1,9))
    assert nullsend.nulls_needed(0, 9, counts) == 1


# ---------------------------------------------------------------------------
# sweep: fused protocol round — the paper's four properties
# ---------------------------------------------------------------------------

_PAD_ROUNDS = 72  # fixed scan length => one compile per (n_members, n_senders)


def _run(n_members, n_senders, schedule, null_send=True, window=1 << 30):
    schedule = np.asarray(schedule)
    assert schedule.shape[0] <= _PAD_ROUNDS
    padded = np.zeros((_PAD_ROUNDS, schedule.shape[1]), np.int64)
    padded[: schedule.shape[0]] = schedule
    stt = sweep.SweepState.init(n_members, n_senders)
    return sweep.run_rounds(stt, jnp.asarray(padded, jnp.int32),
                            null_send=null_send, window=window)


@pytest.mark.parametrize("case", range(25))
def test_sweep_no_stall_with_nulls(case):
    """Correctness (property 3): whatever the sending pattern, with nulls
    every published app message is eventually delivered."""
    rng = _rng(case)
    n_senders = int(rng.integers(2, 6))
    n_members = n_senders + int(rng.integers(0, 3))
    rounds = int(rng.integers(5, 26))
    sched = rng.integers(0, 3, size=(rounds, n_senders))
    # settle: enough empty rounds for visibility + nulls to drain
    settle = np.zeros((rounds + 2 * n_members + 6, n_senders), np.int64)
    st_final, _ = _run(n_members, n_senders, np.vstack([sched, settle]))
    total = int(st_final.published.sum())
    # every published message (app + null) is delivered at every node
    assert np.all(np.asarray(st_final.delivered_num) == total - 1)
    assert int(st_final.app_sent.sum()) == sched.sum()


@pytest.mark.parametrize("case", range(25))
def test_sweep_quiescence(case):
    """Property 4: once the app stops, nulls stop too."""
    rng = _rng(case)
    n_senders = int(rng.integers(2, 6))
    n_members = n_senders
    rounds = int(rng.integers(3, 16))
    sched = rng.integers(0, 3, size=(rounds, n_senders))
    settle = np.zeros((rounds + 2 * n_members + 6, n_senders), np.int64)
    st1, _ = _run(n_members, n_senders, np.vstack([sched, settle]))
    before = int(st1.nulls_sent.sum())
    st2, _ = _run_cont(st1, np.zeros((10, n_senders), np.int64))
    assert int(st2.nulls_sent.sum()) == before


def _run_cont(state, schedule):
    return sweep.run_rounds(state, jnp.asarray(schedule, jnp.int32))


@pytest.mark.parametrize("case", range(20))
def test_sweep_one_round_skew(case):
    """The proof sketch in Sec 3.3: null-sends keep every sender within one
    round of the most advanced sender (after visibility settles)."""
    rng = _rng(case)
    n_senders = int(rng.integers(2, 6))
    rounds = int(rng.integers(3, 13))
    sched = rng.integers(0, 2, size=(rounds, n_senders))
    settle = np.zeros((rounds + 2 * n_senders + 6, n_senders), np.int64)
    st_final, _ = _run(n_senders, n_senders, np.vstack([sched, settle]))
    pub = np.asarray(st_final.published)
    assert pub.max() - pub.min() <= 1


def test_sweep_stalls_without_nulls():
    """Round-robin delivery stalls behind an inactive sender when nulls are
    disabled — the problem Fig. 2 illustrates."""
    sched = np.zeros((20, 3), np.int64)
    sched[:, 0] = 1
    sched[:, 2] = 1           # sender 1 silent
    st_final, _ = _run(3, 3, sched, null_send=False)
    # nothing past the first round-robin gap can deliver
    assert int(np.asarray(st_final.delivered_num).max()) <= 0
    st_ok, _ = _run(3, 3, np.vstack([sched, np.zeros((14, 3), np.int64)]),
                    null_send=True)
    assert int(np.asarray(st_ok.delivered_num).min()) > 30


@pytest.mark.parametrize("case", range(15))
def test_sweep_window_cap_respected(case):
    rng = _rng(case)
    n_senders = int(rng.integers(2, 5))
    window = int(rng.integers(1, 5))
    rounds = int(rng.integers(3, 21))
    sched = rng.integers(0, 4, size=(rounds, n_senders))
    stt = sweep.SweepState.init(n_senders, n_senders)
    for r in range(rounds):
        stt, _ = sweep.sweep(stt, jnp.asarray(sched[r], jnp.int32),
                             window=window)
        pub = np.asarray(stt.published)
        # a sender never runs more than `window` past what it knows to be
        # delivered everywhere
        deliv = np.asarray(stt.deliv_vis).min(axis=1)[:n_senders]
        per_sender = np.array(
            [sst.sender_counts(d + 1, n_senders)[i]
             for i, d in enumerate(deliv)])
        assert np.all(pub - per_sender <= window)
