"""End-to-end behaviour tests for the whole system: the paper's headline
claim, and the full framework lifecycle (train -> checkpoint -> restore ->
serve) wired through the same public APIs the examples use."""

import tempfile

import jax
import numpy as np

from repro.core import simulator as sim
from repro.models import layers, registry
from repro.models.config import ModelConfig
from repro.models.runtime import Runtime
from repro.optim.adamw import OptConfig
from repro.serve.engine import EngineConfig, Request, ServeEngine
from repro.train import checkpoint
from repro.train.trainer import TrainConfig, Trainer

jax.config.update("jax_platform_name", "cpu")

SYS = ModelConfig(name="sys-test", family="dense", n_layers=2,
                  d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
                  vocab_size=512, head_dim=32, tie_embeddings=True)
registry.register("sys-test", lambda: SYS)


def test_paper_headline_claim():
    """Spindle lifts 16-node 10KB multicast bandwidth by >10x and cuts
    latency by >10x — the abstract's claim, end to end."""
    spin = sim.run(sim.single_subgroup(16, n_messages=500))
    base = sim.run(sim.single_subgroup(
        16, n_messages=150, flags=sim.SpindleFlags.baseline()))
    assert spin.throughput_GBps / base.throughput_GBps > 10
    assert base.mean_latency_us / spin.mean_latency_us > 10
    # and it stays inside physics
    assert spin.throughput_GBps * 15 / 16 <= 12.5


def test_full_lifecycle_train_checkpoint_serve():
    """Train a model, checkpoint it, restore into a fresh process-state,
    serve requests from the restored parameters."""
    with tempfile.TemporaryDirectory() as d:
        tcfg = TrainConfig(steps=30, seq_len=64, global_batch=4,
                           checkpoint_dir=d, checkpoint_every=15,
                           log_every=10, data_patterns=4,
                           opt=OptConfig(peak_lr=3e-3, warmup_steps=5,
                                         decay_steps=30))
        trainer = Trainer("sys-test", SYS, tcfg, Runtime())
        params, opt_state = trainer.run()
        losses = [h["loss"] for h in trainer.history]
        assert losses[-1] < losses[0]

        # restore into a fresh tree (as a new process would)
        fresh_p, fresh_o = trainer.init_state()
        step, restored, extra = checkpoint.restore(
            d, {"params": fresh_p, "opt": fresh_o})
        assert step == 30 and extra["arch"] == "sys-test"

        # serve from the restored parameters
        eng = ServeEngine("sys-test", restored["params"], SYS,
                          EngineConfig(max_batch=2, max_len=48), Runtime())
        rng = np.random.default_rng(0)
        for i in range(3):
            eng.submit(Request(rid=i,
                               prompt=rng.integers(0, 512, 4,
                                                   dtype=np.int32),
                               max_new_tokens=4))
        done = eng.run_until_drained()
        assert len(done) == 3
        assert all(len(r.tokens_out) == 4 for r in done)

        # restored params serve identically to the in-memory ones
        def greedy(p):
            e = ServeEngine("sys-test", p, SYS,
                            EngineConfig(max_batch=2, max_len=48),
                            Runtime())
            e.submit(Request(rid=0, prompt=np.arange(4, dtype=np.int32),
                             max_new_tokens=4))
            return e.run_until_drained()[0].tokens_out

        assert greedy(params) == greedy(restored["params"])


def test_gradsync_modes_agree_numerically():
    """The spindle fused-bucket train step computes the same update as the
    default path on a 1-device mesh (N=1 collectives are identities)."""
    from repro.launch.mesh import make_smoke_mesh
    from repro.optim import adamw
    from repro.train.steps import make_train_step

    arch = registry.get("sys-test")
    params = layers.init_tree(registry.param_specs(SYS), jax.random.key(0))
    opt = adamw.init(params)
    batch = {"tokens": jax.random.randint(jax.random.key(1), (2, 16), 0,
                                          512)}
    mesh = make_smoke_mesh()
    rt_g = Runtime(mesh=mesh, dp_axes=("data",), gradsync="gspmd")
    rt_s = Runtime(mesh=mesh, dp_axes=("data",), gradsync="spindle")
    p1, _, m1 = jax.jit(make_train_step(arch, rt_g))(params, opt, batch)
    p2, _, m2 = jax.jit(make_train_step(arch, rt_s))(params, opt, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=1e-5)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32),
            rtol=5e-3, atol=5e-3)
