"""Chaos plane (DESIGN.md Sec. 7): cascading suspicions during wedge,
membership-service failure semantics, the seeded fault-injection
harness, and the gradient plane's stream-routed cut.

Fast tier: the sampling and folding machinery — FaultSpec determinism
and structural constraints, ``suspect`` distinguishing already-removed
from never-a-member, cascade folding into ONE installed view,
``WedgeAborted``/``TotalFailureError`` error paths,
``sst.cascading_trim`` monotonicity, a small stream soak, and the
gradsync-through-GroupStream vs direct-bucketing equivalence under an
elastic resize.

Soak tier (``-m soak``, the CI ``chaos-soak`` job): full seeded soaks
over the stream, serve, and gradient planes — graph vs pallas reports
bit-identical for every seed.  ``CHAOS_SEEDS`` (comma-separated)
overrides the seed set; CI fans one seed per matrix entry.
"""

import dataclasses
import os

import numpy as np
import pytest

from repro import api
from repro.chaos import (ChaosReport, FaultSpec, chaos_soak,
                         events_by_round)
from repro.core import sst
from repro.core.gradsync import BucketSyncStream
from repro.core.views import (MembershipService, TotalFailureError,
                              WedgeAborted)
from repro.train.elastic import ElasticConfig, ElasticRuntime

fast = pytest.mark.fast
soak = pytest.mark.soak

CHAOS_SEEDS = tuple(int(s) for s in
                    os.environ.get("CHAOS_SEEDS", "11,23,47").split(","))


# ---------------------------------------------------------------------------
# fault sampling
# ---------------------------------------------------------------------------


@fast
def test_faultspec_sampling_is_deterministic_and_respects_floors():
    spec = FaultSpec(rounds=40, suspect_rate=0.3, cascade_prob=0.5,
                     join_rate=0.2, slot_kill_rate=0.3, stall_rate=0.2,
                     max_kills=5)
    kw = dict(killable=range(10, 20), joinable=(30, 31),
              slot_groups=((0, 1, 2), (3, 4)))
    a = spec.sample(np.random.default_rng(7), **kw)
    b = spec.sample(np.random.default_rng(7), **kw)
    assert a == b, "same seed must draw the same schedule"
    assert a != spec.sample(np.random.default_rng(8), **kw)
    kills = [n for ev in a if ev.kind in ("suspect", "slot_kill")
             for w in ([ev.nodes] + list(ev.cascade)) for n in w]
    assert len(kills) == len(set(kills)) <= 5, "max_kills cap violated"
    assert all(n in set(range(10, 20)) for ev in a
               if ev.kind == "suspect"
               for w in ([ev.nodes] + list(ev.cascade)) for n in w)
    # slot kills never drain a replica's last publisher lane
    slot_kills = [ev.nodes[0] for ev in a if ev.kind == "slot_kill"]
    assert len([n for n in slot_kills if n in (0, 1, 2)]) <= 2
    assert len([n for n in slot_kills if n in (3, 4)]) <= 1
    rounds = sorted({ev.round for ev in a})
    assert rounds == sorted(events_by_round(a)) and rounds[-1] < 40
    for ev in a:
        if ev.kind == "stall":
            assert 1 <= ev.length <= 3


# ---------------------------------------------------------------------------
# membership semantics: stale suspicions, cascades, error paths
# ---------------------------------------------------------------------------


@fast
def test_suspect_distinguishes_removed_from_never_member():
    ms = MembershipService([0, 1, 2, 3])
    ms.suspect(0, 3)
    ms.propose_and_install({})
    assert 3 not in ms.view.members
    # already-removed member: a recorded no-op, NOT an error (late
    # detectors double-report after the cut lands)
    before = ms.view.vid
    ms.suspect(1, 3)
    assert ms.view.vid == before and not ms.needs_change()
    assert (1, 3, before) in ms.stale_suspicions
    # never a member of ANY view: a caller bug, loudly
    with pytest.raises(ValueError, match="never a member"):
        ms.suspect(0, 99)


@fast
def test_cascading_suspicions_fold_into_one_view():
    ms = MembershipService([0, 1, 2, 3, 4, 5])

    def _wedge(svc, attempt):
        if attempt == 0:
            svc.suspect(0, 4)        # lands while the wedge is open

    v = ms.propose_and_install({}, during_wedge=None)  # no-op baseline
    vid0 = v.vid
    ms.suspect(0, 5)
    v = ms.propose_and_install({}, during_wedge=_wedge)
    # ONE vid consumed for the whole cascade; both victims gone
    assert v.vid == vid0 + 1
    assert set(v.members) == {0, 1, 2, 3}
    assert ms.wedge_retries == 1


@fast
def test_wedge_cascade_error_paths():
    # unbounded cascade: every re-entered wedge finds a new suspicion
    ms = MembershipService(range(12))
    ms.suspect(0, 11)

    def _endless(svc, attempt):
        svc.suspect(0, 10 - attempt)

    with pytest.raises(WedgeAborted, match="max_wedge_retries"):
        ms.propose_and_install({}, during_wedge=_endless,
                               max_wedge_retries=3)
    # total failure: the cascade consumed every member
    ms2 = MembershipService([0, 1])
    ms2.suspect(0, 0)
    ms2.suspect(0, 1)
    with pytest.raises(TotalFailureError):
        ms2.propose_and_install({})


@fast
def test_cascading_trim_is_monotone_and_rejects_growth():
    col = np.array([7, 4, 9, 2])
    stages = [[True] * 4,                       # trim 2
              [True, True, True, False],        # trim 4
              [False, True, False, False]]      # trim 4
    assert sst.cascading_trim(col, stages) == [2, 4, 4]
    assert sst.cascading_trim(col, [[False] * 4]) == [-1]
    with pytest.raises(ValueError, match="only shrink"):
        sst.cascading_trim(col, [stages[1], stages[0]])
    # seeded property: staged trims never roll back while survivors
    # remain (-1 = a stage with NO survivors, the documented total-
    # failure sentinel the membership service raises on before use)
    rng = np.random.default_rng(13)
    for _ in range(50):
        n = int(rng.integers(2, 7))
        c = rng.integers(-1, 40, n)
        alive = rng.random(n) < 0.8
        st = [alive.copy()]
        for _ in range(int(rng.integers(1, 4))):
            alive = alive & (rng.random(n) < 0.7)
            st.append(alive.copy())
        trims = sst.cascading_trim(c, st)
        assert all(b >= a or b == -1
                   for a, b in zip(trims, trims[1:]))


# ---------------------------------------------------------------------------
# the harness, small (fast tier)
# ---------------------------------------------------------------------------


def _chaos_group():
    spec_a = api.SubgroupSpec(members=(0, 1, 2, 3), senders=(0, 1, 2),
                              msg_size=512, window=4, n_messages=0)
    spec_b = api.SubgroupSpec(members=(1, 2, 3), senders=(1, 2),
                              msg_size=256, window=4, n_messages=0)
    return api.Group(api.GroupConfig(members=(0, 1, 2, 3, 4),
                                     subgroups=(spec_a, spec_b)))


@fast
def test_chaos_soak_stream_smoke():
    spec = FaultSpec(rounds=16, suspect_rate=0.25, cascade_prob=0.5,
                     join_rate=0.15, stall_rate=0.1)
    rep = chaos_soak(_chaos_group(), spec, seed=11, backend="graph")
    assert isinstance(rep, ChaosReport) and rep.target == "stream"
    assert rep.views_installed >= 1 and rep.checks > 20
    assert rep.extras["fault_events"] >= 1
    # deterministic: the same seed replays to the same report
    rep2 = chaos_soak(_chaos_group(), spec, seed=11, backend="graph")
    assert rep.extras == rep2.extras and rep.killed == rep2.killed


@fast
def test_chaos_soak_rejects_unknown_targets():
    with pytest.raises(TypeError, match="does not know"):
        chaos_soak(object(), FaultSpec())


# ---------------------------------------------------------------------------
# gradsync through the stream: the elastic resize as a real cut
# ---------------------------------------------------------------------------


def _upd(node, rnd):
    return {"w": float((node + 1) * rnd) * 0.01}


@fast
def test_gradsync_stream_matches_direct_bucketing_under_join_resize():
    """With no failures, routing the reduction through a GroupStream
    changes WHEN updates apply (the delivery watermark) but not WHAT
    applies: the applied-round means equal the direct per-round means
    of the same schedule, through an elastic JOIN resize."""
    members = [0, 1, 2]
    rt = ElasticRuntime(list(members), ElasticConfig())
    gs = BucketSyncStream(members, n_buckets=2, window=6,
                          backend="graph")
    rt.attach_gradient_stream(gs, _upd)
    contributed_by_round = {}
    for _ in range(4):
        res = rt.step()
        contributed_by_round[res["round"]] = list(res["contributed"])
    rt.join(3)
    for _ in range(5):
        res = rt.step()
        contributed_by_round[res["round"]] = list(res["contributed"])
    assert any(len(c) == 4 for c in contributed_by_round.values()), \
        "the joiner never became a contributor"
    rep = rt.gradsync.finish()
    assert not rep.stalled
    applied = rt.gradsync.applied
    assert len(applied) == len(contributed_by_round)
    rounds = sorted(contributed_by_round)
    direct_w = 0.0
    for a, rnd in zip(applied, rounds):
        assert not a.voided
        assert sorted(a.contributors) == \
            sorted(contributed_by_round[rnd])
        direct = float(np.mean([_upd(m, rnd)["w"]
                                for m in sorted(a.contributors)]))
        assert a.update["w"] == pytest.approx(direct, abs=1e-12)
        direct_w += direct
    stream_w = sum(a.update["w"] for a in applied)
    assert stream_w == pytest.approx(direct_w, abs=1e-12)
    # the resize consumed a view and nobody's watermark rolled back:
    # every live worker tracks the same stream watermark (finish()'s
    # drain applies the in-flight tail after the last runtime step)
    assert len(rt.view_changes) == 1
    marks = {w.delivered_step for w in rt.workers.values() if w.alive}
    assert len(marks) == 1 and marks.pop() <= len(applied)


@fast
def test_gradsync_stream_failure_voids_only_dead_no_rollback():
    members = [0, 1, 2, 3]
    rt = ElasticRuntime(list(members), ElasticConfig(heartbeat_timeout=2))
    gs = BucketSyncStream(members, n_buckets=2, window=4,
                          backend="graph")
    rt.attach_gradient_stream(gs, _upd)
    contributed_by_round = {}
    watermarks = {m: [] for m in members}
    for rnd in range(10):
        if rnd == 3:
            rt.fail(3)
        res = rt.step()
        contributed_by_round[res["round"]] = list(res["contributed"])
        for m, w in rt.workers.items():
            watermarks[m].append(w.delivered_step)
    rep = rt.gradsync.finish()
    assert not rep.stalled
    assert len(rt.view_changes) == 1
    assert 3 not in rt.view_changes[0].members
    # delivered_step is monotone for EVERY worker — the stream cut
    # replaces the rollback-to-watermark restart
    for m, seq in watermarks.items():
        assert all(b >= a for a, b in zip(seq, seq[1:])), (m, seq)
    applied = rt.gradsync.applied
    assert len(applied) == len(contributed_by_round)
    rounds = sorted(contributed_by_round)
    for a, rnd in zip(applied, rounds):
        assert set(a.voided) <= {3}
        assert sorted(set(a.contributors) | set(a.voided)) == \
            sorted(contributed_by_round[rnd])
        if a.contributors:
            direct = float(np.mean([_upd(m, rnd)["w"]
                                    for m in sorted(a.contributors)]))
            assert a.update["w"] == pytest.approx(direct, abs=1e-12)


# ---------------------------------------------------------------------------
# seeded soaks over every plane (-m soak; the CI chaos-soak job)
# ---------------------------------------------------------------------------


@soak
@pytest.mark.parametrize("seed", CHAOS_SEEDS)
def test_chaos_stream_soak_graph_pallas_identical(seed):
    spec = FaultSpec(rounds=24, suspect_rate=0.25, cascade_prob=0.5,
                     join_rate=0.15, stall_rate=0.15)
    reps = {be: chaos_soak(_chaos_group(), spec, seed=seed, backend=be)
            for be in ("graph", "pallas")}
    g, p = reps["graph"], reps["pallas"]
    assert g.views_installed == p.views_installed >= 1
    assert g.killed == p.killed and g.joined == p.joined
    assert g.extras == p.extras
    assert g.checks == p.checks > 30


@soak
@pytest.mark.parametrize("seed", CHAOS_SEEDS)
def test_chaos_des_stream_soak_bit_identical_to_graph(seed):
    """The two-phase des stream (DESIGN.md Sec. 12) survives the same
    chaos schedule as graph and produces a bit-identical report — every
    field except the backend tag, including the delivery-sequence
    digests in ``extras``."""
    spec = FaultSpec(rounds=24, suspect_rate=0.25, cascade_prob=0.5,
                     join_rate=0.15, stall_rate=0.15)
    reps = {be: chaos_soak(_chaos_group(), spec, seed=seed, backend=be)
            for be in ("graph", "des")}
    g = dataclasses.asdict(reps["graph"])
    d = dataclasses.asdict(reps["des"])
    assert g.pop("backend") == "graph" and d.pop("backend") == "des"
    assert g == d
    assert reps["des"].views_installed >= 1
    assert reps["des"].checks > 30


@soak
@pytest.mark.parametrize("seed", CHAOS_SEEDS)
def test_chaos_gradsync_soak_graph_pallas_identical(seed):
    spec = FaultSpec(rounds=20, suspect_rate=0.2, cascade_prob=0.5,
                     join_rate=0.2, stall_rate=0.1)
    reps = {}
    for be in ("graph", "pallas"):
        gs = BucketSyncStream([0, 1, 2, 3], n_buckets=2, window=6,
                              backend=be)
        reps[be] = chaos_soak(gs, spec, seed=seed)
    g, p = reps["graph"], reps["pallas"]
    assert g.extras == p.extras
    assert g.killed == p.killed and g.views_installed == p.views_installed
    assert g.extras["applied"], "the soak applied no optimizer rounds"


@soak
@pytest.mark.parametrize("seed", CHAOS_SEEDS)
def test_chaos_serve_soak_graph_pallas_identical(seed):
    from test_viewchange import _fan_engines
    from repro.serve.engine import Request
    from repro.serve.fanout import ReplicatedEngine

    engines, mcfg = _fan_engines()
    spec = FaultSpec(rounds=14, suspect_rate=0.2, cascade_prob=0.5,
                     slot_kill_rate=0.2, stall_rate=0.1)
    reps = {}
    for be in ("graph", "pallas"):
        rep_eng = ReplicatedEngine(engines, subscribers_per_replica=2,
                                   window=4, backend=be)
        rep_eng.reset()
        rng = np.random.default_rng(3)
        for g in range(2):
            for i in range(3):
                rep_eng.submit(g, Request(
                    rid=g * 10 + i,
                    prompt=rng.integers(0, mcfg.vocab_size, 3,
                                        dtype=np.int32),
                    max_new_tokens=4))
        reps[be] = chaos_soak(rep_eng, spec, seed=seed)
    g, p = reps["graph"], reps["pallas"]
    assert g.extras == p.extras
    assert g.killed == p.killed
    assert g.views_installed == p.views_installed
    assert g.rounds == p.rounds


@soak
@pytest.mark.parametrize("seed", CHAOS_SEEDS)
def test_chaos_fused_serve_real_wedge(seed):
    """The fused serve path under chaos: a HOMOGENEOUS mid-run cut (one
    slot node per replica) WEDGES the fused program, performs the cut
    on host, and re-enters a second fused program — two device
    programs, no fallback, zero host hops between cuts — bit-identical
    to the per-round loop.  A heterogeneous cut (a single replica's
    subscriber) still falls back, explicitly."""
    from test_viewchange import _fan_engines
    from repro.serve.engine import Request
    from repro.serve.fanout import ReplicatedEngine

    engines, mcfg = _fan_engines()
    fail_round = 1 + seed % 3

    def drive(fused, fail_nodes):
        rep_eng = ReplicatedEngine(engines, subscribers_per_replica=2,
                                   window=4, backend="graph")
        rep_eng.reset()
        rng = np.random.default_rng(seed)
        for g in range(2):
            for i in range(3):
                rep_eng.submit(g, Request(
                    rid=g * 10 + i,
                    prompt=rng.integers(0, mcfg.vocab_size, 3,
                                        dtype=np.int32),
                    max_new_tokens=4))
        fail_at = {fail_round: fail_nodes(rep_eng)}
        report = rep_eng.run(fail_at=fail_at, fused=fused)
        return rep_eng.completed(), report

    def homogeneous(rep_eng):
        # slot 1's node of EVERY replica: replicas stay equal-shaped
        return [rep_eng._slot_nodes[0][1], rep_eng._slot_nodes[1][1]]

    done_u, rep_u = drive(False, homogeneous)
    done_f, rep_f = drive(True, homogeneous)
    serve = rep_f.extras["serve"]
    assert serve["fused"] is True, serve.get("fused_fallback")
    assert serve["fused_epochs"] == 2
    assert serve["host_hops"] == 0
    assert serve["view_changes"] == 1
    assert serve["drained"]
    assert done_f == done_u
    su = rep_u.extras["serve"]
    for k in ("engine_rounds", "view_changes", "voided_requests",
              "requeued_requests", "slot_failures",
              "fail_at_unreached"):
        assert su[k] == serve[k], (k, su[k], serve[k])

    # replica 0's nodes: slots 0-1, subscribers 2-3; killing ONE
    # replica's subscriber leaves heterogeneous replicas -> explicit
    # per-round fallback with identical results
    done_hu, rep_hu = drive(False, lambda r: [2])
    done_hf, rep_hf = drive(True, lambda r: [2])
    s_het = rep_hf.extras["serve"]
    assert s_het["fused"] is False
    assert "fail_at" in s_het["fused_fallback"]
    assert s_het["view_changes"] == 1
    assert done_hf == done_hu


@soak
@pytest.mark.parametrize("seed", CHAOS_SEEDS)
def test_chaos_soak_fused_leg_bit_identical(seed):
    """chaos_soak(fused=True) drives the real fused wedge when the
    drawn schedule is expressible and falls back otherwise — either
    way the ChaosReport matches the unfused soak except for the
    path-marker keys."""
    from test_viewchange import _fan_engines
    from repro.serve.engine import Request
    from repro.serve.fanout import ReplicatedEngine

    engines, mcfg = _fan_engines()
    spec = FaultSpec(rounds=14, suspect_rate=0.2, cascade_prob=0.5,
                     slot_kill_rate=0.2, stall_rate=0.1)
    reps = {}
    for fused in (False, True):
        rep_eng = ReplicatedEngine(engines, subscribers_per_replica=2,
                                   window=4, backend="graph")
        rep_eng.reset()
        rng = np.random.default_rng(3)
        for g in range(2):
            for i in range(3):
                rep_eng.submit(g, Request(
                    rid=g * 10 + i,
                    prompt=rng.integers(0, mcfg.vocab_size, 3,
                                        dtype=np.int32),
                    max_new_tokens=4))
        reps[fused] = chaos_soak(rep_eng, spec, seed=seed, fused=fused)
    u, f = reps[False], reps[True]
    strip = ("fused", "fused_fallback")
    assert {k: v for k, v in u.extras.items() if k not in strip} == \
        {k: v for k, v in f.extras.items() if k not in strip}
    assert u.killed == f.killed
    assert u.views_installed == f.views_installed
    assert u.rounds == f.rounds
    assert u.stall_rounds == f.stall_rounds
    if f.extras["fused_fallback"] is not None:
        # a fallback must name an inexpressible schedule, not a retired
        # reason (arrivals/stalls/admission/homogeneous cuts all fuse)
        assert "heterogeneous" in f.extras["fused_fallback"] \
            or "overflow" in f.extras["fused_fallback"]
