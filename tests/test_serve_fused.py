"""Fused device-resident serve plane (repro.serve.fused).

Seeded conformance: a fused run — the whole serve session as ONE
compiled program — must be bit-identical to the per-round dispatch loop
on both stacked backends: same tokens, same per-topic delivery logs,
same hold/free traces, same report.  Plus the recurrent-family unlock
(masked decode lets ssm/hybrid ride the slot ring) and the explicit
fallback contract for workloads the fused program cannot express.
"""

import os

import jax
import numpy as np
import pytest

from repro.core import group as group_mod
from repro.models import layers, registry
from repro.models.config import ModelConfig
from repro.serve.engine import EngineConfig, Request, ServeEngine
from repro.serve.fanout import ReplicatedEngine

jax.config.update("jax_platform_name", "cpu")

fast = pytest.mark.fast

SEED = int(os.environ.get("SERVE_FUSED_SEED", "7"))

_DENSE = ModelConfig(name="serve-fused-test", family="dense", n_layers=2,
                     d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
                     vocab_size=512, head_dim=32, tie_embeddings=True)
registry.register("serve-fused-test", lambda: _DENSE)


def _register_reduced(preset: str, name: str) -> ModelConfig:
    cfg = registry.get(preset).cfg.reduced()
    import dataclasses
    cfg = dataclasses.replace(cfg, name=name)
    registry.register(name, lambda: cfg)
    return cfg


def _rep(arch: str, cfg: ModelConfig, *, backend="graph", slots=2,
         replicas=2, reqs=3, prompt=3, new_tokens=4, seed=SEED):
    params = layers.init_tree(registry.param_specs(cfg),
                              jax.random.key(0))
    engines = [ServeEngine(arch, params, cfg,
                           EngineConfig(max_batch=slots, max_len=48))
               for _ in range(replicas)]
    rep = ReplicatedEngine(engines, subscribers_per_replica=1, window=4,
                           backend=backend)
    rng = np.random.default_rng(seed)
    for g in range(replicas):
        for i in range(reqs):
            rep.submit(g, Request(
                rid=g * 10 + i,
                prompt=rng.integers(1, cfg.vocab_size,
                                    size=prompt).astype(np.int32),
                max_new_tokens=new_tokens))
    return rep


def _logs_equal(a, b) -> bool:
    if sorted(a) != sorted(b):
        return False
    for k in a:
        la, lb = a[k], b[k]
        if la.n_senders != lb.n_senders \
                or la.delivered_seq != lb.delivered_seq:
            return False
        if len(la.is_app) != len(lb.is_app) or any(
                not np.array_equal(x, y)
                for x, y in zip(la.is_app, lb.is_app)):
            return False
    return True


def _assert_conformant(rep_u, r_u, rep_f, r_f):
    sf = r_f.extras["serve"]
    assert sf["fused"] is True, sf.get("fused_fallback")
    assert sf["host_hops"] == 0
    assert r_u.extras["serve"]["host_hops"] > 0
    # tokens: every request's full stream, bit-for-bit
    assert rep_u.completed() == rep_f.completed()
    # the multicast side: identical total-order delivery logs
    assert _logs_equal(r_u.extras["delivery_logs"],
                       r_f.extras["delivery_logs"])
    # serve traces: admissions, finishes, watermark-gated frees,
    # queue-depth and backlog evolution — round-for-round
    assert rep_u.admit_rounds == rep_f.admit_rounds
    assert rep_u.admit_slots == rep_f.admit_slots
    assert rep_u.finish_rounds == rep_f.finish_rounds
    assert rep_u.free_rounds == rep_f.free_rounds
    assert rep_u.finish_round_by_rid == rep_f.finish_round_by_rid
    assert rep_u.queue_depth_log == rep_f.queue_depth_log
    assert rep_u.backlog_log == rep_f.backlog_log
    # the merged report (timing fields aside)
    assert r_u.delivered_app_msgs == r_f.delivered_app_msgs
    assert r_u.nulls_sent == r_f.nulls_sent
    assert r_u.extras["streamed_rounds"] == r_f.extras["streamed_rounds"]
    su = r_u.extras["serve"]
    for k in ("engine_rounds", "decode_steps", "requests", "tokens",
              "drained", "held_slots", "replicas"):
        assert su[k] == sf[k], (k, su[k], sf[k])
    assert sf["drained"]


@fast
@pytest.mark.parametrize("backend", ["graph", "pallas"])
def test_fused_bit_identical_to_round_loop(backend):
    rep_u = _rep("serve-fused-test", _DENSE, backend=backend)
    r_u = rep_u.run()
    rep_f = _rep("serve-fused-test", _DENSE, backend=backend)
    r_f = rep_f.run(fused=True)
    _assert_conformant(rep_u, r_u, rep_f, r_f)


@fast
def test_fused_warm_run_is_one_program_zero_hops():
    """A warm fused run appends at most one TRACE_EVENTS entry (the
    fused program itself; zero once cached) and takes zero device->host
    hops between rounds."""
    rep = _rep("serve-fused-test", _DENSE)
    rep.run(fused=True)                       # cold: traces the program
    rep2 = _rep("serve-fused-test", _DENSE)   # same workload shape
    n0 = len(group_mod.TRACE_EVENTS)
    r = rep2.run(fused=True)
    assert len(group_mod.TRACE_EVENTS) - n0 == 0, \
        "warm fused run re-traced"
    assert r.extras["serve"]["fused"] is True
    assert r.extras["serve"]["host_hops"] == 0


@fast
def test_fused_fallback_is_explicit():
    """A workload the fused program cannot express (client stalls) runs
    the per-round loop and SAYS so — extras carry fused=False plus the
    reason — with results identical to asking for the loop directly."""
    def stall(g, rnd):
        return (0,) if rnd in (2, 3) else ()

    rep_u = _rep("serve-fused-test", _DENSE)
    rep_u.stall_fn = stall
    r_u = rep_u.run()
    rep_f = _rep("serve-fused-test", _DENSE)
    rep_f.stall_fn = stall
    r_f = rep_f.run(fused=True)
    sf = r_f.extras["serve"]
    assert sf["fused"] is False
    assert "stall" in sf["fused_fallback"]
    assert rep_u.completed() == rep_f.completed()
    assert r_u.extras["serve"]["engine_rounds"] == sf["engine_rounds"]
    # engine state untouched by the aborted fused attempt: queues were
    # read, not popped, so the fallback served every request
    assert sf["drained"] and sf["requests"] == 6


# ---------------------------------------------------------------------------
# recurrent families: masked decode lets ssm/hybrid ride the slot ring
# ---------------------------------------------------------------------------


@fast
@pytest.mark.parametrize("preset", ["mamba2-2.7b", "zamba2-2.7b"])
def test_recurrent_family_serves(preset):
    """ssm/hybrid decode state mutates cumulatively every step; the
    validity-masked decode body (repro.models.masking) carries invalid
    slots through bit-unchanged, so continuous batching with idle slots
    and mid-ring admissions yields the same tokens as serving each
    request alone."""
    name = f"serve-fused-{preset.split('-')[0]}"
    cfg = _register_reduced(preset, name)
    rep = _rep(name, cfg, replicas=1, slots=2, reqs=3, prompt=3,
               new_tokens=4)
    solo_tokens = {}
    for req in list(rep.engines[0].queue):
        solo = _rep(name, cfg, replicas=1, slots=2, reqs=0)
        solo.submit(0, Request(rid=req.rid,
                               prompt=np.array(req.prompt, np.int32),
                               max_new_tokens=req.max_new_tokens))
        solo.run()
        solo_tokens[req.rid] = solo.engines[0].completed[0].tokens_out
    report = rep.run()
    assert report.extras["serve"]["drained"]
    assert report.extras["serve"]["requests"] == 3
    for req in rep.engines[0].completed:
        assert req.tokens_out == solo_tokens[req.rid], \
            f"{preset} rid={req.rid}: batched != solo decode"


@fast
def test_fused_serves_recurrent_family():
    """The fused program scans the same masked decode body, so the
    recurrent unlock carries over: ssm fused == ssm unfused."""
    name = "serve-fused-mamba2"
    try:
        cfg = registry.get(name).cfg
    except KeyError:
        cfg = _register_reduced("mamba2-2.7b", name)
    rep_u = _rep(name, cfg, replicas=1, slots=2, reqs=3, prompt=3,
                 new_tokens=4)
    r_u = rep_u.run()
    rep_f = _rep(name, cfg, replicas=1, slots=2, reqs=3, prompt=3,
                 new_tokens=4)
    r_f = rep_f.run(fused=True)
    _assert_conformant(rep_u, r_u, rep_f, r_f)


# ---------------------------------------------------------------------------
# dynamic workloads ride the fused program: arrivals + admission + stalls
# ---------------------------------------------------------------------------

from repro.load.admission import ServeAdmission  # noqa: E402
from repro.serve import fused as fused_mod  # noqa: E402


def _schedule(replicas=2, rounds=8, seed=3, kind="poisson", rate=0.8):
    """Seeded open-loop arrival schedule: per-round per-replica request
    cells, the precomputed form the fused program scans in-graph."""
    rng = np.random.default_rng(seed)
    sched, rid = [], 100
    for _t in range(rounds):
        row = []
        for _g in range(replicas):
            if kind == "poisson":
                k = int(rng.poisson(rate))
            else:                       # bursty: idle or a 3-burst
                k = 3 * int(rng.random() < 0.3)
            cell = []
            for _ in range(k):
                cell.append(Request(
                    rid=rid,
                    prompt=rng.integers(1, _DENSE.vocab_size,
                                        size=3).astype(np.int32),
                    max_new_tokens=4))
                rid += 1
            row.append(cell)
        sched.append(row)
    return sched


@fast
@pytest.mark.parametrize("backend,kind", [
    ("graph", "poisson"), ("pallas", "poisson"), ("graph", "bursty")])
def test_fused_dynamic_workload_bit_identical(backend, kind):
    """Open-loop arrivals + ServeAdmission (queue-cap sheds, watermark
    stalls) + a scheduled stall mask all run IN-GRAPH and reproduce the
    per-round loop bit-for-bit — the retired fallback reasons of
    ISSUE 10."""
    stall = np.zeros((8, 2, 2), bool)
    stall[2, 0, 1] = True
    stall[3, 1, 0] = True
    adm = ServeAdmission(queue_cap=2, stall_backlog=6)

    def mk():
        r = _rep("serve-fused-test", _DENSE, reqs=2, backend=backend)
        r.stall_fn = stall
        return r

    rep_u = mk()
    r_u = rep_u.run(arrive_schedule=_schedule(kind=kind), admission=adm)
    rep_f = mk()
    r_f = rep_f.run(arrive_schedule=_schedule(kind=kind), admission=adm,
                    fused=True)
    assert rep_u.shed_log == rep_f.shed_log
    assert rep_u.submit_rounds == rep_f.submit_rounds
    su, sf = r_u.extras["serve"], r_f.extras["serve"]
    for k in ("stall_rounds", "shed_requests", "max_queue_depth",
              "max_backlog"):
        assert su[k] == sf[k], (k, su[k], sf[k])
    _assert_conformant(rep_u, r_u, rep_f, r_f)


@fast
def test_fused_dynamics_do_not_fall_back():
    """The retired reasons return None from fused_fallback_reason."""
    rep = _rep("serve-fused-test", _DENSE, slots=3, reqs=4)
    rep.stall_fn = np.zeros((4, 2, 3), bool)
    cut = {3: [[rep._slot_nodes[0][1], rep._slot_nodes[1][1]]]}
    assert fused_mod.fused_fallback_reason(
        rep, fail_at=cut, arrive_schedule=_schedule(rounds=2),
        admission=ServeAdmission(queue_cap=2, stall_backlog=4)) is None
    # arbitrary host callbacks still fall back, explicitly
    assert "arrive_fn" in fused_mod.fused_fallback_reason(
        rep, arrive_fn=lambda g, rnd: ())


# ---------------------------------------------------------------------------
# wedge-capable fused loop: one cut = two device programs
# ---------------------------------------------------------------------------


def _cut_rep():
    return _rep("serve-fused-test", _DENSE, slots=3, reqs=4)


def _homogeneous_cut(rep):
    # one slot node per replica at round 3: both replicas stay 2-slot
    return {3: [rep._slot_nodes[0][1], rep._slot_nodes[1][1]]}


@fast
def test_fused_mid_run_cut_matches_unfused_fail_at():
    rep_u = _cut_rep()
    r_u = rep_u.run(fail_at=_homogeneous_cut(rep_u))
    rep_f = _cut_rep()
    r_f = rep_f.run(fail_at=_homogeneous_cut(rep_f), fused=True)
    sf = r_f.extras["serve"]
    assert sf["fused_epochs"] == 2
    su = r_u.extras["serve"]
    for k in ("view_changes", "slot_failures", "voided_requests",
              "requeued_requests", "fail_at_unreached"):
        assert su[k] == sf[k], (k, su[k], sf[k])
    # per-epoch closing logs match the unfused view_log entry-for-entry
    assert len(rep_u.view_log) == len(rep_f.view_log)
    for (ru_rnd, _vu, ru_rep, ru_logs), (rf_rnd, _vf, rf_rep, rf_logs) \
            in zip(rep_u.view_log, rep_f.view_log):
        assert ru_rnd == rf_rnd
        assert ru_rep.delivered_app_msgs == rf_rep.delivered_app_msgs
        assert _logs_equal(ru_logs, rf_logs)
    assert ([r["voided_rid"] for r in rep_u.slot_failures]
            == [r["voided_rid"] for r in rep_f.slot_failures])
    _assert_conformant(rep_u, r_u, rep_f, r_f)


@fast
def test_fused_cut_reuses_programs_when_shapes_repeat():
    rep = _cut_rep()
    rep.run(fail_at=_homogeneous_cut(rep), fused=True)  # cold: traces
    rep2 = _cut_rep()
    n0 = len(group_mod.TRACE_EVENTS)
    r = rep2.run(fail_at=_homogeneous_cut(rep2), fused=True)
    assert r.extras["serve"]["fused_epochs"] == 2
    assert len(group_mod.TRACE_EVENTS) - n0 == 0, \
        "shape-preserving cut re-traced a fused epoch program"


# ---------------------------------------------------------------------------
# per-run extras deltas (the stale-maxima regression of ISSUE 10)
# ---------------------------------------------------------------------------


@fast
def test_fused_second_run_reports_per_run_maxima():
    """extras['serve'] maxima must cover THIS run only: a light run
    after a heavy one on the same engines must not inherit the heavy
    run's queue-depth/backlog peaks."""
    def drive(fused):
        rep = _rep("serve-fused-test", _DENSE, reqs=4)
        s1 = rep.run(fused=fused).extras["serve"]
        for g in range(2):
            rep.submit(g, Request(
                rid=900 + g, prompt=np.arange(1, 4, dtype=np.int32),
                max_new_tokens=2))
        s2 = rep.run(fused=fused).extras["serve"]
        return s1, s2

    s1f, s2f = drive(True)
    assert s1f["fused"] is True and s2f["fused"] is True
    _s1u, s2u = drive(False)
    for k in ("max_queue_depth", "max_backlog"):
        assert s2f[k] == s2u[k], (k, s2f[k], s2u[k])
    # 8 queued requests in run 1 vs 2 in run 2: stale history would
    # report run 1's peak again
    assert s2f["max_queue_depth"] < s1f["max_queue_depth"]


# ---------------------------------------------------------------------------
# vectorized ownership forward-fill (replaces the O(T) column scans)
# ---------------------------------------------------------------------------


@fast
def test_owner_fill_matches_reference_column_scan():
    rng = np.random.default_rng(0)
    for _ in range(6):
        t_n = int(rng.integers(1, 12))
        g_n = int(rng.integers(1, 3))
        b = int(rng.integers(1, 4))
        adm = np.where(rng.random((t_n, g_n, b)) < 0.3,
                       rng.integers(0, 9, (t_n, g_n, b)),
                       -1).astype(np.int32)
        init = rng.integers(-1, 5, (g_n, b)).astype(np.int32)
        got = fused_mod._owner_fill(adm, init)
        want = np.empty_like(got)
        for t in range(t_n):
            for g in range(g_n):
                for s in range(b):
                    own = init[g, s]
                    for u in range(t + 1):
                        if adm[u, g, s] >= 0:
                            own = adm[u, g, s]
                    want[t, g, s] = own
        np.testing.assert_array_equal(got, want)
    z = fused_mod._owner_fill(np.zeros((0, 2, 2), np.int32),
                              np.zeros((2, 2), np.int32))
    assert z.shape == (0, 2, 2)
