"""Workload-plane tests (DESIGN.md Sec. 10): seeded determinism,
graph/pallas conformance, des conformance of the released traffic,
honest saturation (shed > 0, bounded p99/queue under overload), the
bounded compile-trace history, and the serve-plane lowering."""

import json

import jax
import numpy as np
import pytest

from repro import api
from repro.core import group as group_mod
from repro.load import (AdmitAll, Diurnal, OnOff, Poisson, Profile,
                        ServeAdmission, Stage, TokenBucket, Trace,
                        WindowSlack, run_profile, staged_ramp)

jax.config.update("jax_platform_name", "cpu")

fast = pytest.mark.fast


def _profile(seed=0, overload=5.0, rate=0.5, rounds=20):
    return staged_ramp(Poisson(rate=rate), warmup=10, steps=(1.0,),
                       rounds_per_stage=rounds, overload=overload,
                       overload_rounds=rounds, seed=seed)


def _group(n=4, senders=2, window=4):
    return api.Group(api.single_group(
        n, n_senders=senders, msg_size=4096, window=window,
        n_messages=0))


# ---------------------------------------------------------------------------
# arrivals + profiles: seeded determinism
# ---------------------------------------------------------------------------

@fast
@pytest.mark.parametrize("spec", [
    Poisson(rate=0.7), OnOff(rate_on=2.0, p_on_off=0.2, p_off_on=0.3),
    Diurnal(rate=1.0, period=30), Trace(counts=[0, 2, 1, 3]),
], ids=["poisson", "onoff", "diurnal", "trace"])
def test_same_seed_bit_identical_arrivals(spec):
    p = Profile(arrivals=spec, seed=7, stages=(
        Stage("a", 12, 0.5), Stage("b", 9, 2.0)))
    m1 = p.matrices((2, 3))
    m2 = p.matrices((2, 3))
    assert len(m1) == len(m2) == 2
    for a, b in zip(m1, m2):
        np.testing.assert_array_equal(a, b)
    # a different seed moves the draw (overwhelmingly likely for these
    # shapes; fixed seeds make it deterministic either way)
    m3 = Profile(arrivals=spec, seed=8, stages=p.stages).matrices((2, 3))
    assert any(not np.array_equal(a, b) for a, b in zip(m1, m3))


@fast
def test_sender_mask_zeroes_padded_lanes_only():
    p = Profile(arrivals=Poisson(rate=5.0), seed=1,
                stages=(Stage("s", 10, 1.0),))
    mask = np.array([[True, True, False], [True, False, False]])
    m = p.matrices((2, 3), mask)[0]
    assert (m[:, ~mask] == 0).all()
    assert m[:, mask].sum() > 0
    # masking happens AFTER sampling: real lanes are unchanged
    unmasked = p.matrices((2, 3))[0]
    np.testing.assert_array_equal(m[:, mask], unmasked[:, mask])


@fast
def test_diurnal_phase_continues_across_stages():
    spec = Diurnal(rate=3.0, period=16, amplitude=1.0)
    split = Profile(arrivals=spec, seed=5, stages=(
        Stage("a", 8, 1.0), Stage("b", 8, 1.0)))
    whole = Profile(arrivals=spec, seed=5, stages=(Stage("w", 16, 1.0),))
    np.testing.assert_array_equal(
        np.concatenate(split.matrices((1, 2)), axis=0),
        whole.matrices((1, 2))[0])


@fast
def test_staged_ramp_shape():
    p = staged_ramp(Poisson(rate=1.0), warmup=5, steps=(0.5, 1.0),
                    rounds_per_stage=7, overload=4.0, seed=0)
    assert [s.name for s in p.stages] == \
        ["warmup", "step-0.5", "step-1", "overload"]
    assert p.total_rounds == 5 + 7 + 7 + 7
    assert p.stage_bounds()[-1] == (19, 26)


# ---------------------------------------------------------------------------
# the harness: determinism + backend conformance
# ---------------------------------------------------------------------------

@fast
def test_load_report_graph_vs_pallas_identical():
    prof = _profile(seed=0)
    adm = lambda: WindowSlack(inflight_limit=8, queue_cap=16)  # noqa: E731
    reports = {be: run_profile(_group(), prof, adm(), backend=be)
               for be in ("graph", "pallas")}
    a = json.dumps(reports["graph"].to_json(), sort_keys=True)
    b = json.dumps(reports["pallas"].to_json(), sort_keys=True)
    assert a == b
    # and the run is internally deterministic: same seed, same report
    again = run_profile(_group(), prof, adm(), backend="graph")
    assert json.dumps(again.to_json(), sort_keys=True) == a


@fast
def test_des_conformance_small_fleet():
    """The stream's released traffic, replayed as a des scenario, is
    order-invariant conformant: identical per-sender app counts at every
    member, each delivered in FIFO (gapless prefix) order."""
    g = _group(n=4, senders=2, window=4)
    stream = g.stream(backend="graph")
    run_profile(stream, _profile(seed=3, overload=3.0, rounds=12),
                WindowSlack(inflight_limit=8, queue_cap=8))
    _, app_pub, _ = stream.traces()
    sent = app_pub[0].sum(axis=0)          # per-sender released apps
    graph_log = g.delivery_logs[0]

    g2 = _group(n=4, senders=2, window=4)
    h = g2.subgroup(0)
    for rank, count in enumerate(sent):
        if count:
            h.send(sender=h.spec.senders[rank], n=int(count))
    g2.run(backend="des")
    des_log = g2.delivery_logs[0]

    assert sent.sum() > 0
    for node in h.spec.members:
        for log in (graph_log, des_log):
            by_rank = {}
            for rank, idx, _app in log.sequence(node):
                by_rank.setdefault(rank, []).append(idx)
            for rank, idxs in by_rank.items():
                # FIFO: app slots delivered in publish order (idx gaps are
                # null slots the open-loop stream published on idle lanes)
                assert idxs == sorted(idxs) and len(set(idxs)) == len(idxs)
        counts_g = dict(zip(*np.unique(
            [r for r, _, _ in graph_log.sequence(node)],
            return_counts=True)))
        counts_d = dict(zip(*np.unique(
            [r for r, _, _ in des_log.sequence(node)],
            return_counts=True)))
        assert counts_g == counts_d        # order-invariant counts


@fast
def test_overload_sheds_and_bounds_tail():
    """The honesty constraint: past saturation the bounding policy sheds
    (goodput < offered) while p99 and queue depth stay bounded."""
    cap, senders = 16, 2
    rep = run_profile(_group(senders=senders), _profile(overload=6.0),
                      WindowSlack(inflight_limit=8, queue_cap=cap))
    over = rep.stage("overload")
    assert over.shed > 0
    assert over.goodput_per_round < over.offered_per_round
    assert over.max_queue_depth <= cap * senders
    # released messages wait at most cap in queue + inflight_limit in
    # stream, each draining >= ~window/3 per sender round: a loose but
    # honest bound far below the unthrottled backlog's reach
    assert over.p99_rounds <= 3 * (cap + 8) + 10
    assert over.undelivered == 0           # drain completed


@fast
def test_admit_all_is_unbounded_baseline():
    """AdmitAll never sheds: under the same overload the stream backlog
    blows past the window and latency dwarfs the controlled run."""
    prof = _profile(overload=6.0)
    free = run_profile(_group(), prof, AdmitAll())
    ctrl = run_profile(_group(), prof,
                       WindowSlack(inflight_limit=8, queue_cap=16))
    over_f, over_c = free.stage("overload"), ctrl.stage("overload")
    assert over_f.shed == 0
    assert over_f.max_stream_backlog > over_c.max_stream_backlog
    assert over_f.p99_rounds > over_c.p99_rounds
    # both report the same offered load — the input is open-loop
    assert over_f.offered == over_c.offered


@fast
def test_token_bucket_caps_release_rate():
    prof = Profile(arrivals=Poisson(rate=3.0), seed=2,
                   stages=(Stage("s", 30, 1.0),))
    rep = run_profile(_group(), prof,
                      TokenBucket(rate=0.5, burst=2.0, queue_cap=4))
    st = rep.stage("s")
    assert st.shed > 0                     # rate cap overflows the queue
    assert st.released < st.offered
    assert st.released + st.shed == st.offered   # queue fully drained
    # tail-latency stays bounded by the tiny queue, not the stage length
    assert st.p99_rounds <= 3 * (4 + 8) + 10


@fast
def test_harness_accounting_balances():
    rep = run_profile(_group(), _profile(overload=6.0),
                      WindowSlack(inflight_limit=8, queue_cap=16))
    t = rep.totals
    assert t["offered"] == (t["released"] + t["shed"]
                            + rep.stages[-1].end_queue_depth)
    assert t["delivered"] + t["undelivered"] == t["released"]


@fast
def test_harness_rejects_stale_stream_and_bad_target():
    g = _group()
    stream = g.stream(backend="graph")
    stream.step(np.zeros(stream.shape, np.int32))
    with pytest.raises(ValueError, match="fresh stream"):
        run_profile(stream, _profile())
    with pytest.raises(TypeError, match="cannot load-test"):
        run_profile(object(), _profile())
    with pytest.raises(TypeError, match="ServeAdmission"):
        run_profile(_group(), _profile(), ServeAdmission(queue_cap=4))


@fast
def test_bound_domain_target_and_push_matrix():
    d = api.many_topic_domain(4, 3, window=8)
    rep = run_profile(d.bind(backend="graph"),
                      _profile(seed=4, rounds=10, overload=3.0),
                      WindowSlack(inflight_limit=8, queue_cap=8))
    assert rep.totals["delivered"] > 0
    # push_matrix is the same step push_round lowers to
    b1, b2 = d.bind(backend="graph"), d.bind(backend="graph")
    v1 = b1.push_round({"topic-0": 2})
    ready = np.zeros(b2.stream.shape, np.int32)
    ready[b2.gid_of("topic-0"), 0] = 2
    v2 = b2.push_matrix(ready)
    np.testing.assert_array_equal(v1.published, v2.published)
    assert set(b2.topic_backlogs()) == {"topic-0", "topic-1", "topic-2"}


# ---------------------------------------------------------------------------
# serve-plane lowering: open-loop arrivals into ReplicatedEngine
# ---------------------------------------------------------------------------

_LOAD_ARCH = "load-test"


def _replicated(replicas=2, slots=2):
    from repro.models import layers, registry
    from repro.models.config import ModelConfig
    from repro.models.runtime import Runtime
    from repro.serve.engine import EngineConfig, ServeEngine
    from repro.serve.fanout import ReplicatedEngine

    cfg = ModelConfig(name=_LOAD_ARCH, family="dense", n_layers=1,
                      d_model=32, n_heads=2, n_kv_heads=2, d_ff=64,
                      vocab_size=64, head_dim=16, tie_embeddings=True)
    registry.register(_LOAD_ARCH, lambda: cfg)
    params = layers.init_tree(registry.param_specs(cfg), jax.random.key(0))
    engines = [ServeEngine(_LOAD_ARCH, params, cfg,
                           EngineConfig(max_batch=slots, max_len=32),
                           Runtime())
               for _ in range(replicas)]
    return ReplicatedEngine(engines, subscribers_per_replica=2, window=4,
                            backend="graph")


@fast
def test_serve_plane_overload_sheds_and_drains():
    """ServeAdmission lowers to the engine loop: queue_cap sheds newest
    requests, stall_backlog stalls slots at the SMC watermark, and the
    run still drains with bounded latency and queue depth."""
    rep = _replicated(replicas=2, slots=2)
    prof = Profile(arrivals=Poisson(rate=1.5), seed=11,
                   stages=(Stage("warmup", 4, 0.25),
                           Stage("overload", 12, 1.0)))
    report = run_profile(rep, prof,
                         ServeAdmission(queue_cap=3, stall_backlog=6),
                         max_new_tokens=3, prompt_len=2)
    over = report.stage("overload")
    assert over.shed > 0
    assert over.max_queue_depth <= 3 * 2          # cap x replicas
    assert over.p99_rounds > 0
    assert report.totals["delivered"] + report.totals["shed"] \
        == report.totals["offered"]
    assert report.totals["undelivered"] == 0      # drained
    serve = report.run_report.extras["serve"]
    assert serve["shed_requests"] == report.totals["shed"]
    assert all(eng.drained() for eng in rep.engines)


# ---------------------------------------------------------------------------
# TRACE_EVENTS bounding + snapshot/reset helpers
# ---------------------------------------------------------------------------

@fast
def test_trace_events_bounded_and_helpers():
    saved = api.trace_snapshot()
    try:
        assert group_mod.TRACE_EVENTS.maxlen == api.TRACE_MAXLEN
        n = api.trace_reset()
        assert n == len(saved) and len(group_mod.TRACE_EVENTS) == 0
        # growth is bounded: the deque drops oldest entries at the cap
        for i in range(api.TRACE_MAXLEN + 50):
            group_mod.TRACE_EVENTS.append(((1, 1, i), (1,), "x"))
        assert len(group_mod.TRACE_EVENTS) == api.TRACE_MAXLEN
        assert api.trace_snapshot()[-1][0][2] == api.TRACE_MAXLEN + 49
        api.trace_reset()
    finally:
        group_mod.TRACE_EVENTS.extend(saved)   # restore history


# ---------------------------------------------------------------------------
# soak: long open-loop run keeps compile traces flat and bounded
# ---------------------------------------------------------------------------

@pytest.mark.soak
def test_soak_trace_growth_bounded_across_stages():
    prof = Profile(arrivals=Diurnal(rate=0.8, period=100), seed=9,
                   stages=(Stage("day-1", 150, 1.0),
                           Stage("day-2", 150, 1.2),
                           Stage("day-3", 150, 0.9)))
    before = len(api.trace_snapshot())
    rep = run_profile(_group(window=8), prof,
                      WindowSlack(inflight_limit=16, queue_cap=32))
    grew = len(api.trace_snapshot()) - before
    assert grew <= 1                      # one trace for the whole run
    assert len(group_mod.TRACE_EVENTS) <= api.TRACE_MAXLEN
    assert rep.totals["delivered"] > 0
    # a second identical run is fully warm: zero new traces
    before = len(api.trace_snapshot())
    run_profile(_group(window=8), prof,
                WindowSlack(inflight_limit=16, queue_cap=32))
    assert len(api.trace_snapshot()) == before


# ---------------------------------------------------------------------------
# fused profile runs: bit-identical LoadReports off the device program
# ---------------------------------------------------------------------------

def _small_profile(seed=0):
    return staged_ramp(Poisson(rate=0.5), warmup=6, steps=(1.0,),
                       rounds_per_stage=8, overload=4.0,
                       overload_rounds=8, seed=seed)


@fast
@pytest.mark.parametrize("backend", ["graph", "pallas"])
@pytest.mark.parametrize("policy", ["admit-all", "window-slack",
                                    "token-bucket"])
def test_fused_profile_loadreport_bit_identical(backend, policy):
    """fused=True runs the whole profile as one device scan plus drain
    chunks; the LoadReport JSON must equal the host loop's byte-for-byte
    for every lowerable policy, on both stacked backends."""
    mk = {"admit-all": lambda: AdmitAll(),
          "window-slack": lambda: WindowSlack(queue_cap=8),
          "token-bucket": lambda: TokenBucket(rate=0.7, burst=4.0,
                                              queue_cap=8)}[policy]
    ru = run_profile(_group(), _small_profile(), mk(), backend=backend)
    rf = run_profile(_group(), _small_profile(), mk(), backend=backend,
                     fused=True)
    lf = rf.run_report.extras.get("load_fused")
    assert lf, "profile did not take the fused path"
    assert lf["profile_rounds"] == _small_profile().total_rounds
    assert ru.json_str() == rf.json_str()


@fast
def test_fused_profile_bursty_arrivals_bit_identical():
    prof = staged_ramp(OnOff(rate_on=2.5, p_on_off=0.2, p_off_on=0.3),
                       warmup=6, steps=(1.0,), rounds_per_stage=8,
                       overload=3.0, overload_rounds=8, seed=4)
    ru = run_profile(_group(), prof, WindowSlack(queue_cap=6))
    rf = run_profile(_group(), prof, WindowSlack(queue_cap=6),
                     fused=True)
    assert rf.run_report.extras.get("load_fused")
    assert ru.json_str() == rf.json_str()


@fast
def test_fused_profile_token_bucket_state_carries_like_host():
    """A fused run leaves the policy's token state exactly where the
    host loop would (device_commit), so reuse behaves identically."""
    pu = TokenBucket(rate=0.6, burst=3.0, queue_cap=8)
    pf = TokenBucket(rate=0.6, burst=3.0, queue_cap=8)
    run_profile(_group(), _small_profile(), pu)
    run_profile(_group(), _small_profile(), pf, fused=True)
    assert pu._tokens is not None and pf._tokens is not None
    assert pu._tokens.dtype == pf._tokens.dtype == np.float32
    np.testing.assert_array_equal(pu._tokens, pf._tokens)


@fast
def test_fused_profile_falls_back_silently():
    """Non-lowerable policies and the des numpy stream keep the host
    loop — same report, no load_fused marker."""
    class HostOnly(AdmitAll):
        def fused_key(self):
            return None

    r1 = run_profile(_group(), _small_profile(), HostOnly(), fused=True)
    r2 = run_profile(_group(), _small_profile(), HostOnly())
    assert "load_fused" not in r1.run_report.extras
    assert r1.json_str() == r2.json_str()
    rdes_f = run_profile(_group(), _small_profile(), AdmitAll(),
                         backend="des", fused=True)
    rdes_u = run_profile(_group(), _small_profile(), AdmitAll(),
                         backend="des")
    assert "load_fused" not in rdes_f.run_report.extras
    assert rdes_f.json_str() == rdes_u.json_str()


@fast
def test_serve_target_fused_loadreport_bit_identical():
    """run_profile(rep, ..., fused=True) drives the wedge-capable fused
    serve loop (zero host hops) and reproduces the unfused LoadReport
    byte-for-byte."""
    prof = Profile(arrivals=Poisson(rate=0.4), seed=9,
                   stages=(Stage("warm", 6, 0.5),
                           Stage("load", 8, 2.0)))
    ru = run_profile(_replicated(), prof, ServeAdmission(queue_cap=3))
    rf = run_profile(_replicated(), prof, ServeAdmission(queue_cap=3),
                     fused=True)
    sf = rf.run_report.extras["serve"]
    assert sf["fused"] is True, sf.get("fused_fallback")
    assert sf["host_hops"] == 0
    assert ru.run_report.extras["serve"]["host_hops"] > 0
    assert ru.json_str() == rf.json_str()
