"""Simulator-level behaviour tests: the paper's headline claims hold in
the calibrated DES, and protocol invariants survive end-to-end runs.
"""

import dataclasses

import numpy as np
import pytest

from repro.core import dds, simulator as sim
from repro.core.views import MembershipService


def test_spindle_beats_baseline_by_an_order():
    spin = sim.run(sim.single_subgroup(8, n_messages=600))
    base = sim.run(sim.single_subgroup(
        8, n_messages=200, flags=sim.SpindleFlags.baseline()))
    assert spin.throughput_GBps > 8 * base.throughput_GBps
    assert spin.mean_latency_us < base.mean_latency_us / 5
    # ack coalescing: writes per delivered message drop dramatically
    spin_wpm = spin.rdma_writes / spin.delivered_app_msgs
    base_wpm = base.rdma_writes / base.delivered_app_msgs
    assert spin_wpm < base_wpm / 5


def test_all_messages_delivered_exactly_once():
    cfg = sim.single_subgroup(5, n_messages=300)
    r = sim.run(cfg)
    assert not r.stalled
    # every member delivers every app message exactly once
    assert r.delivered_app_msgs == 5 * 5 * 300


def test_inactive_sender_stalls_without_nulls_only():
    pats = (((0, 2), sim.SenderPattern(active=False)),)
    no_nulls = sim.run(sim.single_subgroup(
        6, n_messages=150, flags=sim.SpindleFlags(null_send=False),
        patterns=pats, target_delivered=5 * 150, max_time_us=2e5))
    with_nulls = sim.run(sim.single_subgroup(
        6, n_messages=150, patterns=pats, target_delivered=5 * 150))
    assert no_nulls.stalled
    assert not with_nulls.stalled
    assert with_nulls.nulls_sent > 0


def test_quiescence_no_infinite_nulls():
    r = sim.run(sim.single_subgroup(4, n_messages=100))
    # nulls (if any) are bounded by rounds, not unbounded chatter
    assert r.nulls_sent <= 4 * 100
    assert not r.stalled


def test_throughput_respects_link_bandwidth():
    r = sim.run(sim.single_subgroup(16, n_messages=800))
    # per-node egress = 15/16 of delivered bandwidth; must fit 12.5 GB/s
    egress = r.throughput_GBps * 15 / 16
    assert egress < 12.5 + 0.1


def test_window_size_tradeoff():
    """Fig. 6: tiny windows strangle batching; w=100 is near the peak."""
    w5 = sim.run(sim.single_subgroup(8, window=5, n_messages=400))
    w100 = sim.run(sim.single_subgroup(8, window=100, n_messages=400))
    assert w100.throughput_GBps > w5.throughput_GBps


def test_multi_subgroup_fairness_cost_baseline():
    """Fig. 8: inactive subgroups drag the baseline down."""
    def run_k(k, flags, msgs):
        groups = tuple(
            sim.SubgroupSpec(members=tuple(range(8)),
                             senders=tuple(range(8)),
                             n_messages=msgs if g == 0 else 0)
            for g in range(k))
        return sim.run(sim.SimConfig(n_nodes=8, subgroups=groups,
                                     flags=flags))

    base1 = run_k(1, sim.SpindleFlags.baseline(), 150)
    base8 = run_k(8, sim.SpindleFlags.baseline(), 150)
    spin1 = run_k(1, sim.SpindleFlags.spindle(), 500)
    spin8 = run_k(8, sim.SpindleFlags.spindle(), 500)
    assert base8.throughput_GBps < 0.75 * base1.throughput_GBps
    # opportunistic batching absorbs the inactive-subgroup overhead
    assert spin8.throughput_GBps > 0.5 * spin1.throughput_GBps


def test_upcall_delay_sensitivity():
    """Sec. 3.5: 100us upcalls collapse throughput ~90%."""
    fast = sim.run(sim.single_subgroup(
        8, n_messages=250,
        flags=sim.SpindleFlags(batched_upcall=False)))
    slow = sim.run(sim.single_subgroup(
        8, n_messages=250, upcall_extra_us=100.0,
        flags=sim.SpindleFlags(batched_upcall=False)))
    assert slow.throughput_GBps < 0.25 * fast.throughput_GBps


def test_dds_qos_ordering():
    """Fig. 18: cheaper QoS >= more expensive QoS, spindle > baseline."""
    def thr(qos, spindle):
        domain = dds.single_topic_domain(8, 7, qos=qos)
        cfg = domain.sim_config(
            samples_per_publisher=400 if spindle else 120,
            spindle=spindle)
        return sim.run(cfg).throughput_GBps

    atomic_s = thr(dds.QoS.ATOMIC_MULTICAST, True)
    logged_s = thr(dds.QoS.LOGGED, True)
    atomic_b = thr(dds.QoS.ATOMIC_MULTICAST, False)
    assert atomic_s >= logged_s * 0.9
    assert atomic_s > 2 * atomic_b


def test_membership_two_phase_properties():
    ms = MembershipService([0, 1, 2, 3])
    ms.suspect(0, 2)
    v = ms.propose_and_install({0: 10, 1: 12, 3: 9})
    assert v.vid == 1 and 2 not in v.members
    assert ms.restart_watermark() == 9      # min over survivors
    # suspicions cleared per view; monotone vid
    ms.request_join(7)
    v2 = ms.propose_and_install({m: 20 for m in v.members})
    assert v2.vid == 2 and 7 in v2.members and 7 in v2.joiners
