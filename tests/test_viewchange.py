"""Virtual-synchrony cut: in-flight resend across view changes
(paper Secs. 2.1, 3.3; DESIGN.md Sec. 7).

The failure-path suite the robustness claims rest on.  Covers, bottom-up:

* the cut arithmetic: ``sst.ragged_trim`` (stable-delivery frontier over
  survivors) and ``delivery.apps_in_publish_prefix`` (per-sender stable
  app counts from the round traces);
* epoch-carry execution: ``sweep.scan_rounds(backlog0=)`` is
  bit-identical to merging the carry into the first schedule row, and a
  carried ``GroupStream`` resumes from the same arithmetic;
* deterministic view installs: joiner rank assignment must not depend on
  join request arrival order;
* ``Group.reconfigure`` carries queued explicit sends and REUSES the
  cached stacked program when the padded ``(G, N_max, S_max)`` shape
  survives the change (the re-stack-from-scratch regression);
* the cut invariant, seeded (hypothesis is not installed): for random
  membership/suspicion/join schedules driven through
  ``MembershipService``, every in-flight message is delivered in exactly
  one view, everywhere-or-nowhere, with per-sender FIFO preserved across
  cuts — graph and pallas bit-identical, the drained final epoch
  order-invariant conformant with a des run of the same counts;
* multi-view soaks (``-m soak``): >=8 consecutive view changes under
  continuous streamed traffic on graph AND pallas with NO fresh-epoch
  restart — bounded TRACE_EVENTS, monotone app watermarks across cuts;
* the serve plane: ``ReplicatedEngine`` survives a mid-run subscriber
  failure with slot holds re-pinned against the new epoch's watermarks.
"""

import numpy as np
import pytest

from repro import api
from repro.core import delivery, group as group_mod, sst
from repro.core import sweep as sweep_mod

import jax.numpy as jnp

fast = pytest.mark.fast
soak = pytest.mark.soak


# ---------------------------------------------------------------------------
# cut arithmetic
# ---------------------------------------------------------------------------


@fast
def test_ragged_trim_over_survivors():
    col = np.array([7, 4, 9, 2])
    assert sst.ragged_trim(col, [True] * 4) == 2
    assert sst.ragged_trim(col, [True, True, True, False]) == 4
    assert sst.ragged_trim(col, [False, True, False, False]) == 4
    assert sst.ragged_trim(col, [False] * 4) == -1


@fast
def test_apps_in_publish_prefix_counts_apps_before_nulls():
    # rounds publish (apps, nulls): (2,1), (0,2), (3,0)
    app_pub, nulls = np.array([2, 0, 3]), np.array([1, 2, 0])
    want = [0, 1, 2, 2, 2, 2, 3, 4, 5]     # apps among first k publishes
    got = [delivery.apps_in_publish_prefix(app_pub, nulls, k)
           for k in range(9)]
    assert got == want
    # seeded property: consistent with a brute-force publish replay
    rng = np.random.default_rng(7)
    for _ in range(25):
        t = int(rng.integers(1, 9))
        a, n = rng.integers(0, 4, t), rng.integers(0, 3, t)
        flat = []
        for r in range(t):
            flat += [True] * int(a[r]) + [False] * int(n[r])
        for k in (0, len(flat) // 2, len(flat)):
            assert delivery.apps_in_publish_prefix(a, n, k) == \
                sum(flat[:k])


@fast
def test_scan_backlog0_is_bit_identical_to_schedule_head_merge():
    """The epoch-carry contract: starting a scan with the previous view's
    resend counts queued equals merging them into round 0's schedule row
    (step_backlog merges backlog + ready) — so resent messages keep
    per-sender FIFO order ahead of new traffic by construction."""
    rng = np.random.default_rng(20260730)
    for _ in range(10):
        s = int(rng.integers(1, 4))
        n = int(rng.integers(s, 5))
        sched = rng.integers(0, 3, size=(10, s)).astype(np.int32)
        b0 = rng.integers(0, 4, size=s).astype(np.int32)
        window = int(rng.choice([2, 4, 1 << 20]))
        _, tr_carry = sweep_mod.scan_rounds(
            sweep_mod.SweepState.init(n, s), jnp.asarray(sched),
            window=window, backlog0=jnp.asarray(b0))
        merged = sched.copy()
        merged[0] += b0
        _, tr_merged = sweep_mod.scan_rounds(
            sweep_mod.SweepState.init(n, s), jnp.asarray(merged),
            window=window)
        for a, b in zip(tr_carry, tr_merged):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# deterministic view installs
# ---------------------------------------------------------------------------


@fast
def test_joiner_rank_assignment_is_arrival_order_independent():
    """Two replicas of the membership state machine that observe the same
    joins/suspicions in DIFFERENT orders must install the identical view
    (same members, same joiners tuple, same rank for every node)."""
    a = api.MembershipService([0, 1, 2, 3])
    b = api.MembershipService([0, 1, 2, 3])
    for j in (7, 5, 9):
        a.request_join(j)
    for j in (9, 7, 5):
        b.request_join(j)
    a.suspect(0, 2)
    b.suspect(1, 2)                        # different reporter, same truth
    va = a.propose_and_install({m: 1 for m in range(4)})
    vb = b.propose_and_install({m: 1 for m in range(4)})
    assert va == vb
    assert va.joiners == (5, 7, 9)
    for node in va.members:
        assert va.rank(node) == vb.rank(node)


# ---------------------------------------------------------------------------
# Group.reconfigure: explicit-send carry + program-cache reuse
# ---------------------------------------------------------------------------


@fast
def test_reconfigure_carries_queued_explicit_sends_across_backends():
    """Queued-but-never-sent messages are the head of the resend set:
    they survive the view change remapped to surviving sender ranks (a
    failed sender's queue dies with it) and run identically on every
    backend."""
    spec = api.SubgroupSpec(members=(0, 1, 2, 3), senders=(0, 1, 2),
                            msg_size=256, window=8, n_messages=5)
    base = api.Group(api.GroupConfig(members=(0, 1, 2, 3),
                                     subgroups=(spec,)))
    base.subgroup(0).send(sender=0, n=4)
    base.subgroup(0).send(sender=2, n=6)   # sender 2 will fail
    g2 = base.reconfigure(api.View(vid=1, members=(0, 1, 3),
                                   senders=(0, 1, 3)))
    assert np.array_equal(g2._explicit[0], [4, 0])
    delivered = {}
    for backend in ("des", "graph", "pallas"):
        g = api.Group(g2.cfg)
        g._explicit = {k: v.copy() for k, v in g2._explicit.items()}
        r = g.run(backend=backend)
        assert r.delivered_app_msgs == 3 * 4, backend
        delivered[backend] = [g.subgroup(0).delivered(n)
                              for n in (0, 1, 3)]
    assert delivered["des"] == delivered["graph"] == delivered["pallas"]


@fast
def test_reconfigure_same_padded_shape_reuses_cached_program():
    """The re-stack-from-scratch regression: a view change that
    re-shapes one subgroup INSIDE an unchanged padded (G, N_max, S_max)
    stack must reuse the cached stacked program (sizes are traced
    validity masks now, not static key parts) — both for scheduled runs
    and for a live stream crossing the cut."""
    spec_a = api.SubgroupSpec(members=(0, 1, 2, 3), senders=(0, 1),
                              msg_size=512, window=8, n_messages=12)
    spec_b = api.SubgroupSpec(members=(0, 1, 4), senders=(0,),
                              msg_size=256, window=8, n_messages=3)
    cfg = api.GroupConfig(members=(0, 1, 2, 3, 4),
                          subgroups=(spec_a, spec_b))
    g = api.Group(cfg)
    g.run(backend="graph")                     # warm the program cache
    before = len(group_mod.TRACE_EVENTS)
    # node 4 is a non-sender member of B only: B shrinks (3 -> 2
    # members) but A still sets N_max=4, S_max=2 — padded shape intact
    g2 = g.reconfigure(api.View(vid=1, members=(0, 1, 2, 3),
                                senders=(0, 1, 2, 3)))
    r = g2.run(backend="graph")
    assert len(group_mod.TRACE_EVENTS) == before, \
        "same-padded-shape reconfigure re-stacked from scratch"
    assert not r.stalled

    # streaming: the cut hands the SAME cached one-round program on
    stream = api.Group(cfg).stream(backend="graph")
    ready = np.zeros(stream.shape, np.int32)
    ready[0, :2] = 2
    ready[1, 0] = 1
    for _ in range(3):
        stream.step(ready)
    n0 = len(group_mod.TRACE_EVENTS)
    s2 = stream.reconfigure(api.View(vid=1, members=(0, 1, 2, 3),
                                     senders=(0, 1, 2, 3)))
    assert s2.carry is not None and s2.carry.total_resend() > 0
    ready2 = np.zeros(s2.shape, np.int32)
    ready2[0, :2] = 1
    s2.step(ready2)
    s2.finish()
    assert len(group_mod.TRACE_EVENTS) == n0, \
        "mid-stream cut re-traced a shape-preserving epoch"
    with pytest.raises(RuntimeError, match="closed"):
        stream.step(ready)
    with pytest.raises(RuntimeError, match="closed"):
        stream.finish()


# ---------------------------------------------------------------------------
# the cut invariant (seeded property tests — hypothesis is not installed)
# ---------------------------------------------------------------------------

# Nodes 1 and 2 never fail, so both subgroups always survive and gid
# numbering is stable across every schedule (gid_map stays the identity).
_A = dict(members=(0, 1, 2, 3), senders=(0, 1, 2))
_B = dict(members=(1, 2, 3), senders=(1, 2))
_EVENTS = (("fail", 3), ("fail", 0), ("join", 6))


def _vc_group():
    spec_a = api.SubgroupSpec(msg_size=512, window=4, n_messages=0, **_A)
    spec_b = api.SubgroupSpec(msg_size=256, window=4, n_messages=0, **_B)
    return api.Group(api.GroupConfig(members=(0, 1, 2, 3, 4),
                                     subgroups=(spec_a, spec_b)))


def _sender_apps(log, node, spec):
    """Delivered app counts at ``node`` keyed by sender NODE id, asserting
    per-sender FIFO (indices strictly increasing) on the way."""
    counts, last = {}, {}
    for rank, idx, _ in log.sequence(node):
        assert idx > last.get(rank, -1), "per-sender FIFO violated"
        last[rank] = idx
        node_id = spec.senders[rank]
        counts[node_id] = counts.get(node_id, 0) + 1
    return counts


def _drive_schedule(seed, backend):
    """One random membership/suspicion/join schedule under continuous
    in-flight traffic.  Returns (epochs, enqueued_by_node, failed) where
    epochs = [(specs, logs, alive_then, carry_out)] oldest first, the
    last entry being the drained final epoch (carry_out None)."""
    rng = np.random.default_rng(seed)
    ms = api.MembershipService([0, 1, 2, 3, 4])
    stream = _vc_group().stream(backend=backend)
    enqueued = {}                       # (gid, sender node) -> total apps
    failed = set()
    events = [_EVENTS[i] for i in rng.permutation(3)[:2]]
    cut_rounds = sorted(rng.choice(np.arange(2, 9), size=2,
                                   replace=False))
    epochs = []
    for rnd in range(10):
        specs = stream.group.cfg.subgroups
        ready = np.zeros(stream.shape, np.int32)
        for g, spec in enumerate(specs):
            for rank, node in enumerate(spec.senders):
                if node in failed:
                    continue
                c = int(rng.integers(0, 3))
                ready[g, rank] = c
                enqueued[(g, node)] = enqueued.get((g, node), 0) + c
        stream.step(ready)
        if rnd in cut_rounds:
            kind, node = events.pop(0)
            if kind == "fail":
                ms.suspect(1, node)
                failed.add(node)
            else:
                ms.request_join(node)
            old_group, old_specs = stream.group, specs
            view, stream = ms.reconfigure_stream(stream, {})
            assert stream.group is not old_group
            epochs.append((old_specs, old_group.delivery_logs,
                           set(view.members), stream.carry))
    report, logs = stream.finish()
    assert not report.stalled
    epochs.append((stream.group.cfg.subgroups, logs,
                   set(stream.group.cfg.members), None))
    return epochs, enqueued, failed, stream


@fast
@pytest.mark.parametrize("backend", ["graph", "pallas", "des"])
def test_cut_invariant_seeded_everywhere_or_nowhere(backend):
    """For random membership/suspicion/join schedules: every app message
    is delivered in exactly one view, everywhere-or-nowhere among that
    view's survivors, per-sender FIFO preserved across cuts; a failed
    sender loses exactly a FIFO *tail* (nowhere), never a middle."""
    for seed in (11, 23, 47):
        epochs, enqueued, failed, _ = _drive_schedule(seed, backend)
        delivered = {}                  # (gid, sender node) -> apps, @obs
        for e, (specs, logs, alive, carry) in enumerate(epochs):
            final = carry is None
            for gid, spec in enumerate(specs):
                log = logs[gid]
                survivors = [m for m in spec.members if m in alive]
                # everywhere-or-nowhere: identical app sequence at every
                # member surviving the epoch boundary
                seqs = [log.sequence(node) for node in survivors]
                assert all(s == seqs[0] for s in seqs[1:]), \
                    (seed, e, gid)
                per_node = _sender_apps(log, survivors[0], spec)
                for node_id, c in per_node.items():
                    key = (gid, node_id)
                    delivered[key] = delivered.get(key, 0) + c
                if not final:
                    # the epoch delivered exactly its stable prefix: the
                    # carry's stable_apps IS the per-sender delta
                    new_specs = epochs[e + 1][0]
                    for rank, node_id in enumerate(
                            new_specs[gid].senders):
                        assert per_node.get(node_id, 0) == \
                            int(carry.stable_apps[gid][rank]), \
                            (seed, e, gid, node_id)
        for (gid, node_id), total in enqueued.items():
            got = delivered.get((gid, node_id), 0)
            if node_id in failed:
                # unstable tail of a failed sender: delivered nowhere
                assert got <= total, (seed, gid, node_id)
            else:
                assert got == total, (seed, gid, node_id)


def _assert_epochs_bit_identical(ea, eb, ctx=""):
    """Every epoch's specs, logs (sequences AND is_app payloads) and
    carry contents (cut_seq, resend, stable_apps, app_base) agree bit
    for bit."""
    assert len(ea) == len(eb), ctx
    for e, ((specs_a, logs_a, alive_a, carry_a),
            (specs_b, logs_b, alive_b, carry_b)) in \
            enumerate(zip(ea, eb)):
        assert specs_a == specs_b and alive_a == alive_b, (ctx, e)
        assert set(logs_a) == set(logs_b), (ctx, e)
        for gid in logs_a:
            assert logs_a[gid].delivered_seq == \
                logs_b[gid].delivered_seq, (ctx, e, gid)
            for node in logs_a[gid].delivered_seq:
                assert logs_a[gid].sequence(node) == \
                    logs_b[gid].sequence(node), (ctx, e, gid, node)
            for x, y in zip(logs_a[gid].is_app, logs_b[gid].is_app):
                np.testing.assert_array_equal(x, y)
        assert (carry_a is None) == (carry_b is None), (ctx, e)
        if carry_a is not None:
            assert carry_a.from_epoch == carry_b.from_epoch, (ctx, e)
            assert carry_a.cut_seq == carry_b.cut_seq, (ctx, e)
            for field in ("resend", "stable_apps", "app_base"):
                for xa, xb in zip(getattr(carry_a, field),
                                  getattr(carry_b, field)):
                    np.testing.assert_array_equal(xa, xb)


@fast
def test_cut_schedules_bit_identical_graph_pallas_des():
    """graph, pallas AND the two-phase des stream (DESIGN.md Sec. 12)
    agree bit-identically on every epoch of a random cut schedule —
    delivery logs and carries; the drained final epoch is additionally
    order-invariant conformant with a legacy ``des-loop`` run of the
    same counts (send timing differs: stream bursts + cut carry vs
    paced schedule)."""
    for seed in (5, 31):
        results = {}
        for backend in ("graph", "pallas", "des"):
            epochs, enqueued, failed, stream = _drive_schedule(
                seed, backend)
            results[backend] = (epochs, stream)
        eg, sg = results["graph"]
        _assert_epochs_bit_identical(eg, results["pallas"][0],
                                     f"seed{seed}:pallas")
        _assert_epochs_bit_identical(eg, results["des"][0],
                                     f"seed{seed}:des")
        # legacy-loop conformance of the resent final epoch: same
        # per-sender app counts at every member, per-sender FIFO merge
        # (asserted by _sender_apps), compared order-invariantly
        final_specs, final_logs, _, _ = eg[-1]
        g_des = api.Group(sg.group.cfg)
        for gid, spec in enumerate(final_specs):
            for rank, node in enumerate(spec.senders):
                g_des.subgroup(gid).send(
                    sender=node, n=int(sg._enqueued[gid][rank]))
        g_des.run(backend="des-loop")
        for gid, spec in enumerate(final_specs):
            for node in spec.members:
                assert _sender_apps(final_logs[gid], node, spec) == \
                    _sender_apps(g_des.delivery_logs[gid], node, spec), \
                    (seed, gid, node)


@fast
def test_three_cut_timeline_bit_identical_all_backends():
    """A 3-cut view-change timeline produces bit-identical per-epoch
    delivery logs and EpochCarry contents on des, graph and pallas —
    the two-phase scale-out's acceptance bar: cut epochs are
    bit-COMPARABLE across all three substrates, not merely
    order-invariant."""
    def drive(backend):
        ms = api.MembershipService([0, 1, 2, 3, 4])
        stream = _vc_group().stream(backend=backend)
        rng = np.random.default_rng(101)
        epochs = []
        cuts = [(2, "fail", 3), (5, "join", 6), (8, "fail", 0)]
        failed = set()
        for rnd in range(11):
            specs = stream.group.cfg.subgroups
            ready = np.zeros(stream.shape, np.int32)
            for g, spec in enumerate(specs):
                for rank, node in enumerate(spec.senders):
                    if node not in failed:
                        ready[g, rank] = int(rng.integers(0, 3))
            stream.step(ready)
            if cuts and rnd == cuts[0][0]:
                _, kind, node = cuts.pop(0)
                if kind == "fail":
                    ms.suspect(1, node)
                    failed.add(node)
                else:
                    ms.request_join(node)
                old = stream.group
                view, stream = ms.reconfigure_stream(stream, {})
                epochs.append((old.cfg.subgroups, old.delivery_logs,
                               set(view.members), stream.carry))
        report, logs = stream.finish()
        assert not report.stalled
        epochs.append((stream.group.cfg.subgroups, logs,
                       set(stream.group.cfg.members), None))
        return epochs

    eg = drive("graph")
    _assert_epochs_bit_identical(eg, drive("des"), "des")
    _assert_epochs_bit_identical(eg, drive("pallas"), "pallas")
    assert len(eg) == 4                   # 3 cuts + drained final epoch


# ---------------------------------------------------------------------------
# multi-view soaks (-m soak): no fresh-epoch restart
# ---------------------------------------------------------------------------


@soak
@pytest.mark.parametrize("backend", ["graph", "pallas"])
def test_eight_view_soak_no_fresh_epoch_restart(backend):
    """>=8 consecutive view changes under continuous in-flight traffic:
    the stream survives every cut on the SAME cached program (bounded
    TRACE_EVENTS — the per-subgroup shapes are unchanged, so no
    fresh-epoch restart), per-sender app watermarks are monotone across
    cuts, and at the end every enqueued message was delivered exactly
    once at every member."""
    spec_a = api.SubgroupSpec(members=(0, 1, 2, 3), senders=(0, 1),
                              msg_size=512, window=4, n_messages=0)
    spec_b = api.SubgroupSpec(members=(0, 1, 2), senders=(0,),
                              msg_size=256, window=4, n_messages=0)
    g = api.Group(api.GroupConfig(members=(0, 1, 2, 3, 4, 5),
                                  subgroups=(spec_a, spec_b)))
    ms = api.MembershipService(g.cfg.members)
    stream = g.stream(backend=backend)
    n0 = len(group_mod.TRACE_EVENTS)
    rng = np.random.default_rng(99)
    enqueued = np.zeros((2, 2), np.int64)          # (gid, rank)
    stable_seen = np.zeros((2, 2), np.int64)
    prev_base = [np.zeros(2, np.int64), np.zeros(1, np.int64)]
    epochs = []
    n_views = 8
    for v in range(n_views):
        for _ in range(3):                          # in-flight traffic
            ready = np.zeros(stream.shape, np.int32)
            for g_, s_ in ((0, 0), (0, 1), (1, 0)):
                c = int(rng.integers(0, 3))
                ready[g_, s_] = c
                enqueued[g_, s_] += c
            stream.step(ready)
        # nodes 4/5 are OUTSIDE every subgroup: failing/joining them
        # rolls the epoch (a full wedge+cut) without re-shaping the stack
        if v % 2 == 0:
            ms.suspect(0, 4)
        else:
            ms.request_join(4)
        old_group = stream.group
        view, stream = ms.reconfigure_stream(stream, {})
        assert view.vid == v + 1
        carry = stream.carry
        epochs.append((old_group.delivery_logs, carry))
        # monotone watermarks across cuts: the cumulative app base never
        # regresses, and advances by exactly this epoch's stable delta
        for gid in (0, 1):
            base = carry.app_base[gid]
            assert (base >= prev_base[gid]).all(), (backend, v, gid)
            np.testing.assert_array_equal(
                base, prev_base[gid] + carry.stable_apps[gid])
            prev_base[gid] = base.copy()
        s_a = carry.stable_apps[0]
        stable_seen[0, : len(s_a)] += s_a
        stable_seen[1, 0] += int(carry.stable_apps[1][0])
        # every epoch resends exactly what was not yet stable
        resent = sum(int(r.sum()) for r in carry.resend)
        assert resent == int(enqueued.sum() - stable_seen.sum()), \
            (backend, v)
    report, logs = stream.finish()
    assert not report.stalled
    # no fresh-epoch restart: one trace for the WHOLE soak at most (0
    # when an earlier test already cached this shape's program)
    assert len(group_mod.TRACE_EVENTS) - n0 <= 1, \
        f"{backend} soak re-traced across view changes"
    # exactly-once: over all epochs, every member of each subgroup
    # delivered each sender's full enqueued sequence, no loss, no dupes
    epochs.append((logs, None))
    for gid, spec in enumerate(stream.group.cfg.subgroups):
        for pos, node in enumerate(spec.members):
            per_rank = np.zeros(len(spec.senders), np.int64)
            for ep_logs, _ in epochs:
                log = ep_logs.get(gid)      # {} = an epoch with no rounds
                for rank, idx, _ in (log.sequence(node) if log else ()):
                    per_rank[rank] += 1
            np.testing.assert_array_equal(
                per_rank, enqueued[gid, : len(spec.senders)],
                err_msg=f"{backend} gid={gid} node={node}")


# ---------------------------------------------------------------------------
# serve plane: mid-run subscriber failure
# ---------------------------------------------------------------------------


def _fan_engines():
    import jax
    from repro.models import layers, registry
    from repro.models.config import ModelConfig
    from repro.models.runtime import Runtime
    from repro.serve.engine import EngineConfig, ServeEngine

    cfg = ModelConfig(name="viewchange-test", family="dense", n_layers=2,
                      d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
                      vocab_size=512, head_dim=32, tie_embeddings=True)
    registry.register("viewchange-test", lambda: cfg)
    params = layers.init_tree(registry.param_specs(cfg),
                              jax.random.key(0))
    from repro.models.runtime import Runtime as _R
    return [ServeEngine("viewchange-test", params, cfg,
                        EngineConfig(max_batch=2, max_len=48), _R())
            for _ in range(2)], cfg


def test_replicated_engine_survives_subscriber_failure_midrun():
    """A replica's subscriber fails mid-run: the serve session crosses
    the cut with slot holds re-pinned against the new epoch's watermarks
    — every request completes, every hold releases, tokens and per-epoch
    logs are bit-identical graph vs pallas, and the surviving subscriber
    observes every admission/token app message exactly once across the
    two epochs."""
    from repro.serve.engine import Request
    from repro.serve.fanout import ReplicatedEngine

    engines, mcfg = _fan_engines()
    results = {}
    for backend in ("graph", "pallas"):
        rep = ReplicatedEngine(engines, subscribers_per_replica=2,
                               window=4, backend=backend)
        rep.reset()
        rng = np.random.default_rng(3)
        for g in range(2):
            for i in range(3):
                rep.submit(g, Request(
                    rid=g * 10 + i,
                    prompt=rng.integers(0, mcfg.vocab_size, 3,
                                        dtype=np.int32),
                    max_new_tokens=4))
        # node 3 = second subscriber of replica-0's topic (slots 0,1 +
        # subscribers 2,3); fail it while tokens are in flight
        report = rep.run(fail_at={2: [3]})
        serve = report.extras["serve"]
        assert serve["view_changes"] == 1
        assert serve["drained"] and serve["requests"] == 6
        assert serve["tokens"] == 6 * 4
        assert serve["held_slots"] == 0
        # holds re-pinned, all released; no slot freed before its finish
        first_finish, first_free = {}, {}
        for g, slot, rnd in rep.finish_rounds:
            first_finish.setdefault((g, slot), rnd)
        for g, slot, rnd in rep.free_rounds:
            first_free.setdefault((g, slot), rnd)
        assert set(first_finish) == set(first_free)
        for key, fin in first_finish.items():
            assert first_free[key] >= fin
        results[backend] = (rep.completed(), rep.view_log,
                            report.extras["delivery_logs"])
    (tok_g, views_g, logs_g) = results["graph"]
    (tok_p, views_p, logs_p) = results["pallas"]
    assert tok_g == tok_p
    for (rn_g, v_g, _, old_g), (rn_p, v_p, _, old_p) in zip(views_g,
                                                            views_p):
        assert rn_g == rn_p and v_g == v_p
        assert set(old_g) == set(old_p)
        for name in old_g:
            assert old_g[name].delivered_seq == old_p[name].delivered_seq
    # exactly-once at the SURVIVING subscriber of replica 0 (node 2):
    # old-epoch stable prefix + final-epoch (resend + new) = everything
    _, _, old_report, old_logs = views_g[0]
    assert old_report.extras["view_change"]["resend_msgs"] > 0
    per_slot = np.zeros(2, np.int64)
    for log in (old_logs["replica-0"], logs_g["replica-0"]):
        for rank, idx, _ in log.sequence(2):
            per_slot[rank] += 1
    # replica 0 served 3 requests x (1 admission + 4 tokens) app msgs
    assert int(per_slot.sum()) == 3 * 5


# ---------------------------------------------------------------------------
# serve plane: slot-node failure + cascading waves (DESIGN.md Secs. 7, 9)
# ---------------------------------------------------------------------------


def test_replicated_engine_survives_slot_node_failure_with_cascade():
    """A SLOT (publisher) node dies mid-run, and a second suspicion wave
    lands while the wedge is in progress: exactly ONE view installs for
    the cascade (wedge re-entered once, one vid consumed), the dead
    slot's in-flight decode is voided and re-admitted at the queue head
    to restart from its prompt on a surviving slot, surviving slots
    compact onto the shrunken sender ranks, and every request still
    completes — bit-identical graph vs pallas (tokens, epoch logs,
    slot-failure records)."""
    from repro.serve.engine import Request
    from repro.serve.fanout import ReplicatedEngine

    engines, mcfg = _fan_engines()
    results = {}
    for backend in ("graph", "pallas"):
        rep = ReplicatedEngine(engines, subscribers_per_replica=2,
                               window=4, backend=backend)
        rep.reset()
        rng = np.random.default_rng(3)
        for g in range(2):
            for i in range(3):
                rep.submit(g, Request(
                    rid=g * 10 + i,
                    prompt=rng.integers(0, mcfg.vocab_size, 3,
                                        dtype=np.int32),
                    max_new_tokens=4))
        # nodes: replica 0 = slots {0,1} + subs {2,3}; replica 1 =
        # slots {4,5} + subs {6,7}.  Wave 1 kills slot node 0 and
        # subscriber 3; wave 2 (mid-wedge) kills subscriber 6.
        report = rep.run(fail_at={2: [[0, 3], [6]]})
        serve = report.extras["serve"]
        assert serve["view_changes"] == 1, "cascade must fold into ONE view"
        assert rep._ms.wedge_retries == 1
        assert rep.view_log[0][1].vid == 1
        assert serve["drained"] and serve["requests"] == 6
        assert serve["tokens"] == 6 * 4
        assert serve["held_slots"] == 0
        assert serve["slot_failures"] == 1
        assert serve["fail_at_unreached"] == []
        [rec] = serve["slot_failure_log"]
        assert (rec["replica"], rec["slot"], rec["node"]) == (0, 0, 0)
        assert rec["lost_apps"] >= 0
        # the voided decode restarted from its prompt and completed
        if rec["voided_rid"] is not None:
            assert rec["requeued"]
            assert rec["voided_rid"] in {
                r.rid for r in rep.engines[0].completed}
        # survivors compacted: slot 1 now publishes on rank 0
        assert rep._rank_slot[0] == [1]
        assert rep._slot_rank[0] == {1: 0}
        results[backend] = (rep.completed(), rep.view_log,
                            report.extras["delivery_logs"],
                            list(rep.slot_failures))
    (tok_g, views_g, logs_g, sf_g) = results["graph"]
    (tok_p, views_p, logs_p, sf_p) = results["pallas"]
    assert tok_g == tok_p and sf_g == sf_p
    for (rn_g, v_g, _, old_g), (rn_p, v_p, _, old_p) in zip(views_g,
                                                            views_p):
        assert rn_g == rn_p and v_g == v_p
        for name in old_g:
            assert old_g[name].delivered_seq == old_p[name].delivered_seq
    for name in logs_g:
        assert logs_g[name].delivered_seq == logs_p[name].delivered_seq
    # exactly-once at replica 0's surviving subscriber (node 2): the
    # dead slot's stable prefix + the surviving slot's apps across both
    # epochs + the voided request's re-decode = all 3 requests' messages
    _, _, old_report, old_logs = views_g[0]
    stable0 = old_report.extras["view_change"][
        "stable_apps_by_old_rank"][0]
    per_epoch = [sum(1 for _ in log.sequence(2))
                 for log in (old_logs["replica-0"], logs_g["replica-0"])]
    assert per_epoch[0] == int(np.asarray(stable0).sum())
    # the voided request re-publishes its FULL message set (1 admission
    # + 4 tokens) on a surviving slot while the dead slot's stable
    # prefix stays delivered; its unstable tail died with the slot:
    # total = failure-free total + the dead slot's stable prefix
    assert sf_g[0]["voided_rid"] is not None
    assert sum(per_epoch) == 3 * 5 + sf_g[0]["stable_apps"]
    # the failure record's stable count IS the closing report's
    # per-old-rank stable prefix for the dead slot (old rank 0)
    assert sf_g[0]["stable_apps"] == int(stable0[0])


def test_fail_at_unreached_rounds_surface_in_extras():
    """A fail_at round the run never reaches (the engines drained
    first) is NOT an error: it surfaces in
    extras['serve']['fail_at_unreached'] so a sampled chaos schedule
    can overshoot the drain without tripping the run."""
    from repro.serve.engine import Request
    from repro.serve.fanout import ReplicatedEngine

    engines, mcfg = _fan_engines()
    rep = ReplicatedEngine(engines, subscribers_per_replica=1,
                           window=4, backend="graph")
    rep.reset()
    rng = np.random.default_rng(5)
    for g in range(2):
        rep.submit(g, Request(
            rid=g, prompt=rng.integers(0, mcfg.vocab_size, 3,
                                       dtype=np.int32),
            max_new_tokens=3))
    report = rep.run(fail_at={500: [2], 900: [[5], [2]]})
    serve = report.extras["serve"]
    assert serve["drained"] and serve["view_changes"] == 0
    assert serve["fail_at_unreached"] == [500, 900]
    # reached rounds still fail for real: mixed with one live cut
    rep.reset()
    for g in range(2):
        rep.submit(g, Request(
            rid=10 + g, prompt=rng.integers(0, mcfg.vocab_size, 3,
                                            dtype=np.int32),
            max_new_tokens=3))
    report = rep.run(fail_at={1: [2], 700: [5]})
    serve = report.extras["serve"]
    assert serve["drained"] and serve["view_changes"] == 1
    assert serve["fail_at_unreached"] == [700]


# ---------------------------------------------------------------------------
# carry of a carry: consecutive cuts, zero intervening rounds
# ---------------------------------------------------------------------------


@fast
@pytest.mark.parametrize("backend", ["graph", "pallas", "des"])
def test_carry_of_a_carry_consecutive_cuts_zero_rounds(backend):
    """Two cuts with ZERO rounds between them: the second epoch opens
    and closes without a single sweep, so its trim is the -1 floor
    (received_num inits to -1), nothing new goes stable, the first
    carry's resend set is carried VERBATIM into the third epoch
    (merged, per-sender FIFO intact), and app_base stays put — then the
    third epoch drains everything exactly once, des-conformant."""
    spec = api.SubgroupSpec(members=(0, 1, 2, 3), senders=(0, 1, 2),
                            msg_size=512, window=4, n_messages=0)
    g0 = api.Group(api.GroupConfig(members=(0, 1, 2, 3, 4, 5),
                                   subgroups=(spec,)))
    ms = api.MembershipService(g0.cfg.members)
    stream = g0.stream(backend=backend)
    rng = np.random.default_rng(17)
    enq = np.zeros(3, np.int64)
    for _ in range(4):
        ready = np.zeros(stream.shape, np.int32)
        ready[0, :3] = rng.integers(0, 3, 3)
        enq += ready[0, :3]
        stream.step(ready)
    # cut 1: node 4 (outside the subgroup) fails -> epoch rolls, no
    # re-shape; some messages stable, the rest become resend backlog
    ms.suspect(0, 4)
    old1 = stream.group
    _, stream = ms.reconfigure_stream(stream, {})
    c1 = stream.carry
    assert c1 is not None
    base1 = c1.app_base[0].copy()
    resend1 = c1.resend[0].copy()
    np.testing.assert_array_equal(base1 + resend1, enq)
    # cut 2 IMMEDIATELY: zero intervening rounds.  Nothing could go
    # stable, so the second carry must merge the first verbatim.
    ms.suspect(0, 5)
    old2 = stream.group
    _, stream = ms.reconfigure_stream(stream, {})
    c2 = stream.carry
    # a zero-round epoch trims to the -1 floor (received_num inits to
    # -1): zero stable apps, and the cut logs nothing
    assert old2.last_report.extras["view_change"]["cut_seq"][0] == -1
    np.testing.assert_array_equal(c2.stable_apps[0],
                                  np.zeros(3, np.int64))
    np.testing.assert_array_equal(c2.resend[0], resend1)
    np.testing.assert_array_equal(c2.app_base[0], base1)  # monotone, flat
    # the zero-round epoch delivered nothing, everywhere ({} = an epoch
    # with no rounds has no logs at all)
    log2 = old2.delivery_logs.get(0)
    for node in (0, 1, 2, 3):
        assert (log2.sequence(node) if log2 else []) == []
    # third epoch: drain.  Every enqueued message lands exactly once
    # at every member, FIFO per sender, and the total delivered across
    # the three epochs is the total enqueued.
    report, logs = stream.finish()
    assert not report.stalled
    for node in (0, 1, 2, 3):
        per = np.zeros(3, np.int64)
        for ep_logs in (old1.delivery_logs[0], logs[0]):
            last = {}                  # publish idx restarts per epoch
            for rank, idx, _ in ep_logs.sequence(node):
                assert idx > last.get(rank, -1), "per-sender FIFO broke"
                last[rank] = idx
                per[rank] += 1
        np.testing.assert_array_equal(per, enq, err_msg=f"node {node}")
    # des conformance of the final epoch's resend (order-invariant)
    g_des = api.Group(stream.group.cfg)
    for rank in range(3):
        g_des.subgroup(0).send(sender=spec.senders[rank],
                               n=int(stream._enqueued[0][rank]))
    g_des.run(backend="des")
    assert _sender_apps(logs[0], 0, spec) == \
        _sender_apps(g_des.delivery_logs[0], 0, spec)


@fast
def test_carry_of_a_carry_des_roundtrip_conformance():
    """The legacy-loop leg of satellite coverage: the same double-cut
    traffic run as ONE ``des-loop`` schedule delivers the same
    per-sender app counts the stacked streams (graph, pallas AND the
    two-phase des) delivered across their three epochs.  This is the
    kept ORDER-INVARIANT test — the scheduled legacy loop paces sends
    differently from the round streams, so only counts are comparable
    (DESIGN.md Sec. 12); bit-identity for the streams themselves is
    asserted elsewhere."""
    spec = api.SubgroupSpec(members=(0, 1, 2, 3), senders=(0, 1, 2),
                            msg_size=512, window=4, n_messages=0)
    totals = {}
    for backend in ("graph", "pallas", "des"):
        g0 = api.Group(api.GroupConfig(members=(0, 1, 2, 3, 4, 5),
                                       subgroups=(spec,)))
        ms = api.MembershipService(g0.cfg.members)
        stream = g0.stream(backend=backend)
        rng = np.random.default_rng(29)
        enq = np.zeros(3, np.int64)
        epochs = []
        for cut in range(2):
            for _ in range(3):
                ready = np.zeros(stream.shape, np.int32)
                ready[0, :3] = rng.integers(0, 3, 3)
                enq += ready[0, :3]
                stream.step(ready)
            ms.suspect(0, 4 + cut)
            epochs.append(stream.group)
            _, stream = ms.reconfigure_stream(stream, {})
        report, logs = stream.finish()
        assert not report.stalled
        per = {}
        for ep_logs in [e.delivery_logs[0] for e in epochs] + [logs[0]]:
            for node_id, c in _sender_apps(ep_logs, 1, spec).items():
                per[node_id] = per.get(node_id, 0) + c
        totals[backend] = per
        assert sum(per.values()) == int(enq.sum())
    assert totals["graph"] == totals["pallas"] == totals["des"]
    g_des = api.Group(api.GroupConfig(members=(0, 1, 2, 3),
                                      subgroups=(spec,)))
    for rank, node in enumerate(spec.senders):
        g_des.subgroup(0).send(sender=node, n=totals["graph"].get(
            node, 0))
    g_des.run(backend="des-loop")
    assert _sender_apps(g_des.delivery_logs[0], 1, spec) == \
        totals["graph"]
