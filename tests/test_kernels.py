"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode.

Every kernel is validated against ref.py across a grid of shapes and both
bf16/f32; the SSD chunked algorithm is additionally validated against the
definitional step-by-step recurrence.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

pytestmark = pytest.mark.fast

jax.config.update("jax_platform_name", "cpu")


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


def _rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,s,hq,hkv,d", [
    (1, 128, 4, 4, 64),
    (2, 256, 4, 2, 64),
    (1, 384, 8, 1, 128),   # MQA + non-pow2 seq blocks
    (2, 128, 6, 2, 32),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_matches_ref(b, s, hq, hkv, d, dtype, causal):
    k1, k2, k3 = jax.random.split(jax.random.key(0), 3)
    q = _rand(k1, (b, s, hq, d), dtype)
    k = _rand(k2, (b, s, hkv, d), dtype)
    v = _rand(k3, (b, s, hkv, d), dtype)
    got = ops.flash_attention(q, k, v, causal=causal)
    group = hq // hkv
    qf = q.transpose(0, 2, 1, 3).reshape(b * hq, s, d)
    kf = k.transpose(0, 2, 1, 3).reshape(b * hkv, s, d)
    vf = v.transpose(0, 2, 1, 3).reshape(b * hkv, s, d)
    want = ref.flash_attention_ref(qf, kf, vf, group=group, causal=causal)
    want = want.reshape(b, hq, s, d).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        **_tol(dtype))


def test_flash_attention_unpadded_seq():
    # seq not a multiple of the block: wrapper pads, result must match
    b, s, h, d = 1, 200, 2, 64
    keys = jax.random.split(jax.random.key(1), 3)
    q, k, v = (_rand(kk, (b, s, h, d), jnp.float32) for kk in keys)
    got = ops.flash_attention(q, k, v, causal=True)
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, s, d)
    kf = k.transpose(0, 2, 1, 3).reshape(b * h, s, d)
    vf = v.transpose(0, 2, 1, 3).reshape(b * h, s, d)
    want = ref.flash_attention_ref(qf, kf, vf, group=1, causal=True)
    want = want.reshape(b, h, s, d).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# flash decode
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,smax,hq,hkv,d,kvlen", [
    (2, 512, 4, 4, 64, 512),
    (2, 512, 4, 2, 64, 300),    # partially-filled cache
    (1, 1024, 8, 1, 128, 7),    # nearly-empty cache
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_decode_matches_ref(b, smax, hq, hkv, d, kvlen, dtype):
    k1, k2, k3 = jax.random.split(jax.random.key(2), 3)
    q = _rand(k1, (b, hq, d), dtype)
    k = _rand(k2, (b, smax, hkv, d), dtype)
    v = _rand(k3, (b, smax, hkv, d), dtype)
    got = ops.flash_decode(q, k, v, jnp.int32(kvlen))
    group = hq // hkv
    qf = q.reshape(b * hq, 1, d)
    kf = k.transpose(0, 2, 1, 3).reshape(b * hkv, smax, d)
    vf = v.transpose(0, 2, 1, 3).reshape(b * hkv, smax, d)
    want = ref.flash_decode_ref(qf, kf, vf, kvlen, group=group)
    want = want.reshape(b, hq, d)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        **_tol(dtype))


# ---------------------------------------------------------------------------
# SSD scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,s,h,p,n,g,chunk", [
    (1, 64, 2, 16, 16, 1, 16),
    (2, 128, 4, 32, 64, 2, 32),
    (1, 96, 2, 64, 128, 1, 32),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_scan_matches_chunked_ref(b, s, h, p, n, g, chunk, dtype):
    keys = jax.random.split(jax.random.key(3), 7)
    x = _rand(keys[0], (b, s, h, p), dtype)
    dt = _rand(keys[1], (b, s, h), jnp.float32) * 0.5
    a_log = jax.random.uniform(keys[2], (h,), minval=-1.0, maxval=0.5)
    bb = _rand(keys[3], (b, s, g, n), dtype) * 0.3
    cc = _rand(keys[4], (b, s, g, n), dtype) * 0.3
    d_skip = jax.random.uniform(keys[5], (h,))
    dt_bias = jax.random.uniform(keys[6], (h,), minval=-0.5, maxval=0.5)
    y_got, st_got = ops.ssd_scan(x, dt, a_log, bb, cc, d_skip, dt_bias,
                                 chunk)
    y_ref, st_ref = ref.ssd_scan_ref(x, dt, a_log, bb, cc, d_skip, dt_bias,
                                     chunk)
    tol = dict(rtol=5e-2, atol=5e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(y_got, np.float32),
                               np.asarray(y_ref, np.float32), **tol)
    np.testing.assert_allclose(np.asarray(st_got), np.asarray(st_ref),
                               rtol=1e-3, atol=1e-3)


def test_ssd_chunked_matches_sequential():
    """The chunked algorithm == the definitional per-step recurrence."""
    b, s, h, p, n, g = 1, 32, 2, 8, 16, 1
    keys = jax.random.split(jax.random.key(4), 7)
    x = _rand(keys[0], (b, s, h, p), jnp.float32)
    dt = _rand(keys[1], (b, s, h), jnp.float32) * 0.5
    a_log = jax.random.uniform(keys[2], (h,), minval=-1.0, maxval=0.5)
    bb = _rand(keys[3], (b, s, g, n), jnp.float32) * 0.3
    cc = _rand(keys[4], (b, s, g, n), jnp.float32) * 0.3
    d_skip = jax.random.uniform(keys[5], (h,))
    dt_bias = jnp.zeros((h,))
    y_c, st_c = ref.ssd_scan_ref(x, dt, a_log, bb, cc, d_skip, dt_bias, 8)
    y_s, st_s = ref.ssd_sequential_ref(x, dt, a_log, bb, cc, d_skip,
                                       dt_bias)
    np.testing.assert_allclose(np.asarray(y_c), np.asarray(y_s),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(st_c), np.asarray(st_s),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# fused rmsnorm
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("t,d", [(256, 128), (300, 512), (1024, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rms_norm_matches_ref(t, d, dtype):
    k1, k2 = jax.random.split(jax.random.key(5))
    x = _rand(k1, (t, d), dtype)
    w = jax.random.uniform(k2, (d,), minval=0.5, maxval=1.5).astype(dtype)
    got = ops.rms_norm(x, w)
    want = ref.rms_norm_ref(x, w)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rms_norm_residual_matches_ref(dtype):
    k1, k2, k3 = jax.random.split(jax.random.key(6), 3)
    x = _rand(k1, (512, 256), dtype)
    r = _rand(k2, (512, 256), dtype)
    w = jax.random.uniform(k3, (256,), minval=0.5, maxval=1.5).astype(dtype)
    got_o, got_r = ops.rms_norm_residual(x, r, w)
    want_o, want_r = ref.rms_norm_residual_ref(x, r, w)
    np.testing.assert_allclose(np.asarray(got_o, np.float32),
                               np.asarray(want_o, np.float32),
                               **_tol(dtype))
    np.testing.assert_allclose(np.asarray(got_r, np.float32),
                               np.asarray(want_r, np.float32),
                               **_tol(dtype))


# ---------------------------------------------------------------------------
# smc sweep
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("s,w", [(8, 16), (16, 100), (5, 64)])
def test_smc_sweep_matches_ref(s, w):
    rng = np.random.default_rng(7)
    processed = rng.integers(0, 50, size=s)
    published = processed + rng.integers(0, w + 1, size=s)
    counters = np.full((s, w), -1, dtype=np.int64)
    for i in range(s):
        for k in range(published[i]):
            counters[i, k % w] = k // w
    got = ops.smc_sweep(jnp.asarray(counters), jnp.asarray(processed))
    want = ref.smc_sweep_ref(jnp.asarray(counters), jnp.asarray(processed))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    np.testing.assert_array_equal(np.asarray(got), published)


@pytest.mark.parametrize("s", [3, 5, 7, 9])
def test_smc_sweep_pallas_pads_nondivisible_senders(s):
    """The kernel itself (not just the ops wrapper) pads the sender axis:
    3- or 5-sender subgroups run instead of tripping the old assert."""
    from repro.kernels import smc_sweep as ss
    rng = np.random.default_rng(11)
    w = 16
    processed = rng.integers(0, 20, size=s)
    published = processed + rng.integers(0, w + 1, size=s)
    counters = np.asarray(ss.counters_from_counts(published, w))
    got = ss.smc_sweep_pallas(jnp.asarray(counters), jnp.asarray(processed),
                              block_senders=8, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), published)


@pytest.mark.parametrize("s,w", [(8, 16), (5, 32), (16, 100)])
def test_smc_watermark_kernel_matches_materialized_ring(s, w):
    """The in-kernel ring reconstruction is the same fixed point as
    sweeping an explicitly materialized counters_from_counts ring."""
    from repro.kernels import smc_sweep as ss
    rng = np.random.default_rng(13)
    processed = rng.integers(0, 50, size=s)
    published = processed + rng.integers(0, w + 1, size=s)
    via_ring = ops.smc_sweep(
        ss.counters_from_counts(jnp.asarray(published), w),
        jnp.asarray(processed))
    via_watermark = ops.smc_sweep_watermark(
        jnp.asarray(published), jnp.asarray(processed), window=w)
    np.testing.assert_array_equal(np.asarray(via_ring),
                                  np.asarray(via_watermark))
    np.testing.assert_array_equal(np.asarray(via_watermark), published)


@pytest.mark.parametrize("s,w", [(8, 16), (5, 32), (13, 8)])
def test_smc_watermark_kernel_validity_mask(s, w):
    """Member/sender-axis padding in the stacked path arrives at the
    kernel as a flattened lane mask: invalid lanes return ``processed``
    unchanged — whatever garbage their published watermark holds — while
    valid lanes are bit-identical to the unmasked kernel."""
    from repro.kernels import smc_sweep as ss
    rng = np.random.default_rng(17)
    processed = rng.integers(0, 50, size=s)
    published = processed + rng.integers(0, w + 1, size=s)
    valid = rng.integers(0, 2, size=s).astype(bool)
    # poison invalid lanes: advancement there would corrupt padded slots
    published = np.where(valid, published, processed + w)
    got = ss.smc_sweep_watermark_pallas(
        jnp.asarray(published), jnp.asarray(processed), window=w,
        valid=jnp.asarray(valid), interpret=True)
    want = np.where(valid, published, processed)
    np.testing.assert_array_equal(np.asarray(got), want)


def test_smc_watermark_kernel_full_mask_matches_unmasked():
    from repro.kernels import smc_sweep as ss
    rng = np.random.default_rng(19)
    s, w = 7, 16
    processed = rng.integers(0, 20, size=s)
    published = processed + rng.integers(0, w + 1, size=s)
    masked = ss.smc_sweep_watermark_pallas(
        jnp.asarray(published), jnp.asarray(processed), window=w,
        valid=jnp.ones(s, bool), interpret=True)
    plain = ss.smc_sweep_watermark_pallas(
        jnp.asarray(published), jnp.asarray(processed), window=w,
        interpret=True)
    np.testing.assert_array_equal(np.asarray(masked), np.asarray(plain))


# ---------------------------------------------------------------------------
# model integration: pallas impl == xla impl end to end
# ---------------------------------------------------------------------------

def test_attention_impl_parity():
    from repro.models import attention, registry
    import dataclasses as dc
    from repro.models import layers as L
    from repro.models.runtime import Runtime
    cfg = registry.get("qwen3-1.7b").cfg.reduced()
    cfg = dc.replace(cfg, head_dim=64)
    p = L.init_tree(attention.attn_specs(cfg), jax.random.key(8))
    x = _rand(jax.random.key(9), (2, 128, cfg.d_model), jnp.float32)
    out_x = attention.full_attention(p, cfg, x, impl="xla")
    out_p = attention.full_attention(p, cfg, x, impl="pallas")
    np.testing.assert_allclose(np.asarray(out_x, np.float32),
                               np.asarray(out_p, np.float32),
                               rtol=2e-2, atol=2e-2)
