"""Unified Group API: cross-backend conformance, delivery upcalls,
explicit sends, app/null accounting, view-driven reconfiguration, and the
deprecated Domain.sim_config shim.

The load-bearing property: one GroupConfig scenario runs unmodified on the
``des`` (discrete-event), ``graph`` (fused-sweep scan) and ``pallas``
(SMC-kernel receive) backends and yields the SAME delivered round-robin
sequence and app/null accounting — the seam every later scaling PR plugs
into.
"""

import dataclasses
import warnings

import numpy as np
import pytest

from repro import api
from repro.core import delivery, dds
from repro.core import simulator as sim_mod

pytestmark = pytest.mark.fast


def _cfg(**kw):
    base = dict(n_senders=3, msg_size=1024, window=16, n_messages=20)
    base.update(kw)
    n = base.pop("n_nodes", 4)
    return api.single_group(n, **base)


def _run(cfg, backend):
    g = api.Group(cfg)
    return g, g.run(backend=backend)


# ---------------------------------------------------------------------------
# cross-backend conformance
# ---------------------------------------------------------------------------

def test_des_and_graph_agree_on_delivered_sequence():
    cfg = _cfg()
    gd, rd = _run(cfg, "des")
    gg, rg = _run(cfg, "graph")
    for node in cfg.members:
        assert gd.subgroup(0).delivered(node) == \
            gg.subgroup(0).delivered(node)
    assert rd.delivered_app_msgs == rg.delivered_app_msgs == 4 * 3 * 20
    assert rd.delivered_null_msgs == rg.delivered_null_msgs
    assert not rd.stalled and not rg.stalled


def test_des_and_graph_agree_with_inactive_sender_nulls():
    """The null-send path: an inactive sender is covered by nulls on both
    substrates with identical app subsequences and null accounting."""
    pats = (((0, 1), api.SenderPattern(active=False)),)
    cfg = _cfg(n_messages=15, patterns=pats, target_delivered=2 * 15)
    gd, rd = _run(cfg, "des")
    gg, rg = _run(cfg, "graph")
    assert rd.nulls_sent > 0 and rg.nulls_sent > 0
    assert rd.nulls_sent == rg.nulls_sent
    assert rd.delivered_null_msgs == rg.delivered_null_msgs > 0
    for node in cfg.members:
        assert gd.subgroup(0).delivered(node) == \
            gg.subgroup(0).delivered(node)


def test_target_delivered_clips_both_backends_to_same_point():
    """target_delivered is a measurement window: both substrates clip the
    delivery log at the target-th app message, so sequences stay
    comparable even though the DES stops on simulated time."""
    cfg = _cfg(n_messages=30, target_delivered=10)
    gd, rd = _run(cfg, "des")
    gg, rg = _run(cfg, "graph")
    assert rd.delivered_app_msgs == rg.delivered_app_msgs == 4 * 10
    for node in cfg.members:
        assert gd.subgroup(0).delivered(node) == \
            gg.subgroup(0).delivered(node)
    assert not rd.stalled and not rg.stalled


def test_small_window_throttling_conforms():
    """A tiny ring window throttles publishing; the graph lowering must
    requeue (not drop) window-capped sends, like the DES app queue."""
    cfg = _cfg(window=2, n_messages=20)
    gd, rd = _run(cfg, "des")
    gg, rg = _run(cfg, "graph")
    assert rd.delivered_app_msgs == rg.delivered_app_msgs == 4 * 3 * 20
    assert not rd.stalled and not rg.stalled
    for node in cfg.members:
        assert gd.subgroup(0).delivered(node) == \
            gg.subgroup(0).delivered(node)


def test_sim_config_roundtrip_preserves_des_knobs():
    cfg = sim_mod.single_subgroup(4, n_messages=5, upcall_extra_us=7.0,
                                  max_sweeps=999, idle_tick_us=3.0,
                                  llc_bytes=123)
    back = api.GroupConfig.from_sim_config(cfg).to_sim_config()
    assert (back.upcall_extra_us, back.max_sweeps,
            back.idle_tick_us, back.llc_bytes) == (7.0, 999, 3.0, 123)


def test_reconfigure_remaps_gids_when_a_subgroup_dies():
    """Dropping a subgroup whose members all failed must re-key surviving
    subgroups' patterns and upcall registrations to their new gids."""
    spec_a = api.SubgroupSpec(members=(4, 5), senders=(4, 5),
                              msg_size=64, window=8, n_messages=3)
    spec_b = api.SubgroupSpec(members=(0, 1, 2), senders=(0, 1),
                              msg_size=64, window=8, n_messages=3)
    pats = (((1, 1), api.SenderPattern(active=False)),)
    g = api.Group(api.GroupConfig(members=(0, 1, 2, 4, 5),
                                  subgroups=(spec_a, spec_b),
                                  patterns=pats))
    hits = []
    g.subgroup(1).on_delivery(lambda m, d: hits.append(m))
    g2 = g.reconfigure(api.View(vid=1, members=(0, 1, 2),
                                senders=(0, 1, 2)))
    assert len(g2.cfg.subgroups) == 1          # subgroup A died with 4, 5
    assert g2.cfg.patterns == (((0, 1), pats[0][1]),)   # re-keyed to gid 0
    r = g2.run(backend="graph")
    assert hits                                 # upcalls followed the gid
    # sender 1 stays inactive through the re-keyed pattern
    assert r.delivered_app_msgs == 3 * 3


def test_pallas_backend_matches_graph_exactly():
    """The kernel-receive path is the same protocol fixed point: delivered
    sequences and every count agree with the graph backend."""
    cfg = _cfg(n_messages=12)
    gg, rg = _run(cfg, "graph")
    gp, rp = _run(cfg, "pallas")
    assert rp.backend == "pallas"
    for node in cfg.members:
        assert gg.subgroup(0).delivered(node) == \
            gp.subgroup(0).delivered(node)
    assert (rg.delivered_app_msgs, rg.delivered_null_msgs, rg.nulls_sent) \
        == (rp.delivered_app_msgs, rp.delivered_null_msgs, rp.nulls_sent)


def test_every_backend_returns_populated_report():
    cfg = _cfg(n_messages=10)
    for backend in ("des", "graph", "pallas"):
        _, r = _run(cfg, backend)
        assert r.backend == backend
        assert r.delivered_app_msgs == 4 * 3 * 10
        assert r.throughput_GBps > 0
        assert r.mean_latency_us > 0
        assert r.p99_latency_us >= r.mean_latency_us
        assert r.rdma_writes > 0
        assert r.duration_us > 0
        assert not r.stalled
        assert isinstance(r.summary(), dict)


def test_unknown_backend_rejected():
    with pytest.raises(KeyError):
        api.Group(_cfg()).run(backend="quantum")


# ---------------------------------------------------------------------------
# sends + upcalls
# ---------------------------------------------------------------------------

def test_explicit_sends_override_scenario_default():
    cfg = _cfg(n_senders=2, n_messages=0)
    for backend in ("des", "graph"):
        g = api.Group(cfg)
        h = g.subgroup(0)
        h.ordered_send(sender=0, n=7)
        h.send(sender=1, n=3)
        r = g.run(backend=backend)
        assert r.delivered_app_msgs == 4 * 10, backend
        assert not r.stalled


def test_run_overrides_apply_consistently_across_backends():
    """Per-run **overrides must feed the send-count lowering too, so the
    same override yields the same result on every backend."""
    pat = (((0, 1), api.SenderPattern(active=False)),)
    results = {}
    for backend in ("des", "graph"):
        g = api.Group(api.single_group(4, n_senders=2, msg_size=256,
                                       window=8, n_messages=10))
        results[backend] = g.run(backend, patterns=pat,
                                 target_delivered=10).delivered_app_msgs
    assert results["des"] == results["graph"] == 4 * 10


def test_multi_subgroup_target_delivered_conforms_with_des():
    """The stacked path runs every subgroup on ONE shared round timeline,
    so the cross-subgroup target_delivered window (a per-member aggregate
    across subgroups, like Simulator._done) is now supported on
    graph/pallas.  The des backend stops on simulated time, so its
    per-subgroup cut points are timing-dependent; conformance is (a) every
    member reaches the target summed across subgroups on every backend,
    (b) each subgroup's delivered app sequence is prefix-consistent with
    the des backend's (both are prefixes of the same total order), and
    (c) graph and pallas agree bit-identically."""
    spec = api.SubgroupSpec(members=(0, 1, 2, 3), senders=(0, 1),
                            msg_size=256, window=8, n_messages=30)
    cfg = api.GroupConfig(members=(0, 1, 2, 3), subgroups=(spec, spec),
                          target_delivered=40)
    groups, reports = {}, {}
    for backend in ("des", "graph", "pallas"):
        groups[backend], reports[backend] = _run(cfg, backend)
    for backend, g in groups.items():
        assert not reports[backend].stalled, backend
        for node in cfg.members:
            total = sum(g.delivery_logs[gid].app_null_counts(node)[0]
                        for gid in (0, 1))
            assert total >= 40, (backend, node, total)
    for gid in (0, 1):
        for node in cfg.members:
            des_seq = groups["des"].subgroup(gid).delivered(node)
            graph_seq = groups["graph"].subgroup(gid).delivered(node)
            k = min(len(des_seq), len(graph_seq))
            assert des_seq[:k] == graph_seq[:k], (gid, node)
            assert groups["pallas"].subgroup(gid).delivered(node) == \
                graph_seq, (gid, node)


def test_explicit_send_takes_over_pattern_budgets():
    pats = (((0, 1), api.SenderPattern(n_messages=50)),)
    g = api.Group(api.single_group(3, n_senders=2, msg_size=256, window=8,
                                   n_messages=0, patterns=pats))
    g.subgroup(0).send(sender=0, n=5)
    r = g.run(backend="graph")
    # sender 1's 50-message pattern budget is replaced, not mixed in
    assert r.delivered_app_msgs == 3 * 5


def test_send_rejects_non_sender():
    g = api.Group(_cfg(n_senders=2))
    with pytest.raises(ValueError):
        g.subgroup(0).send(sender=3)


def test_explicit_sends_conflict_with_sender_override_is_loud():
    """An override that changes the sender set cannot silently discard
    queued explicit sends."""
    g = api.Group(_cfg(n_senders=2, n_messages=5))
    g.subgroup(0).send(sender=0, n=7)
    bigger = dataclasses.replace(g.cfg.subgroups[0], senders=(0, 1, 2))
    with pytest.raises(ValueError):
        g.run(backend="graph", subgroups=(bigger,))


def test_delivery_upcalls_fire_in_total_order():
    cfg = _cfg(n_senders=2, n_messages=5, n_nodes=3)
    g = api.Group(cfg)
    got = []
    g.subgroup(0).on_delivery(
        lambda member, d: got.append((member, d.seq)))
    g.run(backend="graph")
    assert got, "no upcalls fired"
    per_member = {}
    for member, seq in got:
        assert seq == per_member.get(member, -1) + 1  # gapless, in order
        per_member[member] = seq
    assert set(per_member) == set(cfg.subgroups[0].members)
    assert all(v == 2 * 5 - 1 for v in per_member.values())


# ---------------------------------------------------------------------------
# app/null accounting (the real split_app_and_null)
# ---------------------------------------------------------------------------

def test_split_app_and_null_counts():
    batch = delivery.DeliveryBatch(lo_seq=0, hi_seq=5, n_senders=2)
    # sender 0: app, app, null; sender 1: app, null, null
    is_app = [np.array([True, True, False]),
              np.array([True, False, False])]
    n_app, n_null = delivery.split_app_and_null(batch, is_app)
    assert (n_app, n_null) == (3, 3)
    empty = delivery.DeliveryBatch(lo_seq=0, hi_seq=-1, n_senders=2)
    assert delivery.split_app_and_null(empty, is_app) == (0, 0)


def test_report_app_null_accounting_matches_logs():
    pats = (((0, 2), api.SenderPattern(active=False)),)
    cfg = _cfg(n_messages=10, patterns=pats, target_delivered=2 * 10)
    g, r = _run(cfg, "graph")
    log = g.delivery_logs[0]
    total_app = sum(log.app_null_counts(n)[0] for n in cfg.members)
    total_null = sum(log.app_null_counts(n)[1] for n in cfg.members)
    assert (r.delivered_app_msgs, r.delivered_null_msgs) == \
        (total_app, total_null)
    assert total_null > 0


# ---------------------------------------------------------------------------
# reconfiguration through MembershipService
# ---------------------------------------------------------------------------

def test_membership_service_drives_group_reconfiguration():
    ms = api.MembershipService([0, 1, 2, 3])
    g = api.Group(_cfg(n_messages=8))
    view, g2 = ms.reconfigure(g, {m: 1 for m in range(4)})
    assert g2 is g and view.vid == 0          # nothing pending: no-op
    ms.suspect(0, 2)
    view, g2 = ms.reconfigure(g, {0: 5, 1: 5, 3: 5})
    assert view.vid == 1 and 2 not in view.members
    assert g2 is not g
    assert g2.cfg.epoch == g.cfg.epoch + 1
    spec = g2.cfg.subgroups[0]
    assert 2 not in spec.members and 2 not in spec.senders
    r = g2.run(backend="des")
    assert not r.stalled
    # 3 surviving members x 2 surviving senders (rank 2 failed) x 8 msgs
    assert r.delivered_app_msgs == 3 * 2 * 8


@pytest.mark.parametrize("backend", ["graph", "pallas"])
def test_reconfigure_multi_subgroup_across_view_changes(backend):
    """Virtual-synchrony reconfiguration on the STACKED substrate: a
    multi-subgroup group survives two successive view changes on
    graph/pallas (previously only des-exercised), with each epoch's
    delivered sequences conforming to the des backend and upcalls
    following the remapped gids."""
    spec_a = api.SubgroupSpec(members=(0, 1, 2), senders=(0, 1),
                              msg_size=512, window=8, n_messages=6)
    spec_b = api.SubgroupSpec(members=(1, 2, 3, 4), senders=(3, 4),
                              msg_size=256, window=4, n_messages=5)
    spec_c = api.SubgroupSpec(members=(3, 4), senders=(3,),
                              msg_size=128, window=4, n_messages=4)
    g = api.Group(api.GroupConfig(members=(0, 1, 2, 3, 4),
                                  subgroups=(spec_a, spec_b, spec_c)))
    hits = []
    g.subgroup(1).on_delivery(lambda m, d: hits.append((m, d.subgroup)))
    for vid, survivors in ((1, (0, 1, 2, 3)), (2, (1, 2, 3))):
        g = g.reconfigure(api.View(vid=vid, members=survivors,
                                   senders=survivors))
        r = g.run(backend=backend)
        assert not r.stalled, (backend, vid)
        gd = api.Group(g.cfg)
        gd.run(backend="des")
        for gid, spec in enumerate(g.cfg.subgroups):
            for node in spec.members:
                assert g.subgroup(gid).delivered(node) == \
                    gd.subgroup(gid).delivered(node), (backend, vid, gid)
    assert g.cfg.epoch == 2
    assert hits, "upcalls did not follow the remapped gid"
    # after node 0 and 4 fail, subgroup B survives as (1, 2, 3); its
    # upcalls keep firing under the remapped gid
    assert {m for m, _ in hits} <= {1, 2, 3}


def test_many_topic_domain_runs_stacked():
    """A 16-topic DDS domain lowers to one 16-subgroup stacked program
    and its per-topic delivery matches the des backend."""
    from repro.core import group as group_mod

    d = dds.many_topic_domain(6, 16, subscribers_per_topic=2,
                              sample_size=512, window=8)
    g = d.group(samples_per_publisher=5)
    assert g.n_subgroups == 16
    g.run(backend="graph")                     # warm the program cache
    before = len(group_mod.TRACE_EVENTS)
    g2 = d.group(samples_per_publisher=5)
    r = g2.run(backend="graph")
    assert len(group_mod.TRACE_EVENTS) == before, \
        "warm 16-topic run re-traced (not one cached stacked program)"
    assert not r.stalled
    # every topic delivers publisher's 5 samples at its 3 members
    assert r.delivered_app_msgs == 16 * 3 * 5
    gd = d.group(samples_per_publisher=5)
    gd.run(backend="des")
    for gid in range(16):
        for node in d.topics[gid].members:
            assert g2.subgroup(gid).delivered(node) == \
                gd.subgroup(gid).delivered(node), (gid, node)


def test_reconfigure_carries_upcalls_not_logs():
    g = api.Group(_cfg(n_messages=4))
    hits = []
    g.subgroup(0).on_delivery(lambda m, d: hits.append(m))
    g.run(backend="graph")
    n_before = len(hits)
    assert n_before > 0
    g2 = g.reconfigure(api.View(vid=1, members=(0, 1, 2),
                                senders=(0, 1, 2)))
    assert g2.delivery_logs == {}
    g2.run(backend="graph")
    assert len(hits) > n_before               # registration carried over


# ---------------------------------------------------------------------------
# dds integration + deprecated shim
# ---------------------------------------------------------------------------

def test_domain_group_runs_on_des_and_graph():
    d = dds.single_topic_domain(4, 3)
    for backend in ("des", "graph"):
        r = d.group(samples_per_publisher=15).run(backend=backend)
        # 1 publisher x 15 samples delivered at all 4 members
        assert r.delivered_app_msgs == 4 * 15
        assert not r.stalled


def test_domain_sim_config_shim_still_works_and_warns_exactly_once():
    d = dds.single_topic_domain(4, 3)
    dds._SIM_CONFIG_WARNED = False             # fresh once-per-process state
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        cfg = d.sim_config(samples_per_publisher=15)
        d.sim_config(samples_per_publisher=15)  # second call: silent
    deprecations = [x for x in w
                    if issubclass(x.category, DeprecationWarning)]
    assert len(deprecations) == 1
    # the shim lowers to exactly what the des backend runs
    assert cfg.n_nodes == 4
    assert cfg.subgroups == d.group(
        samples_per_publisher=15).cfg.to_sim_config().subgroups
    from repro.core import simulator as sim
    r = sim.run(dataclasses.replace(cfg))
    assert r.delivered_app_msgs == 4 * 15


# ---------------------------------------------------------------------------
# config plumbing
# ---------------------------------------------------------------------------

def test_group_config_roundtrips_through_sim_config():
    cfg = _cfg()
    back = api.GroupConfig.from_sim_config(cfg.to_sim_config())
    assert back.subgroups == cfg.subgroups
    assert back.flags == cfg.flags
    assert back.members == cfg.members


def test_subgroup_outside_membership_rejected():
    spec = api.SubgroupSpec(members=(0, 5), senders=(0,))
    with pytest.raises(AssertionError):
        api.GroupConfig(members=(0, 1), subgroups=(spec,))
