"""Per-architecture smoke tests: a REDUCED config of each family runs one
train step (loss finite, grads finite) and one decode step (shapes right,
no NaNs) on CPU.  Full configs are only ever lowered via the dry-run.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import layers, registry
from repro.models.config import ModelConfig, ShapeConfig
from repro.models.runtime import CPU_RUNTIME as RT

jax.config.update("jax_platform_name", "cpu")

ARCHS = list(registry.names())
SMOKE_SHAPE = ShapeConfig("smoke", seq_len=32, global_batch=2, kind="train")
DECODE_SHAPE = ShapeConfig("smoke_dec", seq_len=32, global_batch=2,
                           kind="decode")


def _batch_for(cfg: ModelConfig, key) -> dict:
    b, s = SMOKE_SHAPE.global_batch, SMOKE_SHAPE.seq_len
    k1, k2 = jax.random.split(key)
    if cfg.family == "encdec":
        return {
            "frames": jax.random.normal(k1, (b, s // 2, cfg.d_model),
                                        jnp.float32).astype(jnp.bfloat16),
            "tokens": jax.random.randint(k2, (b, s // 2), 0,
                                         cfg.vocab_size),
        }
    if cfg.family == "vlm":
        n_p = cfg.vlm.n_patches
        return {
            "patches": jax.random.normal(
                k1, (b, n_p, cfg.vlm.vision_dim),
                jnp.float32).astype(jnp.bfloat16),
            "tokens": jax.random.randint(k2, (b, s - n_p), 0,
                                         cfg.vocab_size),
        }
    return {"tokens": jax.random.randint(k1, (b, s), 0, cfg.vocab_size)}


@pytest.fixture(scope="module")
def reduced(request):
    return {}


def _init(cfg):
    specs = registry.param_specs(cfg)
    return layers.init_tree(specs, jax.random.key(0))


@pytest.mark.parametrize("name", ARCHS)
def test_train_step_smoke(name):
    arch = registry.get(name)
    cfg = arch.cfg.reduced()
    params = _init(cfg)
    batch = _batch_for(cfg, jax.random.key(1))
    loss_fn = arch.loss_fn()

    @jax.jit
    def step(p, b):
        loss, grads = jax.value_and_grad(
            lambda pp: loss_fn(pp, cfg, b, RT))(p)
        return loss, grads

    loss, grads = step(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{name}: non-finite loss"
    # a sensible CE magnitude for random init: ~log(vocab)
    assert 0.1 < float(loss) < 3 * np.log(cfg.vocab_size) + 5
    finite = jax.tree.map(lambda g: bool(jnp.all(jnp.isfinite(
        g.astype(jnp.float32)))), grads)
    assert all(jax.tree.leaves(finite)), f"{name}: non-finite grads"
    nonzero = sum(float(jnp.sum(jnp.abs(g.astype(jnp.float32))))
                  for g in jax.tree.leaves(grads))
    assert nonzero > 0, f"{name}: all-zero grads"


@pytest.mark.parametrize("name", ARCHS)
def test_decode_step_smoke(name):
    arch = registry.get(name)
    cfg = arch.cfg.reduced()
    params = _init(cfg)
    b = DECODE_SHAPE.global_batch
    cache_specs = registry.cache_specs(cfg, DECODE_SHAPE, batch_override=b)
    cache = jax.tree.map(
        lambda s: jnp.zeros(s.shape, s.dtype), cache_specs,
        is_leaf=lambda x: isinstance(x, layers.ParamSpec))
    tokens = jax.random.randint(jax.random.key(3), (b, 1), 0,
                                cfg.vocab_size)
    decode = arch.decode_fn()
    pos = jnp.int32(DECODE_SHAPE.seq_len - 1)

    @jax.jit
    def step(p, c, t):
        return decode(p, cfg, c, t, pos, RT)

    logits, new_cache = step(params, cache, tokens)
    assert logits.shape == (b, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    # cache structure is preserved (donation-compatible)
    assert jax.tree.structure(new_cache) == jax.tree.structure(cache)
    for a, b_ in zip(jax.tree.leaves(new_cache), jax.tree.leaves(cache)):
        assert a.shape == b_.shape and a.dtype == b_.dtype


@pytest.mark.parametrize("name", ARCHS)
def test_input_specs_cover_shapes(name):
    """Every non-skipped (arch x shape) cell has well-defined input specs."""
    from repro.models.config import SHAPES
    arch = registry.get(name)
    for shape in SHAPES:
        if arch.skip_reason(shape):
            continue
        specs = arch.input_specs(shape)
        assert specs, (name, shape.name)
        for k, v in specs.items():
            assert all(d > 0 for d in v.shape), (name, shape.name, k)
        if shape.is_decode:
            cache = arch.cache_specs(shape)
            assert jax.tree.leaves(cache), (name, shape.name)


def test_decode_matches_prefill_dense():
    """Decode with a prefilled cache reproduces full-forward logits."""
    from repro.models import transformer as T
    arch = registry.get("qwen3-1.7b")
    cfg = arch.cfg.reduced()
    params = _init(cfg)
    tokens = jax.random.randint(jax.random.key(5), (2, 12), 0,
                                cfg.vocab_size)
    # full forward logits at the last position
    x = T.embed(params, cfg, tokens, RT)
    x, _ = T.forward(params, cfg, x, RT)
    want = T.unembed(params, cfg, x[:, -1:], RT)[:, 0]
    # prefill on the prefix, then decode the last token
    logits_p, cache = T.prefill(params, cfg, tokens[:, :-1], RT)
    pad = 4
    cache = {k: jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
             for k, v in cache.items()}
    got, _ = T.decode_step(params, cfg, cache, tokens[:, -1:],
                           jnp.int32(11), RT)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=0.05, atol=0.05)
