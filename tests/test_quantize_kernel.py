"""int8 block-quantize Pallas kernel vs oracle + roundtrip error bounds.

Property cases come from seeded numpy generators (no hypothesis in the
container)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.quantize import dequantize_pallas, quantize_pallas

pytestmark = pytest.mark.fast

jax.config.update("jax_platform_name", "cpu")


def _ref_quant(x, block):
    xb = np.asarray(x, np.float32).reshape(-1, block)
    scales = np.maximum(np.abs(xb).max(axis=1), 1e-12) / 127.0
    q = np.clip(np.round(xb / scales[:, None]), -127, 127).astype(np.int8)
    return q.reshape(-1), scales


@pytest.mark.parametrize("n,block", [(2048, 2048), (8192, 2048),
                                     (4096, 512)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_quantize_matches_ref(n, block, dtype):
    x = (jax.random.normal(jax.random.key(0), (n,), jnp.float32) * 3
         ).astype(dtype)
    q, s = quantize_pallas(x, block=block)
    qr, sr = _ref_quant(x.astype(jnp.float32), block)
    np.testing.assert_allclose(np.asarray(s), sr, rtol=1e-6)
    # rounding at .5 boundaries may differ by 1 ulp between paths
    assert np.max(np.abs(np.asarray(q, np.int32) - qr.astype(np.int32))) \
        <= 1


@pytest.mark.parametrize("case", range(10))
def test_roundtrip_error_bounded(case):
    rng = np.random.default_rng(33_000 + case)
    nblocks = int(rng.integers(1, 9))
    # log-uniform over [0.01, 100]: scale magnitudes spanning 4 decades
    scale_mag = float(10.0 ** rng.uniform(-2, 2))
    block = 512
    x = jax.random.normal(jax.random.key(nblocks), (nblocks * block,),
                          jnp.float32) * scale_mag
    q, s = quantize_pallas(x, block=block)
    back = dequantize_pallas(q, s, block=block)
    absmax = np.abs(np.asarray(x)).reshape(nblocks, block).max(axis=1)
    bound = np.repeat(absmax / 127.0, block) * 0.5 + 1e-9
    err = np.abs(np.asarray(back) - np.asarray(x))
    assert np.all(err <= bound + 1e-6)


def test_zero_input_is_exact():
    x = jnp.zeros((2048,), jnp.float32)
    q, s = quantize_pallas(x)
    back = dequantize_pallas(q, s)
    np.testing.assert_array_equal(np.asarray(back), 0.0)


def test_kernel_matches_gradsync_inline_path():
    """The Pallas kernel and the in-graph quantizer used by
    compressed_psum_mean agree (the kernel is the real-TPU fast path for
    the same math)."""
    from repro.core.gradsync import _quantize_int8
    x = jax.random.normal(jax.random.key(9), (2048,), jnp.float32) * 7
    q_k, s_k = quantize_pallas(x, block=2048)
    q_g, s_g = _quantize_int8(x)
    np.testing.assert_allclose(float(s_k[0]), float(s_g), rtol=1e-6)
    assert np.max(np.abs(np.asarray(q_k, np.int32)
                         - np.asarray(q_g, np.int32))) <= 1
