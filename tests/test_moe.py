"""MoE dispatch/properties: capacity, first-choice priority, weight
normalization, drop semantics, and expert-parallel slice equivalence.

Property cases come from seeded numpy generators (no hypothesis in the
container; tests/conftest.py enforces a ~0 skip budget)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import layers, moe
from repro.models.config import ModelConfig, MoEConfig

pytestmark = pytest.mark.fast

jax.config.update("jax_platform_name", "cpu")


def _cfg(n_routed=8, top_k=2, n_shared=0, cap=1.25, pad=None):
    return ModelConfig(
        name="m", family="moe", n_layers=1, d_model=32, n_heads=2,
        n_kv_heads=2, d_ff=0, vocab_size=64, head_dim=16,
        moe=MoEConfig(n_routed=n_routed, top_k=top_k, n_shared=n_shared,
                      d_ff_expert=16, capacity_factor=cap, ep_pad_to=pad))


@pytest.mark.parametrize("case", range(20))
def test_dispatch_tables_capacity_and_validity(case):
    rng = np.random.default_rng(31_000 + case)
    t = int(rng.integers(4, 65))
    e = int(rng.integers(2, 9))
    k = min(int(rng.integers(1, 4)), e)
    key = jax.random.key(t * 131 + e)
    # distinct experts per token, like a real top_k
    scores = jax.random.normal(key, (t, e))
    idx = jnp.argsort(-scores, axis=-1)[:, :k]
    w = jax.nn.softmax(jax.random.normal(key, (t, k)), axis=-1)
    cap = max(2, t * k // e)
    tok, wt, valid = moe.dispatch_tables(idx, w, e, cap, t)
    tok, wt, valid = map(np.asarray, (tok, wt, valid))
    # every valid slot points at a real token; invalid slots are OOB
    assert tok.shape == (e, cap)
    assert np.all(tok[valid] < t) and np.all(tok[valid] >= 0)
    assert np.all(tok[~valid] == t)
    assert np.all(wt[~valid] == 0)
    # no expert exceeds capacity and no (token, expert) pair duplicates
    for ei in range(e):
        toks = tok[ei][valid[ei]]
        assert len(set(toks.tolist())) == len(toks)


def test_dispatch_first_choice_priority():
    """When an expert is oversubscribed, first-choice (k=0) assignments
    win slots before second choices."""
    t, e, cap = 6, 2, 3
    # tokens 0..2 first-choice expert 0; tokens 3..5 second-choice expert 0
    idx = jnp.array([[0, 1]] * 3 + [[1, 0]] * 3)
    w = jnp.full((t, 2), 0.5)
    tok, wt, valid = moe.dispatch_tables(idx, w, e, cap, t)
    slot_tokens = set(np.asarray(tok)[0][np.asarray(valid)[0]].tolist())
    assert slot_tokens == {0, 1, 2}   # first choices took every slot


def test_route_weights_normalized():
    cfg = _cfg()
    p = layers.init_tree(moe.moe_specs(cfg), jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (16, 32))
    idx, w, aux = moe.route(p, cfg, x)
    np.testing.assert_allclose(np.asarray(w.sum(-1)), 1.0, rtol=1e-5)
    assert np.asarray(idx).max() < cfg.moe.n_routed
    assert float(aux) >= 0.0


def test_moe_block_output_finite_and_shaped():
    cfg = _cfg(n_shared=2)
    p = layers.init_tree(moe.moe_specs(cfg), jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (2, 8, 32)).astype(
        jnp.bfloat16)
    y, aux = moe.moe_block(p, cfg, x, ep_axis=None)
    assert y.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(y.astype(jnp.float32))))


def test_ep_slices_sum_to_whole():
    """Running each expert-parallel rank's slice locally and psumming
    (here: adding) equals the single-rank computation — the EP invariant
    the shard_map path relies on."""
    cfg = _cfg(n_routed=8, top_k=2)
    p = layers.init_tree(moe.moe_specs(cfg), jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (24, 32)).astype(jnp.float32)

    whole, aux_w = moe._moe_ffn_sharded(p, cfg, x, jnp.int32(0), 1)

    ep = 4
    e_local = 8 // ep
    partial_sum = jnp.zeros_like(whole)
    for r in range(ep):
        p_slice = dict(p)
        for kname in ("w_gate", "w_up", "w_down"):
            p_slice[kname] = p[kname][r * e_local:(r + 1) * e_local]
        part, aux_r = moe._moe_ffn_sharded(p_slice, cfg, x,
                                           jnp.int32(r), ep)
        partial_sum = partial_sum + part
        np.testing.assert_allclose(float(aux_r), float(aux_w), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(partial_sum),
                               np.asarray(whole), rtol=2e-3, atol=2e-3)


def test_ep_padding_never_routes():
    """qwen2-moe pads 60 experts to 64 EP slots; the router must never
    select a pad slot."""
    cfg = _cfg(n_routed=6, top_k=2, pad=8)
    p = layers.init_tree(moe.moe_specs(cfg), jax.random.key(0))
    assert p["w_gate"].shape[0] == 8          # padded expert bank
    x = jax.random.normal(jax.random.key(1), (64, 32))
    idx, w, _ = moe.route(p, cfg, x)
    assert int(jnp.max(idx)) < 6              # router logits only cover 6


def test_dropped_tokens_contribute_zero():
    """With capacity factor << 1 most tokens drop; output stays finite and
    dropped tokens' outputs are exactly zero."""
    cfg = _cfg(n_routed=2, top_k=1, cap=0.1)
    p = layers.init_tree(moe.moe_specs(cfg), jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (64, 32)).astype(jnp.float32)
    y, _ = moe._moe_ffn_sharded(p, cfg, x, jnp.int32(0), 1)
    y = np.asarray(y)
    nonzero_rows = int((np.abs(y).sum(-1) > 0).sum())
    cap = moe._capacity(64, cfg)
    assert nonzero_rows <= 2 * cap            # at most E x C served
    assert np.all(np.isfinite(y))
