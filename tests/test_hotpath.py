"""Hot-path coverage for the compiled graph/pallas substrate:

* jit-cache behaviour — a second ``Group.run`` with the same static key
  ``(n_members, n_senders, window, null_send, backend)`` must NOT re-trace
  the scan program (asserted through the trace-counter side effect in
  ``group.TRACE_EVENTS``);
* vectorized delivery-log reconstruction — property-tested against the
  old per-message reference loop on random traces;
* batched multi-scenario execution — ``Group.run_batch`` must reproduce
  looped ``Group.run`` exactly (identical RunReport counts and
  byte-identical delivery logs) on every backend, including the
  sequential-fallback ``des`` path.
"""

import dataclasses

import numpy as np
import pytest

from repro import api
from repro.core import group as group_mod
from repro.core import sst

pytestmark = pytest.mark.fast


def _cfg(**kw):
    base = dict(n_senders=3, msg_size=1024, window=16, n_messages=15)
    base.update(kw)
    n = base.pop("n_nodes", 4)
    return api.single_group(n, **base)


def _logs_equal(a, b):
    return (a.n_senders == b.n_senders
            and a.delivered_seq == b.delivered_seq
            and len(a.is_app) == len(b.is_app)
            and all(np.array_equal(x, y)
                    for x, y in zip(a.is_app, b.is_app)))


# ---------------------------------------------------------------------------
# jit cache: compile once per static key
# ---------------------------------------------------------------------------

def test_second_run_with_same_static_key_does_not_retrace():
    cfg = _cfg(window=13)                # a window no other test uses
    api.Group(cfg).run(backend="graph")  # may or may not trace (cold cache)
    before = len(group_mod.TRACE_EVENTS)
    r = api.Group(cfg).run(backend="graph")
    assert len(group_mod.TRACE_EVENTS) == before, \
        "same static key re-traced the scan program"
    assert r.delivered_app_msgs == 4 * 3 * 15


def test_changed_static_key_traces_again():
    cfg = _cfg(window=13)
    api.Group(cfg).run(backend="graph")
    before = len(group_mod.TRACE_EVENTS)
    sub = dataclasses.replace(cfg.subgroups[0], window=11)
    api.Group(cfg).run(backend="graph", subgroups=(sub,))
    assert len(group_mod.TRACE_EVENTS) == before + 1


def test_backends_do_not_share_scan_programs():
    cfg = _cfg(window=13)
    api.Group(cfg).run(backend="graph")
    api.Group(cfg).run(backend="pallas")
    before = len(group_mod.TRACE_EVENTS)
    api.Group(cfg).run(backend="pallas")   # warm for pallas too
    assert len(group_mod.TRACE_EVENTS) == before


# ---------------------------------------------------------------------------
# vectorized _reconstruct == the old per-message loop (property test)
# ---------------------------------------------------------------------------

def _reconstruct_reference(spec, batches, app_pub, nulls):
    """The pre-vectorization implementation, kept verbatim as the oracle."""
    n_s = len(spec.senders)
    rounds = batches.shape[0]
    is_app = [[] for _ in range(n_s)]
    pub_round = [[] for _ in range(n_s)]
    for r in range(rounds):
        for s in range(n_s):
            for _ in range(int(app_pub[r, s])):
                is_app[s].append(True)
                pub_round[s].append(r)
            for _ in range(int(nulls[r, s])):
                is_app[s].append(False)
                pub_round[s].append(r)
    delivered_num = np.cumsum(batches, axis=0) - 1
    final = delivered_num[-1] if rounds else np.full(len(spec.members), -1)
    delivered = {node: int(final[pos])
                 for pos, node in enumerate(spec.members)}
    lat = []
    if rounds:
        col = delivered_num[:, 0]
        for seq in range(int(final[0]) + 1):
            rank, idx = seq % n_s, seq // n_s
            if not is_app[rank][idx]:
                continue
            lat.append((pub_round[rank][idx], int(np.searchsorted(col, seq))))
    log = group_mod.DeliveryLog(
        n_senders=n_s,
        is_app=[np.array(a, dtype=bool) for a in is_app],
        delivered_seq=delivered)
    return log, lat


def _random_trace(rng, n_m, n_s, rounds):
    """A random (batches, app_pub, nulls) trace whose delivered prefixes
    stay inside the published round-robin order (the protocol invariant
    _reconstruct may assume)."""
    app_pub = rng.integers(0, 3, size=(rounds, n_s))
    nulls = rng.integers(0, 2, size=(rounds, n_s))
    totals = app_pub.sum(axis=0) + nulls.sum(axis=0)
    max_count = int(sst.rr_prefix(totals))       # valid seqs: 0..max_count-1
    batches = np.zeros((rounds, n_m), dtype=np.int64)
    for pos in range(n_m):
        fin = int(rng.integers(-1, max_count))
        col = np.sort(rng.integers(-1, fin + 1, size=rounds))
        col[-1] = fin
        batches[:, pos] = np.diff(np.concatenate([[-1], col]))
    return batches, app_pub, nulls


def test_vectorized_reconstruct_matches_reference_loop_on_random_traces():
    rng = np.random.default_rng(20260730)
    for case in range(50):
        n_m = int(rng.integers(1, 6))
        n_s = int(rng.integers(1, n_m + 1))
        rounds = int(rng.integers(1, 14))
        spec = api.SubgroupSpec(members=tuple(range(n_m)),
                                senders=tuple(range(n_s)),
                                msg_size=64, window=8, n_messages=0)
        batches, app_pub, nulls = _random_trace(rng, n_m, n_s, rounds)
        log_v, lat_v = group_mod.GraphBackend._reconstruct(
            spec, batches, app_pub, nulls)
        log_r, lat_r = _reconstruct_reference(spec, batches, app_pub, nulls)
        assert _logs_equal(log_v, log_r), f"case {case}: logs diverge"
        assert [tuple(p) for p in lat_v] == lat_r, \
            f"case {case}: latency round-pairs diverge"


def test_reconstruct_empty_trace():
    spec = api.SubgroupSpec(members=(0, 1), senders=(0,), msg_size=64,
                            window=4, n_messages=0)
    z = np.zeros((0, 1), np.int64)
    log, lat = group_mod.GraphBackend._reconstruct(
        spec, np.zeros((0, 2), np.int64), z, z)
    assert log.delivered_seq == {0: -1, 1: -1}
    assert len(lat) == 0


# ---------------------------------------------------------------------------
# run_batch == looped run (cross-backend conformance)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["graph", "pallas", "des"])
def test_run_batch_matches_looped_run_on_window_grid(backend):
    windows = [4, 8, 16]
    g = api.Group(_cfg())
    reports = g.run_batch(backend=backend, windows=windows)
    assert len(reports) == len(windows)
    for w, rb in zip(windows, reports):
        gi = api.Group(_cfg(window=w))
        ri = gi.run(backend=backend)
        assert (rb.delivered_app_msgs, rb.delivered_null_msgs,
                rb.nulls_sent, rb.rdma_writes, rb.rounds) == \
            (ri.delivered_app_msgs, ri.delivered_null_msgs,
             ri.nulls_sent, ri.rdma_writes, ri.rounds), (backend, w)
        assert rb.duration_us == pytest.approx(ri.duration_us, rel=1e-6)
        for gid, log in gi.delivery_logs.items():
            assert _logs_equal(rb.extras["delivery_logs"][gid], log), \
                (backend, w, gid)


def test_run_batch_null_send_grid_matches_single_runs():
    pats = (((0, 1), api.SenderPattern(active=False)),)
    g = api.Group(_cfg(patterns=pats, n_messages=10))
    reports = g.run_batch(backend="graph", null_send=[True, False])
    for flag, rb in zip([True, False], reports):
        cfg_i = dataclasses.replace(
            g.cfg, flags=dataclasses.replace(g.cfg.flags, null_send=flag))
        gi = api.Group(cfg_i)
        ri = gi.run(backend="graph")
        assert rb.nulls_sent == ri.nulls_sent
        assert rb.delivered_app_msgs == ri.delivered_app_msgs
        for gid, log in gi.delivery_logs.items():
            assert _logs_equal(rb.extras["delivery_logs"][gid], log)
    # the grid actually exercised both flag values
    assert reports[0].nulls_sent > 0
    assert reports[1].nulls_sent == 0


def test_run_batch_n_messages_grid():
    msgs = [5, 10, 20]
    reports = api.Group(_cfg()).run_batch(backend="graph", n_messages=msgs)
    for m, rb in zip(msgs, reports):
        assert rb.delivered_app_msgs == 4 * 3 * m
        assert not rb.stalled


def test_run_batch_multi_subgroup_conforms():
    spec_a = api.SubgroupSpec(members=(0, 1, 2), senders=(0, 1),
                              msg_size=512, window=8, n_messages=6)
    spec_b = api.SubgroupSpec(members=(0, 1, 2, 3), senders=(2, 3),
                              msg_size=256, window=4, n_messages=4)
    cfg = api.GroupConfig(members=(0, 1, 2, 3), subgroups=(spec_a, spec_b))
    reports = api.Group(cfg).run_batch(backend="graph", windows=[4, 8])
    for w, rb in zip([4, 8], reports):
        subs = tuple(dataclasses.replace(s, window=w)
                     for s in cfg.subgroups)
        gi = api.Group(dataclasses.replace(cfg, subgroups=subs))
        ri = gi.run(backend="graph")
        assert rb.delivered_app_msgs == ri.delivered_app_msgs
        for gid, log in gi.delivery_logs.items():
            assert _logs_equal(rb.extras["delivery_logs"][gid], log)


def test_run_batch_requires_a_grid():
    with pytest.raises(ValueError):
        api.Group(_cfg()).run_batch(backend="graph")


def test_run_batch_rejects_mismatched_grid_lengths():
    with pytest.raises(ValueError):
        api.Group(_cfg()).run_batch(backend="graph", windows=[4, 8],
                                    null_send=[True])


# ---------------------------------------------------------------------------
# persistent compilation cache: env opt-in (repro.__init__)
# ---------------------------------------------------------------------------

def _run_py(code, env_extra):
    import os
    import pathlib
    import subprocess
    import sys

    import repro

    src = str(pathlib.Path(repro.__file__).resolve().parents[1])
    env = dict(os.environ)
    env.pop("REPRO_COMPILATION_CACHE", None)
    env["PYTHONPATH"] = src
    env.update(env_extra)
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True)
    assert out.returncode == 0, out.stderr
    return out


def test_compilation_cache_env_creates_missing_dir(tmp_path):
    cache = str(tmp_path / "cc" / "nested")
    _run_py(
        "import os, jax, repro\n"
        f"assert os.path.isdir({cache!r}), 'cache dir not created'\n"
        f"assert jax.config.jax_compilation_cache_dir == {cache!r}\n",
        {"REPRO_COMPILATION_CACHE": cache})


def test_compilation_cache_env_warns_when_jax_already_configured(
        tmp_path):
    mine = str(tmp_path / "mine")
    theirs = str(tmp_path / "theirs")
    _run_py(
        "import warnings, jax\n"
        f"jax.config.update('jax_compilation_cache_dir', {theirs!r})\n"
        "with warnings.catch_warnings(record=True) as w:\n"
        "    warnings.simplefilter('always')\n"
        "    import repro\n"
        "assert any('REPRO_COMPILATION_CACHE' in str(x.message)\n"
        "           for x in w), [str(x.message) for x in w]\n"
        "# explicit configuration wins; the env var must not clobber it\n"
        f"assert jax.config.jax_compilation_cache_dir == {theirs!r}\n",
        {"REPRO_COMPILATION_CACHE": mine})
