"""Substrate tests: data pipeline, optimizer, checkpointing, trainer
(end-to-end loss decrease + restart), elastic runtime, serving engine.
"""

import dataclasses
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import pipeline
from repro.models import layers, registry
from repro.models.config import ModelConfig
from repro.models.runtime import Runtime
from repro.optim import adamw
from repro.train import checkpoint
from repro.train.elastic import ElasticConfig, ElasticRuntime
from repro.train.trainer import TrainConfig, Trainer

jax.config.update("jax_platform_name", "cpu")


TINY = ModelConfig(name="tiny-test", family="dense", n_layers=2,
                   d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
                   vocab_size=512, head_dim=32, tie_embeddings=True)
registry.register("tiny-test", lambda: TINY)


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def _dcfg(**kw):
    return pipeline.DataConfig(seq_len=32, global_batch=8, vocab_size=512,
                               **kw)


def test_data_deterministic():
    a = pipeline.ShardedLoader(_dcfg(), 0, 1).batch(7)
    b = pipeline.ShardedLoader(_dcfg(), 0, 1).batch(7)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = pipeline.ShardedLoader(_dcfg(), 0, 1).batch(8)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_data_sharding_partitions_global_batch():
    full = pipeline.ShardedLoader(_dcfg(), 0, 1).batch(3)["tokens"]
    parts = [pipeline.ShardedLoader(_dcfg(), r, 4).batch(3)["tokens"]
             for r in range(4)]
    np.testing.assert_array_equal(np.concatenate(parts), full)


def test_data_reshard_is_stream_preserving():
    factory = pipeline.reshard(_dcfg(), old_ranks=4, new_ranks=2)
    full = pipeline.ShardedLoader(_dcfg(), 0, 1).batch(5)["tokens"]
    parts = [factory(r).batch(5)["tokens"] for r in range(2)]
    np.testing.assert_array_equal(np.concatenate(parts), full)


def test_data_tokens_in_range():
    batch = pipeline.ShardedLoader(_dcfg(), 0, 1).batch(0)["tokens"]
    assert batch.min() >= 0 and batch.max() < 512


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def test_adamw_minimizes_quadratic():
    cfg = adamw.OptConfig(peak_lr=0.1, warmup_steps=5, decay_steps=200,
                          weight_decay=0.0, clip_norm=10.0)
    params = {"w": jnp.ones((8,)) * 5.0}
    state = adamw.init(params)
    loss = lambda p: jnp.sum(jnp.square(p["w"] - 2.0))  # noqa: E731
    for _ in range(150):
        g = jax.grad(loss)(params)
        params, state, _ = adamw.update(cfg, g, state,
                                        param_dtype=jnp.float32)
    assert float(loss(params)) < 0.05


def test_adamw_schedule_shape():
    cfg = adamw.OptConfig(peak_lr=1e-3, warmup_steps=10, decay_steps=100)
    lrs = [float(adamw.schedule(cfg, jnp.int32(s))) for s in range(110)]
    assert lrs[0] < lrs[9] <= 1e-3 + 1e-9          # warmup rises
    assert abs(lrs[10] - 1e-3) < 1e-4              # peak
    assert lrs[-1] < lrs[50] < lrs[11]             # cosine decays
    assert lrs[-1] >= cfg.peak_lr * cfg.min_lr_frac - 1e-9


def test_adamw_clips_gradients():
    cfg = adamw.OptConfig(clip_norm=1.0, warmup_steps=0, decay_steps=10)
    params = {"w": jnp.zeros((4,))}
    state = adamw.init(params)
    g = {"w": jnp.full((4,), 1e6)}
    _, _, metrics = adamw.update(cfg, g, state, param_dtype=jnp.float32)
    assert float(metrics["grad_norm"]) > 1e5   # reported pre-clip


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip_and_latest():
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.bfloat16)}}
    with tempfile.TemporaryDirectory() as d:
        checkpoint.save(d, 3, tree, extra={"note": "x"})
        checkpoint.save(d, 7, tree)
        assert checkpoint.latest_step(d) == 7
        step, restored, extra = checkpoint.restore(d, tree, step=3)
        assert step == 3 and extra == {"note": "x"}
        np.testing.assert_array_equal(np.asarray(restored["a"]),
                                      np.asarray(tree["a"]))
        assert restored["b"]["c"].dtype == jnp.bfloat16


def test_checkpoint_prune_keeps_latest():
    tree = {"a": jnp.zeros((2,))}
    with tempfile.TemporaryDirectory() as d:
        for s in (1, 2, 3, 4, 5):
            checkpoint.save(d, s, tree)
        checkpoint.prune(d, keep=2)
        assert checkpoint.latest_step(d) == 5
        step, _, _ = checkpoint.restore(d, tree)
        assert step == 5


def test_checkpoint_shape_mismatch_rejected():
    with tempfile.TemporaryDirectory() as d:
        checkpoint.save(d, 1, {"a": jnp.zeros((2,))})
        with pytest.raises(ValueError):
            checkpoint.restore(d, {"a": jnp.zeros((3,))})


# ---------------------------------------------------------------------------
# trainer end-to-end
# ---------------------------------------------------------------------------

def test_trainer_loss_decreases_and_restarts():
    with tempfile.TemporaryDirectory() as d:
        tcfg = TrainConfig(steps=40, seq_len=64, global_batch=4,
                           checkpoint_dir=d, checkpoint_every=20,
                           log_every=5, data_patterns=4,
                           opt=adamw.OptConfig(peak_lr=3e-3,
                                               warmup_steps=5,
                                               decay_steps=40))
        tr = Trainer("tiny-test", TINY, tcfg, Runtime())
        tr.run()
        losses = [h["loss"] for h in tr.history]
        assert losses[-1] < losses[0] * 0.9, losses
        assert checkpoint.latest_step(d) == 40
        # restart continues from the watermark, not from scratch
        tr2 = Trainer("tiny-test", TINY,
                      dataclasses.replace(tcfg, steps=45), Runtime())
        tr2.run()
        assert tr2.history[-1]["step"] == 45
        # the resumed loss stays near the pre-restart loss
        assert tr2.history[0]["loss"] < losses[0]


# ---------------------------------------------------------------------------
# elastic runtime
# ---------------------------------------------------------------------------

def test_elastic_failure_triggers_view_change():
    rt = ElasticRuntime(list(range(8)),
                        ElasticConfig(heartbeat_timeout=2))
    for _ in range(3):
        rt.step()
    rt.fail(5)
    changed = False
    for _ in range(6):
        info = rt.step()
        changed = changed or info["view_change"] is not None
    assert changed
    assert 5 not in rt.view.members and len(rt.view.members) == 7


def test_elastic_straggler_null_rounds_not_eviction():
    rt = ElasticRuntime(list(range(4)),
                        ElasticConfig(heartbeat_timeout=5))
    rt.delay(2, 3)
    nulls = 0
    for _ in range(6):
        info = rt.step()
        nulls += len(info["null_rounds"])
        assert info["view_change"] is None
    assert nulls == 3
    assert 2 in rt.view.members


def test_elastic_join_and_watermark():
    rt = ElasticRuntime(list(range(4)))
    for _ in range(5):
        rt.step()
    rt.join(9)
    info = rt.step()
    assert info["view_change"] is not None
    assert 9 in rt.view.members
    assert rt.restart_watermark() >= 5  # survivors carry the watermark


# ---------------------------------------------------------------------------
# serving engine
# ---------------------------------------------------------------------------

def test_engine_serves_all_requests():
    from repro.serve.engine import EngineConfig, Request, ServeEngine
    cfg = TINY
    params = layers.init_tree(registry.param_specs(cfg), jax.random.key(0))
    eng = ServeEngine("tiny-test", params, cfg,
                      EngineConfig(max_batch=3, max_len=48), Runtime())
    rng = np.random.default_rng(0)
    for i in range(5):
        eng.submit(Request(rid=i,
                           prompt=rng.integers(0, 512, 4, dtype=np.int32),
                           max_new_tokens=5))
    done = eng.run_until_drained()
    assert len(done) == 5
    assert all(len(r.tokens_out) == 5 for r in done)
    assert all(0 <= t < 512 for r in done for t in r.tokens_out)


def test_engine_greedy_is_deterministic_per_prompt():
    from repro.serve.engine import EngineConfig, Request, ServeEngine
    cfg = TINY
    params = layers.init_tree(registry.param_specs(cfg), jax.random.key(0))
    prompt = np.arange(4, dtype=np.int32) + 7

    def run_once(n_background: int):
        eng = ServeEngine("tiny-test", params, cfg,
                          EngineConfig(max_batch=4, max_len=48), Runtime())
        eng.submit(Request(rid=0, prompt=prompt, max_new_tokens=6))
        rng = np.random.default_rng(1)
        for i in range(n_background):
            eng.submit(Request(rid=100 + i,
                               prompt=rng.integers(0, 512, 3,
                                                   dtype=np.int32),
                               max_new_tokens=6))
        done = eng.run_until_drained()
        return next(r.tokens_out for r in done if r.rid == 0)

    # continuous batching must not change a request's greedy output
    assert run_once(0) == run_once(3)
