"""Suite-wide guardrails.

Skip budget: the suite runs everywhere at 0 skips — every property test
draws cases from seeded numpy generators, no optional test deps (the
former four hypothesis-based ``importorskip`` modules were converted).
A test that sneaks in an ``importorskip`` or environment-dependent skip
would silently shrink coverage; instead of letting that rot, any pytest
run (local or CI) FAILS when more than ``PYTEST_SKIP_BUDGET`` (default
1 — headroom for one legitimately platform-gated test, not a dep) tests
or modules skip.  New property tests must use seeded RNG loops (see
tests/test_core_protocol.py, tests/test_hotpath.py).
"""

import os

_SKIP_BUDGET = int(os.environ.get("PYTEST_SKIP_BUDGET", "1"))
_skipped = []


def pytest_runtest_logreport(report):
    if report.skipped:
        _skipped.append(report.nodeid)


def pytest_collectreport(report):
    if report.skipped:
        _skipped.append(str(report.nodeid))


def pytest_terminal_summary(terminalreporter, exitstatus, config):
    if len(_skipped) > _SKIP_BUDGET:
        terminalreporter.write_line(
            f"skip budget exceeded: {len(_skipped)} skips > budget of "
            f"{_SKIP_BUDGET} (set PYTEST_SKIP_BUDGET to override):",
            red=True)
        for nodeid in _skipped:
            terminalreporter.write_line(f"  skipped: {nodeid}", red=True)


def pytest_sessionfinish(session, exitstatus):
    if int(exitstatus) == 0 and len(_skipped) > _SKIP_BUDGET:
        session.exitstatus = 1
