"""Suite-wide guardrails.

Skip budget: at most the four hypothesis-based property modules may skip
(they ``importorskip`` and only skip in environments without hypothesis —
e.g. the hermetic eval container; CI installs requirements.txt, so there
it is 0 skips).  A new test that sneaks in another ``importorskip`` (or
an environment-dependent skip) would silently shrink coverage; instead
of letting that rot, any pytest run (local or CI) FAILS when more than
``PYTEST_SKIP_BUDGET`` (default 4) tests/modules skip.  New property
tests must use seeded RNG loops instead of hypothesis (see
tests/test_stacked.py, tests/test_hotpath.py).
"""

import os

_SKIP_BUDGET = int(os.environ.get("PYTEST_SKIP_BUDGET", "4"))
_skipped = []


def pytest_runtest_logreport(report):
    if report.skipped:
        _skipped.append(report.nodeid)


def pytest_collectreport(report):
    if report.skipped:
        _skipped.append(str(report.nodeid))


def pytest_terminal_summary(terminalreporter, exitstatus, config):
    if len(_skipped) > _SKIP_BUDGET:
        terminalreporter.write_line(
            f"skip budget exceeded: {len(_skipped)} skips > budget of "
            f"{_SKIP_BUDGET} (set PYTEST_SKIP_BUDGET to override):",
            red=True)
        for nodeid in _skipped:
            terminalreporter.write_line(f"  skipped: {nodeid}", red=True)


def pytest_sessionfinish(session, exitstatus):
    if int(exitstatus) == 0 and len(_skipped) > _SKIP_BUDGET:
        session.exitstatus = 1
