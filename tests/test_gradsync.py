"""Gradient-multicast tests: bucket plans, fused == per-tensor semantics,
null-round validity reduction, int8 compression with error feedback.

Collective semantics are exercised with vmap axes (jax implements psum &
friends over vmapped axes), so these run on one CPU device with a real
"8-worker" axis.  Property cases come from seeded numpy generators (no
hypothesis in the container).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import gradsync

pytestmark = pytest.mark.fast

jax.config.update("jax_platform_name", "cpu")


def _tree(key, shapes):
    keys = jax.random.split(key, len(shapes))
    return {f"p{i}": jax.random.normal(k, s)
            for i, (k, s) in enumerate(zip(keys, shapes))}


SHAPES = [(17,), (8, 9), (3, 4, 5), (128,), (2, 2)]


# ---------------------------------------------------------------------------
# bucket plan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("case", range(20))
def test_bucket_roundtrip(case):
    rng = np.random.default_rng(32_000 + case)
    n_leaves = int(rng.integers(1, 7))
    target = int(rng.integers(64, 4097))
    tree = {f"w{i}": jnp.arange(i * 7 + 3, dtype=jnp.float32) + i
            for i in range(n_leaves)}
    plan = gradsync.make_plan(tree, target_bytes=target)
    buckets = gradsync.flatten_buckets(tree, plan)
    back = gradsync.unflatten_buckets(buckets, plan)
    assert jax.tree.structure(back) == jax.tree.structure(tree)
    for a, b in zip(jax.tree.leaves(back), jax.tree.leaves(tree)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_bucket_plan_respects_target():
    tree = {f"w{i}": jnp.zeros((1024,)) for i in range(16)}  # 4KB each
    plan = gradsync.make_plan(tree, target_bytes=8192)
    assert plan.n_buckets == 8
    for b in range(plan.n_buckets):
        assert plan.bucket_bytes(b) <= 8192


def test_bucket_order_is_deterministic():
    tree = {"b": jnp.zeros((4,)), "a": jnp.zeros((4,)),
            "c": {"x": jnp.zeros((4,))}}
    p1 = gradsync.make_plan(tree)
    p2 = gradsync.make_plan(tree)
    assert p1.starts == p2.starts and p1.leaf_shapes == p2.leaf_shapes


# ---------------------------------------------------------------------------
# reductions over a vmapped worker axis
# ---------------------------------------------------------------------------

W = 8


def _per_worker_grads(key):
    keys = jax.random.split(key, W)
    return jax.vmap(lambda k: _tree(k, SHAPES))(jnp.stack(keys))


def test_fused_equals_per_tensor_equals_mean():
    grads = _per_worker_grads(jax.random.key(0))
    want = jax.tree.map(lambda g: g.mean(0), grads)

    per_tensor = jax.vmap(
        lambda g: gradsync.per_tensor_psum_mean(g, "w"), axis_name="w")(
        grads)
    plan = gradsync.make_plan(jax.tree.map(lambda g: g[0], grads),
                              target_bytes=1024)
    fused = jax.vmap(
        lambda g: gradsync.fused_psum_mean(g, plan, "w"), axis_name="w")(
        grads)
    for a, b, c in zip(jax.tree.leaves(per_tensor),
                       jax.tree.leaves(fused), jax.tree.leaves(want)):
        np.testing.assert_allclose(np.asarray(a[0]), np.asarray(c),
                                   rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(np.asarray(b[0]), np.asarray(c),
                                   rtol=1e-5, atol=1e-6)


def test_null_round_validity_mean():
    grads = _per_worker_grads(jax.random.key(1))
    valid = jnp.array([1, 1, 0, 1, 0, 1, 1, 1], jnp.float32)
    out, count = jax.vmap(
        lambda g, v: gradsync.psum_with_validity(g, v, "w"),
        axis_name="w")(grads, valid)
    assert float(count[0]) == 6.0
    # mean over live contributors only — stragglers contribute nulls
    for name in grads:
        want = (grads[name] * valid.reshape(
            (W,) + (1,) * (grads[name].ndim - 1))).sum(0) / 6.0
        np.testing.assert_allclose(np.asarray(out[name][0]),
                                   np.asarray(want), rtol=1e-5, atol=1e-6)


def test_null_round_all_invalid_is_safe():
    grads = _per_worker_grads(jax.random.key(2))
    valid = jnp.zeros((W,), jnp.float32)
    out, count = jax.vmap(
        lambda g, v: gradsync.psum_with_validity(g, v, "w"),
        axis_name="w")(grads, valid)
    assert float(count[0]) == 0.0
    for leaf in jax.tree.leaves(out):
        assert bool(jnp.all(jnp.isfinite(leaf)))
        np.testing.assert_allclose(np.asarray(leaf), 0.0, atol=1e-6)


def test_compressed_psum_close_and_error_feedback():
    grads = _per_worker_grads(jax.random.key(3))
    want = jax.tree.map(lambda g: g.mean(0).astype(jnp.float32), grads)
    plan = gradsync.make_plan(jax.tree.map(lambda g: g[0], grads),
                              target_bytes=1 << 20)
    state = gradsync.CompressionState.init(plan)
    state_b = jax.tree.map(
        lambda r: jnp.broadcast_to(r, (W,) + r.shape), state.residuals)

    def step(g, res):
        st = gradsync.CompressionState(residuals=list(res))
        out, new_state = gradsync.compressed_psum_mean(
            g, plan, st, "w", jax.lax.axis_index("w"))
        return out, tuple(new_state.residuals)

    out, new_res = jax.vmap(step, axis_name="w")(grads, tuple(state_b))
    # int8 quantization error is bounded by scale/2 per element
    for name in want:
        got = np.asarray(out[name][0])
        ref = np.asarray(want[name])
        scale = np.abs(ref).max() / 127.0 + 1e-12
        assert np.max(np.abs(got - ref)) < 4 * scale + 1e-4
    # error feedback: residuals hold exactly what quantization lost
    assert any(float(jnp.abs(r).max()) > 0 for r in new_res)

    # applying the residual next step cancels the bias:
    # two steps with the same grads average closer than one step
    out2, _ = jax.vmap(step, axis_name="w")(grads, new_res)
    for name in want:
        ref = np.asarray(want[name])
        one = np.asarray(out[name][0])
        two = (np.asarray(out[name][0]) + np.asarray(out2[name][0])) / 2
        assert np.abs(two - ref).mean() <= np.abs(one - ref).mean() + 1e-6


# ---------------------------------------------------------------------------
# SyncState watermarks
# ---------------------------------------------------------------------------

def test_sync_state_monotone():
    s = gradsync.SyncState()
    s = s.advance().advance(null=True).deliver(1)
    assert s.sent_step == 2 and s.null_rounds == 1
    assert s.delivered_step == 1
    with pytest.raises(ValueError):
        s.deliver(0)
