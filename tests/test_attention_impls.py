"""Parity tests across attention implementations (xla / chunked / pallas)
and decode-position semantics (scalar vs per-slot vector)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import attention as A

pytestmark = pytest.mark.fast

jax.config.update("jax_platform_name", "cpu")


def _qkv(key, b, s, hq, hkv, d):
    ks = jax.random.split(key, 3)
    return (jax.random.normal(ks[0], (b, s, hq, d)),
            jax.random.normal(ks[1], (b, s, hkv, d)),
            jax.random.normal(ks[2], (b, s, hkv, d)))


@pytest.mark.parametrize("b,s,hq,hkv,d", [
    (2, 256, 4, 2, 64),
    (1, 2048, 4, 4, 32),    # multiple q and kv blocks
    (2, 512, 8, 1, 16),     # MQA
])
@pytest.mark.parametrize("causal", [True, False])
def test_chunked_matches_reference(b, s, hq, hkv, d, causal):
    q, k, v = _qkv(jax.random.key(0), b, s, hq, hkv, d)
    ref = A._sdpa(q, k, v, causal=causal)
    got = A._sdpa_chunked(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_chunked_uses_fewer_score_bytes():
    """Structural check: the blocked form never materializes (S, S)."""
    q, k, v = _qkv(jax.random.key(1), 1, 2048, 2, 2, 32)
    text = jax.jit(lambda *a: A._sdpa_chunked(*a, causal=True)).lower(
        q, k, v).compile().as_text()
    assert "2048,2048" not in text


def test_decode_vector_positions_match_scalar():
    """A uniform position vector must equal the scalar-position path."""
    b, smax, h, d = 3, 64, 2, 16
    ks = jax.random.split(jax.random.key(2), 4)
    from repro.models.config import ModelConfig
    cfg = ModelConfig(name="t", family="dense", n_layers=1, d_model=32,
                      n_heads=h, n_kv_heads=h, d_ff=64, vocab_size=64,
                      head_dim=d)
    from repro.models import layers as L
    p = L.init_tree(A.attn_specs(cfg), ks[0])
    x = jax.random.normal(ks[1], (b, 1, 32))
    kc = jax.random.normal(ks[2], (b, smax, h, d))
    vc = jax.random.normal(ks[3], (b, smax, h, d))
    o1, k1, v1 = A.decode_attention(p, cfg, x, kc, vc, jnp.int32(10))
    o2, k2, v2 = A.decode_attention(p, cfg, x, kc, vc,
                                    jnp.full((b,), 10, jnp.int32))
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), rtol=1e-5,
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(k1), np.asarray(k2), rtol=1e-6,
                               atol=1e-6)


def test_decode_vector_positions_are_per_slot():
    """Different slots write their KV at their own positions."""
    b, smax, h, d = 2, 16, 1, 8
    ks = jax.random.split(jax.random.key(3), 4)
    from repro.models.config import ModelConfig
    cfg = ModelConfig(name="t", family="dense", n_layers=1, d_model=16,
                      n_heads=h, n_kv_heads=h, d_ff=32, vocab_size=64,
                      head_dim=d)
    from repro.models import layers as L
    p = L.init_tree(A.attn_specs(cfg), ks[0])
    x = jax.random.normal(ks[1], (b, 1, 16))
    kc = jnp.zeros((b, smax, h, d))
    vc = jnp.zeros((b, smax, h, d))
    pos = jnp.array([3, 11], jnp.int32)
    _, k2, _ = A.decode_attention(p, cfg, x, kc, vc, pos)
    k2 = np.asarray(k2)
    assert np.abs(k2[0, 3]).sum() > 0 and np.abs(k2[1, 11]).sum() > 0
    assert np.abs(k2[0, 11]).sum() == 0 and np.abs(k2[1, 3]).sum() == 0
