"""Serve-plane fan-out conformance (DESIGN.md Sec. 6).

Covers, bottom-up:

* the streaming substrate: ``GroupStream`` fed a scenario's schedule row
  by row matches ``Group.run`` app sequences, compiles ONE stacked
  program for the whole session, and graph/pallas streams fed identical
  rounds are bit-identical;
* streaming/bind input validation (des refuses, padded lanes refuse,
  unknown topics refuse);
* the domain-attached replicated engine: tokens and per-topic delivery
  logs bit-identical graph vs pallas (same engines, reset between runs),
  app sequences identical to a des-backed run of the same counts, the
  stalled-client path publishes null rounds, and slot reuse is gated on
  the delivery watermark (finish < free < re-admit, in engine rounds).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.core import group as group_mod
from repro.models import layers, registry
from repro.models.config import ModelConfig
from repro.models.runtime import Runtime
from repro.serve.engine import EngineConfig, Request, ServeEngine
from repro.serve.fanout import ReplicatedEngine

jax.config.update("jax_platform_name", "cpu")

fast = pytest.mark.fast

FAN = ModelConfig(name="fanout-test", family="dense", n_layers=2,
                  d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
                  vocab_size=512, head_dim=32, tie_embeddings=True)
registry.register("fanout-test", lambda: FAN)

N_REPLICAS, N_SLOTS, N_REQS, NEW_TOKENS = 2, 2, 3, 4


def _logs_identical(a, b):
    return (a.n_senders == b.n_senders
            and a.delivered_seq == b.delivered_seq
            and len(a.is_app) == len(b.is_app)
            and all(np.array_equal(x, y)
                    for x, y in zip(a.is_app, b.is_app)))


# ---------------------------------------------------------------------------
# the streaming substrate (protocol only, no model)
# ---------------------------------------------------------------------------


@fast
def test_stream_matches_scheduled_run_and_traces_once():
    cfg = api.single_group(4, n_senders=2, msg_size=4096, window=4,
                           n_messages=10)
    ref = api.Group(cfg)
    ref.run(backend="graph")

    logs_by_backend = {}
    for backend in ("graph", "pallas"):
        g = api.Group(cfg)
        stream = g.stream(backend=backend)
        n0 = len(group_mod.TRACE_EVENTS)
        ready = np.zeros(stream.shape, np.int32)
        for _ in range(10):                    # the scenario's schedule
            ready[:] = 0
            ready[0, :2] = 1
            stream.step(ready)
        report, logs = stream.finish()
        # however many rounds, the session traced at most once (0 when
        # another test already populated the shape's program cache —
        # the assert must not depend on test execution order)
        assert len(group_mod.TRACE_EVENTS) - n0 <= 1
        assert stream.quiescent() and not report.stalled
        logs_by_backend[backend] = logs[0]
        for node in cfg.subgroups[0].members:
            assert logs[0].sequence(node) == \
                ref.delivery_logs[0].sequence(node)
        # finish() installs logs + report on the Group like run() does
        assert g.delivery_logs[0] is logs[0]
        assert g.last_report is report
    assert _logs_identical(logs_by_backend["graph"],
                           logs_by_backend["pallas"])


@fast
def test_stream_finish_drains_large_backlog():
    """finish() is not a fixed settle budget: a burst far beyond the ring
    window (200 messages/sender through window=4, ~150 throttled rounds)
    drains to quiescence instead of reporting a false stall."""
    cfg = api.single_group(4, n_senders=2, msg_size=256, window=4,
                           n_messages=0)
    g = api.Group(cfg)
    stream = g.stream()
    ready = np.zeros(stream.shape, np.int32)
    ready[0, :2] = 200
    stream.step(ready)
    report, logs = stream.finish()
    assert stream.quiescent() and not report.stalled
    assert report.delivered_app_msgs == 4 * 400    # every member, all
    # a capped drain reports the cut-off honestly
    g2 = api.Group(cfg)
    s2 = g2.stream()
    s2.step(ready)
    capped, _ = s2.finish(settle_max=5)
    assert capped.stalled and capped.delivered_app_msgs < 4 * 400


@fast
def test_stream_and_bind_validate_inputs():
    cfg = api.single_group(3, n_senders=2, n_messages=4)
    with pytest.raises(ValueError, match="graph/pallas"):
        api.Group(cfg).stream(backend="des-loop")
    stream = api.Group(cfg).stream()
    with pytest.raises(ValueError, match="ready must be"):
        stream.step(np.zeros((2, 2), np.int32))

    d = api.many_topic_domain(4, 3, subscribers_per_topic=2, window=8)
    bound = d.bind()
    with pytest.raises(KeyError, match="no-such-topic"):
        bound.push_round({"no-such-topic": 1})
    with pytest.raises(ValueError, match="publishers"):
        bound.push_round({"topic-0": [1, 1]})   # topic has one publisher


@fast
def test_bound_domain_streams_per_round_counts():
    """A bursty per-round publish pattern — inexpressible as a fixed
    samples_per_publisher scenario — delivers exactly what was pushed,
    keyed by topic name."""
    d = api.many_topic_domain(4, 3, subscribers_per_topic=2, window=8)
    bound = d.bind()
    pushed = {t.name: 0 for t in d.topics}
    rng = np.random.default_rng(7)
    for rnd in range(6):
        counts = {}
        for t in d.topics:
            c = int(rng.integers(0, 3))
            if c:
                counts[t.name] = c
                pushed[t.name] += c
        bound.push_round(counts)
    report, logs = bound.finish()
    assert set(logs) == set(pushed)
    for name, log in logs.items():
        assert sum(int(a.sum()) for a in log.is_app) == pushed[name]
        for node in d.topics[bound._gid[name]].members:
            apps = [x for x in log.sequence(node) if x[2]]
            assert len(apps) == pushed[name]
    assert not report.stalled


# ---------------------------------------------------------------------------
# the domain-attached replicated engine
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def engines():
    params = layers.init_tree(registry.param_specs(FAN), jax.random.key(0))
    return [ServeEngine("fanout-test", params, FAN,
                        EngineConfig(max_batch=N_SLOTS, max_len=48),
                        Runtime())
            for _ in range(N_REPLICAS)]


def _submit_wave(rep):
    rng = np.random.default_rng(0)
    for g in range(N_REPLICAS):
        for i in range(N_REQS):
            rep.submit(g, Request(
                rid=g * 10 + i,
                prompt=rng.integers(0, FAN.vocab_size, 3, dtype=np.int32),
                max_new_tokens=NEW_TOKENS))


def _stall(g, rnd):
    return (0,) if (g == 0 and 2 <= rnd < 5) else ()


def _des_run(engines, named_logs):
    """A des-backend Group run of the same per-sender app counts the
    fan-out published (slot-sender rank order)."""
    domain = ReplicatedEngine(engines, subscribers_per_replica=2,
                              window=4).domain
    g_des = api.Group(domain.group(samples_per_publisher=0).cfg)
    for gid, topic in enumerate(domain.topics):
        log = named_logs[topic.name]
        for rank, node in enumerate(topic.publishers):
            n = int(log.is_app[rank].sum())
            if n:
                g_des.subgroup(gid).send(sender=node, n=n)
    g_des.run(backend="des")
    return g_des


def test_fanout_conformance_graph_pallas_des(engines):
    """Tokens and delivery logs bit-identical graph vs pallas; app
    sequences identical to a des run of the same counts; stalled-client
    rounds publish nulls; the whole run is one stacked program."""
    results = {}
    for backend in ("graph", "pallas"):
        rep = ReplicatedEngine(engines, subscribers_per_replica=2,
                               window=4, backend=backend,
                               stall_fn=_stall)
        rep.reset()
        _submit_wave(rep)
        n0 = len(group_mod.TRACE_EVENTS)
        report = rep.run()
        # one stacked program for the whole run (0 new entries when the
        # shape's program was already cached by an earlier same-process
        # stream — never one per engine round or per topic)
        assert len(group_mod.TRACE_EVENTS) - n0 <= 1
        results[backend] = (report, rep.completed(),
                            report.extras["delivery_logs"])
    (rg, tokens_g, logs_g) = results["graph"]
    (rp, tokens_p, logs_p) = results["pallas"]
    assert tokens_g == tokens_p
    assert set(logs_g) == set(logs_p)
    assert all(_logs_identical(logs_g[k], logs_p[k]) for k in logs_g)

    # serving metrics merged into the multicast report
    serve = rg.extras["serve"]
    assert serve["drained"] and serve["requests"] == N_REPLICAS * N_REQS
    assert serve["tokens"] == N_REPLICAS * N_REQS * NEW_TOKENS
    assert serve["tokens_per_s"] > 0 and rg.rdma_writes > 0
    assert serve["stall_rounds"] == 3 and serve["held_slots"] == 0
    # the stalled slot's rank was covered by null rounds
    assert rg.nulls_sent > 0 and rg.nulls_sent == rp.nulls_sent
    # every admission + token reached the log: per topic,
    # requests * (1 admission + NEW_TOKENS tokens) app messages
    for name, log in logs_g.items():
        assert sum(int(a.sum()) for a in log.is_app) == \
            N_REQS * (1 + NEW_TOKENS)

    # des conformance under stalls: engine pacing interleaves nulls at
    # timing-dependent seqs, so the cross-backend guarantee is the
    # order-invariant one — same per-sender app counts, and every
    # member's app sequence a per-sender-FIFO merge (each sender's
    # indices in increasing order), like the des backend's.
    g_des = _des_run(engines, logs_g)
    for gid, topic in enumerate(g_des.cfg.subgroups):
        log = logs_g[f"replica-{gid}"]
        des_log = g_des.delivery_logs[gid]
        for rank in range(log.n_senders):
            assert int(log.is_app[rank].sum()) == \
                int(des_log.is_app[rank].sum())
        for node in topic.members:
            per_sender = {}
            for rank, idx, _ in log.sequence(node):
                assert idx > per_sender.get(rank, -1), (gid, node)
                per_sender[rank] = idx


def test_fanout_watermark_gates_slot_reuse(engines):
    """More requests than slots: a freed slot re-admits only after the
    delivery watermark passes its last message (finish < free < admit)."""
    rep = ReplicatedEngine(engines, subscribers_per_replica=2, window=4)
    rep.reset()
    _submit_wave(rep)
    rep.run()
    first_finish, first_free = {}, {}
    for g, slot, rnd in rep.finish_rounds:
        first_finish.setdefault((g, slot), rnd)
    for g, slot, rnd in rep.free_rounds:
        first_free.setdefault((g, slot), rnd)
    # delivery lags publication: no slot frees the round it finishes
    for key, fin in first_finish.items():
        assert key in first_free and first_free[key] > fin
    refills = [(rid, rep.admit_slots[rid])
               for rid, rnd in rep.admit_rounds.items() if rnd > 0]
    assert refills, "wave never refilled a slot"
    for rid, key in refills:
        assert rep.admit_rounds[rid] > first_free[key]
    # every request still completed, every hold eventually released
    assert rep.last_report.extras["serve"]["requests"] == \
        N_REPLICAS * N_REQS
    assert rep.last_report.extras["serve"]["held_slots"] == 0
    assert not rep.last_report.stalled
    # without stalls the engine-paced stream delivers the same app
    # sequences as a des-backed run of the same counts (prefix-
    # consistency degenerates to identity: both complete all apps)
    logs = rep.last_report.extras["delivery_logs"]
    g_des = _des_run(engines, logs)
    for gid, spec in enumerate(g_des.cfg.subgroups):
        for node in spec.members:
            assert logs[f"replica-{gid}"].sequence(node) == \
                g_des.delivery_logs[gid].sequence(node), (gid, node)


def test_fanout_tiny_window_releases_all_holds(engines):
    """window=2: the last token messages are still window-throttled when
    the engines drain, so their holds are pinned+released only during
    finish() — every hold must still end released (regression test for
    the unpinned-last_idx leak)."""
    rep = ReplicatedEngine(engines, subscribers_per_replica=2, window=2)
    rep.reset()
    rng = np.random.default_rng(1)
    for g in range(N_REPLICAS):
        for i in range(4):                   # 4 requests on 2 slots
            rep.submit(g, Request(
                rid=g * 10 + i,
                prompt=rng.integers(0, FAN.vocab_size, 3, dtype=np.int32),
                max_new_tokens=NEW_TOKENS))
    report = rep.run()
    assert report.extras["serve"]["requests"] == N_REPLICAS * 4
    assert report.extras["serve"]["held_slots"] == 0
    assert report.extras["serve"]["drained"]
    assert not report.stalled
    # max_rounds exhaustion is surfaced, not silently normal-looking —
    # and a second run without reset() reports per-RUN deltas, not the
    # first run's cumulative tokens at the new run's wall clock
    rep.submit(0, Request(rid=99, prompt=np.arange(3, dtype=np.int32),
                          max_new_tokens=NEW_TOKENS))
    short = rep.run(max_rounds=2)
    assert not short.extras["serve"]["drained"]
    assert short.extras["serve"]["requests"] == 0    # rid 99 unfinished
    assert short.extras["serve"]["tokens"] == 0
    freed = {(g, s) for g, s, _ in rep.free_rounds}
    assert freed == {(g, s) for g, s, _ in rep.finish_rounds}
