"""Stacked multi-subgroup execution: the whole group as ONE device-sharded
compiled program.

Covers, bottom-up:

* masked round-robin arithmetic (``sst.rr_prefix_masked`` /
  ``sender_counts_masked``) equals the unmasked forms on full masks and
  the unpadded forms on padded inputs;
* the masked padded sweep is bit-identical to the unpadded sweep on the
  active sub-array (seeded property test — hypothesis is not installed);
* a G>=8-subgroup scenario runs as ONE compiled program (a single
  TRACE_EVENTS entry) with delivery logs bit-identical to sequential
  per-subgroup runs on graph and pallas;
* ``run_batch`` shape-mismatch errors name the offending grid point;
* the placement policy degrades to vmap on one device and shards over
  virtual CPU devices (subprocess with XLA_FLAGS, not in the fast gate)
  with bit-identical results.
"""

import dataclasses
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.core import group as group_mod
from repro.core import placement, sst
from repro.core import sweep as sweep_mod

fast = pytest.mark.fast


# ---------------------------------------------------------------------------
# masked round-robin arithmetic
# ---------------------------------------------------------------------------

@fast
def test_rr_prefix_masked_equals_unmasked_on_full_mask():
    rng = np.random.default_rng(1)
    for _ in range(20):
        s = int(rng.integers(1, 9))
        counts = jnp.asarray(rng.integers(0, 6, size=(3, s)), jnp.int32)
        mask = jnp.ones(s, bool)
        got = np.asarray(sst.rr_prefix_masked(counts, mask, s))
        want = np.asarray(sst.rr_prefix(counts))
        np.testing.assert_array_equal(got, want)


@fast
def test_rr_prefix_masked_ignores_padded_suffix():
    rng = np.random.default_rng(2)
    for _ in range(20):
        s = int(rng.integers(1, 6))
        pad = int(rng.integers(1, 5))
        counts = rng.integers(0, 6, size=s)
        padded = np.concatenate(
            [counts, rng.integers(0, 9, size=pad)])       # garbage suffix
        mask = np.arange(s + pad) < s
        got = int(sst.rr_prefix_masked(jnp.asarray(padded, jnp.int32),
                                       jnp.asarray(mask), s))
        want = int(sst.rr_prefix(counts))
        assert got == want, (counts, padded)


@fast
def test_sender_counts_masked_matches_unmasked_prefix():
    rng = np.random.default_rng(3)
    for _ in range(20):
        s = int(rng.integers(1, 6))
        pad = int(rng.integers(0, 4))
        prefix = jnp.asarray(rng.integers(0, 30, size=4), jnp.int32)
        got = np.asarray(sst.sender_counts_masked(prefix, s, s + pad))
        want = np.asarray(sst.sender_counts(prefix, s))
        np.testing.assert_array_equal(got[..., :s], want)


# ---------------------------------------------------------------------------
# masked padded sweep == unpadded sweep (the stacking correctness core)
# ---------------------------------------------------------------------------

def _random_scenario(rng):
    n = int(rng.integers(1, 6))
    s = int(rng.integers(1, n + 1))
    rounds = int(rng.integers(4, 20))
    window = int(rng.choice([2, 4, 8, 1 << 20]))
    sched = rng.integers(0, 3, size=(rounds, s)).astype(np.int32)
    null_send = bool(rng.integers(0, 2))
    return n, s, window, sched, null_send


@fast
def test_masked_padded_scan_matches_unpadded_scan():
    """Pad members and senders with garbage-free suffix slots: the active
    sub-array of every per-round trace must be bit-identical to the
    unpadded scan, and padded sender lanes must never publish."""
    rng = np.random.default_rng(20260730)
    for case in range(25):
        n, s, window, sched, null_send = _random_scenario(rng)
        n_pad = n + int(rng.integers(0, 4))
        s_pad = s + int(rng.integers(0, 4))
        s_pad = min(s_pad, n_pad)              # senders are members
        state = sweep_mod.SweepState.init(n, s)
        _, (batches, app_pub, nulls) = sweep_mod.scan_rounds(
            state, jnp.asarray(sched), window=window, null_send=null_send)
        padded_sched = np.zeros((sched.shape[0], s_pad), np.int32)
        padded_sched[:, :s] = sched
        pstate = sweep_mod.SweepState.init(n_pad, s_pad)
        member_mask = np.arange(n_pad) < n
        sender_mask = np.arange(s_pad) < s
        _, (pbatches, papp, pnulls) = sweep_mod.scan_rounds(
            pstate, jnp.asarray(padded_sched), window=window,
            null_send=null_send, member_mask=jnp.asarray(member_mask),
            sender_mask=jnp.asarray(sender_mask))
        np.testing.assert_array_equal(np.asarray(pbatches)[:, :n],
                                      np.asarray(batches), err_msg=f"case {case}")
        np.testing.assert_array_equal(np.asarray(papp)[:, :s],
                                      np.asarray(app_pub), err_msg=f"case {case}")
        np.testing.assert_array_equal(np.asarray(pnulls)[:, :s],
                                      np.asarray(nulls), err_msg=f"case {case}")
        assert not np.asarray(papp)[:, s:].any(), f"case {case}: padded sender published"
        assert not np.asarray(pnulls)[:, s:].any(), f"case {case}: padded sender sent nulls"


# ---------------------------------------------------------------------------
# G>=8 subgroups: ONE compiled program, bit-identical to sequential runs
# ---------------------------------------------------------------------------

def _hetero_group(n_sub=8, seed=42):
    rng = np.random.default_rng(seed)
    specs = []
    for _ in range(n_sub):
        n = int(rng.integers(2, 6))
        s = int(rng.integers(1, n + 1))
        specs.append(api.SubgroupSpec(
            members=tuple(range(n)), senders=tuple(range(s)),
            msg_size=int(rng.choice([256, 1024])),
            window=int(rng.choice([4, 8, 16])),
            n_messages=int(rng.integers(3, 12))))
    n_nodes = max(len(sp.members) for sp in specs)
    return api.GroupConfig(members=tuple(range(n_nodes)),
                           subgroups=tuple(specs))


@fast
@pytest.mark.parametrize("backend", ["graph", "pallas"])
def test_eight_subgroups_single_trace_bit_identical(backend):
    cfg = _hetero_group()
    g_warm = api.Group(cfg)
    g_warm.run(backend=backend)                # cold: traces (<= once)
    before = len(group_mod.TRACE_EVENTS)
    g = api.Group(cfg)
    r = g.run(backend=backend)
    assert len(group_mod.TRACE_EVENTS) == before, \
        "warm 8-subgroup run re-dispatched/re-traced"
    assert not r.stalled
    for gid, spec in enumerate(cfg.subgroups):
        solo = api.GroupConfig(members=spec.members, subgroups=(spec,),
                               flags=cfg.flags)
        gi = api.Group(solo)
        gi.run(backend=backend)
        stacked, alone = g.delivery_logs[gid], gi.delivery_logs[0]
        assert stacked.delivered_seq == alone.delivered_seq, (backend, gid)
        assert len(stacked.is_app) == len(alone.is_app)
        for x, y in zip(stacked.is_app, alone.is_app):
            np.testing.assert_array_equal(x, y, err_msg=f"{backend} {gid}")


@fast
def test_eight_subgroup_cold_run_is_one_trace():
    # a window no other test uses -> a fresh cache key, one trace exactly
    cfg = _hetero_group(seed=97)
    sub = tuple(dataclasses.replace(s, window=19) for s in cfg.subgroups)
    cfg = dataclasses.replace(cfg, subgroups=sub)
    before = len(group_mod.TRACE_EVENTS)
    api.Group(cfg).run(backend="graph")
    assert len(group_mod.TRACE_EVENTS) == before + 1, \
        "8 subgroups did not compile as ONE program"


# ---------------------------------------------------------------------------
# run_batch: named grid-point shape errors + placement policy
# ---------------------------------------------------------------------------

@fast
def test_run_batch_shape_mismatch_names_grid_point():
    spec = api.SubgroupSpec(members=(0, 1, 2), senders=(0, 1),
                            msg_size=256, window=8, n_messages=4)
    small = api.GroupConfig(members=(0, 1, 2), subgroups=(spec,))
    big = api.GroupConfig(
        members=(0, 1, 2, 3),
        subgroups=(dataclasses.replace(spec, members=(0, 1, 2, 3)),))
    be = group_mod.GraphBackend()
    counts = {0: np.array([4, 4])}
    with pytest.raises(ValueError, match=r"grid point 2"):
        be.run_batch([small, small, big],
                     [counts, counts, counts])


@fast
def test_shard_count_policy():
    n_dev = len(jax.devices())
    assert placement.shard_count(0) == 1
    if n_dev == 1:
        assert placement.shard_count(8) == 1       # vmap fallback
    else:
        assert placement.shard_count(n_dev) == n_dev
        assert 8 % placement.shard_count(8) == 0
    mesh = placement.batch_mesh(1)
    assert mesh.devices.size == 1


_SHARDED_CONFORMANCE = textwrap.dedent("""
    import dataclasses
    import numpy as np
    import jax
    from repro import api
    from repro.core import placement

    assert len(jax.devices()) == 4, jax.devices()
    assert placement.shard_count(8) == 4

    def _cfg(**kw):
        base = dict(n_senders=2, msg_size=512, window=8, n_messages=8)
        base.update(kw)
        n = base.pop("n_nodes", 4)
        return api.single_group(n, **base)

    def check(make_cfg, backend, windows):
        reports = api.Group(make_cfg()).run_batch(backend=backend,
                                                  windows=windows)
        for w, rb in zip(windows, reports):
            base = make_cfg()
            subs = tuple(dataclasses.replace(s, window=w)
                         for s in base.subgroups)
            gi = api.Group(dataclasses.replace(base, subgroups=subs))
            ri = gi.run(backend=backend)
            assert (rb.delivered_app_msgs, rb.nulls_sent, rb.rounds) == \\
                (ri.delivered_app_msgs, ri.nulls_sent, ri.rounds), \\
                (backend, w)
            for gid, log in gi.delivery_logs.items():
                lb = rb.extras["delivery_logs"][gid]
                assert lb.delivered_seq == log.delivered_seq, (backend, w)
                assert all(np.array_equal(x, y)
                           for x, y in zip(lb.is_app, log.is_app)), \\
                    (backend, w)

    # heterogeneous 2-subgroup config: exercises the MASKED sharded path
    def _hetero():
        spec_a = api.SubgroupSpec(members=(0, 1, 2), senders=(0, 1),
                                  msg_size=512, window=8, n_messages=6)
        spec_b = api.SubgroupSpec(members=(0, 1, 2, 3), senders=(2, 3),
                                  msg_size=256, window=4, n_messages=4)
        return api.GroupConfig(members=(0, 1, 2, 3),
                               subgroups=(spec_a, spec_b))

    check(_cfg, "graph", [4, 6, 8, 12, 16, 24, 32, 48])
    check(_cfg, "pallas", [4, 6, 8, 12])
    check(_hetero, "graph", [4, 8, 16, 32])
    print("SHARDED-OK")
""")


def test_run_batch_shards_over_virtual_devices_bit_identically():
    """The multi-device path: grid points shard_mapped over 4 virtual
    CPU devices must be bit-identical to sequential single-device runs —
    on graph AND pallas (the kernel path needs check_rep off in
    shard_map), including a heterogeneous masked multi-subgroup stack.
    Runs in a subprocess because XLA_FLAGS must be set before jax
    initializes (excluded from -m fast; the full tier-1 suite covers it)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        " --xla_force_host_platform_device_count=4").strip()
    src = str(Path(__file__).resolve().parent.parent / "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", _SHARDED_CONFORMANCE],
                          capture_output=True, text=True, timeout=600,
                          env=env)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "SHARDED-OK" in proc.stdout
