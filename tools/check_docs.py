"""Docs-consistency gate (CI step `docs-check`).

Two checks, both hard failures:

1. **DESIGN.md section references resolve.**  Docstrings across `src/`
   cite `DESIGN.md Sec. N`; every cited N must exist as a `## Sec. N`
   heading in DESIGN.md (and DESIGN.md itself must exist).  This is what
   keeps the doc from rotting back into a dangling citation — the state
   this repo was in before PR 4.

2. **README runnable snippets run.**  Fenced code blocks in README.md
   tagged ```` ```python run ```` are executed (in order, one shared
   namespace per block) so the quickstart can't drift from the API.

Run:  PYTHONPATH=src python tools/check_docs.py
"""

from __future__ import annotations

import re
import sys
import time
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
DESIGN = ROOT / "DESIGN.md"
README = ROOT / "README.md"
SRC = ROOT / "src"

SECTION_RE = re.compile(r"^##\s+Sec\.\s*(\d+)", re.MULTILINE)
# whitespace-tolerant: docstring line wraps may split "DESIGN.md Sec. N"
CITE_RE = re.compile(r"DESIGN\.md\s+Sec\.\s*(\d+)")
SNIPPET_RE = re.compile(r"^```python\s+run\s*$(.*?)^```\s*$",
                        re.MULTILINE | re.DOTALL)


def check_design_sections() -> list:
    errors = []
    if not DESIGN.exists():
        return [f"{DESIGN.name} does not exist (cited all over src/)"]
    sections = {int(m) for m in SECTION_RE.findall(DESIGN.read_text())}
    if not sections:
        return [f"{DESIGN.name} has no '## Sec. N' headings"]
    cited = 0
    for path in sorted(SRC.rglob("*.py")):
        text = path.read_text()
        for m in CITE_RE.finditer(text):
            cited += 1
            sec = int(m.group(1))
            if sec not in sections:
                line = text[: m.start()].count("\n") + 1
                errors.append(
                    f"{path.relative_to(ROOT)}:{line}: cites DESIGN.md "
                    f"Sec. {sec}, which does not exist (have "
                    f"{sorted(sections)})")
    print(f"design-refs: {cited} citation(s) across src/ against "
          f"sections {sorted(sections)}")
    if not cited:
        errors.append("no DESIGN.md citations found under src/ — the "
                      "scan pattern or the tree moved")
    return errors


def check_readme_snippets() -> list:
    errors = []
    if not README.exists():
        return ["README.md does not exist"]
    snippets = SNIPPET_RE.findall(README.read_text())
    if not snippets:
        return ["README.md has no '```python run' snippet — the "
                "quickstart must stay executable"]
    for i, code in enumerate(snippets):
        t0 = time.perf_counter()
        try:
            exec(compile(code, f"README.md[snippet {i}]", "exec"), {})
            print(f"readme-snippet {i}: OK "
                  f"({time.perf_counter() - t0:.1f}s)")
        except Exception as e:                      # noqa: BLE001
            errors.append(f"README.md snippet {i} failed: {e!r}")
    return errors


def main() -> int:
    errors = check_design_sections() + check_readme_snippets()
    for e in errors:
        print(f"docs-check FAIL: {e}", file=sys.stderr)
    if not errors:
        print("docs-check passed")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
