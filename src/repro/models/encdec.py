"""Encoder-decoder transformer (SeamlessM4T-medium backbone).

The speech/text modality frontend is a STUB per the assignment: the
encoder consumes precomputed frame embeddings (B, S_src, d_model) directly
(``input_specs`` provides them).  The decoder is a standard causal stack
with cross-attention; decode shapes exercise the decoder with a self-KV
cache plus precomputed cross-KV.

Deviations noted in DESIGN.md: RoPE instead of sinusoidal positions,
RMSNorm instead of LayerNorm (uniform with the rest of the zoo).
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention, layers
from repro.models.config import ModelConfig
from repro.models.layers import ParamSpec
from repro.models.runtime import Runtime

Array = Any
PyTree = Any


def _enc_block_specs(cfg: ModelConfig) -> Dict[str, Any]:
    return {
        "attn_norm": layers.norm_specs(cfg.d_model),
        "attn": attention.attn_specs(cfg),
        "ffn_norm": layers.norm_specs(cfg.d_model),
        "mlp": layers.mlp_specs(cfg.d_model, cfg.d_ff),
    }


def _dec_block_specs(cfg: ModelConfig) -> Dict[str, Any]:
    specs = _enc_block_specs(cfg)
    specs["cross_norm"] = layers.norm_specs(cfg.d_model)
    specs["cross"] = attention.attn_specs(cfg)
    return specs


def encdec_specs(cfg: ModelConfig) -> Dict[str, Any]:
    e = cfg.encdec
    stack = lambda specs, n: jax.tree.map(  # noqa: E731
        lambda s: s.stack_layers(n), specs,
        is_leaf=lambda x: isinstance(x, ParamSpec))
    return {
        "embed": ParamSpec((cfg.vocab_size, cfg.d_model),
                           ("vocab", "fsdp_embed")),
        "encoder": stack(_enc_block_specs(cfg), e.n_encoder_layers),
        "decoder": stack(_dec_block_specs(cfg), e.n_decoder_layers),
        "enc_norm": layers.norm_specs(cfg.d_model),
        "final_norm": layers.norm_specs(cfg.d_model),
        "lm_head": ParamSpec((cfg.d_model, cfg.vocab_size),
                             ("fsdp_embed", "vocab")),
    }


def encode(params: PyTree, cfg: ModelConfig, frames: Array, rt: Runtime
           ) -> Array:
    """frames: (B, S_src, d_model) — stubbed modality frontend output."""

    def body(carry, lp):
        h = layers.rms_norm(carry, lp["attn_norm"]["scale"], cfg.norm_eps)
        carry = carry + attention.full_attention(
            lp["attn"], cfg, h, causal=False, impl=rt.attn_impl)
        h = layers.rms_norm(carry, lp["ffn_norm"]["scale"], cfg.norm_eps)
        m = lp["mlp"]
        carry = carry + layers.swiglu(h, m["w_gate"], m["w_up"], m["w_down"])
        return rt.constrain(carry, "batch", "seq", None), None

    body = rt.checkpoint(body)
    x, _ = jax.lax.scan(body, frames.astype(layers.DEFAULT_DTYPE),
                        params["encoder"])
    return layers.rms_norm(x, params["enc_norm"]["scale"], cfg.norm_eps)


def decode_train(params: PyTree, cfg: ModelConfig, tokens: Array,
                 memory: Array, rt: Runtime) -> Array:
    x = jnp.take(params["embed"], tokens, axis=0).astype(
        layers.DEFAULT_DTYPE)

    def body(carry, lp):
        h = layers.rms_norm(carry, lp["attn_norm"]["scale"], cfg.norm_eps)
        carry = carry + attention.full_attention(
            lp["attn"], cfg, h, causal=True, impl=rt.attn_impl)
        h = layers.rms_norm(carry, lp["cross_norm"]["scale"], cfg.norm_eps)
        carry = carry + attention.cross_attention(lp["cross"], cfg, h,
                                                  memory)
        h = layers.rms_norm(carry, lp["ffn_norm"]["scale"], cfg.norm_eps)
        m = lp["mlp"]
        carry = carry + layers.swiglu(h, m["w_gate"], m["w_up"], m["w_down"])
        return rt.constrain(carry, "batch", "seq", None), None

    body = rt.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["decoder"])
    return x


def seq2seq_loss(params: PyTree, cfg: ModelConfig, batch: Dict[str, Array],
                 rt: Runtime) -> Array:
    """batch: frames (B,S_src,d), tokens (B,S_tgt) targets."""
    memory = encode(params, cfg, batch["frames"], rt)
    x = decode_train(params, cfg, batch["tokens"][:, :-1], memory, rt)
    x = layers.rms_norm(x, params["final_norm"]["scale"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"])
    mask = batch.get("mask")
    return layers.cross_entropy_loss(
        logits, batch["tokens"][:, 1:],
        mask[:, 1:] if mask is not None else None)


# ---------------------------------------------------------------------------
# Decode with self-KV cache + precomputed cross-KV
# ---------------------------------------------------------------------------

def cache_specs(cfg: ModelConfig, batch: int, max_len: int, src_len: int
                ) -> Dict[str, ParamSpec]:
    nl = cfg.encdec.n_decoder_layers
    kv = ("layers", "batch", "seq", "kv_heads", "head_dim")
    return {
        "k": ParamSpec((nl, batch, max_len, cfg.n_kv_heads, cfg.head_dim_),
                       kv),
        "v": ParamSpec((nl, batch, max_len, cfg.n_kv_heads, cfg.head_dim_),
                       kv),
        "cross_k": ParamSpec(
            (nl, batch, src_len, cfg.n_kv_heads, cfg.head_dim_), kv),
        "cross_v": ParamSpec(
            (nl, batch, src_len, cfg.n_kv_heads, cfg.head_dim_), kv),
    }


def decode_step(params: PyTree, cfg: ModelConfig, cache: Dict[str, Array],
                tokens: Array, position: Array, rt: Runtime
                ) -> Tuple[Array, Dict[str, Array]]:
    x = jnp.take(params["embed"], tokens, axis=0).astype(
        layers.DEFAULT_DTYPE)

    def body(carry, xs):
        lp, kc, vc, ck, cv = xs
        h = layers.rms_norm(carry, lp["attn_norm"]["scale"], cfg.norm_eps)
        a, kc, vc = attention.decode_attention(
            lp["attn"], cfg, h, kc, vc, position, impl=rt.attn_impl)
        carry = carry + a
        # cross attention against the precomputed encoder KV
        h = layers.rms_norm(carry, lp["cross_norm"]["scale"], cfg.norm_eps)
        q = jnp.einsum("bsd,dhk->bshk", h, lp["cross"]["wq"])
        if cfg.qkv_bias:
            q = q + lp["cross"]["bq"]
        q = layers.apply_rope(q, jnp.full((h.shape[0], 1), position),
                              cfg.rope_theta)
        o = attention._sdpa(q, ck, cv, causal=False)
        carry = carry + jnp.einsum("bshk,hkd->bsd", o, lp["cross"]["wo"])
        h = layers.rms_norm(carry, lp["ffn_norm"]["scale"], cfg.norm_eps)
        m = lp["mlp"]
        carry = carry + layers.swiglu(h, m["w_gate"], m["w_up"], m["w_down"])
        return carry, (kc, vc)

    x, (ks, vs) = jax.lax.scan(
        body, x, (params["decoder"], cache["k"], cache["v"],
                  cache["cross_k"], cache["cross_v"]))
    x = layers.rms_norm(x, params["final_norm"]["scale"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"])[:, 0]
    return logits, {"k": ks, "v": vs, "cross_k": cache["cross_k"],
                    "cross_v": cache["cross_v"]}
