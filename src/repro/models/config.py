"""Model configuration dataclasses for every supported architecture family.

One frozen dataclass tree describes an architecture completely; builders in
:mod:`repro.configs` instantiate the ten assigned architectures with their
exact published hyperparameters.  ``reduced()`` shrinks any config to a
CPU-smoke-testable size while preserving family semantics.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_routed: int                 # routed experts
    top_k: int
    n_shared: int = 0             # always-on shared experts
    d_ff_expert: int = 0          # per-expert FFN width
    capacity_factor: float = 1.25
    router_z_coef: float = 1e-3   # router z-loss
    aux_coef: float = 1e-2        # load-balance loss
    ep_pad_to: Optional[int] = None   # pad routed experts for EP divisibility


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int                  # N (SSD state size)
    head_dim: int = 64            # P
    expand: int = 2               # d_inner = expand * d_model
    chunk: int = 256              # SSD chunk length
    conv_width: int = 4
    n_groups: int = 1             # B/C groups (GVA)


@dataclasses.dataclass(frozen=True)
class HybridConfig:
    attn_every: int = 6           # shared attention block period (Zamba2)
    n_shared_blocks: int = 1      # distinct shared transformer blocks


@dataclasses.dataclass(frozen=True)
class EncDecConfig:
    n_encoder_layers: int
    n_decoder_layers: int
    frontend_dim: int = 80        # stub: precomputed frame features dim


@dataclasses.dataclass(frozen=True)
class VLMConfig:
    n_patches: int = 256          # stub: precomputed patch embeddings
    vision_dim: int = 3200        # InternViT-6B width (projector input)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 1e4
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    hybrid: Optional[HybridConfig] = None
    encdec: Optional[EncDecConfig] = None
    vlm: Optional[VLMConfig] = None
    # which shapes this arch cannot run, with the reason (DESIGN.md Sec. 5)
    skip_shapes: Tuple[Tuple[str, str], ...] = ()

    @property
    def head_dim_(self) -> int:
        if self.head_dim is not None:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    def param_count(self) -> int:
        """Approximate parameter count (exact for what we instantiate)."""
        from repro.models import registry  # lazy, avoids cycle
        import numpy as np
        specs = registry.param_specs(self)
        import jax
        return int(sum(np.prod(s.shape, dtype=np.int64)
                       for s in jax.tree.leaves(specs)))

    def active_param_count(self) -> int:
        """Active params per token (MoE counts shared + top_k experts)."""
        if self.moe is None:
            return self.param_count()
        total = self.param_count()
        import numpy as np
        m = self.moe
        per_expert = 3 * self.d_model * m.d_ff_expert
        inactive = self.n_layers * (m.n_routed - m.top_k) * per_expert
        return int(total - inactive)

    def reduced(self) -> "ModelConfig":
        """A tiny same-family config for CPU smoke tests."""
        changes = dict(
            n_layers=min(self.n_layers, 2 if self.hybrid is None else 4),
            d_model=128,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads else 0,
            d_ff=256,
            vocab_size=512,
            head_dim=32,
        )
        if self.moe:
            changes["moe"] = dataclasses.replace(
                self.moe, n_routed=8, top_k=2,
                n_shared=min(self.moe.n_shared, 2), d_ff_expert=64,
                ep_pad_to=None)
        if self.ssm:
            changes["ssm"] = dataclasses.replace(
                self.ssm, d_state=16, head_dim=16, chunk=32)
        if self.encdec:
            changes["encdec"] = dataclasses.replace(
                self.encdec, n_encoder_layers=2, n_decoder_layers=2)
        if self.hybrid:
            changes["hybrid"] = dataclasses.replace(self.hybrid,
                                                    attn_every=2)
        if self.vlm:
            changes["vlm"] = dataclasses.replace(
                self.vlm, n_patches=8, vision_dim=64)
        return dataclasses.replace(self, **changes)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One (input shape x step kind) cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: str          # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", 4096, 256, "train"),
    ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    ShapeConfig("decode_32k", 32768, 128, "decode"),
    ShapeConfig("long_500k", 524288, 1, "decode"),
)


def shape_by_name(name: str) -> ShapeConfig:
    for s in SHAPES:
        if s.name == name:
            return s
    raise KeyError(name)
