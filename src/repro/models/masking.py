"""Validity-masked decode-state updates (DESIGN.md Sec. 6).

The serve plane's slot ring decodes every slot of the batch each round,
active or not: an idle/stalled slot still flows through the decode step
so the round stays one fused program.  For position-addressed state (KV
caches) the idle slot's garbage write lands at its own next position and
is overwritten before any read — harmless.  Recurrent families
(ssm/hybrid) mutate state *cumulatively* every step, so the same trick
corrupts them; what they need is the null-round idea of
:mod:`repro.core.gradsync` applied to decode: an invalid slot's state
update is a masked no-op, its old rows carried through bit-unchanged.

:func:`masked_update` implements that generically over any family's
cache pytree: each :class:`~repro.models.layers.ParamSpec` leaf names
its logical axes, so the per-slot validity vector is broadcast along the
leaf's ``"batch"`` axis wherever it sits (axis 1 for dense/ssm/encdec
leaves, axis 2 for the hybrid family's per-super-block state).  Applied
uniformly it also makes the KV write-then-overwrite dance explicit and
unnecessary — the masked form is what the fused serve program
(:mod:`repro.serve.fused`) scans, and it is bit-identical to the
unmasked engine loop for KV families by the overwrite argument above.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models.layers import ParamSpec

PyTree = Any


def batch_axis(spec: ParamSpec) -> int:
    """Index of the ``"batch"`` axis in a cache leaf's logical axes."""
    if "batch" not in spec.axes:
        raise ValueError(f"cache leaf has no batch axis: {spec.axes}")
    return spec.axes.index("batch")


def reset_rows(specs: PyTree, cache: PyTree, valid) -> PyTree:
    """Zero the cache rows of slots where ``valid`` — the admission
    reset.

    A freed slot's KV rows are harmlessly stale (position-overwritten by
    the next request's prefill before any read), but recurrent state is
    CUMULATIVE: without this reset a reused slot would prefill on top of
    the previous request's final ssm/conv state.  Applied uniformly at
    admission — KV families are output-unchanged by the overwrite
    argument, recurrent families become correct — in both the per-round
    engine (:meth:`repro.serve.engine.ServeEngine._prefill_slot`) and
    the fused serve program, so the two paths stay bit-identical."""
    valid = jnp.asarray(valid, bool)

    def leaf(spec, o):
        ax = batch_axis(spec)
        shape = [1] * o.ndim
        shape[ax] = valid.shape[0]
        return jnp.where(valid.reshape(shape), jnp.zeros_like(o), o)

    return jax.tree.map(leaf, specs, cache,
                        is_leaf=lambda x: isinstance(x, ParamSpec))


def masked_update(specs: PyTree, old: PyTree, new: PyTree,
                  valid) -> PyTree:
    """``where(valid, new, old)`` per cache leaf, ``valid`` broadcast
    along each leaf's batch axis.

    ``specs`` is the :func:`repro.models.registry.cache_specs` pytree
    describing ``old``/``new`` (same treedef); ``valid`` is a ``(B,)``
    bool vector — slot ``b``'s state advances only where
    ``valid[b]``.  Invalid slots keep their old rows bit-for-bit (the
    null-round no-op), which is what lets recurrent decode state ride
    the slot ring."""
    valid = jnp.asarray(valid, bool)

    def leaf(spec, o, n):
        ax = batch_axis(spec)
        shape = [1] * n.ndim
        shape[ax] = valid.shape[0]
        return jnp.where(valid.reshape(shape), n, o)

    return jax.tree.map(leaf, specs, old, new,
                        is_leaf=lambda x: isinstance(x, ParamSpec))
