"""Runtime context: mesh, sharding rules and implementation switches.

A single :class:`Runtime` is threaded through every forward function; the
dry-run, the trainer and the serving engine build different ones.  All of
its fields are hillclimbing levers for the Sec.-Perf loop: logical->mesh
rules, remat policy, attention/SSD kernel implementation, and the gradient
reduction mode (GSPMD-implicit vs Spindle fused buckets vs compressed).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers


@dataclasses.dataclass(frozen=True)
class Runtime:
    mesh: Optional[jax.sharding.Mesh] = None
    rules: Optional[Dict[str, Any]] = None
    attn_impl: str = "xla"            # xla | pallas
    ssm_impl: str = "xla"             # xla | pallas
    remat: str = "full"               # none | full | dots
    dp_axes: Tuple[str, ...] = ("data",)
    ep_axis: Optional[str] = "model"
    gradsync: str = "gspmd"           # gspmd | spindle | spindle_compressed

    def rules_(self) -> Dict[str, Any]:
        return self.rules if self.rules is not None else layers.DEFAULT_RULES

    @property
    def spmd(self) -> bool:
        return self.mesh is not None and len(self.mesh.devices.flatten()) > 1

    def constrain(self, x, *logical_axes):
        """Apply a sharding constraint by logical axis names (None entries
        = replicated dims).  No-op off-mesh."""
        if not self.spmd:
            return x
        from jax.sharding import NamedSharding, PartitionSpec as P
        rules = self.rules_()
        spec = []
        used = set()
        for dim, name in zip(x.shape, logical_axes):
            target = rules.get(name) if name else None
            if target is None:
                spec.append(None)
                continue
            axes = target if isinstance(target, tuple) else (target,)
            # a mesh axis can shard at most one dim: first logical axis
            # in the rules wins (e.g. seq@model beats mlp@model under the
            # sequence-parallel presets)
            axes = tuple(a for a in axes
                         if a in self.mesh.shape and a not in used)
            import numpy as np
            size = int(np.prod([self.mesh.shape[a] for a in axes])) or 1
            if not axes or dim % size != 0:
                spec.append(None)
            else:
                used.update(axes)
                spec.append(axes if len(axes) > 1 else axes[0])
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, P(*spec)))

    def checkpoint(self, fn):
        if self.remat == "none":
            return fn
        if self.remat == "dots":
            return jax.checkpoint(
                fn, policy=jax.checkpoint_policies.checkpoint_dots)
        return jax.checkpoint(fn)

    def moe_ep_size(self) -> int:
        if not self.spmd or self.ep_axis not in (self.mesh.shape if self.mesh else {}):
            return 1
        return int(self.mesh.shape[self.ep_axis])


CPU_RUNTIME = Runtime()
