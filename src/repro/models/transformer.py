"""Decoder-only LM: dense and MoE variants, train / prefill / decode.

Layers are scanned over stacked parameters so the HLO stays one-block-sized
at any depth (essential for 512-device dry-run compiles), with a
configurable remat policy.  The MoE FFN runs under shard_map expert
parallelism when a mesh is present (see repro.models.moe).
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention, layers, moe
from repro.models.config import ModelConfig
from repro.models.layers import ParamSpec
from repro.models.runtime import Runtime

Array = Any
PyTree = Any


# ---------------------------------------------------------------------------
# Parameter specs
# ---------------------------------------------------------------------------

def block_specs(cfg: ModelConfig) -> Dict[str, Any]:
    specs: Dict[str, Any] = {
        "attn_norm": layers.norm_specs(cfg.d_model),
        "attn": attention.attn_specs(cfg),
        "ffn_norm": layers.norm_specs(cfg.d_model),
    }
    if cfg.moe is not None:
        specs["moe"] = moe.moe_specs(cfg)
    else:
        specs["mlp"] = layers.mlp_specs(cfg.d_model, cfg.d_ff)
    return specs


def stack_block_specs(cfg: ModelConfig, n_layers: int) -> Dict[str, Any]:
    base = block_specs(cfg)
    return jax.tree.map(lambda s: s.stack_layers(n_layers), base,
                        is_leaf=lambda x: isinstance(x, ParamSpec))


def lm_specs(cfg: ModelConfig) -> Dict[str, Any]:
    specs = {
        "embed": ParamSpec((cfg.vocab_size, cfg.d_model),
                           ("vocab", "fsdp_embed")),
        "layers": stack_block_specs(cfg, cfg.n_layers),
        "final_norm": layers.norm_specs(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        specs["lm_head"] = ParamSpec((cfg.d_model, cfg.vocab_size),
                                     ("fsdp_embed", "vocab"))
    return specs


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------

def _ffn(p: Dict[str, Array], cfg: ModelConfig, x: Array, rt: Runtime
         ) -> Tuple[Array, Array]:
    if cfg.moe is None:
        m = p["mlp"]
        return layers.swiglu(x, m["w_gate"], m["w_up"], m["w_down"],
                             constrain=rt.constrain), \
            jnp.zeros((), jnp.float32)
    ep = rt.moe_ep_size()
    if ep <= 1:
        return moe.moe_block(p["moe"], cfg, x, ep_axis=None)
    from jax.sharding import PartitionSpec as P
    from jax import shard_map
    batch_axes = tuple(a for a in ("pod", "data") if a in rt.mesh.shape)
    tok_spec = P(batch_axes if len(batch_axes) > 1 else batch_axes[0],
                 None, None) if batch_axes else P(None, None, None)
    expert_specs = {
        "router": P(None, None),
        "w_gate": P("model", None, None),
        "w_up": P("model", None, None),
        "w_down": P("model", None, None),
    }
    if cfg.moe.n_shared:
        expert_specs["shared"] = {k: P(None, None)
                                  for k in ("w_gate", "w_up", "w_down")}

    def _moe_local(pp, xx):
        return moe.moe_block(pp, cfg, xx, ep_axis=rt.ep_axis)

    fn = shard_map(
        _moe_local,
        mesh=rt.mesh,
        in_specs=(expert_specs, tok_spec),
        out_specs=(tok_spec, P()),
        check_vma=False,
    )
    return fn(p["moe"], x)


def block(p: Dict[str, Array], cfg: ModelConfig, x: Array, rt: Runtime
          ) -> Tuple[Array, Array]:
    """One decoder block: pre-norm attn + pre-norm FFN.  x: (B, S, d)."""
    h = layers.rms_norm(x, p["attn_norm"]["scale"], cfg.norm_eps)
    x = x + attention.full_attention(p["attn"], cfg, h, causal=True,
                                     impl=rt.attn_impl)
    x = rt.constrain(x, "batch", "seq", None)
    h = layers.rms_norm(x, p["ffn_norm"]["scale"], cfg.norm_eps)
    y, aux = _ffn(p, cfg, h, rt)
    x = x + y
    return rt.constrain(x, "batch", "seq", None), aux


def decode_block(p: Dict[str, Array], cfg: ModelConfig, x: Array,
                 k_cache: Array, v_cache: Array, position: Array,
                 rt: Runtime) -> Tuple[Array, Array, Array, Array]:
    h = layers.rms_norm(x, p["attn_norm"]["scale"], cfg.norm_eps)
    a, k_cache, v_cache = attention.decode_attention(
        p["attn"], cfg, h, k_cache, v_cache, position, impl=rt.attn_impl)
    x = x + a
    h = layers.rms_norm(x, p["ffn_norm"]["scale"], cfg.norm_eps)
    y, aux = _ffn(p, cfg, h, rt)
    del aux
    return x + y, k_cache, v_cache


# ---------------------------------------------------------------------------
# Model-level forward / loss / prefill / decode
# ---------------------------------------------------------------------------

def embed(params: PyTree, cfg: ModelConfig, tokens: Array,
          rt: Runtime) -> Array:
    x = jnp.take(params["embed"], tokens, axis=0).astype(layers.DEFAULT_DTYPE)
    return rt.constrain(x, "batch", "seq", None)


def unembed(params: PyTree, cfg: ModelConfig, x: Array, rt: Runtime) -> Array:
    x = layers.rms_norm(x, params["final_norm"]["scale"], cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, params["embed"])
    else:
        logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"])
    return rt.constrain(logits, "batch", None, "vocab")


def forward(params: PyTree, cfg: ModelConfig, x: Array, rt: Runtime,
            ) -> Tuple[Array, Array]:
    """Run the scanned decoder stack on embedded inputs.
    Returns (hidden (B,S,d), total moe aux loss)."""

    def body(carry, lp):
        h, aux = block(lp, cfg, carry, rt)
        return h, aux

    body = rt.checkpoint(body)
    x, auxs = jax.lax.scan(body, x, params["layers"])
    return x, jnp.sum(auxs)


def lm_loss(params: PyTree, cfg: ModelConfig, batch: Dict[str, Array],
            rt: Runtime) -> Array:
    """Next-token CE over `tokens`; `mask` marks valid target positions."""
    tokens = batch["tokens"]
    x = embed(params, cfg, tokens, rt)
    x, aux = forward(params, cfg, x, rt)
    logits = unembed(params, cfg, x[:, :-1], rt)
    labels = tokens[:, 1:]
    mask = batch.get("mask")
    mask = mask[:, 1:] if mask is not None else None
    return layers.cross_entropy_loss(logits, labels, mask) + aux


def prefill(params: PyTree, cfg: ModelConfig, tokens: Array, rt: Runtime
            ) -> Tuple[Array, Dict[str, Array]]:
    """Full forward that also materializes the KV cache.
    Returns (last-position logits (B,V), cache {k,v: (L,B,S,Hkv,D)})."""

    def body(carry, lp):
        h = layers.rms_norm(carry, lp["attn_norm"]["scale"], cfg.norm_eps)
        positions = jnp.arange(carry.shape[1])[None, :]
        q, k, v = attention._project_qkv(lp["attn"], cfg, h, positions)
        if rt.attn_impl == "chunked":
            o = attention._sdpa_chunked(q, k, v, causal=True)
        else:
            o = attention._sdpa(q, k, v, causal=True)
        a = jnp.einsum("bshk,hkd->bsd", o, lp["attn"]["wo"])
        x = carry + a
        hh = layers.rms_norm(x, lp["ffn_norm"]["scale"], cfg.norm_eps)
        y, _ = _ffn(lp, cfg, hh, rt)
        return x + y, (k, v)

    body = rt.checkpoint(body)
    x = embed(params, cfg, tokens, rt)
    x, (ks, vs) = jax.lax.scan(body, x, params["layers"])
    logits = unembed(params, cfg, x[:, -1:], rt)[:, 0]
    return logits, {"k": ks, "v": vs}


def decode_step(params: PyTree, cfg: ModelConfig, cache: Dict[str, Array],
                tokens: Array, position: Array, rt: Runtime
                ) -> Tuple[Array, Dict[str, Array]]:
    """One decode step.  tokens: (B, 1) int32; position: scalar int32;
    cache arrays (L, B, S_max, Hkv, D), donated by the caller."""
    x = embed(params, cfg, tokens, rt)

    def body(carry, xs):
        lp, kc, vc = xs
        h, kc, vc = decode_block(lp, cfg, carry, kc, vc, position, rt)
        return h, (kc, vc)

    x, (ks, vs) = jax.lax.scan(body, x, (params["layers"],
                                         cache["k"], cache["v"]))
    logits = unembed(params, cfg, x, rt)[:, 0]
    return logits, {"k": ks, "v": vs}
