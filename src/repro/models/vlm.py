"""InternVL2-style VLM: stubbed vision frontend + InternLM2 LM backbone.

Per the assignment the modality frontend is a STUB: ``input_specs``
provides precomputed InternViT patch embeddings (B, n_patches, vision_dim);
here they pass through the 2-layer MLP projector into the LM embedding
space and are prepended to the text embeddings.  Loss is computed on text
positions only.  Decode reuses the plain decoder-only path (the image
tokens live in the prompt/KV cache).
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers, transformer
from repro.models.config import ModelConfig
from repro.models.layers import ParamSpec
from repro.models.runtime import Runtime

Array = Any
PyTree = Any


def vlm_specs(cfg: ModelConfig) -> Dict[str, Any]:
    specs = transformer.lm_specs(cfg)
    v = cfg.vlm
    specs["projector"] = {
        "norm": layers.norm_specs(v.vision_dim),
        "w1": ParamSpec((v.vision_dim, cfg.d_model), (None, "fsdp_embed")),
        "w2": ParamSpec((cfg.d_model, cfg.d_model),
                        (None, "fsdp_embed")),
    }
    return specs


def project_patches(params: PyTree, cfg: ModelConfig, patches: Array
                    ) -> Array:
    p = params["projector"]
    x = layers.rms_norm(patches.astype(layers.DEFAULT_DTYPE),
                        p["norm"]["scale"], cfg.norm_eps)
    x = jnp.einsum("bpd,de->bpe", x, p["w1"])
    x = jax.nn.gelu(x.astype(jnp.float32)).astype(layers.DEFAULT_DTYPE)
    return jnp.einsum("bpd,de->bpe", x, p["w2"])


def vlm_loss(params: PyTree, cfg: ModelConfig, batch: Dict[str, Array],
             rt: Runtime) -> Array:
    """batch: patches (B, P, vision_dim), tokens (B, S_text).
    The combined sequence is [patches; text]; CE on text positions."""
    patches, tokens = batch["patches"], batch["tokens"]
    vis = project_patches(params, cfg, patches)
    txt = transformer.embed(params, cfg, tokens, rt)
    x = jnp.concatenate([vis, txt], axis=1)
    x = rt.constrain(x, "batch", "seq", None)
    x, aux = transformer.forward(params, cfg, x, rt)
    n_p = patches.shape[1]
    # predict text token t+1 from position n_p + t - 1
    x_text = x[:, n_p - 1:-1]
    logits = transformer.unembed(params, cfg, x_text, rt)
    mask = batch.get("mask")
    return layers.cross_entropy_loss(logits, tokens, mask) + aux


def prefill(params: PyTree, cfg: ModelConfig, batch: Dict[str, Array],
            rt: Runtime) -> Tuple[Array, Dict[str, Array]]:
    """Multimodal prefill: embeds [patches; text] and fills the KV cache."""
    vis = project_patches(params, cfg, batch["patches"])
    txt = transformer.embed(params, cfg, batch["tokens"], rt)
    x = jnp.concatenate([vis, txt], axis=1)

    def body(carry, lp):
        from repro.models import attention
        h = layers.rms_norm(carry, lp["attn_norm"]["scale"], cfg.norm_eps)
        positions = jnp.arange(carry.shape[1])[None, :]
        q, k, v = attention._project_qkv(lp["attn"], cfg, h, positions)
        if rt.attn_impl == "chunked":
            o = attention._sdpa_chunked(q, k, v, causal=True)
        else:
            o = attention._sdpa(q, k, v, causal=True)
        carry = carry + jnp.einsum("bshk,hkd->bsd", o, lp["attn"]["wo"])
        h = layers.rms_norm(carry, lp["ffn_norm"]["scale"], cfg.norm_eps)
        y, _ = transformer._ffn(lp, cfg, h, rt)
        return carry + y, (k, v)

    body = rt.checkpoint(body)
    x, (ks, vs) = jax.lax.scan(body, x, params["layers"])
    logits = transformer.unembed(params, cfg, x[:, -1:], rt)[:, 0]
    return logits, {"k": ks, "v": vs}


# decode: identical to the plain LM decoder (image tokens are in the cache)
decode_step = transformer.decode_step
