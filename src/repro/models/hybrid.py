"""Zamba2-style hybrid: a backbone of Mamba2 blocks with ONE shared
attention+MLP transformer block invoked periodically (weight reuse).

Structure (arXiv:2411.15242, simplified): ``n_layers`` Mamba2 blocks; after
every ``attn_every``-th block the shared transformer block runs (same
parameters each invocation — Zamba2's signature parameter-sharing trick).
The original concatenates the embedding output with the hidden state at
shared-block inputs and applies per-invocation LoRAs; we keep the shared
block + periodic schedule and note the simplification in DESIGN.md.

Scan layout: mamba layers are stacked (G, attn_every, ...) and scanned as
G super-blocks of ``attn_every`` layers, the shared block applying once per
super-block — HLO stays two-blocks-sized at any depth.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention, layers, ssm
from repro.models.config import ModelConfig
from repro.models.layers import ParamSpec
from repro.models.runtime import Runtime

Array = Any
PyTree = Any


def _groups(cfg: ModelConfig) -> Tuple[int, int]:
    k = cfg.hybrid.attn_every
    assert cfg.n_layers % k == 0, (cfg.n_layers, k)
    return cfg.n_layers // k, k


def hybrid_specs(cfg: ModelConfig) -> Dict[str, Any]:
    g, k = _groups(cfg)
    mamba = {
        "norm": layers.norm_specs(cfg.d_model),
        "ssm": ssm.ssm_specs(cfg),
    }
    stacked = jax.tree.map(
        lambda s: ParamSpec((g, k) + s.shape, ("layers", "layers") + s.axes,
                            s.dtype, s.init),
        mamba, is_leaf=lambda x: isinstance(x, ParamSpec))
    shared = {
        "attn_norm": layers.norm_specs(cfg.d_model),
        "attn": attention.attn_specs(cfg),
        "ffn_norm": layers.norm_specs(cfg.d_model),
        "mlp": layers.mlp_specs(cfg.d_model, cfg.d_ff),
    }
    return {
        "embed": ParamSpec((cfg.vocab_size, cfg.d_model),
                           ("vocab", "fsdp_embed")),
        "mamba_layers": stacked,
        "shared_block": shared,
        "final_norm": layers.norm_specs(cfg.d_model),
        "lm_head": ParamSpec((cfg.d_model, cfg.vocab_size),
                             ("fsdp_embed", "vocab")),
    }


def _shared_block(p: Dict[str, Array], cfg: ModelConfig, x: Array,
                  rt: Runtime) -> Array:
    h = layers.rms_norm(x, p["attn_norm"]["scale"], cfg.norm_eps)
    x = x + attention.full_attention(p["attn"], cfg, h, causal=True,
                                     impl=rt.attn_impl)
    h = layers.rms_norm(x, p["ffn_norm"]["scale"], cfg.norm_eps)
    m = p["mlp"]
    return x + layers.swiglu(h, m["w_gate"], m["w_up"], m["w_down"])


def forward(params: PyTree, cfg: ModelConfig, x: Array, rt: Runtime) -> Array:
    g, k = _groups(cfg)
    shared = params["shared_block"]

    def super_block(carry, lp):
        def mamba_one(c, lpi):
            h = layers.rms_norm(c, lpi["norm"]["scale"], cfg.norm_eps)
            c = c + ssm.mamba_block(lpi["ssm"], cfg, h, impl=rt.ssm_impl)
            return rt.constrain(c, "batch", "seq", None), None

        carry, _ = jax.lax.scan(mamba_one, carry, lp)
        carry = _shared_block(shared, cfg, carry, rt)
        return rt.constrain(carry, "batch", "seq", None), None

    super_block = rt.checkpoint(super_block)
    x, _ = jax.lax.scan(super_block, x, params["mamba_layers"])
    return x


def lm_loss(params: PyTree, cfg: ModelConfig, batch: Dict[str, Array],
            rt: Runtime) -> Array:
    tokens = batch["tokens"]
    x = jnp.take(params["embed"], tokens, axis=0).astype(
        layers.DEFAULT_DTYPE)
    x = forward(params, cfg, x, rt)
    x = layers.rms_norm(x[:, :-1], params["final_norm"]["scale"],
                        cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"])
    mask = batch.get("mask")
    return layers.cross_entropy_loss(
        logits, tokens[:, 1:], mask[:, 1:] if mask is not None else None)


# ---------------------------------------------------------------------------
# Decode: SSM states for every mamba layer + ONE KV cache for the shared
# block per invocation group (the shared block still attends at g points).
# ---------------------------------------------------------------------------

def cache_specs(cfg: ModelConfig, batch: int, max_len: int
                ) -> Dict[str, Any]:
    g, k = _groups(cfg)
    d_inner = cfg.ssm.expand * cfg.d_model
    nh = d_inner // cfg.ssm.head_dim
    conv_dim = d_inner + 2 * cfg.ssm.n_groups * cfg.ssm.d_state
    return {
        "ssm_state": ParamSpec(
            (g, k, batch, nh, cfg.ssm.head_dim, cfg.ssm.d_state),
            ("layers", "layers", "batch", "ssm_heads", "head_dim",
             "ssm_state"), dtype=jnp.float32),
        "conv_state": ParamSpec(
            (g, k, batch, cfg.ssm.conv_width - 1, conv_dim),
            ("layers", "layers", "batch", None, "ssm_inner")),
        # shared attention block: one KV cache per invocation group
        "k": ParamSpec((g, batch, max_len, cfg.n_kv_heads, cfg.head_dim_),
                       ("layers", "batch", "seq", "kv_heads", "head_dim")),
        "v": ParamSpec((g, batch, max_len, cfg.n_kv_heads, cfg.head_dim_),
                       ("layers", "batch", "seq", "kv_heads", "head_dim")),
    }


def decode_step(params: PyTree, cfg: ModelConfig, cache: Dict[str, Array],
                tokens: Array, position: Array, rt: Runtime
                ) -> Tuple[Array, Dict[str, Array]]:
    x = jnp.take(params["embed"], tokens, axis=0).astype(
        layers.DEFAULT_DTYPE)
    shared = params["shared_block"]

    def super_block(carry, xs):
        lp, sstate, cstate, kc, vc = xs

        def mamba_one(c, xsi):
            lpi, ss, cs = xsi
            h = layers.rms_norm(c, lpi["norm"]["scale"], cfg.norm_eps)
            o, ss, cs = ssm.mamba_decode_block(lpi["ssm"], cfg, h, ss, cs)
            return c + o, (ss, cs)

        carry, (sstate, cstate) = jax.lax.scan(
            mamba_one, carry, (lp, sstate, cstate))
        h = layers.rms_norm(carry, shared["attn_norm"]["scale"],
                            cfg.norm_eps)
        a, kc, vc = attention.decode_attention(
            shared["attn"], cfg, h, kc, vc, position, impl=rt.attn_impl)
        carry = carry + a
        h = layers.rms_norm(carry, shared["ffn_norm"]["scale"], cfg.norm_eps)
        m = shared["mlp"]
        carry = carry + layers.swiglu(h, m["w_gate"], m["w_up"], m["w_down"])
        return carry, (sstate, cstate, kc, vc)

    x, (ss, cs, ks, vs) = jax.lax.scan(
        super_block, x,
        (params["mamba_layers"], cache["ssm_state"], cache["conv_state"],
         cache["k"], cache["v"]))
    x = layers.rms_norm(x, params["final_norm"]["scale"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"])[:, 0]
    return logits, {"ssm_state": ss, "conv_state": cs, "k": ks, "v": vs}
