"""Architecture registry: ``--arch <id>`` resolves here.

For every architecture this module answers:
  * ``param_specs(cfg)``      — the full parameter pytree (ParamSpec leaves)
  * ``loss_fn(cfg)``          — train-step loss callable
  * ``prefill_fn / decode_fn``— serving entry points
  * ``input_specs(cfg, shape)``— ShapeDtypeStruct stand-ins for every input
  * ``cache_specs(cfg, shape)``— decode-state pytree for decode shapes
  * ``skip_reason(cfg, shape)``— why a cell is skipped (or None)

The ten assigned architecture configs live in :mod:`repro.configs`.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import (attention, encdec, hybrid, layers, ssm,
                          transformer, vlm)
from repro.models.config import ModelConfig, ShapeConfig, shape_by_name
from repro.models.layers import ParamSpec
from repro.models.runtime import Runtime

Array = Any
PyTree = Any


# ---------------------------------------------------------------------------
# ssm-family LM (mamba2): thin assembly over ssm.py blocks
# ---------------------------------------------------------------------------

def _ssm_lm_specs(cfg: ModelConfig) -> Dict[str, Any]:
    block = {"norm": layers.norm_specs(cfg.d_model),
             "ssm": ssm.ssm_specs(cfg)}
    stacked = jax.tree.map(lambda s: s.stack_layers(cfg.n_layers), block,
                           is_leaf=lambda x: isinstance(x, ParamSpec))
    return {
        "embed": ParamSpec((cfg.vocab_size, cfg.d_model),
                           ("vocab", "fsdp_embed")),
        "layers": stacked,
        "final_norm": layers.norm_specs(cfg.d_model),
        "lm_head": ParamSpec((cfg.d_model, cfg.vocab_size),
                             ("fsdp_embed", "vocab")),
    }


def _ssm_lm_loss(params, cfg: ModelConfig, batch, rt: Runtime):
    tokens = batch["tokens"]
    x = jnp.take(params["embed"], tokens, axis=0).astype(
        layers.DEFAULT_DTYPE)

    def body(carry, lp):
        h = layers.rms_norm(carry, lp["norm"]["scale"], cfg.norm_eps)
        carry = carry + ssm.mamba_block(lp["ssm"], cfg, h, impl=rt.ssm_impl)
        return rt.constrain(carry, "batch", "seq", None), None

    body = rt.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["layers"])
    x = layers.rms_norm(x[:, :-1], params["final_norm"]["scale"],
                        cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"])
    mask = batch.get("mask")
    return layers.cross_entropy_loss(
        logits, tokens[:, 1:], mask[:, 1:] if mask is not None else None)


def _ssm_decode_step(params, cfg: ModelConfig, cache, tokens, position,
                     rt: Runtime):
    x = jnp.take(params["embed"], tokens, axis=0).astype(
        layers.DEFAULT_DTYPE)

    def body(carry, xs):
        lp, sstate, cstate = xs
        h = layers.rms_norm(carry, lp["norm"]["scale"], cfg.norm_eps)
        o, sstate, cstate = ssm.mamba_decode_block(lp["ssm"], cfg, h,
                                                   sstate, cstate)
        return carry + o, (sstate, cstate)

    x, (ss, cs) = jax.lax.scan(body, x, (params["layers"],
                                         cache["ssm_state"],
                                         cache["conv_state"]))
    x = layers.rms_norm(x, params["final_norm"]["scale"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"])[:, 0]
    return logits, {"ssm_state": ss, "conv_state": cs}


# ---------------------------------------------------------------------------
# Arch record
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Arch:
    cfg: ModelConfig

    # -- parameters ---------------------------------------------------------
    def param_specs(self) -> PyTree:
        return param_specs(self.cfg)

    # -- train --------------------------------------------------------------
    def loss_fn(self) -> Callable:
        f = self.cfg.family
        if f in ("dense", "moe"):
            return transformer.lm_loss
        if f == "ssm":
            return _ssm_lm_loss
        if f == "hybrid":
            return hybrid.lm_loss
        if f == "encdec":
            return encdec.seq2seq_loss
        if f == "vlm":
            return vlm.vlm_loss
        raise KeyError(f)

    # -- serve ----------------------------------------------------------------
    def prefill_fn(self) -> Callable:
        f = self.cfg.family
        if f in ("dense", "moe"):
            return lambda p, b, rt: transformer.prefill(
                p, self.cfg, b["tokens"], rt)
        if f == "vlm":
            return lambda p, b, rt: vlm.prefill(p, self.cfg, b, rt)
        if f == "encdec":
            def _enc_prefill(p, b, rt):
                memory = encdec.encode(p, self.cfg, b["frames"], rt)
                return memory, {}
            return _enc_prefill
        if f in ("ssm", "hybrid"):
            # prefill for recurrent families == chunked forward; lowered as
            # the train-shaped forward without loss
            return None
        raise KeyError(f)

    def decode_fn(self) -> Callable:
        f = self.cfg.family
        if f in ("dense", "moe", "vlm"):
            return transformer.decode_step
        if f == "ssm":
            return _ssm_decode_step
        if f == "hybrid":
            return hybrid.decode_step
        if f == "encdec":
            return encdec.decode_step
        raise KeyError(f)

    # -- shapes -----------------------------------------------------------------
    def skip_reason(self, shape: ShapeConfig) -> Optional[str]:
        for name, reason in self.cfg.skip_shapes:
            if name == shape.name:
                return reason
        return None

    def input_specs(self, shape: ShapeConfig, *, batch_override=None
                    ) -> Dict[str, jax.ShapeDtypeStruct]:
        return input_specs(self.cfg, shape, batch_override=batch_override)

    def cache_specs(self, shape: ShapeConfig, *, batch_override=None
                    ) -> PyTree:
        return cache_specs(self.cfg, shape, batch_override=batch_override)


# ---------------------------------------------------------------------------
# Free functions (dispatch on family)
# ---------------------------------------------------------------------------

def param_specs(cfg: ModelConfig) -> PyTree:
    f = cfg.family
    if f in ("dense", "moe"):
        return transformer.lm_specs(cfg)
    if f == "ssm":
        return _ssm_lm_specs(cfg)
    if f == "hybrid":
        return hybrid.hybrid_specs(cfg)
    if f == "encdec":
        return encdec.encdec_specs(cfg)
    if f == "vlm":
        return vlm.vlm_specs(cfg)
    raise KeyError(f)


def input_specs(cfg: ModelConfig, shape: ShapeConfig, *,
                batch_override: Optional[int] = None
                ) -> Dict[str, jax.ShapeDtypeStruct]:
    b = batch_override if batch_override is not None else shape.global_batch
    s = shape.seq_len
    i32 = jnp.int32
    bf16 = layers.DEFAULT_DTYPE
    f = cfg.family
    if shape.kind == "decode":
        tok = {"tokens": jax.ShapeDtypeStruct((b, 1), i32)}
        if f == "encdec":
            return tok
        return tok
    if f == "encdec":
        half = s // 2
        return {
            "frames": jax.ShapeDtypeStruct((b, half, cfg.d_model), bf16),
            "tokens": jax.ShapeDtypeStruct((b, half), i32),
        }
    if f == "vlm":
        n_p = cfg.vlm.n_patches
        return {
            "patches": jax.ShapeDtypeStruct((b, n_p, cfg.vlm.vision_dim),
                                            bf16),
            "tokens": jax.ShapeDtypeStruct((b, s - n_p), i32),
        }
    return {"tokens": jax.ShapeDtypeStruct((b, s), i32)}


def cache_specs(cfg: ModelConfig, shape: ShapeConfig, *,
                batch_override: Optional[int] = None) -> PyTree:
    """Decode-state ParamSpec pytree sized for `shape` (cache of seq_len)."""
    b = batch_override if batch_override is not None else shape.global_batch
    s = shape.seq_len
    f = cfg.family
    if f in ("dense", "moe", "vlm"):
        return attention.kv_cache_specs(cfg, b, s)
    if f == "ssm":
        return ssm.ssm_cache_specs(cfg, b)
    if f == "hybrid":
        return hybrid.cache_specs(cfg, b, s)
    if f == "encdec":
        return encdec.cache_specs(cfg, b, s, src_len=s)
    raise KeyError(f)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, Callable[[], ModelConfig]] = {}


def register(name: str, builder: Callable[[], ModelConfig]):
    _REGISTRY[name] = builder


def get(name: str) -> Arch:
    _ensure_configs_loaded()
    if name not in _REGISTRY:
        raise KeyError(
            f"unknown arch {name!r}; available: {sorted(_REGISTRY)}")
    return Arch(cfg=_REGISTRY[name]())


def names() -> Tuple[str, ...]:
    _ensure_configs_loaded()
    return tuple(sorted(_REGISTRY))


def _ensure_configs_loaded():
    import repro.configs  # noqa: F401  (registers all archs on import)
