"""Grouped-query attention: full (train/prefill) and KV-cache decode.

Supports QKV bias (Qwen1.5/Qwen2), qk-norm (Qwen3), GQA with any
n_kv_heads <= n_heads, RoPE.  The inner product can be computed by the
pure-jnp reference path (default — XLA fuses it well and the dry-run's
cost_analysis sees real FLOPs) or by the Pallas flash kernels
(``impl='pallas'``, validated in interpret mode in tests).
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.models.config import ModelConfig
from repro.models.layers import ParamSpec

Array = Any


def attn_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d, hd = cfg.d_model, cfg.head_dim_
    nh, nkv = cfg.n_heads, cfg.n_kv_heads
    specs = {
        "wq": ParamSpec((d, nh, hd), ("fsdp_embed", "heads", "head_dim")),
        "wk": ParamSpec((d, nkv, hd), ("fsdp_embed", "kv_heads", "head_dim")),
        "wv": ParamSpec((d, nkv, hd), ("fsdp_embed", "kv_heads", "head_dim")),
        "wo": ParamSpec((nh, hd, d), ("heads", "head_dim", "fsdp_embed")),
    }
    if cfg.qkv_bias:
        specs["bq"] = ParamSpec((nh, hd), ("heads", "head_dim"),
                                init="zeros")
        specs["bk"] = ParamSpec((nkv, hd), ("kv_heads", "head_dim"),
                                init="zeros")
        specs["bv"] = ParamSpec((nkv, hd), ("kv_heads", "head_dim"),
                                init="zeros")
    if cfg.qk_norm:
        specs["q_norm"] = ParamSpec((hd,), ("head_dim",), init="ones")
        specs["k_norm"] = ParamSpec((hd,), ("head_dim",), init="ones")
    return specs


def _project_qkv(p: Dict[str, Array], cfg: ModelConfig, x: Array,
                 positions: Array) -> Tuple[Array, Array, Array]:
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qkv_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    if cfg.qk_norm:
        q = layers.rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = layers.rms_norm(k, p["k_norm"], cfg.norm_eps)
    q = layers.apply_rope(q, positions, cfg.rope_theta)
    k = layers.apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _sdpa(q: Array, k: Array, v: Array, causal: bool,
          q_offset: Optional[Array] = None,
          kv_len: Optional[Array] = None) -> Array:
    """Reference scaled-dot-product GQA attention.

    q: (B, Sq, Hq, D); k/v: (B, Skv, Hkv, D).  Hq % Hkv == 0.
    q_offset: absolute position of q[.., 0] — scalar or per-batch (B,)
    (for decode / chunked prefill).
    kv_len: number of valid kv positions — scalar or (B,) (padded caches).
    """
    b, sq, hq, d = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    group = hq // hkv
    qg = q.reshape(b, sq, hkv, group, d)
    scores = jnp.einsum("bshgd,bthd->bhgst", qg, k).astype(jnp.float32)
    scores = scores / jnp.sqrt(d).astype(jnp.float32)
    kpos = jnp.arange(skv)                                   # (skv,)
    # mask built at (B, sq, skv) broadcast granularity
    mask = jnp.ones((1, sq, skv), dtype=bool)
    if causal:
        qpos = jnp.arange(sq)[None, :]                       # (1, sq)
        if q_offset is not None:
            off = jnp.asarray(q_offset)
            off = off[:, None] if off.ndim == 1 else off[None, None]
            qpos = qpos + off                                # (B|1, sq)
        mask = mask & (kpos[None, None, :] <= qpos[..., None])
    if kv_len is not None:
        kl = jnp.asarray(kv_len)
        kl = kl[:, None, None] if kl.ndim == 1 else kl[None, None, None]
        mask = mask & (kpos[None, None, :] < kl)
    scores = jnp.where(mask[:, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgst,bthd->bshgd", probs.astype(v.dtype), v)
    return out.reshape(b, sq, hq, d)


def _sdpa_chunked(q: Array, k: Array, v: Array, causal: bool,
                  bq: int = 512, bk: int = 1024) -> Array:
    """Flash-style blocked attention in pure XLA: an unrolled loop over
    query blocks, each scanning only the key blocks it can see (causal
    skipping is structural, not masked-out compute), with online-softmax
    accumulators.  Peak memory O(bq*bk) instead of O(S^2) — this is the
    optimization that moves the dry-run's memory roofline term (see
    EXPERIMENTS.md Sec-Perf) and the XLA twin of kernels/flash_attention.
    """
    b, s, hq, d = q.shape
    hkv = k.shape[2]
    group = hq // hkv
    bq = min(bq, s)
    bk = min(bk, s)
    assert s % bq == 0 and s % bk == 0, (s, bq, bk)
    nq, nk = s // bq, s // bk
    scale = 1.0 / (d ** 0.5)
    qf = (q.astype(jnp.float32) * scale).reshape(b, nq, bq, hkv, group, d)
    kf = k.reshape(b, nk, bk, hkv, d)
    vf = v.reshape(b, nk, bk, hkv, d)

    def one_q_block(i: int):
        qb = qf[:, i]                                   # (b,bq,hkv,g,d)
        n_vis = ((i + 1) * bq + bk - 1) // bk if causal else nk

        def kv_step(carry, j):
            m, l, acc = carry
            kb = jax.lax.dynamic_index_in_dim(kf, j, 1, keepdims=False)
            vb = jax.lax.dynamic_index_in_dim(vf, j, 1, keepdims=False)
            sc = jnp.einsum("bqhgd,bkhd->bhgqk", qb,
                            kb.astype(jnp.float32))
            if causal:
                rows = i * bq + jnp.arange(bq)[:, None]
                cols = j * bk + jnp.arange(bk)[None, :]
                sc = jnp.where(rows >= cols, sc, -1e30)
            m_new = jnp.maximum(m, sc.max(-1))
            p_ = jnp.exp(sc - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + p_.sum(-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bhgqk,bkhd->bhgqd", p_, vb.astype(jnp.float32))
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, hkv, group, bq), -1e30, jnp.float32)
        l0 = jnp.zeros((b, hkv, group, bq), jnp.float32)
        a0 = jnp.zeros((b, hkv, group, bq, d), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0),
                                      jnp.arange(n_vis))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        # (b,hkv,g,bq,d) -> (b,bq,h,d)
        return out.transpose(0, 3, 1, 2, 4).reshape(b, bq, hq, d)

    blocks = [one_q_block(i) for i in range(nq)]
    out = jnp.concatenate(blocks, axis=1) if nq > 1 else blocks[0]
    return out.astype(q.dtype)


def full_attention(p: Dict[str, Array], cfg: ModelConfig, x: Array,
                   causal: bool = True, impl: str = "xla") -> Array:
    """Self-attention over a full sequence (train / prefill)."""
    b, s, _ = x.shape
    positions = jnp.arange(s)[None, :]
    q, k, v = _project_qkv(p, cfg, x, positions)
    if impl == "pallas":
        from repro.kernels import ops
        out = ops.flash_attention(q, k, v, causal=causal)
    elif impl == "chunked":
        out = _sdpa_chunked(q, k, v, causal=causal)
    else:
        out = _sdpa(q, k, v, causal=causal)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


def cross_attention(p: Dict[str, Array], cfg: ModelConfig, x: Array,
                    memory: Array) -> Array:
    """Encoder-decoder cross attention (no causal mask, no rope on kv)."""
    b, s, _ = x.shape
    positions = jnp.arange(s)[None, :]
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    if cfg.qkv_bias:
        q = q + p["bq"]
    q = layers.apply_rope(q, positions, cfg.rope_theta)
    k = jnp.einsum("bsd,dhk->bshk", memory, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", memory, p["wv"])
    if cfg.qkv_bias:
        k, v = k + p["bk"], v + p["bv"]
    out = _sdpa(q, k, v, causal=False)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


# ---------------------------------------------------------------------------
# KV-cache decode
# ---------------------------------------------------------------------------

def kv_cache_specs(cfg: ModelConfig, batch: int, max_len: int,
                   n_layers: Optional[int] = None) -> Dict[str, ParamSpec]:
    nl = n_layers if n_layers is not None else cfg.n_layers
    shape = (nl, batch, max_len, cfg.n_kv_heads, cfg.head_dim_)
    axes = ("layers", "batch", "seq", "kv_heads", "head_dim")
    return {"k": ParamSpec(shape, axes), "v": ParamSpec(shape, axes)}


def decode_attention(p: Dict[str, Array], cfg: ModelConfig, x: Array,
                     k_cache: Array, v_cache: Array, position: Array,
                     impl: str = "xla") -> Tuple[Array, Array, Array]:
    """One-token attention against a cache.

    x: (B, 1, d); k_cache/v_cache: (B, S_max, Hkv, D); position: scalar or
    per-request (B,) — the index this token writes (cache valid in
    [0, position]).  Returns (out (B,1,d), new_k, new_v).
    """
    position = jnp.asarray(position)
    b = x.shape[0]
    pos_vec = position if position.ndim == 1 else \
        jnp.full((b,), position)
    q, k, v = _project_qkv(p, cfg, x, pos_vec[:, None])
    if position.ndim == 0:
        k_cache = jax.lax.dynamic_update_slice_in_dim(
            k_cache, k.astype(k_cache.dtype), position, axis=1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(
            v_cache, v.astype(v_cache.dtype), position, axis=1)
    else:  # per-slot positions (continuous batching)
        rows = jnp.arange(b)
        k_cache = k_cache.at[rows, pos_vec].set(
            k[:, 0].astype(k_cache.dtype))
        v_cache = v_cache.at[rows, pos_vec].set(
            v[:, 0].astype(v_cache.dtype))
    if impl == "pallas" and position.ndim == 0:
        from repro.kernels import ops
        out = ops.flash_decode(q[:, 0], k_cache, v_cache, position + 1)
        out = out[:, None]
    else:
        out = _sdpa(q, k_cache, v_cache, causal=False,
                    q_offset=pos_vec, kv_len=pos_vec + 1)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"]), k_cache, v_cache
