"""Building-block layers and the logical-axis parameter system.

Every parameter is declared as a :class:`ParamSpec` carrying *logical* axis
names (``'embed'``, ``'heads'``, ``'mlp'``, ``'vocab'``, ``'experts'``,
``'layers'`` ...).  A sharding-rules table maps logical names to mesh axes;
changing the table re-lowers the whole model under a different distribution
without touching model code — this is the main lever the Sec.-Perf
hillclimbing turns.

All forward functions are pure; parameters are plain nested dicts of
arrays (or ShapeDtypeStructs for the dry-run).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Array = Any
PyTree = Any

DEFAULT_DTYPE = jnp.bfloat16


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]     # logical axis per dim
    dtype: Any = DEFAULT_DTYPE
    init: str = "normal"                # normal | zeros | ones | scaled

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)

    def abstract(self) -> jax.ShapeDtypeStruct:
        return jax.ShapeDtypeStruct(self.shape, self.dtype)

    def stack_layers(self, n: int) -> "ParamSpec":
        return ParamSpec((n,) + self.shape, ("layers",) + self.axes,
                         self.dtype, self.init)


def initialize(spec: ParamSpec, key: jax.Array) -> Array:
    """Materialize one parameter (smoke tests / examples only).

    fan_in = product of all non-output dims, excluding stacked 'layers'
    axes (the last dim is treated as the output; for fused projections
    like (d, heads, head_dim) this under-scales by sqrt(heads), which is
    safe — over-scaling is what explodes deep stacks)."""
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, spec.dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, spec.dtype)
    fan_in = 1
    for dim, axis in list(zip(spec.shape, spec.axes))[:-1]:
        if axis != "layers":
            fan_in *= dim
    fan_in = fan_in if fan_in > 1 else (spec.shape[-1] or 1)
    scale = 1.0 / math.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, spec.shape, jnp.float32) * scale
            ).astype(spec.dtype)


def init_tree(specs: PyTree, key: jax.Array) -> PyTree:
    leaves, treedef = jax.tree.flatten(
        specs, is_leaf=lambda x: isinstance(x, ParamSpec))
    keys = jax.random.split(key, len(leaves))
    vals = [initialize(s, k) for s, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, vals)


def abstract_tree(specs: PyTree) -> PyTree:
    return jax.tree.map(lambda s: s.abstract(), specs,
                        is_leaf=lambda x: isinstance(x, ParamSpec))


# ---------------------------------------------------------------------------
# Logical-axis -> mesh-axis rules
# ---------------------------------------------------------------------------

# The baseline rules (Sec.-Perf iterates on these).  Values may be a mesh
# axis name, a tuple of mesh axes, or None (replicated).
DEFAULT_RULES: Dict[str, Any] = {
    "batch": ("pod", "data"),
    "seq": None,
    "embed": None,
    "fsdp_embed": "data",       # FSDP-sharded input dim of big matmuls
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "mlp": "model",
    "vocab": "model",
    "experts": "model",
    "expert_mlp": None,
    "layers": None,
    "ssm_inner": "model",
    "ssm_state": None,
    "ssm_heads": "model",
    "conv": None,
}


def spec_to_pspec(spec: ParamSpec, rules: Dict[str, Any],
                  mesh: jax.sharding.Mesh):
    """PartitionSpec for one parameter under `rules`, with divisibility
    fallback: a dim whose size does not divide the mapped mesh axes is
    replicated instead (correct, just less sharded)."""
    from jax.sharding import PartitionSpec as P
    out = []
    for dim, name in zip(spec.shape, spec.axes):
        target = rules.get(name) if name else None
        if target is None:
            out.append(None)
            continue
        axes = target if isinstance(target, tuple) else (target,)
        axes = tuple(a for a in axes if a in mesh.shape)
        size = int(np.prod([mesh.shape[a] for a in axes])) if axes else 1
        if size <= 1 or dim % size != 0:
            out.append(None)
        else:
            out.append(axes if len(axes) > 1 else axes[0])
    return P(*out)


def tree_pspecs(specs: PyTree, rules: Dict[str, Any],
                mesh: jax.sharding.Mesh) -> PyTree:
    return jax.tree.map(lambda s: spec_to_pspec(s, rules, mesh), specs,
                        is_leaf=lambda x: isinstance(x, ParamSpec))


def tree_shardings(specs: PyTree, rules: Dict[str, Any],
                   mesh: jax.sharding.Mesh) -> PyTree:
    from jax.sharding import NamedSharding
    return jax.tree.map(
        lambda s: NamedSharding(mesh, spec_to_pspec(s, rules, mesh)), specs,
        is_leaf=lambda x: isinstance(x, ParamSpec))


# ---------------------------------------------------------------------------
# Primitive layers
# ---------------------------------------------------------------------------

def rms_norm(x: Array, weight: Array, eps: float = 1e-6) -> Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps)
    return (out * weight.astype(jnp.float32)).astype(dtype)


def layer_norm(x: Array, weight: Array, bias: Array,
               eps: float = 1e-6) -> Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    out = (x - mu) * jax.lax.rsqrt(var + eps)
    return (out * weight.astype(jnp.float32)
            + bias.astype(jnp.float32)).astype(dtype)


def rope_freqs(head_dim: int, theta: float) -> Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2,
                                       dtype=jnp.float32) / head_dim))


def apply_rope(x: Array, positions: Array, theta: float) -> Array:
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                         # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    cos = jnp.cos(angles)[..., None, :]                  # (..., S, 1, D/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin,
                           x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def swiglu(x: Array, w_gate: Array, w_up: Array, w_down: Array,
           constrain=None) -> Array:
    g = jnp.einsum("...d,df->...f", x, w_gate)
    u = jnp.einsum("...d,df->...f", x, w_up)
    if constrain is not None:
        # pin the hidden activation to the tensor-parallel layout so the
        # partitioner cannot replicate the (B, S, d_ff) f32 intermediate
        g = constrain(g, "batch", "seq", "mlp")
        u = constrain(u, "batch", "seq", "mlp")
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    return jnp.einsum("...f,fd->...d", h, w_down)


def mlp_specs(d_model: int, d_ff: int) -> Dict[str, ParamSpec]:
    return {
        "w_gate": ParamSpec((d_model, d_ff), ("fsdp_embed", "mlp")),
        "w_up": ParamSpec((d_model, d_ff), ("fsdp_embed", "mlp")),
        "w_down": ParamSpec((d_ff, d_model), ("mlp", "fsdp_embed")),
    }


def norm_specs(d_model: int, ln: bool = False) -> Dict[str, ParamSpec]:
    out = {"scale": ParamSpec((d_model,), ("embed",), init="ones")}
    if ln:
        out["bias"] = ParamSpec((d_model,), ("embed",), init="zeros")
    return out


def cross_entropy_loss(logits: Array, labels: Array,
                       mask: Optional[Array] = None,
                       z_coef: float = 1e-4) -> Array:
    """Token-mean CE with z-loss; logits (..., V) f32-accumulated."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None],
                               axis=-1).squeeze(-1)
    nll = lse - gold
    z = z_coef * jnp.square(lse)
    loss = nll + z
    if mask is not None:
        loss = loss * mask
        return loss.sum() / jnp.maximum(mask.sum(), 1.0)
    return loss.mean()
