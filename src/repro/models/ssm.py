"""Mamba2 — SSD (state-space duality) blocks, pure JAX.

The chunked SSD algorithm (Dao & Gu, arXiv:2405.21060): within a chunk the
output is computed in quadratic attention-like form; across chunks a linear
recurrence carries the (H, P, N) state, evaluated with an associative scan.
Decode is the exact single-step recurrence over the same state, so
``long_500k`` costs O(1) per token — the sub-quadratic path the shape table
requires for ssm/hybrid architectures.

Layout follows the reference implementation: d_inner = expand * d_model,
H = d_inner / head_dim heads, scalar decay A per head, B/C shared across
heads in ``n_groups`` groups.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.models.config import ModelConfig, SSMConfig
from repro.models.layers import ParamSpec

Array = Any


def _dims(cfg: ModelConfig) -> Tuple[int, int, int, int, int]:
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    n_heads = d_inner // s.head_dim
    return d_inner, n_heads, s.head_dim, s.d_state, s.n_groups


def ssm_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d = cfg.d_model
    s = cfg.ssm
    d_inner, nh, hp, dn, ng = _dims(cfg)
    conv_dim = d_inner + 2 * ng * dn
    return {
        # fused input projection: [z, x, B, C, dt]
        "w_in": ParamSpec((d, 2 * d_inner + 2 * ng * dn + nh),
                          ("fsdp_embed", "ssm_inner")),
        "conv_w": ParamSpec((s.conv_width, conv_dim), ("conv", "ssm_inner")),
        "conv_b": ParamSpec((conv_dim,), ("ssm_inner",), init="zeros"),
        "a_log": ParamSpec((nh,), ("ssm_heads",), init="ones",
                           dtype=jnp.float32),
        "dt_bias": ParamSpec((nh,), ("ssm_heads",), init="zeros",
                             dtype=jnp.float32),
        "d_skip": ParamSpec((nh,), ("ssm_heads",), init="ones",
                            dtype=jnp.float32),
        "norm": ParamSpec((d_inner,), ("ssm_inner",), init="ones"),
        "w_out": ParamSpec((d_inner, d), ("ssm_inner", "fsdp_embed")),
    }


def _split_proj(cfg: ModelConfig, zxbcdt: Array):
    d_inner, nh, hp, dn, ng = _dims(cfg)
    z, xbc, dt = jnp.split(
        zxbcdt, [d_inner, 2 * d_inner + 2 * ng * dn], axis=-1)
    return z, xbc, dt


def _causal_conv(xbc: Array, w: Array, b: Array) -> Array:
    """Depthwise causal conv over (B, S, C) with kernel (W, C)."""
    width = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (width - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xbc.shape[1]] * w[i] for i in range(width))
    return jax.nn.silu((out + b).astype(jnp.float32)).astype(xbc.dtype)


def _segsum(x: Array) -> Array:
    """exp-stable segment-sum: out[..., i, j] = sum_{j<k<=i} x[..., k]."""
    t = x.shape[-1]
    c = jnp.cumsum(x, axis=-1)
    diff = c[..., :, None] - c[..., None, :]
    mask = jnp.tril(jnp.ones((t, t), dtype=bool), 0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x: Array, dt: Array, a_log: Array, b: Array, c: Array,
                d_skip: Array, dt_bias: Array, chunk: int,
                init_state: Optional[Array] = None,
                ) -> Tuple[Array, Array]:
    """Chunked SSD scan.

    x: (B, S, H, P)  dt: (B, S, H)  b,c: (B, S, G, N)  a_log/dt_bias/d_skip: (H,)
    Returns (y (B,S,H,P), final_state (B,H,P,N)).
    """
    bsz, s, h, p = x.shape
    g, n = b.shape[2], b.shape[3]
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    rep = h // g

    dt = jax.nn.softplus(dt.astype(jnp.float32) + dt_bias)      # (B,S,H)
    a = -jnp.exp(a_log.astype(jnp.float32))                     # (H,)
    da = dt * a                                                 # (B,S,H)
    xdt = x.astype(jnp.float32) * dt[..., None]                 # B x_t dt

    # reshape into chunks
    def ch(t, extra=()):
        return t.reshape(t.shape[0], nc, chunk, *t.shape[2:])
    xc = ch(xdt)                                                # (B,nc,L,H,P)
    bc = ch(b.astype(jnp.float32))                              # (B,nc,L,G,N)
    cc = ch(c.astype(jnp.float32))
    dac = ch(da).transpose(0, 3, 1, 2)                          # (B,H,nc,L)

    da_cs = jnp.cumsum(dac, axis=-1)                            # (B,H,nc,L)

    # ---- intra-chunk (quadratic, attention-like) ----
    lmat = jnp.exp(_segsum(dac))                                # (B,H,nc,L,L)
    bheads = jnp.repeat(bc, rep, axis=3)                        # (B,nc,L,H,N)
    cheads = jnp.repeat(cc, rep, axis=3)
    scores = jnp.einsum("bclhn,bcshn->bhcls", cheads, bheads)   # (B,H,nc,L,L)
    y_diag = jnp.einsum("bhcls,bhcls,bcshp->bclhp",
                        scores, lmat, xc)

    # ---- chunk-final states ----
    decay_states = jnp.exp(da_cs[..., -1:] - da_cs)             # (B,H,nc,L)
    states = jnp.einsum("bclhn,bhcl,bclhp->bchpn",
                        bheads, decay_states, xc)               # (B,nc,H,P,N)

    # ---- inter-chunk linear recurrence (associative scan) ----
    chunk_decay = jnp.exp(da_cs[..., -1]).transpose(0, 2, 1)    # (B,nc,H)
    if init_state is None:
        init_state = jnp.zeros((bsz, h, p, n), jnp.float32)

    def combine(left, right):
        dl, sl = left
        dr, sr = right
        return dl * dr, sr + dr * sl

    decays, carried = jax.lax.associative_scan(
        combine, (chunk_decay[..., None, None], states), axis=1)
    # carried[c] = state at end of chunk c from chunks <= c (excl. init)
    total_decay = decays                                        # (B,nc,H,1,1)
    carried = carried + total_decay * init_state[:, None]
    # state entering chunk c = carried[c-1] (init for c=0)
    prev = jnp.concatenate([init_state[:, None], carried[:, :-1]], axis=1)

    # ---- chunk-state contribution to outputs ----
    state_decay = jnp.exp(da_cs)                                # (B,H,nc,L)
    y_off = jnp.einsum("bclhn,bchpn,bhcl->bclhp",
                       cheads, prev, state_decay)

    y = (y_diag + y_off).reshape(bsz, s, h, p)
    y = y + d_skip[None, None, :, None] * x.astype(jnp.float32)
    return y.astype(x.dtype), carried[:, -1]


def ssd_decode_step(x: Array, dt: Array, a_log: Array, b: Array, c: Array,
                    d_skip: Array, dt_bias: Array, state: Array,
                    ) -> Tuple[Array, Array]:
    """Exact single-token recurrence.

    x: (B, H, P); dt: (B, H); b,c: (B, G, N); state: (B, H, P, N).
    """
    h, p = x.shape[1], x.shape[2]
    g = b.shape[1]
    rep = h // g
    dt = jax.nn.softplus(dt.astype(jnp.float32) + dt_bias)
    a = -jnp.exp(a_log.astype(jnp.float32))
    decay = jnp.exp(dt * a)                                     # (B,H)
    bh = jnp.repeat(b.astype(jnp.float32), rep, axis=1)         # (B,H,N)
    ch = jnp.repeat(c.astype(jnp.float32), rep, axis=1)
    xf = x.astype(jnp.float32)
    new_state = decay[..., None, None] * state + \
        jnp.einsum("bh,bhp,bhn->bhpn", dt, xf, bh)
    y = jnp.einsum("bhn,bhpn->bhp", ch, new_state)
    y = y + d_skip[None, :, None] * xf
    return y.astype(x.dtype), new_state


# ---------------------------------------------------------------------------
# Full block (in_proj -> conv -> SSD -> gated norm -> out_proj)
# ---------------------------------------------------------------------------

def mamba_block(p: Dict[str, Array], cfg: ModelConfig, x: Array,
                impl: str = "xla") -> Array:
    """Full-sequence Mamba2 block.  x: (B, S, d_model)."""
    d_inner, nh, hp, dn, ng = _dims(cfg)
    zxbcdt = jnp.einsum("bsd,de->bse", x, p["w_in"])
    z, xbc, dt = _split_proj(cfg, zxbcdt)
    xbc = _causal_conv(xbc, p["conv_w"], p["conv_b"])
    xs, b, c = jnp.split(xbc, [d_inner, d_inner + ng * dn], axis=-1)
    bsz, s = x.shape[0], x.shape[1]
    xs = xs.reshape(bsz, s, nh, hp)
    b = b.reshape(bsz, s, ng, dn)
    c = c.reshape(bsz, s, ng, dn)
    if impl == "pallas":
        from repro.kernels import ops
        y, _ = ops.ssd_scan(xs, dt, p["a_log"], b, c, p["d_skip"],
                            p["dt_bias"], cfg.ssm.chunk)
    else:
        y, _ = ssd_chunked(xs, dt, p["a_log"], b, c, p["d_skip"],
                           p["dt_bias"], cfg.ssm.chunk)
    y = y.reshape(bsz, s, d_inner)
    y = layers.rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(
        y.dtype), p["norm"], cfg.norm_eps)
    return jnp.einsum("be,ed->bd" if y.ndim == 2 else "bse,ed->bsd",
                      y, p["w_out"])


def ssm_cache_specs(cfg: ModelConfig, batch: int,
                    n_layers: Optional[int] = None) -> Dict[str, ParamSpec]:
    d_inner, nh, hp, dn, ng = _dims(cfg)
    nl = n_layers if n_layers is not None else cfg.n_layers
    conv_dim = d_inner + 2 * ng * dn
    return {
        "ssm_state": ParamSpec((nl, batch, nh, hp, dn),
                               ("layers", "batch", "ssm_heads",
                                "head_dim", "ssm_state"),
                               dtype=jnp.float32),
        "conv_state": ParamSpec((nl, batch, cfg.ssm.conv_width - 1,
                                 conv_dim),
                                ("layers", "batch", None, "ssm_inner")),
    }


def mamba_decode_block(p: Dict[str, Array], cfg: ModelConfig, x: Array,
                       ssm_state: Array, conv_state: Array,
                       ) -> Tuple[Array, Array, Array]:
    """One-token Mamba2 step.  x: (B, 1, d_model);
    ssm_state: (B, H, P, N); conv_state: (B, W-1, conv_dim)."""
    d_inner, nh, hp, dn, ng = _dims(cfg)
    zxbcdt = jnp.einsum("bsd,de->bse", x, p["w_in"])[:, 0]
    z, xbc, dt = _split_proj(cfg, zxbcdt)
    # rolling conv state
    window = jnp.concatenate([conv_state, xbc[:, None]], axis=1)  # (B,W,C)
    conv_out = jnp.einsum("bwc,wc->bc", window, p["conv_w"]) + p["conv_b"]
    conv_out = jax.nn.silu(conv_out.astype(jnp.float32)).astype(x.dtype)
    new_conv_state = window[:, 1:]
    xs, b, c = jnp.split(conv_out, [d_inner, d_inner + ng * dn], axis=-1)
    bsz = x.shape[0]
    y, new_ssm_state = ssd_decode_step(
        xs.reshape(bsz, nh, hp), dt, p["a_log"],
        b.reshape(bsz, ng, dn), c.reshape(bsz, ng, dn),
        p["d_skip"], p["dt_bias"], ssm_state)
    y = y.reshape(bsz, d_inner)
    y = layers.rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(
        y.dtype), p["norm"], cfg.norm_eps)
    out = jnp.einsum("be,ed->bd", y, p["w_out"])[:, None]
    return out, new_ssm_state, new_conv_state
