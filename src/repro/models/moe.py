"""Mixture-of-Experts with TPU-native expert parallelism.

Design (DESIGN.md Sec. 4): tokens are replicated across the ``model`` mesh
axis (they already are, in the megatron-style layout), experts are sharded
across it.  Every model-rank computes the same routing for its local
tokens, gathers only the slice of the capacity-dispatch table that belongs
to its experts, runs its experts, and contributes a partial output;
ONE psum over ``model`` combines — the same collective cost as a dense TP
MLP, no all-to-all.  This keeps the MoE layer inside the paper's
"few large collectives beat many small messages" regime.

Dispatch is GShard-style capacity routing: first-choice slots get priority,
over-capacity tokens drop (their weight mass is simply lost, standard).
Aux losses: Switch load-balance + router z-loss.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers
from repro.models.config import ModelConfig
from repro.models.layers import ParamSpec

Array = Any


def _padded_experts(cfg: ModelConfig) -> int:
    m = cfg.moe
    return m.ep_pad_to if m.ep_pad_to else m.n_routed


def moe_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    m = cfg.moe
    d, f = cfg.d_model, m.d_ff_expert
    e = _padded_experts(cfg)
    specs = {
        "router": ParamSpec((d, m.n_routed), ("embed", None),
                            dtype=jnp.float32),
        "w_gate": ParamSpec((e, d, f), ("experts", "embed", "expert_mlp")),
        "w_up": ParamSpec((e, d, f), ("experts", "embed", "expert_mlp")),
        "w_down": ParamSpec((e, f, d), ("experts", "expert_mlp", "embed")),
    }
    if m.n_shared:
        specs["shared"] = layers.mlp_specs(d, m.n_shared * f)
    return specs


def _capacity(n_tokens: int, cfg: ModelConfig) -> int:
    m = cfg.moe
    c = int(np.ceil(n_tokens * m.top_k * m.capacity_factor
                    / _padded_experts(cfg)))
    return max(8, int(np.ceil(c / 8)) * 8)   # pad for TPU lane alignment


def route(p: Dict[str, Array], cfg: ModelConfig, x: Array
          ) -> Tuple[Array, Array, Array]:
    """Router: top-k experts per token with normalized weights.

    x: (T, d) -> (idx (T,K), weights (T,K), aux_loss scalar)."""
    m = cfg.moe
    logits = jnp.einsum("td,de->te", x.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    weights, idx = jax.lax.top_k(probs, m.top_k)
    weights = weights / jnp.maximum(
        weights.sum(-1, keepdims=True), 1e-9)
    # Switch load-balance loss + router z-loss
    e = m.n_routed
    frac = jnp.mean(
        jax.nn.one_hot(idx[:, 0], e, dtype=jnp.float32), axis=0)
    mean_prob = jnp.mean(probs, axis=0)
    aux = m.aux_coef * e * jnp.sum(frac * mean_prob)
    z = m.router_z_coef * jnp.mean(
        jnp.square(jax.nn.logsumexp(logits, axis=-1)))
    return idx, weights, aux + z


def dispatch_tables(idx: Array, weights: Array, n_experts: int,
                    capacity: int, n_tokens: int
                    ) -> Tuple[Array, Array, Array]:
    """Capacity-dispatch: (E, C) token-index / weight / valid tables.

    First-choice routes take priority (k-major cumsum order).  Tokens over
    capacity drop.  Invalid slots carry index == n_tokens (out of bounds ->
    scatter-drop / gather-fill semantics).
    """
    t, k = idx.shape
    # (K, T, E) one-hot in k-major order => first choices fill slots first
    oh = jax.nn.one_hot(idx.T, n_experts, dtype=jnp.int32)      # (K,T,E)
    flat = oh.reshape(k * t, n_experts)
    pos = jnp.cumsum(flat, axis=0) - 1                          # (K*T, E)
    pos = jnp.sum(pos * flat, axis=-1)                          # (K*T,)
    expert = idx.T.reshape(-1)                                  # (K*T,)
    keep = pos < capacity
    token = jnp.tile(jnp.arange(t), (k,))
    w = weights.T.reshape(-1)
    slot_e = jnp.where(keep, expert, n_experts)                 # OOB drop
    slot_c = jnp.where(keep, pos, capacity)
    token_table = jnp.full((n_experts, capacity), n_tokens, jnp.int32)
    token_table = token_table.at[slot_e, slot_c].set(
        token.astype(jnp.int32), mode="drop")
    weight_table = jnp.zeros((n_experts, capacity), jnp.float32)
    weight_table = weight_table.at[slot_e, slot_c].set(w, mode="drop")
    valid = token_table < n_tokens
    return token_table, weight_table, valid


def _expert_ffn(xe: Array, wg: Array, wu: Array, wd: Array) -> Array:
    """xe: (E_l, C, d) through per-expert SwiGLU."""
    g = jnp.einsum("ecd,edf->ecf", xe, wg)
    u = jnp.einsum("ecd,edf->ecf", xe, wu)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(xe.dtype) * u
    return jnp.einsum("ecf,efd->ecd", h, wd)


def moe_block(p: Dict[str, Array], cfg: ModelConfig, x: Array,
              ep_axis: Optional[str] = None) -> Tuple[Array, Array]:
    """Full MoE FFN: routed experts (+psum over EP) + shared experts.

    x: (B, S, d).  When ``ep_axis`` is set, this must run inside shard_map
    with x replicated along that axis and expert weights sharded on it —
    the expert weights arriving here are then the LOCAL slice, so
    ``ep_rank``/``ep_size`` come from the axis; otherwise single-program.
    """
    b, s, d = x.shape
    xt = x.reshape(b * s, d)
    if ep_axis is None:
        y, aux = _moe_ffn_sharded(p, cfg, xt, jnp.int32(0), 1)
    else:
        rank = jax.lax.axis_index(ep_axis)
        size = jax.lax.psum(1, ep_axis)
        # NOTE: inside shard_map the expert arrays are already local slices;
        # moe_ffn_local slices the dispatch tables to match.
        y, aux = _moe_ffn_sharded(p, cfg, xt, rank, size)
        y = jax.lax.psum(y, ep_axis)
    y = y.reshape(b, s, d)
    if cfg.moe.n_shared:
        sh = p["shared"]
        y = y + layers.swiglu(x, sh["w_gate"], sh["w_up"], sh["w_down"])
    return y, aux


def _moe_ffn_sharded(p: Dict[str, Array], cfg: ModelConfig, x: Array,
                     ep_rank: Array, ep_size: int) -> Tuple[Array, Array]:
    """Like moe_ffn_local but expert weights are pre-sliced by shard_map."""
    t, d = x.shape
    e_pad = _padded_experts(cfg)
    e_local = p["w_gate"].shape[0]
    assert e_local * ep_size == e_pad
    cap = _capacity(t, cfg)
    idx, weights, aux = route(p, cfg, x)
    token_table, weight_table, valid = dispatch_tables(
        idx, weights, e_pad, cap, t)
    lo = ep_rank * e_local
    tt = jax.lax.dynamic_slice(token_table, (lo, 0), (e_local, cap))
    wt = jax.lax.dynamic_slice(weight_table, (lo, 0), (e_local, cap))
    vt = jax.lax.dynamic_slice(valid, (lo, 0), (e_local, cap))
    xg = jnp.take(x, jnp.clip(tt, 0, t - 1).reshape(-1), axis=0)
    xg = xg.reshape(e_local, cap, d) * vt[..., None].astype(x.dtype)
    ye = _expert_ffn(xg, p["w_gate"], p["w_up"], p["w_down"])
    ye = ye * (wt * vt).astype(ye.dtype)[..., None]
    y = jnp.zeros((t, d), ye.dtype).at[tt.reshape(-1)].add(
        ye.reshape(-1, d), mode="drop")
    return y, aux
