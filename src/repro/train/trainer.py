"""The training loop: checkpoint/restart, elastic view changes, straggler
null-rounds, Spindle gradient multicast — the full runtime.

Single-process reference that is faithful to the multi-host control flow:
the same train_step the dry-run lowers for 512 chips runs here on the
local device(s); the elastic runtime (repro.train.elastic) drives view
changes; the checkpointer publishes the delivered_step watermark the next
view restores from.
"""

from __future__ import annotations

import dataclasses
import time
from pathlib import Path
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.gradsync import SyncState
from repro.data import pipeline
from repro.models import layers, registry
from repro.models.config import ModelConfig
from repro.models.runtime import Runtime
from repro.optim import adamw
from repro.train import checkpoint
from repro.train.steps import make_train_step

PyTree = Any


@dataclasses.dataclass
class TrainConfig:
    steps: int = 100
    seq_len: int = 128
    global_batch: int = 8
    checkpoint_every: int = 50
    checkpoint_dir: Optional[str] = None
    keep_checkpoints: int = 3
    log_every: int = 10
    seed: int = 0
    data_patterns: int = 512     # synthetic-stream difficulty
    opt: adamw.OptConfig = dataclasses.field(default_factory=adamw.OptConfig)


class Trainer:
    def __init__(self, arch_name: str, cfg: ModelConfig, tcfg: TrainConfig,
                 rt: Runtime = Runtime()):
        self.arch = registry.get(arch_name)
        self.cfg = cfg
        self.tcfg = tcfg
        self.rt = rt
        self.data_cfg = pipeline.DataConfig(
            seq_len=tcfg.seq_len, global_batch=tcfg.global_batch,
            vocab_size=cfg.vocab_size, seed=tcfg.seed,
            n_patterns=tcfg.data_patterns)
        self.loader = pipeline.ShardedLoader(self.data_cfg, rank=0,
                                             n_ranks=1)
        self.sync = SyncState()
        self.history: List[Dict[str, float]] = []

        step_fn = make_train_step(self.arch, rt, tcfg.opt)
        self._step = jax.jit(step_fn, donate_argnums=(0, 1))

    # -- state ----------------------------------------------------------------

    def init_state(self, key=None):
        key = key if key is not None else jax.random.key(self.tcfg.seed)
        specs = registry.param_specs(self.cfg)
        params = layers.init_tree(specs, key)
        opt_state = adamw.init(params)
        return params, opt_state

    def maybe_restore(self, params, opt_state):
        d = self.tcfg.checkpoint_dir
        if not d or checkpoint.latest_step(d) is None:
            return 0, params, opt_state
        step, tree, extra = checkpoint.restore(
            d, {"params": params, "opt": opt_state})
        self.sync = SyncState(delivered_step=step, sent_step=step)
        return step, tree["params"], tree["opt"]

    # -- the loop --------------------------------------------------------------

    def _batch_for(self, step: int) -> Dict[str, jnp.ndarray]:
        raw = self.loader.batch(step)
        batch = {"tokens": jnp.asarray(raw["tokens"])}
        if self.cfg.family == "encdec":
            toks = batch["tokens"]
            half = toks.shape[1] // 2
            # stub frontend: frame embeddings derived deterministically
            frames = jax.nn.one_hot(toks[:, :half] % self.cfg.d_model,
                                    self.cfg.d_model,
                                    dtype=jnp.bfloat16)
            batch = {"frames": frames, "tokens": toks[:, half:]}
        elif self.cfg.family == "vlm":
            toks = batch["tokens"]
            n_p = self.cfg.vlm.n_patches
            patches = jax.nn.one_hot(
                toks[:, :n_p] % self.cfg.vlm.vision_dim,
                self.cfg.vlm.vision_dim, dtype=jnp.bfloat16)
            batch = {"patches": patches, "tokens": toks[:, n_p:]}
        return batch

    def run(self, params=None, opt_state=None,
            on_step: Optional[Callable[[int, Dict], None]] = None):
        if params is None:
            params, opt_state = self.init_state()
        start, params, opt_state = self.maybe_restore(params, opt_state)
        t0 = time.time()
        for step in range(start, self.tcfg.steps):
            batch = self._batch_for(step)
            params, opt_state, metrics = self._step(params, opt_state,
                                                    batch)
            self.sync = self.sync.advance()
            if (step + 1) % self.tcfg.log_every == 0 or \
                    step == self.tcfg.steps - 1:
                m = {k: float(v) for k, v in metrics.items()}
                m["step"] = step + 1
                m["wall_s"] = time.time() - t0
                self.history.append(m)
                print(f"step {step+1:5d} loss {m['loss']:.4f} "
                      f"gnorm {m['grad_norm']:.3f} lr {m['lr']:.2e}",
                      flush=True)
            if self.tcfg.checkpoint_dir and \
                    (step + 1) % self.tcfg.checkpoint_every == 0:
                self._save(step + 1, params, opt_state)
            if on_step:
                on_step(step, metrics)
        if self.tcfg.checkpoint_dir:
            self._save(self.tcfg.steps, params, opt_state)
        return params, opt_state

    def _save(self, step: int, params, opt_state):
        checkpoint.save(self.tcfg.checkpoint_dir, step,
                        {"params": params, "opt": opt_state},
                        extra={"arch": self.cfg.name})
        self.sync = self.sync.deliver(step)
        checkpoint.prune(self.tcfg.checkpoint_dir,
                         self.tcfg.keep_checkpoints)
