"""Sharding-rule presets — the logical->mesh tables the Perf loop iterates.

``get(name, cfg)`` returns a rules dict for :mod:`repro.models.layers`.
Presets:

  baseline   FSDP over 'data' + tensor parallel over 'model' (MaxText-like)
  megatron   pure TP over 'model', params replicated over 'data' (classic)
  pure_dp    data parallel only — params fully replicated; the explicit
             Spindle gradient-multicast modes run on top of this
  fsdp_only  everything sharded over 'data', no tensor parallelism
  seq_model  sequence dim of activations onto 'model' (sequence-parallel
             lever for long-context shapes)
"""

from __future__ import annotations

from typing import Any, Dict

from repro.models.config import ModelConfig
from repro.models.layers import DEFAULT_RULES


def _base() -> Dict[str, Any]:
    return dict(DEFAULT_RULES)


def get(name: str, cfg: ModelConfig) -> Dict[str, Any]:
    if name == "baseline":
        rules = _base()
    elif name == "megatron":
        rules = _base()
        rules["fsdp_embed"] = None
    elif name == "full_dp":
        # every mesh axis is data parallel: params replicated, batch
        # sharded 256-way.  The right regime for small models at train_4k
        # — zero forward collectives; the gradient multicast (one fused
        # all-reduce) is the only coordination, exactly the paper's
        # small-message world
        rules = _base()
        rules.update({"batch": ("pod", "data", "model"),
                      "fsdp_embed": None, "heads": None, "kv_heads": None,
                      "mlp": None, "vocab": None, "ssm_inner": None,
                      "ssm_heads": None, "experts": None})
    elif name == "pure_dp":
        rules = _base()
        rules.update({"fsdp_embed": None, "heads": None, "kv_heads": None,
                      "mlp": None, "vocab": None, "ssm_inner": None,
                      "ssm_heads": None})
        # experts stay on 'model' (EP) — replicating 60 experts per device
        # would not fit; noted in DESIGN.md
    elif name == "fsdp_only":
        rules = _base()
        rules.update({"heads": None, "kv_heads": None, "mlp": "data",
                      "vocab": "data", "ssm_inner": "data",
                      "ssm_heads": None, "experts": "model"})
    elif name == "seq_model":
        rules = _base()
        rules["seq"] = "model"
    elif name == "megatron_seq":
        # classic TP + sequence-parallel residual stream: the (B,S,d)
        # activations (and their f32 backward cotangents) shard S over
        # 'model' between attention/MLP blocks
        rules = _base()
        rules["fsdp_embed"] = None
        rules["seq"] = "model"
    elif name == "ssm_seq":
        # sequence parallelism for recurrent stacks: activations shard the
        # SEQUENCE over 'model', ssm weights replicate across it — the
        # per-layer TP all-reduce of (B,S,d) disappears entirely; the
        # cross-shard state handoff is tiny (B,H,P,N)
        rules = _base()
        rules.update({"seq": "model", "ssm_inner": None, "ssm_heads": None,
                      "heads": None, "kv_heads": None, "mlp": None})
    else:
        raise KeyError(f"unknown rules preset {name!r}")
    return rules


PRESETS = ("baseline", "megatron", "pure_dp", "fsdp_only", "seq_model",
           "megatron_seq", "ssm_seq")
