"""Elastic runtime: virtual-synchrony views driving mesh/loader/checkpoint
reconfiguration (fault tolerance at 1000+ node scale).

The control flow on a real cluster (and, deterministically, in tests):

  1. every worker heartbeats by bumping a monotone SST counter; a stalled
     counter triggers ``MembershipService.suspect`` (straggler detection
     uses the same watermark with a softer threshold -> null-rounds first,
     eviction only if the lag persists);
  2. the surviving leader runs the two-phase monotone view change
     (wedge -> watermark agreement -> install);
  3. every member of the new view restores from the checkpoint watermark
     (``delivered_step``), rebuilds the mesh with the new DP extent and
     re-partitions the deterministic data stream (repro.data.pipeline);
  4. training resumes; steps beyond the watermark that some old members
     had locally applied are recomputed — exactly virtual synchrony's
     "deliver everywhere or nowhere, resend in the next view".

The in-process harness below exercises all of that logic with simulated
failures so it is testable on one CPU.

With a gradient stream attached (:meth:`ElasticRuntime
.attach_gradient_stream` -> :class:`repro.core.gradsync.BucketSyncStream`)
step 3 changes character: the resize is a real virtual-synchrony CUT —
wedge, ragged trim, ``EpochCarry`` resend (DESIGN.md Sec. 7) — in-flight
bucket rounds survive the view change instead of being recomputed, and
``delivered_step`` tracks the stream's monotone applied watermark (no
rollback).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.core.views import MembershipService, View


@dataclasses.dataclass
class WorkerState:
    """Host-side per-worker runtime state (the SST row, host edition)."""

    node: int
    heartbeat: int = 0            # monotone; bumped every local step
    delivered_step: int = 0       # last optimizer step known applied
    alive: bool = True
    lag: int = 0                  # straggler rounds covered by null-rounds


@dataclasses.dataclass
class ElasticConfig:
    heartbeat_timeout: int = 5      # missed beats -> suspected failed
    straggler_threshold: int = 2    # missed beats -> null-round instead
    checkpoint_every: int = 20


class ElasticRuntime:
    """Deterministic elastic-training control loop."""

    def __init__(self, members: List[int], cfg: ElasticConfig = ElasticConfig()):
        self.cfg = cfg
        self.membership = MembershipService(members)
        self.workers: Dict[int, WorkerState] = {
            m: WorkerState(node=m) for m in members}
        self.round = 0
        self.view_changes: List[View] = []
        # optional multicast gradient plane (attach_gradient_stream)
        self.gradsync = None
        self._update_fn: Optional[Callable[[int, int], Any]] = None

    @property
    def view(self) -> View:
        return self.membership.view

    def fail(self, node: int):
        self.workers[node].alive = False

    def delay(self, node: int, rounds: int):
        self.workers[node].lag += rounds

    def join(self, node: int):
        self.membership.request_join(node)
        self.workers.setdefault(node, WorkerState(node=node))

    def attach_gradient_stream(self, gradsync,
                               update_fn: Callable[[int, int], Any]):
        """Route this runtime's rounds through a
        :class:`repro.core.gradsync.BucketSyncStream`: each round's
        contributors publish ``update_fn(node, round)`` as fused bucket
        messages, updates apply in the multicast total order once
        delivered everywhere, and a resize becomes a REAL
        virtual-synchrony cut — wedge, ragged trim, ``EpochCarry``
        resend (DESIGN.md Sec. 7) — instead of the rollback-to-watermark
        restart below: a survivor's in-flight buckets are resent in the
        new view, a dead worker's unstable tail is voided, and no
        worker's ``delivered_step`` ever rolls back."""
        self.gradsync = gradsync
        self._update_fn = update_fn

    def step(self) -> Dict[str, Any]:
        """One global training round: returns which members contributed,
        who null-rounded, and whether a view change happened."""
        self.round += 1
        view = self.view
        contributed, nulls = [], []
        for m in view.members:
            w = self.workers[m]
            if not w.alive:
                continue
            if w.lag > 0:
                w.lag -= 1
                nulls.append(m)       # null-round: the Sec. 3.3 adaptation
                w.heartbeat += 1      # still alive, just slow
                continue
            w.heartbeat += 1
            if self.gradsync is None:
                w.delivered_step += 1
            contributed.append(m)
        if self.gradsync is not None:
            # publish this round's bucket set; delivered_step advances
            # with the stream's applied watermark, not local application
            self.gradsync.contribute({
                m: self._update_fn(m, self.round) for m in contributed})
            applied = self.gradsync.applied_step
            for m in view.members:
                w = self.workers[m]
                if w.alive:
                    w.delivered_step = max(w.delivered_step, applied)
        # failure detection from heartbeat watermarks
        expect = max((self.workers[m].heartbeat for m in view.members
                      if self.workers[m].alive), default=0)
        for m in view.members:
            w = self.workers[m]
            if not w.alive or expect - w.heartbeat >= \
                    self.cfg.heartbeat_timeout:
                for reporter in view.members:
                    if self.workers[reporter].alive:
                        self.membership.suspect(reporter, m)
        changed = None
        if self.membership.needs_change():
            committed = {m: self.workers[m].delivered_step
                         for m in view.members if self.workers[m].alive}
            if self.gradsync is not None:
                # a REAL cut: the stream wedges and trims, survivors'
                # in-flight buckets become resend backlog, and nobody's
                # delivered_step moves backwards — the applied watermark
                # is monotone across the cut by construction
                changed, self.gradsync = \
                    self.membership.reconfigure_stream(self.gradsync,
                                                       committed)
                self.view_changes.append(changed)
                applied = self.gradsync.applied_step
                beat = max((self.workers[n].heartbeat
                            for n in changed.members
                            if n in self.workers), default=0)
                for m in changed.members:
                    w = self.workers.setdefault(m, WorkerState(node=m))
                    w.delivered_step = max(w.delivered_step, applied)
                    w.heartbeat = beat
            else:
                changed = self.membership.propose_and_install(committed)
                self.view_changes.append(changed)
                watermark = self.membership.restart_watermark()
                for m in changed.members:
                    w = self.workers.setdefault(m, WorkerState(node=m))
                    # virtual-synchrony cleanup: roll back past the
                    # watermark (the restart-style path, kept for
                    # runtimes without a gradient stream attached)
                    w.delivered_step = watermark
                    w.heartbeat = max(self.workers[n].heartbeat
                                      for n in changed.members
                                      if n in self.workers)
        return {
            "round": self.round,
            "contributed": contributed,
            "null_rounds": nulls,
            "view_change": changed.vid if changed else None,
            "dp_size": len(self.view.members),
            "applied_step": (self.gradsync.applied_step
                             if self.gradsync is not None else None),
        }

    def restart_watermark(self) -> int:
        return self.membership.restart_watermark()
