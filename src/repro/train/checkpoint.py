"""Sharding-aware atomic checkpointer with restart/elastic-restore.

Layout (no tensorstore in this environment — plain npz shards):

    <dir>/step_000123/
        manifest.json        # step, leaf paths, shapes, dtypes, view id
        shard_00000.npz      # flat {leaf_path: array} chunks
        ...
    <dir>/LATEST             # atomic pointer (rename-into-place)

Guarantees:
  * atomic: a checkpoint directory is staged under a temp name and
    renamed into place; LATEST is updated last — a crash mid-save never
    corrupts the restore path (the previous checkpoint stays valid);
  * monotone: LATEST only ever advances (the delivered_step watermark of
    the virtual-synchrony adaptation — see DESIGN.md);
  * elastic: restore() only needs the manifest to rebuild any sharding —
    arrays are saved unsharded-logical (gathered), so a new view with a
    different mesh/rank-count can load them under new shardings.
"""

from __future__ import annotations

import json
import os
import shutil
import tempfile
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

PyTree = Any

_SHARD_BYTES = 512 << 20


def _flatten(tree: PyTree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = leaf
    return flat


def save(directory: str | Path, step: int, tree: PyTree,
         extra: Optional[Dict[str, Any]] = None) -> Path:
    directory = Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    flat = _flatten(tree)
    final = directory / f"step_{step:09d}"
    if final.exists():
        return final  # idempotent (restart re-saves the same watermark)
    stage = Path(tempfile.mkdtemp(dir=directory, prefix=".stage_"))
    manifest = {"step": step, "leaves": {}, "shards": [],
                "extra": extra or {}}
    shard: Dict[str, np.ndarray] = {}
    shard_bytes = 0
    shard_id = 0

    def flush():
        nonlocal shard, shard_bytes, shard_id
        if not shard:
            return
        name = f"shard_{shard_id:05d}.npz"
        np.savez(stage / name, **shard)
        manifest["shards"].append(name)
        shard, shard_bytes = {}, 0
        shard_id += 1

    for key, leaf in sorted(flat.items()):
        arr = np.asarray(jax.device_get(leaf))
        true_dtype = str(arr.dtype)
        if arr.dtype.kind not in "biufc":   # ml_dtypes (bf16, fp8, ...)
            arr = np.ascontiguousarray(arr).view(
                f"u{arr.dtype.itemsize}")
        manifest["leaves"][key] = {"shard": shard_id,
                                   "dtype": true_dtype,
                                   "shape": list(arr.shape)}
        shard[key] = arr
        shard_bytes += arr.nbytes
        if shard_bytes >= _SHARD_BYTES:
            flush()
    flush()
    (stage / "manifest.json").write_text(json.dumps(manifest))
    os.replace(stage, final)                       # atomic publish
    tmp_latest = directory / ".LATEST.tmp"
    tmp_latest.write_text(final.name)
    os.replace(tmp_latest, directory / "LATEST")   # atomic pointer bump
    return final


def latest_step(directory: str | Path) -> Optional[int]:
    pointer = Path(directory) / "LATEST"
    if not pointer.exists():
        return None
    name = pointer.read_text().strip()
    if not (Path(directory) / name / "manifest.json").exists():
        return None
    return int(name.split("_")[-1])


def restore(directory: str | Path, like: PyTree,
            step: Optional[int] = None,
            shardings: Optional[PyTree] = None
            ) -> Tuple[int, PyTree, Dict[str, Any]]:
    """Restore into the structure of `like` (arrays or ShapeDtypeStructs).
    With `shardings`, leaves are device_put under the NEW mesh — this is
    the elastic-restore path after a view change."""
    directory = Path(directory)
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {directory}")
    ckpt = directory / f"step_{step:09d}"
    manifest = json.loads((ckpt / "manifest.json").read_text())
    import ml_dtypes
    arrays: Dict[str, np.ndarray] = {}
    for name in manifest["shards"]:
        with np.load(ckpt / name) as z:
            for k in z.files:
                arr = z[k]
                true_dtype = manifest["leaves"][k]["dtype"]
                if str(arr.dtype) != true_dtype:
                    arr = arr.view(np.dtype(
                        getattr(ml_dtypes, true_dtype)))
                arrays[k] = arr
    flat_like = _flatten(like)
    flat_shard = _flatten(shardings) if shardings is not None else {}
    leaves_out = {}
    for key, ref in flat_like.items():
        if key not in arrays:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = arrays[key]
        want_shape = tuple(ref.shape)
        if tuple(arr.shape) != want_shape:
            raise ValueError(f"{key}: shape {arr.shape} != {want_shape}")
        if key in flat_shard:
            leaves_out[key] = jax.device_put(arr, flat_shard[key])
        else:
            leaves_out[key] = jax.numpy.asarray(arr, dtype=ref.dtype)
    # rebuild tree in like's structure
    paths, treedef = jax.tree_util.tree_flatten_with_path(like)
    ordered = []
    for path, _ in paths:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        ordered.append(leaves_out[key])
    return step, jax.tree_util.tree_unflatten(treedef, ordered), \
        manifest.get("extra", {})


def prune(directory: str | Path, keep: int = 3):
    """Keep the newest `keep` checkpoints (never the LATEST target)."""
    directory = Path(directory)
    latest = latest_step(directory)
    steps = sorted(int(p.name.split("_")[-1])
                   for p in directory.glob("step_*") if p.is_dir())
    for s in steps[:-keep] if len(steps) > keep else []:
        if s != latest:
            shutil.rmtree(directory / f"step_{s:09d}", ignore_errors=True)
