"""Step builders: train_step (loss + grads + Spindle gradsync + AdamW) and
serve_step (prefill / decode), shared by the trainer, the serving engine
and the dry-run.

Gradient-reduction modes (rt.gradsync):

  gspmd               XLA owns the reduction (per-gradient collectives are
                      inserted by SPMD partitioning — the "per-event ack"
                      baseline of the paper's analogy when params are
                      DP-replicated).
  spindle             explicit fused-bucket multicast: grads computed under
                      a partial-manual shard_map over the DP axes, every
                      ready bucket coalesced into ONE psum (opportunistic
                      batching, Sec. 3.2 adaptation).
  spindle_per_tensor  explicit per-tensor psum (the unbatched strawman, for
                      the Fig. 5-style incremental comparison).
  spindle_compressed  fused buckets + int8 all-gather leg with error
                      feedback (beyond-paper; repro.core.gradsync).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.core import gradsync
from repro.models.registry import Arch
from repro.models.runtime import Runtime
from repro.optim import adamw

PyTree = Any


def _dp_spec(rt: Runtime, ndim: int):
    from jax.sharding import PartitionSpec as P
    axes = rt.dp_axes
    lead = axes if len(axes) > 1 else (axes[0] if axes else None)
    return P(lead, *([None] * (ndim - 1)))


def _manual_grads(arch: Arch, rt: Runtime, bucket_bytes: int = 32 << 20):
    """Grad computation under a FULL-manual shard_map (pure data parallel:
    parameters replicated, batch sharded over the DP axes), with the
    Spindle reduction applied inside — the collectives this emits are
    exactly the fused / per-tensor / compressed schedule, the training
    analogue of the paper's multicast batching comparison."""
    from jax.sharding import PartitionSpec as P
    cfg = arch.cfg
    loss_fn = arch.loss_fn()
    axes = rt.dp_axes
    axis = axes if len(axes) > 1 else axes[0]
    # inside the manual region, no GSPMD constraints apply
    rt_inner = dataclasses.replace(rt, mesh=None, rules=None)

    def local_grads(params, batch):
        loss, grads = jax.value_and_grad(
            lambda p: loss_fn(p, cfg, batch, rt_inner))(params)
        n = jax.lax.psum(1, axis)
        loss = jax.lax.psum(loss, axis) / n
        if rt.gradsync == "spindle_per_tensor":
            grads = gradsync.per_tensor_psum_mean(grads, axis)
        elif rt.gradsync == "spindle_compressed":
            plan = gradsync.make_plan(grads, target_bytes=bucket_bytes)
            comp_axis = axes[-1]          # compress the widest DP leg
            state = gradsync.CompressionState.init(plan)
            grads, _ = gradsync.compressed_psum_mean(
                grads, plan, state, comp_axis,
                jax.lax.axis_index(comp_axis))
            if len(axes) > 1:             # plain mean across pods
                for a in axes[:-1]:
                    grads = jax.tree.map(
                        lambda g: jax.lax.pmean(g, a), grads)
        else:
            plan = gradsync.make_plan(grads, target_bytes=bucket_bytes)
            grads = gradsync.fused_psum_mean(grads, plan, axis)
        return loss, grads

    def wrapped(params, batch):
        batch_specs = jax.tree.map(lambda x: _dp_spec(rt, x.ndim), batch)
        fn = jax.shard_map(
            local_grads, mesh=rt.mesh,
            in_specs=(P(), batch_specs), out_specs=(P(), P()),
            axis_names=set(rt.mesh.axis_names), check_vma=False)
        return fn(params, batch)

    return wrapped


def make_train_step(arch: Arch, rt: Runtime,
                    opt_cfg: adamw.OptConfig = adamw.OptConfig()
                    ) -> Callable:
    cfg = arch.cfg
    loss_fn = arch.loss_fn()

    def train_step(params, opt_state, batch):
        if rt.gradsync.startswith("spindle") and rt.spmd:
            loss, grads = _manual_grads(arch, rt)(params, batch)
        else:
            loss, grads = jax.value_and_grad(
                lambda p: loss_fn(p, cfg, batch, rt))(params)
        new_params, new_opt, metrics = adamw.update(opt_cfg, grads,
                                                    opt_state)
        metrics["loss"] = loss
        return new_params, new_opt, metrics

    return train_step


def make_serve_step(arch: Arch, rt: Runtime, kind: str) -> Callable:
    cfg = arch.cfg
    if kind == "prefill":
        fn = arch.prefill_fn()
        if fn is not None:
            return lambda params, batch: fn(params, batch, rt)
        # recurrent families: prefill == chunked full forward; lower the
        # forward pass (same compute), emitting last-position logits
        loss_fn = arch.loss_fn()

        def forward_like(params, batch):
            return loss_fn(params, cfg, batch, rt)

        return forward_like
    if kind == "decode":
        decode = arch.decode_fn()

        def serve_step(params, cache, batch, position):
            return decode(params, cfg, cache, batch["tokens"], position,
                          rt)

        return serve_step
    raise KeyError(kind)
