"""Mamba2 SSD chunked scan as a Pallas TPU kernel.

Grid (B, H, n_chunks): batch/head parallel, chunk dimension sequential —
the (P, N) recurrent state lives in VMEM scratch and is carried across
chunk steps, exactly the HBM->VMEM blocking the SSD algorithm wants on
TPU: each chunk's x/B/C tiles stream through VMEM once, the quadratic
intra-chunk work runs on the MXU at (L x L) x (L x P) tile sizes, and the
cross-chunk state never round-trips to HBM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, alog_ref, b_ref, c_ref, dskip_ref,
                dtbias_ref, y_ref, state_out_ref, state_scr, *,
                chunk: int, n_chunks: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[0, :, 0].astype(jnp.float32)                # (L, P)
    dt = dt_ref[0, :, 0].astype(jnp.float32)              # (L,)
    a_log = alog_ref[0]                                   # scalar-ish (1,)
    b = b_ref[0, :, 0].astype(jnp.float32)                # (L, N)
    c = c_ref[0, :, 0].astype(jnp.float32)                # (L, N)
    d_skip = dskip_ref[0]
    dt_bias = dtbias_ref[0]

    dt = jax.nn.softplus(dt + dt_bias)
    a = -jnp.exp(a_log)
    da = dt * a                                           # (L,)
    xdt = x * dt[:, None]
    cs = jnp.cumsum(da)                                   # (L,)

    # intra-chunk: y_diag[l] = C_l . sum_{s<=l} exp(cs_l - cs_s) B_s xdt_s
    seg = cs[:, None] - cs[None, :]
    rows = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    lmat = jnp.where(rows >= cols, jnp.exp(seg), 0.0)     # (L, L)
    scores = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    y = jax.lax.dot_general(scores * lmat, xdt,
                            (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)

    # inbound state contribution: y_off[l] = C_l . (exp(cs_l) * S_in)
    s_in = state_scr[...]                                 # (P, N)
    y = y + jnp.exp(cs)[:, None] * jax.lax.dot_general(
        c, s_in, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    # state update: S_out = exp(cs_last) S_in + sum_s exp(cs_last - cs_s) xdt_s B_s^T
    decay_states = jnp.exp(cs[-1] - cs)                   # (L,)
    s_new = jnp.exp(cs[-1]) * s_in + jax.lax.dot_general(
        xdt * decay_states[:, None], b, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    state_scr[...] = s_new

    y_ref[0, :, 0] = (y + d_skip * x).astype(y_ref.dtype)

    @pl.when(ci == n_chunks - 1)
    def _emit_state():
        state_out_ref[0, 0] = s_new.astype(state_out_ref.dtype)


def ssd_scan_pallas(x, dt, a_log, b, c, d_skip, dt_bias, chunk: int,
                    *, interpret: bool = True):
    """x: (B, S, H, P); dt: (B, S, H); a_log/d_skip/dt_bias: (H,);
    b, c: (B, S, G, N).  Returns (y (B,S,H,P), state (B,H,P,N)).

    The group->head map is a static division in the BlockSpec index maps
    (head h reads group h // (H//G)).
    """
    bsz, s, h, p = x.shape
    g, n = b.shape[2], b.shape[3]
    assert s % chunk == 0
    nc = s // chunk
    rep = h // g
    kernel = functools.partial(_ssd_kernel, chunk=chunk, n_chunks=nc)
    y, state = pl.pallas_call(
        kernel,
        grid=(bsz, h, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, 1, p),
                         lambda bi, hi, ci: (bi, ci, hi, 0)),
            pl.BlockSpec((1, chunk, 1),
                         lambda bi, hi, ci: (bi, ci, hi)),
            pl.BlockSpec((1,), lambda bi, hi, ci: (hi,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, chunk, 1, n),
                         lambda bi, hi, ci: (bi, ci, hi // rep, 0)),
            pl.BlockSpec((1, chunk, 1, n),
                         lambda bi, hi, ci: (bi, ci, hi // rep, 0)),
            pl.BlockSpec((1,), lambda bi, hi, ci: (hi,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1,), lambda bi, hi, ci: (hi,),
                         memory_space=pltpu.SMEM),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, p),
                         lambda bi, hi, ci: (bi, ci, hi, 0)),
            pl.BlockSpec((1, 1, p, n), lambda bi, hi, ci: (bi, hi, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(x.shape, x.dtype),
            jax.ShapeDtypeStruct((bsz, h, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(x, dt, a_log.astype(jnp.float32), b, c,
      d_skip.astype(jnp.float32), dt_bias.astype(jnp.float32))
    return y, state
