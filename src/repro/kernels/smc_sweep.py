"""SMC receive-sweep as a Pallas kernel — the opportunistic-batching inner
loop (paper Sec. 3.2) expressed as a TPU data-movement kernel.

Given every sender's slot-counter ring (S, W) and the per-sender processed
counts, compute in ONE pass (a) the new visible count per sender (the
contiguous-slot scan of the receive predicate) and (b) the round-robin
received_num prefix — i.e. a whole receive-predicate iteration for all
senders, fused.  The polling area streams HBM->VMEM in (senders x window)
tiles; this is the structural analogue of keeping the SMC polling area
cache-resident (Fig. 6's w=100 sweet spot).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _sweep_kernel(counters_ref, processed_ref, visible_ref, *, window: int):
    counters = counters_ref[...]                  # (bs, W) int32
    processed = processed_ref[...]                # (bs,)  int32
    bs = counters.shape[0]
    # candidate message indexes k = processed + j, j in [0, W)
    j = jax.lax.broadcasted_iota(jnp.int32, (bs, window), 1)
    ks = processed[:, None] + j
    slots = ks % window
    want = ks // window
    have = jnp.take_along_axis(counters, slots, axis=1) >= want
    run = jnp.cumprod(have.astype(jnp.int32), axis=1).sum(axis=1)
    visible_ref[...] = processed + run


def counters_from_counts(published, window: int):
    """Materialize the SMC slot-counter ring a receiver would observe after
    ``published`` messages from each sender.

    published: (S,) int32 counts -> (S, W) int32 counters.  Slot ``j``
    holds the counter of the latest message index ``k < published`` with
    ``k % W == j`` (``-1`` if the slot was never written) — exactly the
    state :func:`repro.core.smc.publish` builds incrementally.  This lets
    the ``pallas`` Group backend drive the kernel from protocol counts.
    """
    published = jnp.asarray(published, jnp.int32)
    slots = jnp.arange(window, dtype=jnp.int32)[None, :]
    pub = published[:, None]
    return jnp.where(pub > slots, (pub - 1 - slots) // window, -1)


def smc_sweep_pallas(counters, processed, *, block_senders: int = 8,
                     interpret: bool = True):
    """counters: (S, W) int32 slot counters; processed: (S,) int32.
    Returns visible counts (S,) — the batched receive for every sender."""
    s, w = counters.shape
    assert s % block_senders == 0, (s, block_senders)
    return pl.pallas_call(
        functools.partial(_sweep_kernel, window=w),
        grid=(s // block_senders,),
        in_specs=[
            pl.BlockSpec((block_senders, w), lambda i: (i, 0)),
            pl.BlockSpec((block_senders,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block_senders,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((s,), jnp.int32),
        interpret=interpret,
    )(counters.astype(jnp.int32), processed.astype(jnp.int32))
