"""SMC receive-sweep as a Pallas kernel — the opportunistic-batching inner
loop (paper Sec. 3.2) expressed as a TPU data-movement kernel.

Given every sender's slot-counter ring (S, W) and the per-sender processed
counts, compute in ONE pass (a) the new visible count per sender (the
contiguous-slot scan of the receive predicate) and (b) the round-robin
received_num prefix — i.e. a whole receive-predicate iteration for all
senders, fused.  The polling area streams HBM->VMEM in (senders x window)
tiles; this is the structural analogue of keeping the SMC polling area
cache-resident (Fig. 6's w=100 sweet spot).

Two entry points:

* :func:`smc_sweep_pallas` — sweeps an explicit (S, W) counter ring (the
  real SMC data structure, e.g. one built by :func:`repro.core.smc.publish`).
* :func:`smc_sweep_watermark_pallas` — sweeps from per-sender published
  watermarks only: the counter tile the ring would hold is reconstructed
  *inside* the kernel (registers/VMEM), so nothing (S, W)-shaped is ever
  materialized in HBM.  This is the Group hot path: per protocol round it
  moves O(S) instead of O(S*W) bytes.

Both pad the sender axis to a ``block_senders`` multiple internally (any
sender count runs; results are sliced back) and compile to Mosaic on TPU,
falling back to interpret mode elsewhere (``interpret=None`` = auto).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _auto_interpret(interpret) -> bool:
    """Compiled (Mosaic) path on TPU, interpret fallback elsewhere."""
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret


def _contiguous_run(counters, processed, window: int):
    """Shared predicate core: length of the contiguous visible run starting
    at ``processed`` given a (bs, W) counter tile."""
    bs = counters.shape[0]
    # candidate message indexes k = processed + j, j in [0, W)
    j = jax.lax.broadcasted_iota(jnp.int32, (bs, window), 1)
    ks = processed[:, None] + j
    slots = ks % window
    want = ks // window
    have = jnp.take_along_axis(counters, slots, axis=1) >= want
    return jnp.cumprod(have.astype(jnp.int32), axis=1).sum(axis=1)


def _sweep_kernel(counters_ref, processed_ref, visible_ref, *, window: int):
    counters = counters_ref[...]                  # (bs, W) int32
    processed = processed_ref[...]                # (bs,)  int32
    visible_ref[...] = processed + _contiguous_run(counters, processed,
                                                   window)


def _watermark_run(published, processed, window: int):
    """Shared tile core of the watermark kernels: rebuild the counter tile
    in-registers from the published watermark (see
    :func:`counters_from_counts` for the ring state being reproduced — no
    (S, W) array crosses HBM) and return the contiguous visible run."""
    bs = published.shape[0]
    slots = jax.lax.broadcasted_iota(jnp.int32, (bs, window), 1)
    pub = published[:, None]
    counters = jnp.where(pub > slots, (pub - 1 - slots) // window, -1)
    return _contiguous_run(counters, processed, window)


def _watermark_kernel(published_ref, processed_ref, visible_ref, *,
                      window: int):
    """Receive sweep from published watermarks, ring rebuilt in-kernel."""
    published = published_ref[...]                # (bs,) int32
    processed = processed_ref[...]                # (bs,) int32
    visible_ref[...] = processed + _watermark_run(published, processed,
                                                  window)


def _watermark_masked_kernel(published_ref, processed_ref, valid_ref,
                             visible_ref, *, window: int):
    """:func:`_watermark_kernel` with an explicit per-lane validity mask —
    the stacked multi-subgroup path flattens a padded (member, sender)
    plane into the lane axis, so padded member rows AND padded sender
    ranks arrive here as lanes whose ring must stay untouched.  An invalid
    lane returns ``processed`` unchanged (no advancement), whatever its
    published watermark holds."""
    published = published_ref[...]                # (bs,) int32
    processed = processed_ref[...]                # (bs,) int32
    valid = valid_ref[...]                        # (bs,) int32 (0/1)
    run = _watermark_run(published, processed, window)
    visible_ref[...] = processed + jnp.where(valid > 0, run, 0)


def counters_from_counts(published, window: int):
    """Materialize the SMC slot-counter ring a receiver would observe after
    ``published`` messages from each sender.

    published: (S,) int32 counts -> (S, W) int32 counters.  Slot ``j``
    holds the counter of the latest message index ``k < published`` with
    ``k % W == j`` (``-1`` if the slot was never written) — exactly the
    state :func:`repro.core.smc.publish` builds incrementally.  Prefer
    :func:`smc_sweep_watermark_pallas` on the hot path, which computes the
    same tile inside the kernel instead of materializing it here.
    """
    published = jnp.asarray(published, jnp.int32)
    slots = jnp.arange(window, dtype=jnp.int32)[None, :]
    pub = published[:, None]
    return jnp.where(pub > slots, (pub - 1 - slots) // window, -1)


def _pad_senders(arrays, block_senders: int, pad_values):
    s = arrays[0].shape[0]
    pad = (-s) % block_senders
    if pad:
        arrays = [jnp.pad(a, ((0, pad),) + ((0, 0),) * (a.ndim - 1),
                          constant_values=v)
                  for a, v in zip(arrays, pad_values)]
    return arrays, s, s + pad


def smc_sweep_pallas(counters, processed, *, block_senders: int = 8,
                     interpret=None):
    """counters: (S, W) int32 slot counters; processed: (S,) int32.
    Returns visible counts (S,) — the batched receive for every sender.

    Any S runs: the sender axis is padded to a ``block_senders`` multiple
    (padding rows sweep an empty ring) and the result sliced back.
    """
    w = counters.shape[1]
    (counters, processed), s, sp = _pad_senders(
        [counters.astype(jnp.int32), processed.astype(jnp.int32)],
        block_senders, pad_values=(-1, 0))
    out = pl.pallas_call(
        functools.partial(_sweep_kernel, window=w),
        grid=(sp // block_senders,),
        in_specs=[
            pl.BlockSpec((block_senders, w), lambda i: (i, 0)),
            pl.BlockSpec((block_senders,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block_senders,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((sp,), jnp.int32),
        interpret=_auto_interpret(interpret),
    )(counters, processed)
    return out[:s]


def smc_sweep_watermark_pallas(published, processed, *, window: int,
                               valid=None, block_senders: int = 8,
                               interpret=None):
    """published/processed: (S,) int32 -> visible counts (S,).

    Same fixed point as :func:`smc_sweep_pallas` over
    :func:`counters_from_counts`, but the ring tile lives only inside the
    kernel: HBM traffic per call is O(S), not O(S*W).  This is what the
    ``pallas`` Group backend scans every protocol round.

    ``valid`` (optional, (S,) bool/int): per-lane validity for stacked
    padded execution.  The lane axis here is really a flattened
    (member, sender) plane when driven by the Group backends, so the mask
    covers member-axis padding as well as sender-axis padding: an invalid
    lane's result is its ``processed`` count unchanged.  (The internal
    block padding below is the third, kernel-private padding level.)
    """
    operands = [jnp.asarray(published, jnp.int32),
                jnp.asarray(processed, jnp.int32)]
    kernel = _watermark_kernel
    if valid is not None:
        operands.append(jnp.asarray(valid, jnp.int32))
        kernel = _watermark_masked_kernel
    operands, s, sp = _pad_senders(operands, block_senders,
                                   pad_values=(0,) * len(operands))
    lane_spec = pl.BlockSpec((block_senders,), lambda i: (i,))
    out = pl.pallas_call(
        functools.partial(kernel, window=window),
        grid=(sp // block_senders,),
        in_specs=[lane_spec] * len(operands),
        out_specs=lane_spec,
        out_shape=jax.ShapeDtypeStruct((sp,), jnp.int32),
        interpret=_auto_interpret(interpret),
    )(*operands)
    return out[:s]
