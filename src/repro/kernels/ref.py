"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, group: int, causal: bool = True):
    """q: (BHq, S, D); k/v: (BHkv, S, D)."""
    bh, s, d = q.shape
    kk = jnp.repeat(k, group, axis=0)
    vv = jnp.repeat(v, group, axis=0)
    scores = jnp.einsum("hqd,hkd->hqk", q.astype(jnp.float32),
                        kk.astype(jnp.float32)) / (d ** 0.5)
    if causal:
        mask = jnp.tril(jnp.ones((s, s), dtype=bool))
        scores = jnp.where(mask[None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("hqk,hkd->hqd", probs,
                      vv.astype(jnp.float32)).astype(q.dtype)


def flash_decode_ref(q, k, v, kv_len, *, group: int):
    """q: (BHq, 1, D); k/v: (BHkv, S, D)."""
    bh, _, d = q.shape
    s = k.shape[1]
    kk = jnp.repeat(k, group, axis=0)
    vv = jnp.repeat(v, group, axis=0)
    scores = jnp.einsum("hqd,hkd->hqk", q.astype(jnp.float32),
                        kk.astype(jnp.float32)) / (d ** 0.5)
    mask = jnp.arange(s)[None, None, :] < kv_len
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("hqk,hkd->hqd", probs,
                      vv.astype(jnp.float32)).astype(q.dtype)


def ssd_scan_ref(x, dt, a_log, b, c, d_skip, dt_bias, chunk):
    """Delegates to the model-stack reference implementation (itself tested
    against a step-by-step recurrence in test_kernels.py)."""
    from repro.models.ssm import ssd_chunked
    return ssd_chunked(x, dt, a_log, b, c, d_skip, dt_bias, chunk)


def ssd_sequential_ref(x, dt, a_log, b, c, d_skip, dt_bias):
    """O(S) step-by-step recurrence — the definitional oracle."""
    from repro.models.ssm import ssd_decode_step
    bsz, s, h, p = x.shape
    n = b.shape[-1]
    state = jnp.zeros((bsz, h, p, n), jnp.float32)
    ys = []
    for t in range(s):
        y, state = ssd_decode_step(x[:, t], dt[:, t], a_log, b[:, t],
                                   c[:, t], d_skip, dt_bias, state)
        ys.append(y)
    return jnp.stack(ys, axis=1), state


def rms_norm_ref(x, weight, eps: float = 1e-6):
    from repro.models.layers import rms_norm
    return rms_norm(x, weight, eps)


def rms_norm_residual_ref(x, residual, weight, eps: float = 1e-6):
    r = (residual.astype(jnp.float32) + x.astype(jnp.float32)).astype(
        x.dtype)
    return rms_norm_ref(r, weight, eps), r


def smc_sweep_ref(counters, processed):
    from repro.core.smc import visible_from_counters
    w = counters.shape[-1]
    return visible_from_counters(counters, processed, w).astype(jnp.int32)
