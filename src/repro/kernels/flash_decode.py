"""Flash decode: one-token attention against a (possibly padded) KV cache.

Grid (BHq, S//bk) with the KV dimension innermost/sequential; positions
>= kv_len are masked so a statically max-sized cache decodes correctly at
any fill level.  Memory-bound by design — each cache block is streamed
through VMEM exactly once (the roofline term this kernel moves is HBM
bytes, not FLOPs).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref,
                   m_scr, l_scr, acc_scr, *, bk: int, scale: float,
                   n_k_blocks: int):
    kj = pl.program_id(1)
    kv_len = len_ref[0]

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    @pl.when(kj * bk < kv_len)
    def _block():
        q = q_ref[0].astype(jnp.float32) * scale            # (1, D)
        k = k_ref[0].astype(jnp.float32)                    # (bk, D)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (1,bk)
        cols = kj * bk + jax.lax.broadcasted_iota(jnp.int32, (1, bk), 1)
        s = jnp.where(cols < kv_len, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(kj == n_k_blocks - 1)
    def _finish():
        denom = jnp.maximum(l_scr[...], 1e-30)[:, None]
        o_ref[0] = (acc_scr[...] / denom).astype(o_ref.dtype)


def flash_decode_flat(q, k, v, kv_len, *, group: int, bk: int = 512,
                      interpret: bool = True):
    """q: (BHq, 1, D); k/v: (BHkv, S, D); kv_len: scalar int32."""
    bh, _, d = q.shape
    s = k.shape[1]
    assert s % bk == 0, (s, bk)
    n_k = s // bk
    scale = 1.0 / (d ** 0.5)
    kernel = functools.partial(_decode_kernel, bk=bk, scale=scale,
                               n_k_blocks=n_k)
    return pl.pallas_call(
        kernel,
        grid=(bh, n_k),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, d), lambda h, j: (h, 0, 0)),
            pl.BlockSpec((1, bk, d), lambda h, j: (h // group, j, 0)),
            pl.BlockSpec((1, bk, d), lambda h, j: (h // group, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, d), lambda h, j: (h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1, d), jnp.float32),
        ],
        interpret=interpret,
    )(jnp.asarray(kv_len, jnp.int32).reshape(1), q, k, v)
