"""Fused RMSNorm (+ optional residual add) Pallas kernel.

Row-blocked: each grid step streams a (rows, d) tile through VMEM, does the
f32 reduction and scale in-register, writes the normalized tile (and the
updated residual stream when fused).  Saves one full HBM round-trip of the
activation versus norm-then-add.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rms_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(var + eps) *
                  w_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def _rms_residual_kernel(x_ref, res_ref, w_ref, o_ref, new_res_ref, *,
                         eps: float):
    r = res_ref[...].astype(jnp.float32) + x_ref[...].astype(jnp.float32)
    new_res_ref[...] = r.astype(new_res_ref.dtype)
    var = jnp.mean(jnp.square(r), axis=-1, keepdims=True)
    o_ref[...] = (r * jax.lax.rsqrt(var + eps) *
                  w_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def rms_norm_pallas(x, weight, eps: float = 1e-6, *, rows: int = 256,
                    interpret: bool = True):
    """x: (T, d) row-major; weight: (d,)."""
    t, d = x.shape
    assert t % rows == 0, (t, rows)
    return pl.pallas_call(
        functools.partial(_rms_kernel, eps=eps),
        grid=(t // rows,),
        in_specs=[
            pl.BlockSpec((rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(x, weight)


def rms_norm_residual_pallas(x, residual, weight, eps: float = 1e-6, *,
                             rows: int = 256, interpret: bool = True):
    """Fused (residual + x) -> rmsnorm.  Returns (normed, new_residual)."""
    t, d = x.shape
    assert t % rows == 0, (t, rows)
    return pl.pallas_call(
        functools.partial(_rms_residual_kernel, eps=eps),
        grid=(t // rows,),
        in_specs=[
            pl.BlockSpec((rows, d), lambda i: (i, 0)),
            pl.BlockSpec((rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((rows, d), lambda i: (i, 0)),
            pl.BlockSpec((rows, d), lambda i: (i, 0)),
        ],
        out_shape=[jax.ShapeDtypeStruct(x.shape, x.dtype),
                   jax.ShapeDtypeStruct(x.shape, x.dtype)],
        interpret=interpret,
    )(x, residual, weight)
