"""Block-scaled int8 quantize/dequantize Pallas kernels.

The hot loop of the compressed gradient multicast
(repro.core.gradsync.compressed_psum_mean): each (rows,) block of a
flattened gradient bucket is scaled by its own absmax and rounded to int8.
On TPU this is a bandwidth kernel — one HBM pass reads f32 and writes
int8 + one scale per block (4.0x wire reduction for the all-gather leg,
~3.97x HBM reduction after scales).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _quant_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)              # (block,)
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q_ref[...] = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    s_ref[...] = jnp.full_like(s_ref, scale)


def _dequant_kernel(q_ref, s_ref, x_ref):
    x_ref[...] = (q_ref[...].astype(jnp.float32) *
                  s_ref[0]).astype(x_ref.dtype)


def quantize_pallas(x, *, block: int = 2048, interpret: bool = True):
    """x: (n,) float -> (q (n,) int8, scales (n/block,) f32)."""
    n = x.shape[0]
    assert n % block == 0, (n, block)
    nb = n // block
    return pl.pallas_call(
        _quant_kernel,
        grid=(nb,),
        in_specs=[pl.BlockSpec((block,), lambda i: (i,))],
        out_specs=[pl.BlockSpec((block,), lambda i: (i,)),
                   pl.BlockSpec((1,), lambda i: (i,))],
        out_shape=[jax.ShapeDtypeStruct((n,), jnp.int8),
                   jax.ShapeDtypeStruct((nb,), jnp.float32)],
        interpret=interpret,
    )(x)


def dequantize_pallas(q, scales, *, block: int = 2048,
                      out_dtype=jnp.float32, interpret: bool = True):
    """Inverse of quantize_pallas."""
    n = q.shape[0]
    assert n % block == 0 and scales.shape[0] == n // block
    return pl.pallas_call(
        _dequant_kernel,
        grid=(n // block,),
        in_specs=[pl.BlockSpec((block,), lambda i: (i,)),
                  pl.BlockSpec((1,), lambda i: (i,))],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), out_dtype),
        interpret=interpret,
    )(q, scales)
