"""Pallas TPU kernels for the framework's compute hot spots.

Each kernel: <name>.py (pl.pallas_call + explicit BlockSpec VMEM tiling),
a pure-jnp oracle in ref.py, and a jit'd public wrapper in ops.py that
pads/reshapes model-layout tensors and selects interpret mode off-TPU.

  flash_attention  causal GQA flash attention (train / prefill)
  flash_decode     one-token attention vs a padded KV cache
  ssd_scan         Mamba2 SSD chunked scan, state carried in VMEM scratch
  rmsnorm          fused RMSNorm (+ residual) row kernel
  quantize         block-scaled int8 quant/dequant (gradient compression)
  smc_sweep        the paper's receive-predicate sweep as a data-movement
                   kernel (opportunistic batching inner loop)
"""

from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
