"""Jit'd public wrappers around the Pallas kernels.

These adapt model-layout tensors (B, S, H, D) to the kernels' flattened
layouts, pad sequences to block multiples, and select interpret mode
automatically (interpret=True off-TPU so the kernels VALIDATE on CPU; on a
real TPU backend they compile to Mosaic).
"""

from __future__ import annotations

import functools

import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import flash_decode as _fd
from repro.kernels import rmsnorm as _rn
from repro.kernels import smc_sweep as _ss
from repro.kernels import ssd_scan as _sc


def _interpret() -> bool:
    # The single compile-vs-interpret policy lives in
    # kernels.smc_sweep._auto_interpret (kernels callable without this
    # wrapper layer need it too); this is the same decision.
    return _ss._auto_interpret(None)


def flash_attention(q, k, v, *, causal: bool = True, bq: int = 128,
                    bk: int = 128):
    """q: (B, S, Hq, D); k/v: (B, S, Hkv, D) -> (B, S, Hq, D)."""
    b, s, hq, d = q.shape
    hkv = k.shape[2]
    group = hq // hkv
    pad = (-s) % max(bq, bk)
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    qf = q.transpose(0, 2, 1, 3).reshape(b * hq, s + pad, d)
    kf = k.transpose(0, 2, 1, 3).reshape(b * hkv, s + pad, d)
    vf = v.transpose(0, 2, 1, 3).reshape(b * hkv, s + pad, d)
    of = _fa.flash_attention_flat(qf, kf, vf, group=group, causal=causal,
                                  bq=bq, bk=bk, interpret=_interpret())
    out = of.reshape(b, hq, s + pad, d).transpose(0, 2, 1, 3)
    return out[:, :s]


def flash_decode(q, k_cache, v_cache, kv_len, *, bk: int = 512):
    """q: (B, Hq, D); caches: (B, S, Hkv, D); kv_len: scalar."""
    b, hq, d = q.shape
    s, hkv = k_cache.shape[1], k_cache.shape[2]
    group = hq // hkv
    pad = (-s) % bk
    if pad:
        k_cache = jnp.pad(k_cache, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v_cache = jnp.pad(v_cache, ((0, 0), (0, pad), (0, 0), (0, 0)))
    qf = q.reshape(b * hq, 1, d)
    kf = k_cache.transpose(0, 2, 1, 3).reshape(b * hkv, s + pad, d)
    vf = v_cache.transpose(0, 2, 1, 3).reshape(b * hkv, s + pad, d)
    of = _fd.flash_decode_flat(qf, kf, vf, kv_len, group=group, bk=bk,
                               interpret=_interpret())
    return of.reshape(b, hq, d)


def ssd_scan(x, dt, a_log, b, c, d_skip, dt_bias, chunk: int):
    """Mamba2 SSD scan; same signature as models.ssm.ssd_chunked."""
    return _sc.ssd_scan_pallas(x, dt, a_log, b, c, d_skip, dt_bias, chunk,
                               interpret=_interpret())


def rms_norm(x, weight, eps: float = 1e-6, *, rows: int = 256):
    """x: (..., d) -> same shape."""
    shape = x.shape
    t = 1
    for dim in shape[:-1]:
        t *= dim
    xf = x.reshape(t, shape[-1])
    pad = (-t) % rows
    if pad:
        xf = jnp.pad(xf, ((0, pad), (0, 0)))
    out = _rn.rms_norm_pallas(xf, weight, eps, rows=rows,
                              interpret=_interpret())
    return out[:t].reshape(shape)


def rms_norm_residual(x, residual, weight, eps: float = 1e-6, *,
                      rows: int = 256):
    shape = x.shape
    t = 1
    for dim in shape[:-1]:
        t *= dim
    xf = x.reshape(t, shape[-1])
    rf = residual.reshape(t, shape[-1])
    pad = (-t) % rows
    if pad:
        xf = jnp.pad(xf, ((0, pad), (0, 0)))
        rf = jnp.pad(rf, ((0, pad), (0, 0)))
    o, r = _rn.rms_norm_residual_pallas(xf, rf, weight, eps, rows=rows,
                                        interpret=_interpret())
    return o[:t].reshape(shape), r[:t].reshape(shape)


def smc_sweep(counters, processed, *, block_senders: int = 8):
    """Batched receive-predicate sweep (see kernels.smc_sweep).  The kernel
    pads non-multiple sender counts internally."""
    return _ss.smc_sweep_pallas(counters, processed,
                                block_senders=block_senders,
                                interpret=_interpret())


def smc_sweep_watermark(published, processed, *, window: int, valid=None,
                        block_senders: int = 8):
    """Receive sweep from published watermarks only — the counter ring is
    rebuilt inside the kernel tile, so no (S, W) array is materialized
    (see kernels.smc_sweep).  The Group ``pallas`` backend's per-round
    receive predicate.  ``valid`` masks padded (member, sender) lanes in
    stacked multi-subgroup execution."""
    return _ss.smc_sweep_watermark_pallas(published, processed,
                                          window=window, valid=valid,
                                          block_senders=block_senders,
                                          interpret=_interpret())
