"""Flash attention (forward) as a Pallas TPU kernel.

TPU adaptation notes (DESIGN.md Sec. 2): the GPU flash-attention blocking
(warps, shared-memory tiles) is rethought for the TPU memory hierarchy —
HBM -> VMEM block copies driven by BlockSpecs, MXU-aligned 128x128 tiles,
online-softmax accumulators carried in VMEM scratch across the innermost
(sequential) grid dimension, and whole-block causal skipping with pl.when
(the TPU analogue of early-exit warp blocks).

Layout: q (BH, S, D), k/v (BH_kv, S, D) pre-flattened by ops.py; GQA is a
static head-group division in the BlockSpec index maps.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                 bq: int, bk: int, causal: bool, scale: float,
                 n_k_blocks: int):
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    run = (kj * bk <= qi * bq + bq - 1) if causal else True

    @pl.when(run)
    def _block():
        q = q_ref[0].astype(jnp.float32) * scale          # (bq, D)
        k = k_ref[0].astype(jnp.float32)                  # (bk, D)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        if causal:
            rows = qi * bq + jax.lax.broadcasted_iota(jnp.int32,
                                                      (bq, bk), 0)
            cols = kj * bk + jax.lax.broadcasted_iota(jnp.int32,
                                                      (bq, bk), 1)
            s = jnp.where(rows >= cols, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(kj == n_k_blocks - 1)
    def _finish():
        denom = jnp.maximum(l_scr[...], 1e-30)[:, None]
        o_ref[0] = (acc_scr[...] / denom).astype(o_ref.dtype)


def flash_attention_flat(q, k, v, *, group: int, causal: bool = True,
                         bq: int = 128, bk: int = 128,
                         interpret: bool = True):
    """q: (BHq, S, D), k/v: (BHkv, S, D); BHq == BHkv * group.

    Block sizes default to the MXU-native 128.  Sequences are padded by the
    ops.py wrapper so S % bq == S % bk == 0.
    """
    bh, s, d = q.shape
    assert k.shape[0] * group == bh
    assert s % bq == 0 and s % bk == 0, (s, bq, bk)
    n_q, n_k = s // bq, s // bk
    scale = 1.0 / (d ** 0.5)

    kernel = functools.partial(_attn_kernel, bq=bq, bk=bk, causal=causal,
                               scale=scale, n_k_blocks=n_k)
    return pl.pallas_call(
        kernel,
        grid=(bh, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, bk, d), lambda h, i, j: (h // group, j, 0)),
            pl.BlockSpec((1, bk, d), lambda h, i, j: (h // group, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
