"""Seeded chaos soaks: drive a live target through a sampled fault
schedule under load-plane traffic and assert the virtual-synchrony
invariants after EVERY installed view (DESIGN.md Sec. 7).

:func:`chaos_soak` dispatches on the target:

* a :class:`~repro.core.group.Group` / ``GroupStream`` — streamed
  multicast traffic with suspicions (optionally cascading mid-wedge),
  joins, and stall bursts; checks per cut: monotone node-keyed
  ``app_base``, the conservation law ``app_base + resend ==
  cumulative enqueued`` per surviving sender, everywhere-or-nowhere
  epoch logs, per-sender FIFO, and :func:`repro.core.sst.cascading_trim`
  monotonicity over the cascade's survivor stages; at the end,
  exactly-once for every live sender and lost-tail-only for dead ones.
* a :class:`~repro.serve.fanout.ReplicatedEngine` — a sampled
  ``fail_at`` schedule mixing subscriber kills, slot-node kills, and
  cascading waves over pre-submitted requests; checks the engines
  drain, epoch logs agree at every surviving subscriber, each epoch
  delivers exactly its stable prefix, and completed/shed partition the
  submitted work.
* a :class:`~repro.core.gradsync.BucketSyncStream` — optimizer rounds
  with kills/joins/stall rounds; checks the applied ledger is in step
  order with no gaps, voided contributions only ever belong to dead
  workers, and the per-node stable base is monotone across cuts.

Every check that fails raises :class:`InvariantViolation` (an
``AssertionError`` subclass, so plain ``pytest`` machinery reports it);
the returned :class:`ChaosReport` carries comparable digests in
``extras`` so a test can run the same seed on graph and pallas and
assert the reports are bit-identical.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import group as group_mod
from repro.core import sst
from repro.core import views as views_mod

from repro.chaos.faults import FaultEvent, FaultSpec, events_by_round


class InvariantViolation(AssertionError):
    """A chaos-soak invariant failed (exactly-once / FIFO / monotone
    ``app_base`` / everywhere-or-nowhere).  The message carries the
    seed, the round, and the failing arithmetic — enough to replay."""


@dataclasses.dataclass
class ChaosReport:
    """What one soak did and verified.  ``extras`` holds plain-data
    digests (delivery sequences, per-node app counts, applied rounds)
    that must be bit-identical for the same seed across graph, pallas
    and the two-phase des stream (DESIGN.md Sec. 12)."""

    target: str                       # "stream" | "serve" | "gradsync"
    seed: int
    backend: str
    rounds: int
    views_installed: int
    wedge_retries: int
    killed: Tuple[int, ...]
    joined: Tuple[int, ...]
    stall_rounds: int
    checks: int                       # invariant assertions that ran
    extras: Dict[str, Any] = dataclasses.field(default_factory=dict)


class _Checker:
    """Counts assertions so a report can prove the soak actually
    checked something (a soak whose schedule drew zero faults still
    runs the end-state checks)."""

    def __init__(self, seed: int):
        self.seed = seed
        self.n = 0

    def __call__(self, cond: bool, msg: str, *ctx):
        self.n += 1
        if not cond:
            raise InvariantViolation(
                f"[seed={self.seed}] {msg}"
                + (f" :: {ctx}" if ctx else ""))


def _fifo_apps(log, node) -> Dict[int, int]:
    """Delivered app count per sender RANK at ``node``, asserting
    per-sender FIFO (publish indices strictly increasing) on the way."""
    counts: Dict[int, int] = {}
    last: Dict[int, int] = {}
    for rank, idx, _ in log.sequence(node):
        if idx <= last.get(rank, -1):
            raise InvariantViolation(
                f"per-sender FIFO violated at node {node}: rank {rank} "
                f"idx {idx} after {last[rank]}")
        last[rank] = idx
        counts[rank] = counts.get(rank, 0) + 1
    return counts


def _waves_of(ev: FaultEvent) -> List[List[int]]:
    return [list(ev.nodes)] + [list(w) for w in ev.cascade]


# ---------------------------------------------------------------------------
# stream soak
# ---------------------------------------------------------------------------

def _soak_stream(target, spec: FaultSpec, seed: int,
                 backend: str) -> ChaosReport:
    rng = np.random.default_rng(seed)
    check = _Checker(seed)
    if isinstance(target, group_mod.GroupStream):
        stream = target
    else:
        stream = target.stream(backend=backend)
    cfg = stream.group.cfg
    # survivability floor: the first member and first sender of every
    # subgroup (plus the reporter) are never killable, so no subgroup
    # loses all members or all senders and gid numbering stays put
    protected = {cfg.members[0]}
    for sg in cfg.subgroups:
        protected.add(sg.members[0])
        protected.add(sg.senders[0])
    reporter = cfg.members[0]
    killable = [m for m in cfg.members if m not in protected]
    joinable = [max(cfg.members) + 1 + i for i in range(3)]
    schedule = spec.sample(rng, killable=killable, joinable=joinable)
    by_round = events_by_round(schedule)

    ms = views_mod.MembershipService(cfg.members)
    cum_enq: Dict[Tuple[int, int], int] = {}      # (gid, node) -> apps
    prev_base: Dict[Tuple[int, int], int] = {}
    cum_delivered: Dict[Tuple[int, int], int] = {}
    killed: List[int] = []
    joined: List[int] = []
    stall_left = 0
    stall_rounds = 0
    epoch_digests: List[Any] = []
    trim_stages: List[List[int]] = []

    def _account_epoch(old_group, alive: set) -> None:
        """Check one closed epoch: everywhere-or-nowhere + per-sender
        FIFO on its logs, and fold its delivered app counts into the
        cumulative node-keyed ledger the carry checks reconcile."""
        specs = old_group.cfg.subgroups
        logs = old_group.delivery_logs
        digest = []
        for gid, sg in enumerate(specs):
            log = logs[gid]
            survivors = [m for m in sg.members if m in alive]
            check(bool(survivors),
                  "epoch closed with no surviving members", gid)
            seqs = [log.sequence(m) for m in survivors]
            for s in seqs[1:]:
                check(s == seqs[0],
                      "everywhere-or-nowhere violated: surviving "
                      "members disagree on the epoch log", gid)
            per_rank = _fifo_apps(log, survivors[0])
            check.n += 1                           # the FIFO pass itself
            for rank, c in per_rank.items():
                node = sg.senders[rank]
                key = (gid, node)
                cum_delivered[key] = cum_delivered.get(key, 0) + c
            digest.append(tuple(seqs[0]))
        epoch_digests.append(tuple(digest))

    n_events = 0
    for rnd in range(spec.rounds):
        ready = np.zeros(stream.shape, np.int32)
        if stall_left > 0:
            stall_left -= 1
            stall_rounds += 1                      # pure null round
        else:
            for g, sg in enumerate(stream.group.cfg.subgroups):
                for rank, node in enumerate(sg.senders):
                    if node in killed:
                        continue
                    c = int(rng.integers(0, 3))
                    ready[g, rank] = c
                    key = (g, node)
                    cum_enq[key] = cum_enq.get(key, 0) + c
        stream.step(ready)

        evs = by_round.get(rnd, ())
        waves: List[List[int]] = []
        membership_dirty = False
        for ev in evs:
            if ev.kind == "stall":
                stall_left = max(stall_left, ev.length)
            elif ev.kind == "join":
                for n in ev.nodes:
                    ms.request_join(n)
                    joined.append(n)
                membership_dirty = True
            elif ev.kind in ("suspect", "slot_kill"):
                waves.extend(_waves_of(ev))
                membership_dirty = True
        if not membership_dirty:
            continue
        n_events += 1
        for w in waves:
            killed.extend(w)
        # exercise the cascade trim arithmetic against the live SST
        # snapshot: each wave only shrinks survivors, so the staged
        # trims are monotone non-decreasing (sst.cascading_trim)
        received = np.asarray(stream._states.received_num)
        alive_now = set(ms.view.members)
        for g, sg in enumerate(stream.group.cfg.subgroups):
            dead_acc: set = set()
            stages = []
            for w in (waves or [[]]):
                dead_acc |= set(w)
                stages.append([m in alive_now and m not in dead_acc
                               for m in sg.members])
            trims = sst.cascading_trim(
                received[g, : len(sg.members)], stages)
            for a, b in zip(trims, trims[1:]):
                check(b >= a, "cascading trim rolled a watermark back",
                      rnd, g, trims)
            trim_stages.append(trims)
        if waves:
            for n in waves[0]:
                ms.suspect(reporter, n)

        def _during_wedge(svc, attempt, _waves=waves):
            nxt = attempt + 1
            if nxt < len(_waves):
                for n in _waves[nxt]:
                    svc.suspect(reporter, n)

        old_group = stream.group
        view, stream = ms.reconfigure_stream(
            stream, {},
            during_wedge=_during_wedge if len(waves) > 1 else None)
        carry = stream.carry
        alive = set(view.members)
        _account_epoch(old_group, alive)
        # per-cut invariants on the carry, keyed by NODE (rank maps
        # change across cuts; node identity is the stable key)
        for g, sg in enumerate(stream.group.cfg.subgroups):
            for rank, node in enumerate(sg.senders):
                key = (g, node)
                base = int(carry.app_base[g][rank])
                check(base >= prev_base.get(key, 0),
                      "app_base rolled back across the cut", rnd, key)
                prev_base[key] = base
                check(base + int(carry.resend[g][rank])
                      == cum_enq.get(key, 0),
                      "conservation violated: stable base + resend "
                      "backlog != total enqueued", rnd, key,
                      base, int(carry.resend[g][rank]),
                      cum_enq.get(key, 0))
                check(cum_delivered.get(key, 0) == base,
                      "delivered-so-far disagrees with the carry's "
                      "cumulative stable base", rnd, key)

    report, _logs = stream.finish()
    check(not report.stalled, "final epoch stalled short of its target")
    _account_epoch(stream.group, set(ms.view.members))
    for (g, node), total in cum_enq.items():
        got = cum_delivered.get((g, node), 0)
        if node in killed:
            check(got <= total,
                  "dead sender delivered MORE than it enqueued",
                  g, node)
        else:
            check(got == total,
                  "exactly-once violated for a live sender",
                  g, node, got, total)
    return ChaosReport(
        target="stream", seed=seed, backend=backend, rounds=spec.rounds,
        views_installed=len(ms.history) - 1, wedge_retries=ms.wedge_retries,
        killed=tuple(killed), joined=tuple(joined),
        stall_rounds=stall_rounds, checks=check.n,
        extras={
            "delivered": {f"{g}:{n}": c
                          for (g, n), c in sorted(cum_delivered.items())},
            "enqueued": {f"{g}:{n}": c
                         for (g, n), c in sorted(cum_enq.items())},
            "epoch_digests": epoch_digests,
            "trim_stages": trim_stages,
            "fault_events": n_events,
        })


# ---------------------------------------------------------------------------
# serve soak
# ---------------------------------------------------------------------------

def _soak_serve(engine, spec: FaultSpec, seed: int,
                fused: bool = False) -> ChaosReport:
    rng = np.random.default_rng(seed)
    check = _Checker(seed)
    submitted = [req.rid for eng in engine.engines for req in eng.queue]
    if not submitted:
        raise ValueError(
            "chaos_soak over a ReplicatedEngine needs pre-submitted "
            "requests (engine.submit(replica, req) before the soak)")
    # subscribers: keep the FIRST of every topic so each epoch always
    # has a log to read; slot nodes: FaultSpec keeps >= 1 live per
    # replica by construction (it only draws while a group has > 1)
    killable = [s for t in engine.topics for s in t.subscribers[1:]]
    slot_groups = [list(nodes) for nodes in engine._slot_nodes]
    schedule = spec.sample(rng, killable=killable,
                           slot_groups=slot_groups)
    fail_at: Dict[int, List[List[int]]] = {}
    stall_at: Dict[int, int] = {}
    killed: List[int] = []
    for ev in schedule:
        if ev.kind == "stall":
            stall_at[ev.round] = max(stall_at.get(ev.round, 0),
                                     ev.length)
        elif ev.kind in ("suspect", "slot_kill"):
            ws = _waves_of(ev)
            fail_at.setdefault(ev.round, []).extend(ws)
            for w in ws:
                killed.extend(w)

    old_stall = engine.stall_fn
    stall_rounds_set = {r + k for r, ln in stall_at.items()
                        for k in range(ln)}
    # stall bursts as a precomputed (rounds, G, slots) mask — the form
    # the fused program scans in-graph (a host closure would force the
    # per-round loop); the unfused loop reads the same mask, so the two
    # paths see identical stall sets
    if stall_rounds_set:
        b_max = max(engine._slots)
        stall_mask = np.zeros((max(stall_rounds_set) + 1,
                               len(engine.engines), b_max), bool)
        for r in stall_rounds_set:
            for g, b in enumerate(engine._slots):
                stall_mask[r, g, :b] = True
        engine.stall_fn = stall_mask
    else:
        engine.stall_fn = None
    try:
        report = engine.run(fail_at=fail_at, fused=fused)
    finally:
        engine.stall_fn = old_stall
    serve = report.extras["serve"]
    check(serve["drained"], "serve plane failed to drain the schedule")
    check(serve["fail_at_unreached"] == sorted(
        r for r in fail_at if r >= serve["engine_rounds"]),
        "unreached fail_at rounds mis-surfaced")

    alive = set(range(engine.domain.n_nodes)) - set(killed)
    epochs: List[Tuple[Dict[str, Any], Optional[Any]]] = [
        (old_logs, old_report) for (_, _, old_report, old_logs)
        in engine.view_log]
    epochs.append((report.extras["delivery_logs"], None))
    epoch_digests: List[Any] = []
    for e, (logs, old_report) in enumerate(epochs):
        digest = []
        for g, topic in enumerate(engine.topics):
            if topic.name not in logs:
                continue
            log = logs[topic.name]
            # never-killed subscribers survived EVERY epoch, so they
            # must agree on each epoch's log (subscribers that died in
            # a later epoch also held this one's; checking the common
            # survivors is the everywhere-or-nowhere core)
            surv = [s for s in topic.subscribers if s in alive]
            if not surv:
                continue
            seqs = [log.sequence(s) for s in surv]
            for s in seqs[1:]:
                check(s == seqs[0],
                      "surviving subscribers disagree on an epoch log",
                      e, topic.name)
            per_rank = _fifo_apps(log, surv[0])
            check.n += 1
            if old_report is not None:
                stable = old_report.extras["view_change"][
                    "stable_apps_by_old_rank"][g]
                for rank, cnt in enumerate(stable):
                    check(per_rank.get(rank, 0) == int(cnt),
                          "epoch delivered more or less than its "
                          "stable prefix", e, topic.name, rank,
                          per_rank.get(rank, 0), int(cnt))
            digest.append((topic.name, tuple(seqs[0])))
        epoch_digests.append(tuple(digest))

    completed_rids = {r.rid for eng in engine.engines
                      for r in eng.completed}
    shed_rids = {rid for rid, _ in engine.shed_log}
    check(completed_rids.isdisjoint(shed_rids),
          "a request both completed and shed", completed_rids & shed_rids)
    check(completed_rids | shed_rids == set(submitted),
          "completed + shed do not partition the submitted work",
          sorted(set(submitted) - completed_rids - shed_rids))
    for rec in engine.slot_failures:
        check(rec["lost_apps"] >= 0,
              "dead slot delivered more apps than it enqueued", rec)
    return ChaosReport(
        target="serve", seed=seed, backend=engine.backend,
        rounds=serve["engine_rounds"],
        views_installed=serve["view_changes"],
        wedge_retries=engine._ms.wedge_retries,
        killed=tuple(killed), joined=(),
        stall_rounds=serve["stall_rounds"], checks=check.n,
        extras={
            "epoch_digests": epoch_digests,
            "completed_tokens": {
                g: [tuple(t) for t in toks]
                for g, toks in engine.completed().items()},
            "slot_failures": serve["slot_failures"],
            "voided": serve["voided_requests"],
            "requeued": serve["requeued_requests"],
            "shed": sorted(shed_rids),
            "fail_at_unreached": serve["fail_at_unreached"],
            "fused": serve.get("fused", False),
            "fused_fallback": serve.get("fused_fallback"),
        })


# ---------------------------------------------------------------------------
# gradsync soak
# ---------------------------------------------------------------------------

def _soak_gradsync(gs, spec: FaultSpec, seed: int) -> ChaosReport:
    rng = np.random.default_rng(seed)
    check = _Checker(seed)
    members0 = gs.members
    reporter = members0[0]
    killable = list(members0[1:])
    joinable = [max(members0) + 1 + i for i in range(2)]
    schedule = spec.sample(rng, killable=killable, joinable=joinable)
    by_round = events_by_round(schedule)

    ms = views_mod.MembershipService(members0)
    killed: List[int] = []
    joined: List[int] = []
    stall_left = 0
    stall_rounds = 0
    contributors_by_step: Dict[int, set] = {}
    prev_base: Dict[int, int] = {}
    n_rounds = 0
    for rnd in range(spec.rounds):
        live = [m for m in gs.members if m not in killed]
        if stall_left > 0:
            stall_left -= 1
            stall_rounds += 1
            gs.contribute({})                      # pure drain round
        else:
            step = gs._next_step
            contribs = {m: {"w": float(rng.normal())} for m in live}
            contributors_by_step[step] = set(contribs)
            gs.contribute(contribs)
            n_rounds += 1
        evs = by_round.get(rnd, ())
        waves: List[List[int]] = []
        dirty = False
        for ev in evs:
            if ev.kind == "stall":
                stall_left = max(stall_left, ev.length)
            elif ev.kind == "join":
                for n in ev.nodes:
                    ms.request_join(n)
                    joined.append(n)
                dirty = True
            elif ev.kind in ("suspect", "slot_kill"):
                waves.extend(_waves_of(ev))
                dirty = True
        if not dirty:
            continue
        for w in waves:
            killed.extend(w)
        if waves:
            for n in waves[0]:
                ms.suspect(reporter, n)

        def _during_wedge(svc, attempt, _waves=waves):
            nxt = attempt + 1
            if nxt < len(_waves):
                for n in _waves[nxt]:
                    svc.suspect(reporter, n)

        applied_before = gs.applied_step
        _view, gs = ms.reconfigure_stream(
            gs, {},
            during_wedge=_during_wedge if len(waves) > 1 else None)
        check(gs.applied_step >= applied_before,
              "applied watermark rolled back across the cut", rnd)
        for node, base in gs._base.items():
            check(base >= prev_base.get(node, 0),
                  "per-node stable base rolled back", rnd, node)
            prev_base[node] = base
    gs.finish()
    steps = [a.step for a in gs.applied]
    check(steps == sorted(set(steps)),
          "rounds applied out of order or twice", steps)
    check(steps == list(range(len(steps))),
          "an optimizer round was skipped", steps)
    check(len(steps) == n_rounds,
          "not every contributed round applied", len(steps), n_rounds)
    for a in gs.applied:
        check(set(a.contributors) | set(a.voided)
              == contributors_by_step[a.step],
              "an applied round gained or lost contributors", a.step)
        check(set(a.voided) <= set(killed),
              "a LIVE contributor was voided", a.step, a.voided)
        check(not (set(a.contributors) & set(a.voided)),
              "a contributor both applied and voided", a.step)
    return ChaosReport(
        target="gradsync", seed=seed, backend=gs.backend,
        rounds=spec.rounds, views_installed=len(ms.history) - 1,
        wedge_retries=ms.wedge_retries, killed=tuple(killed),
        joined=tuple(joined), stall_rounds=stall_rounds, checks=check.n,
        extras={
            "applied": [(a.step, a.contributors, a.voided)
                        for a in gs.applied],
            "updates": [round(float(a.update["w"]), 12)
                        if a.update is not None else None
                        for a in gs.applied],
        })


# ---------------------------------------------------------------------------
# the dispatcher
# ---------------------------------------------------------------------------

def chaos_soak(target, spec: FaultSpec, *, seed: int = 0,
               backend: str = "graph",
               fused: bool = False) -> ChaosReport:
    """Run ``target`` through one seeded fault schedule drawn from
    ``spec`` and assert the plane's invariants after every installed
    view (module docstring lists them per target kind).  ``backend``
    selects the substrate when the soak builds the stream itself (a
    ``Group`` target); targets that already carry a backend
    (``GroupStream`` / ``ReplicatedEngine`` / ``BucketSyncStream``) use
    their own.  Deterministic: same target shape + spec + seed =>
    same schedule, same report, on every backend that is bit-identical
    (graph vs pallas vs des, whose numpy round mirror replays the same
    int32 sweep arithmetic — the soak tests assert exactly that).

    ``fused=True`` (serve targets only) asks the run for the
    wedge-capable fused path: schedules whose cuts stay homogeneous run
    as one device program per membership epoch; heterogeneous draws
    fall back to the per-round loop with the reason recorded — either
    way the report is bit-identical, and
    ``extras['fused']``/``extras['fused_fallback']`` say which path
    actually ran."""
    from repro.core.gradsync import BucketSyncStream
    if isinstance(target, BucketSyncStream):
        return _soak_gradsync(target, spec, seed)
    if isinstance(target, (group_mod.Group, group_mod.GroupStream)):
        return _soak_stream(target, spec, seed, backend)
    # lazy: the serve plane pulls in the model zoo
    cls = type(target).__name__
    if cls == "ReplicatedEngine":
        return _soak_serve(target, spec, seed, fused=fused)
    raise TypeError(
        f"chaos_soak does not know how to drive a {cls}: expected a "
        "Group, GroupStream, ReplicatedEngine, or BucketSyncStream")
