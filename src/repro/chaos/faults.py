"""Seeded fault schedules: the sampling half of the chaos plane.

A :class:`FaultSpec` is a distribution over *fault schedules* — per-round
suspicions (with optional cascades that land DURING the wedge), joins,
slot-node kills, and load-plane stall bursts — and :meth:`FaultSpec.sample`
draws one concrete, fully deterministic schedule from a caller-provided
``numpy`` generator.  The same seed always yields the same schedule, so a
chaos soak is a reproducible test case, not a flake: CI pins a seed
matrix (the ``chaos-soak`` job) and a failure replays locally with
nothing but the seed.

The sampler enforces the structural survivability constraints the
drivers require — it never kills a protected node, never schedules a
replica's LAST live slot node, and respects ``max_kills`` — so every
sampled schedule is survivable by construction; what the soak then
checks is that the *protocol* survives it (exactly-once, FIFO, monotone
``app_base`` — :mod:`repro.chaos.soak`).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One scheduled fault.

    ``kind`` is one of:

    * ``"suspect"`` — ``nodes`` fail after round ``round``'s dispatch;
      ``cascade`` holds later waves whose suspicions land while the
      wedge for ``nodes`` is in progress (folded into the SAME cut).
    * ``"join"`` — ``nodes`` request to join at round ``round`` (they
      ride the next installed view).
    * ``"slot_kill"`` — ``nodes`` are slot (publisher) nodes of a serve
      replica; same failure semantics as ``suspect`` but sampled under
      the keep-one-slot-per-replica constraint.
    * ``"stall"`` — a load-plane stall burst: for ``length`` rounds
      starting at ``round`` the affected senders are backpressured
      (publish nothing / decode null rounds).
    """

    round: int
    kind: str
    nodes: Tuple[int, ...] = ()
    cascade: Tuple[Tuple[int, ...], ...] = ()
    length: int = 0


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """Per-round fault rates; ``sample`` draws a deterministic schedule.

    ``suspect_rate``/``join_rate``/``slot_kill_rate``/``stall_rate`` are
    per-round Bernoulli probabilities; ``cascade_prob`` is the chance a
    suspicion brings a second wave mid-wedge (applied recursively, so
    deeper cascades are geometrically rarer); ``stall_len`` bounds a
    stall burst's length (inclusive).  ``max_kills`` caps total nodes
    killed across suspicions and slot kills (None = only the structural
    constraints cap it).
    """

    rounds: int = 24
    suspect_rate: float = 0.08
    cascade_prob: float = 0.35
    join_rate: float = 0.05
    slot_kill_rate: float = 0.0
    stall_rate: float = 0.08
    stall_len: Tuple[int, int] = (1, 3)
    max_kills: Optional[int] = None

    def sample(self, rng: np.random.Generator, *,
               killable: Sequence[int] = (),
               joinable: Sequence[int] = (),
               slot_groups: Sequence[Sequence[int]] = (),
               ) -> List[FaultEvent]:
        """Draw one schedule.

        ``killable`` — nodes that may be suspected (the driver excludes
        the nodes whose survival its invariant checks require, e.g. one
        member+sender per subgroup or one subscriber per topic);
        ``joinable`` — nodes that may request a join; ``slot_groups`` —
        per-replica slot-node lists (a kill is only drawn while the
        group keeps >= 2 live slots, so no replica ever loses its last
        publisher lane).  Events are returned in round order; at most
        one event of each kind per round.
        """
        killable = list(dict.fromkeys(killable))
        joinable = list(dict.fromkeys(joinable))
        groups = [list(g) for g in slot_groups]
        kills_left = (self.max_kills if self.max_kills is not None
                      else len(killable) + sum(map(len, groups)))
        events: List[FaultEvent] = []
        for rnd in range(self.rounds):
            if (killable and kills_left > 0
                    and rng.random() < self.suspect_rate):
                waves = []
                while (killable and kills_left > 0
                       and len(waves) < 1 + 3):   # primary + <=3 cascades
                    victim = killable.pop(
                        int(rng.integers(len(killable))))
                    waves.append((victim,))
                    kills_left -= 1
                    if rng.random() >= self.cascade_prob:
                        break
                events.append(FaultEvent(
                    round=rnd, kind="suspect", nodes=waves[0],
                    cascade=tuple(waves[1:])))
            live_groups = [i for i, g in enumerate(groups) if len(g) > 1]
            if (live_groups and kills_left > 0
                    and rng.random() < self.slot_kill_rate):
                gi = live_groups[int(rng.integers(len(live_groups)))]
                victim = groups[gi].pop(
                    int(rng.integers(len(groups[gi]))))
                kills_left -= 1
                events.append(FaultEvent(round=rnd, kind="slot_kill",
                                         nodes=(victim,)))
            if joinable and rng.random() < self.join_rate:
                node = joinable.pop(int(rng.integers(len(joinable))))
                events.append(FaultEvent(round=rnd, kind="join",
                                         nodes=(node,)))
            if self.stall_rate and rng.random() < self.stall_rate:
                lo, hi = self.stall_len
                events.append(FaultEvent(
                    round=rnd, kind="stall",
                    length=int(rng.integers(lo, hi + 1))))
        return events


def events_by_round(events: Sequence[FaultEvent]
                    ) -> Dict[int, List[FaultEvent]]:
    out: Dict[int, List[FaultEvent]] = {}
    for ev in events:
        out.setdefault(ev.round, []).append(ev)
    return out
