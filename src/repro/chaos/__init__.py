"""repro.chaos — seeded fault injection over every plane.

One import gives the chaos surface::

    from repro.chaos import FaultSpec, chaos_soak

    spec = FaultSpec(rounds=24, suspect_rate=0.15, cascade_prob=0.4)
    report = chaos_soak(api.Group(cfg), spec, seed=11, backend="graph")

:class:`FaultSpec` samples a deterministic schedule of suspicions
(optionally cascading mid-wedge), joins, slot-node kills, and stall
bursts; :func:`chaos_soak` drives a ``Group``/``GroupStream``,
``ReplicatedEngine``, or ``BucketSyncStream`` through it under
load-plane traffic and asserts the virtual-synchrony invariants
(exactly-once, per-sender FIFO, monotone ``app_base``,
everywhere-or-nowhere) after every installed view — DESIGN.md Sec. 7.
CI pins a seed matrix in the ``chaos-soak`` job.
"""

from repro.chaos.faults import FaultEvent, FaultSpec, events_by_round
from repro.chaos.soak import ChaosReport, InvariantViolation, chaos_soak

__all__ = [
    "ChaosReport", "FaultEvent", "FaultSpec", "InvariantViolation",
    "chaos_soak", "events_by_round",
]
