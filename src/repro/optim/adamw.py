"""AdamW with fp32 master weights, cosine schedule and global-norm clip.

Pure JAX (no optax in this environment).  State layout per parameter:
fp32 master + fp32 m + fp32 v — this is what makes the dry-run's
memory_analysis the *real* training-memory picture (bf16 params + 12
bytes/param of optimizer state, sharded like the parameters).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class OptConfig:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    decay_steps: int = 10_000
    min_lr_frac: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def schedule(cfg: OptConfig, step):
    step = step.astype(jnp.float32)
    warm = cfg.peak_lr * step / jnp.maximum(cfg.warmup_steps, 1)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.decay_steps - cfg.warmup_steps, 1),
                 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (
        1 + jnp.cos(jnp.pi * t))
    return jnp.where(step < cfg.warmup_steps, warm, cfg.peak_lr * cos)


def init(params: PyTree) -> Dict[str, Any]:
    f32 = lambda p: p.astype(jnp.float32)  # noqa: E731
    return {
        "step": jnp.zeros((), jnp.int32),
        "master": jax.tree.map(f32, params),
        "m": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        "v": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
    }


def abstract_state(abstract_params: PyTree) -> Dict[str, Any]:
    f32 = lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32)  # noqa: E731
    return {
        "step": jax.ShapeDtypeStruct((), jnp.int32),
        "master": jax.tree.map(f32, abstract_params),
        "m": jax.tree.map(f32, abstract_params),
        "v": jax.tree.map(f32, abstract_params),
    }


def global_norm(tree: PyTree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def update(cfg: OptConfig, grads: PyTree, state: Dict[str, Any],
           param_dtype=jnp.bfloat16) -> Tuple[PyTree, Dict[str, Any], Dict]:
    """Returns (new_params (cast to param_dtype), new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mh = m / b1c
        vh = v / b2c
        p = p - lr * (mh / (jnp.sqrt(vh) + cfg.eps)
                      + cfg.weight_decay * p)
        return m, v, p

    flat_g = jax.tree.leaves(grads)
    tdef = jax.tree.structure(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    flat_p = jax.tree.leaves(state["master"])
    out = [upd(g, m, v, p)
           for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_m = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_v = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_master = jax.tree.unflatten(tdef, [o[2] for o in out])
    new_params = jax.tree.map(lambda p: p.astype(param_dtype), new_master)
    new_state = {"step": step, "master": new_master, "m": new_m,
                 "v": new_v}
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
