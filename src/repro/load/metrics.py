"""Per-message tail-latency accounting from round traces
(DESIGN.md Sec. 10).

The protocol backends don't timestamp individual messages — they emit
round traces: per-round per-sender app publishes (``app_pub``), nulls,
and per-round per-member delivery counts (``batches``).  Those traces
determine every message's life exactly, because the total order is
round-robin arithmetic:

* sender ``s``'s ``j``-th app message (FIFO — released order IS publish
  order, messages are indistinguishable counts) publishes in the round
  where its per-sender app cumsum reaches ``j+1``; its publish index
  among the sender's apps+nulls places it at total-order seq
  ``index * S + s``;
* it is DELIVERED EVERYWHERE in the first round where every real
  member's delivered watermark (``cumsum(batches) - 1``) reaches that
  seq.

Latency is measured from the message's open-loop ARRIVAL round (when
the workload generated it), not its publish round — so it includes
admission queueing and SMC window throttling.  That is the honest
open-loop number: a closed-loop measurement from publish round would
hide exactly the queueing that saturation causes.  Round-granular
latencies convert to microseconds through the same calibrated cost fold
the backends charge (:func:`repro.core.group.fold_cost_np`).

Reported per stage: p50/p99/p999/mean latency (rounds and us), offered
vs goodput (messages per round), shed and undelivered counts, and peak
queue depth / stream backlog — goodput and offered load are SEPARATE
columns, never conflated.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.group import RunReport, fold_cost_np


def sender_app_timeline(app_pub_s: np.ndarray, nulls_s: np.ndarray
                        ) -> Tuple[np.ndarray, np.ndarray]:
    """One sender's (T,) app/null publish trace -> per app message
    ``(publish_round, publish_index)`` arrays, publish index counting
    apps AND nulls (apps precede nulls within a round, matching the
    sweep's ``published + app_pub + nulls`` ordering and
    :meth:`repro.core.group.GroupStream.app_publish_index`)."""
    a = np.asarray(app_pub_s, np.int64)
    nl = np.asarray(nulls_s, np.int64)
    app_cum = np.cumsum(a)
    tot_start = np.cumsum(a + nl) - (a + nl)      # pubs before the round
    app_start = app_cum - a                       # apps before the round
    rounds = np.repeat(np.arange(a.shape[0]), a)  # (K,) publish rounds
    j = np.arange(int(app_cum[-1]) if a.size else 0)
    idx = tot_start[rounds] + (j - app_start[rounds])
    return rounds, idx


def delivered_watermark(batches_g: np.ndarray, n_members: int
                        ) -> np.ndarray:
    """(T, N) per-round delivery counts -> (T,) highest total-order seq
    delivered at EVERY real member by the end of each round."""
    if batches_g.shape[0] == 0:
        return np.zeros(0, np.int64)
    per_member = np.cumsum(batches_g[:, :n_members], axis=0) - 1
    return per_member.min(axis=1).astype(np.int64)


@dataclasses.dataclass(frozen=True)
class StageStats:
    """One profile stage's accounting.  ``offered`` counts every
    open-loop arrival in the stage; ``released`` those admission let
    into the stream; ``shed`` those admission dropped; ``delivered``
    the released messages that reached every member by the end of the
    run (``undelivered`` = released - delivered, nonzero only when the
    drain was capped).  Latency percentiles cover delivered messages
    that ARRIVED in this stage, measured arrival -> delivered-everywhere."""

    name: str
    rounds: int
    scale: float
    offered: int
    released: int
    shed: int
    delivered: int
    undelivered: int
    p50_rounds: float
    p99_rounds: float
    p999_rounds: float
    mean_rounds: float
    p50_us: float
    p99_us: float
    p999_us: float
    offered_per_round: float
    goodput_per_round: float
    max_queue_depth: int
    max_stream_backlog: int
    end_queue_depth: int

    def to_json(self) -> Dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class LoadReport:
    """What a profile run measured: per-stage stats plus the protocol's
    own :class:`~repro.core.group.RunReport` for the whole session."""

    stages: List[StageStats]
    totals: Dict[str, float]
    run_report: Optional[RunReport] = None

    def stage(self, name: str) -> StageStats:
        for s in self.stages:
            if s.name == name:
                return s
        raise KeyError(f"no stage {name!r}; have "
                       f"{[s.name for s in self.stages]}")

    def to_json(self) -> Dict:
        return {"stages": [s.to_json() for s in self.stages],
                "totals": dict(self.totals)}

    def json_str(self) -> str:
        return json.dumps(self.to_json(), indent=2, sort_keys=True)


def _pct(arr: np.ndarray, q: float) -> float:
    return float(np.percentile(arr, q)) if arr.size else 0.0


@dataclasses.dataclass
class StageTally:
    """Harness-side per-stage counters accumulated while driving."""

    name: str
    rounds: int
    scale: float
    offered: int = 0
    released: int = 0
    shed: int = 0
    max_queue_depth: int = 0
    max_stream_backlog: int = 0
    end_queue_depth: int = 0


def build_report(*, batches: np.ndarray, app_pub: np.ndarray,
                 nulls: np.ndarray, costs: np.ndarray,
                 n_members: Sequence[int], n_senders: Sequence[int],
                 released: Sequence[Sequence[Tuple[np.ndarray,
                                                   np.ndarray]]],
                 tallies: Sequence[StageTally],
                 run_report: Optional[RunReport] = None) -> LoadReport:
    """Reconstruct per-message latencies from the stacked round traces
    and fold them into per-stage stats.

    ``released[g][s]`` is ``(arrival_rounds, stage_idx)`` arrays for the
    lane's released messages in release (= publish) order; ``tallies``
    carries the harness-side counters the traces can't know (offered,
    shed, queue depths)."""
    g_n, t_n = app_pub.shape[0], app_pub.shape[1]
    n_stages = len(tallies)
    lat_rounds: List[List[np.ndarray]] = [[] for _ in range(n_stages)]
    lat_us: List[List[np.ndarray]] = [[] for _ in range(n_stages)]
    delivered = np.zeros(n_stages, np.int64)
    undelivered = np.zeros(n_stages, np.int64)
    for g in range(g_n):
        dmin = delivered_watermark(batches[g], int(n_members[g]))
        end_t = np.cumsum(fold_cost_np(app_pub[g], costs[g]))
        s_g = int(n_senders[g])
        for s in range(s_g):
            arr_rounds, stage_idx = released[g][s]
            if arr_rounds.size == 0:
                continue
            pub_r, pub_idx = sender_app_timeline(app_pub[g, :, s],
                                                 nulls[g, :, s])
            k = pub_r.shape[0]            # published apps (<= released)
            seqs = pub_idx * s_g + s
            dr = np.searchsorted(dmin, seqs)       # delivery rounds
            ok = dr < t_n
            # messages released but never published (capped drain) or
            # published but not yet stable both count undelivered
            n_undel = (arr_rounds.size - k) + int((~ok).sum())
            arr_k = arr_rounds[:k]
            stg_k = stage_idx[:k]
            lr = dr[ok] - arr_k[ok] + 1            # same-round delivery=1
            arr_t = np.where(arr_k[ok] > 0, end_t[arr_k[ok] - 1], 0.0)
            lus = end_t[dr[ok]] - arr_t
            for si in range(n_stages):
                m = stg_k[ok] == si
                if m.any():
                    lat_rounds[si].append(lr[m])
                    lat_us[si].append(lus[m])
                delivered[si] += int(m.sum())
            # attribute undelivered to the stages of the stranded tail
            if n_undel:
                tail_stages = np.concatenate(
                    [stg_k[~ok], stage_idx[k:]])
                for si in range(n_stages):
                    undelivered[si] += int((tail_stages == si).sum())
    stages = []
    for si, tl in enumerate(tallies):
        lr = (np.concatenate(lat_rounds[si]) if lat_rounds[si]
              else np.zeros(0))
        lus = (np.concatenate(lat_us[si]) if lat_us[si]
               else np.zeros(0))
        stages.append(StageStats(
            name=tl.name, rounds=tl.rounds, scale=tl.scale,
            offered=tl.offered, released=tl.released, shed=tl.shed,
            delivered=int(delivered[si]),
            undelivered=int(undelivered[si]),
            p50_rounds=_pct(lr, 50), p99_rounds=_pct(lr, 99),
            p999_rounds=_pct(lr, 99.9),
            mean_rounds=float(lr.mean()) if lr.size else 0.0,
            p50_us=_pct(lus, 50), p99_us=_pct(lus, 99),
            p999_us=_pct(lus, 99.9),
            offered_per_round=tl.offered / tl.rounds,
            goodput_per_round=float(delivered[si]) / tl.rounds,
            max_queue_depth=tl.max_queue_depth,
            max_stream_backlog=tl.max_stream_backlog,
            end_queue_depth=tl.end_queue_depth))
    totals = {
        "offered": int(sum(s.offered for s in stages)),
        "released": int(sum(s.released for s in stages)),
        "shed": int(sum(s.shed for s in stages)),
        "delivered": int(sum(s.delivered for s in stages)),
        "undelivered": int(sum(s.undelivered for s in stages)),
        "rounds": int(sum(s.rounds for s in stages)),
    }
    return LoadReport(stages=stages, totals=totals, run_report=run_report)
