"""Seeded open-loop arrival generators (DESIGN.md Sec. 10).

Each generator produces a ``(T, G, S)`` integer matrix of per-round,
per-subgroup, per-sender message arrivals — the open-loop offered load.
Open-loop means the matrix is a function of the clock only: arrivals do
NOT slow down when the protocol falls behind (that feedback, if any, is
the admission policy's job — :mod:`repro.load.admission`).  The closed-
loop scenarios elsewhere in this repo (fixed per-sender budgets lowered
upfront) answer "how fast can the protocol go"; these answer "what does
it do when traffic doesn't wait" — Spindle's robustness-to-delay claim
is only testable this way.

Determinism contract: every generator draws exclusively from the
``numpy.random.Generator`` passed in, in a fixed order, so one seeded
generator threaded through a profile's stages yields bit-identical
matrices on every run, platform, and backend.  ``start`` carries the
global round offset so phase-dependent generators (diurnal, traces)
continue seamlessly across stage boundaries.

The matrix form is also what makes the FUSED load and serve paths
possible (DESIGN.md Sec. 6): because the offered load is a precomputed
host array rather than a per-round callback, the whole profile can ride
into one compiled device program as a scan operand — the harness's
``fused=True`` and the serve plane's ``arrive_schedule`` both lean on
exactly this property, and it is why arbitrary ``arrive_fn`` callables
are the one arrival form that still forces the per-round host loop.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence, Tuple

import numpy as np


class ArrivalSpec:
    """Protocol for arrival generators: ``sample(rounds, shape, scale,
    rng, start=0) -> (rounds,) + shape int64`` arrival counts.  ``scale``
    is the stage's load multiplier (profiles ramp it); ``start`` the
    global round index of the first sampled round."""

    def sample(self, rounds: int, shape: Tuple[int, int], scale: float,
               rng: np.random.Generator, start: int = 0) -> np.ndarray:
        raise NotImplementedError


def _lam(rate, shape, scale: float) -> np.ndarray:
    """Broadcast a scalar or per-(g, s) rate to ``shape``, scaled."""
    lam = np.broadcast_to(np.asarray(rate, np.float64), shape) * scale
    if (lam < 0).any():
        raise ValueError("arrival rates must be >= 0")
    return lam


@dataclasses.dataclass(frozen=True)
class Poisson(ArrivalSpec):
    """Memoryless arrivals: each sender independently receives
    ``Poisson(rate * scale)`` messages per round.  ``rate`` may be a
    scalar or anything broadcastable to ``(G, S)`` for heterogeneous
    per-client rates."""

    rate: object = 1.0

    def sample(self, rounds, shape, scale, rng, start=0):
        lam = _lam(self.rate, shape, scale)
        return rng.poisson(lam, size=(rounds,) + tuple(shape)).astype(
            np.int64)


@dataclasses.dataclass(frozen=True)
class OnOff(ArrivalSpec):
    """Bursty MMPP-style arrivals: each sender is an independent two-state
    Markov chain (ON at ``rate_on``, OFF at ``rate_off``), flipping with
    per-round probabilities ``p_on_off`` / ``p_off_on``.  Starts from the
    chain's stationary distribution so the first round is not special."""

    rate_on: float = 2.0
    rate_off: float = 0.0
    p_on_off: float = 0.1
    p_off_on: float = 0.1

    def sample(self, rounds, shape, scale, rng, start=0):
        if not (0 <= self.p_on_off <= 1 and 0 <= self.p_off_on <= 1):
            raise ValueError("flip probabilities must be in [0, 1]")
        p_on = self.p_off_on / max(self.p_on_off + self.p_off_on, 1e-12)
        on = rng.random(shape) < p_on
        out = np.zeros((rounds,) + tuple(shape), np.int64)
        for t in range(rounds):
            lam = np.where(on, self.rate_on, self.rate_off) * scale
            out[t] = rng.poisson(np.maximum(lam, 0.0))
            flip = rng.random(shape)
            on = np.where(on, flip >= self.p_on_off, flip < self.p_off_on)
        return out


@dataclasses.dataclass(frozen=True)
class Diurnal(ArrivalSpec):
    """Sinusoidally modulated Poisson arrivals — the day/night envelope:
    rate ``rate * scale * (1 + amplitude * sin(2*pi*(t + phase)/period))``
    clipped at zero.  The phase follows the GLOBAL round index (via
    ``start``), so a multi-stage profile sees one continuous day, not a
    sunrise per stage."""

    rate: float = 1.0
    period: int = 200
    amplitude: float = 0.8
    phase: int = 0

    def sample(self, rounds, shape, scale, rng, start=0):
        t = np.arange(start, start + rounds, dtype=np.float64)
        env = 1.0 + self.amplitude * np.sin(
            2.0 * np.pi * (t + self.phase) / max(self.period, 1))
        lam = np.maximum(self.rate * scale * env, 0.0)
        return rng.poisson(lam[:, None, None],
                           size=(rounds,) + tuple(shape)).astype(np.int64)


@dataclasses.dataclass(frozen=True)
class Trace(ArrivalSpec):
    """Replay a recorded per-client arrival trace, cyclically.  ``counts``
    is ``(T0,)`` (broadcast over every sender) or ``(T0, G, S)``; the
    stage ``scale`` multiplies it with stochastic rounding (floor plus a
    Bernoulli on the fraction) so non-integer scaling stays unbiased
    while the matrix stays integer."""

    counts: Sequence

    def sample(self, rounds, shape, scale, rng, start=0):
        base = np.asarray(self.counts, np.float64)
        if base.ndim == 1:
            base = np.broadcast_to(base[:, None, None],
                                   (base.shape[0],) + tuple(shape))
        if base.shape[1:] != tuple(shape):
            raise ValueError(
                f"trace shape {base.shape} does not broadcast to "
                f"per-round shape {tuple(shape)}")
        idx = (start + np.arange(rounds)) % base.shape[0]
        scaled = base[idx] * scale
        lo = np.floor(scaled)
        frac = scaled - lo
        return (lo + (rng.random(scaled.shape) < frac)).astype(np.int64)
