"""Staged load profiles: warmup -> step loads -> overload
(DESIGN.md Sec. 10).

A :class:`Profile` is an arrival generator plus an ordered list of
:class:`Stage`\\ s, each scaling the generator's base rate for a number
of rounds — the k6/locust "ramping arrival rate" executor shape, in
protocol rounds instead of wall seconds.  The profile owns the seed:
``matrices(shape)`` threads ONE seeded generator through the stages in
order, so the same (seed, stages, generator) triple yields bit-identical
arrival matrices everywhere — the determinism the conformance tests and
the loadtest benchmark gates rely on.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.load.arrivals import ArrivalSpec


@dataclasses.dataclass(frozen=True)
class Stage:
    """One constant-scale segment of a profile."""

    name: str
    rounds: int
    scale: float = 1.0

    def __post_init__(self):
        if self.rounds <= 0:
            raise ValueError(f"stage {self.name!r} needs rounds >= 1")
        if self.scale < 0:
            raise ValueError(f"stage {self.name!r} has negative scale")


@dataclasses.dataclass(frozen=True)
class Profile:
    """An arrival generator swept through staged rate scales."""

    arrivals: ArrivalSpec
    stages: Tuple[Stage, ...]
    seed: int = 0

    def __post_init__(self):
        if not self.stages:
            raise ValueError("profile needs at least one stage")
        names = [s.name for s in self.stages]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate stage names in {names}")

    @property
    def total_rounds(self) -> int:
        return sum(s.rounds for s in self.stages)

    def stage_bounds(self) -> List[Tuple[int, int]]:
        """Per stage: ``[start_round, end_round)`` in global rounds."""
        bounds, t = [], 0
        for s in self.stages:
            bounds.append((t, t + s.rounds))
            t += s.rounds
        return bounds

    def matrices(self, shape: Tuple[int, int],
                 sender_mask: Optional[np.ndarray] = None
                 ) -> List[np.ndarray]:
        """Sample every stage's ``(rounds, G, S)`` arrival matrix from
        one seeded generator, in stage order.  ``sender_mask`` (G, S)
        zeroes padded sender lanes AFTER sampling, so the drawn random
        stream — and hence every real lane's arrivals — is independent
        of how much padding the target's stacked shape happens to
        carry."""
        rng = np.random.default_rng(self.seed)
        out, t = [], 0
        for s in self.stages:
            m = self.arrivals.sample(s.rounds, shape, s.scale, rng,
                                     start=t)
            if sender_mask is not None:
                m = np.where(sender_mask[None, :, :], m, 0)
            out.append(m)
            t += s.rounds
        return out


def staged_ramp(arrivals: ArrivalSpec, *, warmup: int = 20,
                warmup_scale: float = 0.25,
                steps: Sequence[float] = (0.5, 1.0),
                rounds_per_stage: int = 40,
                overload: float = 4.0,
                overload_rounds: Optional[int] = None,
                seed: int = 0) -> Profile:
    """The canonical open-loop sweep: a low-rate warmup (compile + cache
    fill), ascending step loads, then one stage deliberately past
    saturation.  The overload stage is not optional — a load test that
    never saturates cannot distinguish goodput from offered load
    (DESIGN.md Sec. 10)."""
    stages = [Stage("warmup", warmup, warmup_scale)]
    stages += [Stage(f"step-{s:g}", rounds_per_stage, s) for s in steps]
    stages.append(Stage("overload", overload_rounds or rounds_per_stage,
                        overload))
    return Profile(arrivals=arrivals, stages=tuple(stages), seed=seed)
