"""The open-loop load harness: run a profile against a target, get a
:class:`~repro.load.metrics.LoadReport` (DESIGN.md Sec. 10).

Targets, in ascending stack depth:

* a bare :class:`~repro.core.group.Group` / ``GroupStream`` — the
  protocol plane alone;
* a :class:`~repro.core.dds.BoundDomain` — the same stream behind the
  topic-keyed DDS front (arrival lanes are topic publishers);
* a :class:`~repro.serve.fanout.ReplicatedEngine` — the serve plane
  (arrivals become requests; latency is submit -> finish in engine
  rounds).

The stream path is the reference loop: per round, arrivals land in
per-lane FIFO queues; the admission policy releases/sheds against the
previous round's SMC backlog watermark; the released counts become the
round's ``step(ready)``; after the last stage the admission queue keeps
releasing (no new arrivals) until it empties, then the stream drains
(:meth:`finish`) and per-message latencies are reconstructed from the
round traces (:mod:`repro.load.metrics`).  Everything is deterministic
given (profile, target, policy): graph and pallas produce bit-identical
reports, and the loadtest benchmark gates on that.

``fused=True`` runs the same accounting off a FUSED device program
(DESIGN.md Sec. 6/10): the whole profile becomes one ``lax.scan`` over
the precomputed ``(T, G, S)`` arrival matrices with the admission
policy's :meth:`~repro.load.admission.AdmissionPolicy.device_admit`
lowering and the stream round body inlined per step, followed by
chunked device drain sweeps; per-message FIFO attribution is then
REPLAYED on the host from the device's release/shed matrices (identical
arithmetic, so identical queues), and the rounds are absorbed into the
stream (:meth:`~repro.core.group.GroupStream.absorb`) so
``finish``/``build_report`` post-process through the exact unfused
machinery.  The resulting :class:`LoadReport` is bit-identical to the
per-round loop's by construction — fused runs mark themselves only in
``run_report.extras['load_fused']``, never in the stage/totals JSON.
Non-lowerable policies and the des (numpy) stream fall back silently to
the host loop.
"""

from __future__ import annotations

import collections
from typing import List, Optional, Tuple

import numpy as np

from repro.core import dds as dds_mod
from repro.core import group as group_mod
from repro.load.admission import (AdmissionPolicy, AdmitAll,
                                  ServeAdmission)
from repro.load.metrics import (LoadReport, StageStats, StageTally,
                                build_report)
from repro.load.profiles import Profile


def _resolve_stream(target, backend: str):
    if isinstance(target, group_mod.Group):
        return target.stream(backend=backend)
    if isinstance(target, group_mod.GroupStream):
        return target
    if isinstance(target, dds_mod.BoundDomain):
        return target.stream
    raise TypeError(
        f"cannot load-test {type(target).__name__}; pass a Group, "
        "GroupStream, BoundDomain, or ReplicatedEngine")


def run_profile(target, profile: Profile,
                admission: Optional[AdmissionPolicy] = None, *,
                backend: str = "graph",
                settle_max: Optional[int] = None,
                max_new_tokens: int = 4,
                prompt_len: int = 2,
                fused: bool = False) -> LoadReport:
    """Drive ``target`` open-loop through ``profile`` and account the
    result.  ``admission`` defaults to :class:`AdmitAll` (the
    uncontrolled baseline) on stream targets and must be a
    :class:`ServeAdmission` (or None) on a ``ReplicatedEngine``.
    ``backend`` picks the stream substrate when ``target`` is a bare
    ``Group``; ``settle_max`` caps the post-profile drain (capped-off
    messages report as ``undelivered``).  ``max_new_tokens`` /
    ``prompt_len`` shape the synthetic requests on the serve path.
    ``fused=True`` runs the profile through the fused device program
    (bit-identical report, see the module docstring); it falls back to
    the host loop when the target or policy cannot be lowered."""
    if hasattr(target, "engines") and hasattr(target, "submit"):
        return _run_serve_profile(target, profile, admission,
                                  settle_max=settle_max,
                                  max_new_tokens=max_new_tokens,
                                  prompt_len=prompt_len,
                                  fused=fused)
    stream = _resolve_stream(target, backend)
    if stream.rounds or stream.carry is not None:
        raise ValueError(
            "load profiles need a fresh stream: rounds already streamed "
            "or an epoch carry would misalign the FIFO latency "
            "accounting")
    policy = admission if admission is not None else AdmitAll()
    if isinstance(policy, ServeAdmission):
        raise TypeError("ServeAdmission lowers to the serve plane; "
                        "stream targets take an AdmissionPolicy")
    g_n, s_max = stream.shape
    mask = np.zeros((g_n, s_max), bool)
    for g, s_g in enumerate(stream.n_senders):
        mask[g, :s_g] = True
    windows = np.asarray(stream.windows, np.int64)
    stage_mats = profile.matrices((g_n, s_max), mask)
    if fused:
        report = _run_stream_profile_fused(stream, profile, policy,
                                           mask, stage_mats,
                                           settle_max)
        if report is not None:
            return report
    pending: List[List[collections.deque]] = [
        [collections.deque() for _ in range(s_max)] for _ in range(g_n)]
    rel_rounds: List[List[List[int]]] = [
        [[] for _ in range(s_max)] for _ in range(g_n)]
    rel_stages: List[List[List[int]]] = [
        [[] for _ in range(s_max)] for _ in range(g_n)]
    tallies: List[StageTally] = [
        StageTally(name=st.name, rounds=st.rounds, scale=st.scale)
        for st in profile.stages]
    view = None
    t_global = 0

    def admit_round(tally: StageTally):
        nonlocal view, t_global
        queued = np.array([[len(pending[g][s]) for s in range(s_max)]
                           for g in range(g_n)], np.int64)
        backlog = (np.where(mask, view.backlog, 0).astype(np.int64)
                   if view is not None
                   else np.zeros((g_n, s_max), np.int64))
        release, shed = policy.admit(t_global, queued, backlog, windows)
        release = np.minimum(np.maximum(release, 0), queued)
        shed = np.minimum(np.maximum(shed, 0), queued - release)
        # released/shed counts go to the message's ARRIVAL stage, same
        # attribution as the delivered/latency stats built from traces
        for g, s in zip(*np.nonzero(release)):
            for _ in range(int(release[g, s])):
                a_rnd, a_stage = pending[g][s].popleft()
                rel_rounds[g][s].append(a_rnd)
                rel_stages[g][s].append(a_stage)
                tallies[a_stage].released += 1
        for g, s in zip(*np.nonzero(shed)):
            for _ in range(int(shed[g, s])):
                _, a_stage = pending[g][s].pop()  # tail drop: newest
                tallies[a_stage].shed += 1
        view = stream.step(release.astype(np.int32))
        depth = int(queued.sum() - release.sum() - shed.sum())
        tally.max_queue_depth = max(tally.max_queue_depth, depth)
        bl = int(np.where(mask, view.backlog, 0).sum())
        tally.max_stream_backlog = max(tally.max_stream_backlog, bl)
        t_global += 1
        return int(release.sum() + shed.sum())

    for si, (stage, mat) in enumerate(zip(profile.stages, stage_mats)):
        tally = tallies[si]
        for t in range(stage.rounds):
            arr = mat[t]
            tally.offered += int(arr.sum())
            for g, s in zip(*np.nonzero(arr)):
                pending[g][s].extend([(t_global, si)] * int(arr[g, s]))
            admit_round(tally)
        tally.end_queue_depth = int(
            sum(len(q) for row in pending for q in row))
    # drain the admission queue: arrivals stopped, but admitted-but-queued
    # work keeps releasing under the same policy until the lanes empty (or
    # the policy stalls for 64 straight rounds — leftovers then report as
    # end_queue_depth).  Without this, overload goodput misreports the
    # plateau as collapse purely from stranded-queue accounting
    # (DESIGN.md Sec. 10).
    idle = 0
    while (idle < 64
           and any(q for row in pending for q in row)):
        progressed = admit_round(tallies[-1])
        idle = 0 if progressed else idle + 1
    tallies[-1].end_queue_depth = int(
        sum(len(q) for row in pending for q in row))
    run_report, _logs = stream.finish(settle_max=settle_max)
    batches, app_pub, nulls = stream.traces()
    released = [[(np.asarray(rel_rounds[g][s], np.int64),
                  np.asarray(rel_stages[g][s], np.int64))
                 for s in range(s_max)] for g in range(g_n)]
    return build_report(batches=batches, app_pub=app_pub, nulls=nulls,
                        costs=stream.cost_params,
                        n_members=stream.n_members,
                        n_senders=stream.n_senders,
                        released=released, tallies=tallies,
                        run_report=run_report)


def _build_load_programs(policy, g_n, n_max, s_max, windows, null_send,
                         backend, masked, chunk):
    """Build the two jitted programs of the fused stream path: the
    profile scan (one device program for every arrival round) and the
    drain sweep (a fixed-size chunk of zero-arrival rounds with the host
    loop's ``idle < 64 and queue nonempty`` gate evaluated in-graph).
    Each round is the EXACT host round: admission lowering -> clip ->
    queue arithmetic -> :func:`repro.core.sweep.stream_stacked` — the
    same scan body the per-round ``GroupStream.step`` dispatches."""
    import jax
    import jax.numpy as jnp
    from jax import lax

    from repro.core import sweep as sweep_mod

    ring = max(windows) if backend == "pallas" else 0
    receive_fn = (group_mod._kernel_receive(ring)
                  if backend == "pallas" else None)
    win_arr = np.asarray(windows, np.int32)

    def round_fn(states, backlogs, pend, pol, arr_t, sender_mask,
                 masks):
        queued = pend + arr_t
        bl_prev = jnp.where(sender_mask, backlogs, 0)
        release, shed, pol = policy.device_admit(
            pol, queued, bl_prev, jnp.asarray(win_arr))
        release = jnp.clip(release, 0, queued)
        shed = jnp.clip(shed, 0, queued - release)
        pend = queued - release - shed
        mm, sm = masks if masked else (None, None)
        (states, backlogs), (batch, pub, nulls) = \
            sweep_mod.stream_stacked(
                states, backlogs, release.astype(jnp.int32),
                windows=win_arr, null_send=null_send,
                member_masks=mm, sender_masks=sm,
                receive_fn=receive_fn)
        bl_now = jnp.where(sender_mask, backlogs, 0)
        return (states, backlogs, pend, pol,
                (batch, pub, nulls, release, shed, bl_now))

    def profile_fn(arr, pol0, sender_mask, *masks):
        group_mod.TRACE_EVENTS.append(
            ((g_n, n_max, s_max), tuple(windows), backend + "+load"))
        states = sweep_mod.batch_states(n_max, s_max, g_n)
        backlogs = jnp.zeros((g_n, s_max), jnp.int32)
        pend = jnp.zeros((g_n, s_max), jnp.int32)

        def body(carry, arr_t):
            states, backlogs, pend, pol = carry
            states, backlogs, pend, pol, ys = round_fn(
                states, backlogs, pend, pol, arr_t, sender_mask, masks)
            return (states, backlogs, pend, pol), ys

        return lax.scan(body, (states, backlogs, pend, pol0), arr)

    def drain_fn(states, backlogs, pend, pol, idle, sender_mask,
                 *masks):
        group_mod.TRACE_EVENTS.append(
            ((g_n, n_max, s_max), tuple(windows), backend + "+drain"))
        zero = jnp.zeros((g_n, s_max), jnp.int32)

        def body(carry, _):
            states, backlogs, pend, pol, idle, t = carry
            live = (idle < 64) & (pend.sum() > 0)
            ns, nb, npend, npol, ys = round_fn(
                states, backlogs, pend, pol, zero, sender_mask, masks)
            _, _, _, release, shed, _ = ys
            prog = (release.sum() + shed.sum()) > 0
            nidle = jnp.where(prog, 0, idle + 1)

            def sel(a, b):
                return jnp.where(live, a, b)

            states = jax.tree_util.tree_map(sel, ns, states)
            backlogs = sel(nb, backlogs)
            pend = sel(npend, pend)
            pol = jax.tree_util.tree_map(sel, npol, pol)
            idle = jnp.where(live, nidle, idle)
            t = jnp.where(live, t + 1, t)
            ys = jax.tree_util.tree_map(
                lambda y: jnp.where(live, y, jnp.zeros_like(y)), ys)
            return (states, backlogs, pend, pol, idle, t), ys

        t0 = jnp.asarray(0, jnp.int32)
        carry = (states, backlogs, pend, pol, idle, t0)
        return lax.scan(body, carry, None, length=chunk)

    return jax.jit(profile_fn), jax.jit(drain_fn)


_DRAIN_CHUNK = 128


def _run_stream_profile_fused(stream, profile: Profile,
                              policy: AdmissionPolicy,
                              mask: np.ndarray,
                              stage_mats: List[np.ndarray],
                              settle_max: Optional[int]
                              ) -> Optional[LoadReport]:
    """The fused stream path: profile scan + drain chunks on device,
    FIFO attribution replayed on host from the device release/shed
    matrices, rounds absorbed into the stream so finish/build_report run
    the unfused machinery verbatim.  Returns None (silent fallback to
    the host loop) when the stream is the des numpy mirror or the policy
    has no device lowering."""
    if stream._numpy or policy.fused_key() is None:
        return None
    import jax.numpy as jnp

    g_n, s_max = stream.shape
    arr = np.concatenate(stage_mats, axis=0).astype(np.int32)
    t_prof = arr.shape[0]
    backend = stream.backend.name
    null_send = stream.group.cfg.flags.null_send
    masked = bool(stream._mask_args)
    key = ("load-fused", g_n, stream.n_max, s_max,
           tuple(stream.windows), null_send, backend, masked,
           t_prof, _DRAIN_CHUNK, policy.fused_key())
    profile_prog, drain_prog = group_mod.fused_stream_program(
        key, lambda: _build_load_programs(
            policy, g_n, stream.n_max, s_max, tuple(stream.windows),
            null_send, backend, masked, _DRAIN_CHUNK))
    sender_mask_dev = jnp.asarray(mask)
    pol0 = policy.device_init((g_n, s_max))
    (states, backlogs, pend, pol), ys = profile_prog(
        jnp.asarray(arr), pol0, sender_mask_dev, *stream._mask_args)
    rows = [np.asarray(y) for y in ys]
    batches = list(rows[0])
    pubs = list(rows[1])
    nulls_l = list(rows[2])
    rel_l = list(rows[3])
    shed_l = list(rows[4])
    bl_l = list(rows[5])
    idle = jnp.asarray(0, jnp.int32)
    device_calls = 1
    while (int(np.asarray(idle)) < 64
           and int(np.asarray(pend).sum()) > 0):
        (states, backlogs, pend, pol, idle, t_c), dys = drain_prog(
            states, backlogs, pend, pol, idle, sender_mask_dev,
            *stream._mask_args)
        device_calls += 1
        t_c = int(np.asarray(t_c))
        drows = [np.asarray(y)[:t_c] for y in dys]
        batches += list(drows[0])
        pubs += list(drows[1])
        nulls_l += list(drows[2])
        rel_l += list(drows[3])
        shed_l += list(drows[4])
        bl_l += list(drows[5])
        if t_c < _DRAIN_CHUNK:
            break
    policy.device_commit(pol)

    # host replay of the per-message FIFO attribution: same queues, same
    # pops, driven by the device's release/shed counts instead of a
    # policy call — depths and stage tallies land exactly where the
    # host loop puts them
    tallies: List[StageTally] = [
        StageTally(name=st.name, rounds=st.rounds, scale=st.scale)
        for st in profile.stages]
    pending: List[List[collections.deque]] = [
        [collections.deque() for _ in range(s_max)] for _ in range(g_n)]
    rel_rounds: List[List[List[int]]] = [
        [[] for _ in range(s_max)] for _ in range(g_n)]
    rel_stages: List[List[List[int]]] = [
        [[] for _ in range(s_max)] for _ in range(g_n)]
    t_global = 0

    def apply_round(tally: StageTally):
        nonlocal t_global
        rel, sh = rel_l[t_global], shed_l[t_global]
        for g, s in zip(*np.nonzero(rel)):
            for _ in range(int(rel[g, s])):
                a_rnd, a_stage = pending[g][s].popleft()
                rel_rounds[g][s].append(a_rnd)
                rel_stages[g][s].append(a_stage)
                tallies[a_stage].released += 1
        for g, s in zip(*np.nonzero(sh)):
            for _ in range(int(sh[g, s])):
                _, a_stage = pending[g][s].pop()  # tail drop: newest
                tallies[a_stage].shed += 1
        depth = int(sum(len(q) for row in pending for q in row))
        tally.max_queue_depth = max(tally.max_queue_depth, depth)
        bl = int(bl_l[t_global].sum())
        tally.max_stream_backlog = max(tally.max_stream_backlog, bl)
        t_global += 1

    for si, (stage, mat) in enumerate(zip(profile.stages, stage_mats)):
        tally = tallies[si]
        for t in range(stage.rounds):
            a = mat[t]
            tally.offered += int(a.sum())
            for g, s in zip(*np.nonzero(a)):
                pending[g][s].extend([(t_global, si)] * int(a[g, s]))
            apply_round(tally)
        tally.end_queue_depth = int(
            sum(len(q) for row in pending for q in row))
    while t_global < len(rel_l):
        apply_round(tallies[-1])
    tallies[-1].end_queue_depth = int(
        sum(len(q) for row in pending for q in row))

    total_rel = (np.sum(np.stack(rel_l), axis=0) if rel_l
                 else np.zeros((g_n, s_max), np.int64))
    stream.absorb(states, backlogs, batches, pubs, nulls_l,
                  [total_rel[g].astype(np.int64) for g in range(g_n)])
    run_report, _logs = stream.finish(settle_max=settle_max)
    batches_t, app_pub_t, nulls_t = stream.traces()
    released = [[(np.asarray(rel_rounds[g][s], np.int64),
                  np.asarray(rel_stages[g][s], np.int64))
                 for s in range(s_max)] for g in range(g_n)]
    report = build_report(batches=batches_t, app_pub=app_pub_t,
                          nulls=nulls_t, costs=stream.cost_params,
                          n_members=stream.n_members,
                          n_senders=stream.n_senders,
                          released=released, tallies=tallies,
                          run_report=run_report)
    if run_report is not None:
        run_report.extras["load_fused"] = {
            "rounds": len(batches), "profile_rounds": t_prof,
            "drain_rounds": len(batches) - t_prof,
            "device_calls": device_calls}
    return report


def _run_serve_profile(rep, profile: Profile,
                       admission: Optional[ServeAdmission], *,
                       settle_max: Optional[int],
                       max_new_tokens: int, prompt_len: int,
                       fused: bool = False) -> LoadReport:
    """The serve-plane lowering: arrival lanes are KV slots, per-round
    lane sums become request arrivals per replica; latency is request
    submit -> finish in engine rounds (the decode loop has no
    cost-model microseconds — the us percentiles report 0)."""
    from repro.serve.engine import Request

    if admission is not None and not isinstance(admission,
                                                ServeAdmission):
        raise TypeError("ReplicatedEngine targets take a ServeAdmission "
                        f"policy, got {type(admission).__name__}")
    g_n = len(rep.engines)
    slots = [eng.ecfg.max_batch for eng in rep.engines]
    s_max = max(slots)
    mask = np.zeros((g_n, s_max), bool)
    for g, b in enumerate(slots):
        mask[g, :b] = True
    stage_mats = profile.matrices((g_n, s_max), mask)
    counts = np.concatenate(stage_mats, axis=0).sum(axis=2)  # (T, G)
    total_rounds = counts.shape[0]
    prompt_rng = np.random.default_rng(profile.seed + 1)
    vocab = min(eng.cfg.vocab_size for eng in rep.engines)
    schedule: List[List[List[Request]]] = [
        [[] for _ in range(g_n)] for _ in range(total_rounds)]
    rid = 0
    for t in range(total_rounds):
        for g in range(g_n):
            for _ in range(int(counts[t, g])):
                prompt = prompt_rng.integers(
                    1, max(vocab - 1, 2), size=prompt_len).astype(
                        np.int32)
                schedule[t][g].append(Request(
                    rid=rid, prompt=prompt,
                    max_new_tokens=max_new_tokens))
                rid += 1
    run_report = rep.run(
        arrive_schedule=schedule,
        arrive_rounds=total_rounds, admission=admission,
        settle_max=settle_max, fused=fused,
        max_rounds=total_rounds + 10_000)
    bounds = profile.stage_bounds()

    def stage_of(rnd: int) -> int:
        for si, (lo, hi) in enumerate(bounds):
            if lo <= rnd < hi:
                return si
        return len(bounds) - 1
    shed_rids = {r for r, _ in rep.shed_log}
    lat: List[List[float]] = [[] for _ in profile.stages]
    n = len(profile.stages)
    offered = np.zeros(n, np.int64)
    shed = np.zeros(n, np.int64)
    delivered = np.zeros(n, np.int64)
    for r, rnd in rep.submit_rounds.items():
        si = stage_of(rnd)
        offered[si] += 1
        if r in shed_rids:
            shed[si] += 1
        elif r in rep.finish_round_by_rid:
            delivered[si] += 1
            lat[si].append(rep.finish_round_by_rid[r] - rnd + 1)
    stages = []
    for si, stage in enumerate(profile.stages):
        lo, hi = bounds[si]
        depths = rep.queue_depth_log[lo:hi]
        backlogs = rep.backlog_log[lo:hi]
        if si == n - 1:                 # drain rounds land on the tail
            depths = rep.queue_depth_log[lo:]
            backlogs = rep.backlog_log[lo:]
        lr = np.asarray(lat[si], np.float64)
        stages.append(StageStats(
            name=stage.name, rounds=stage.rounds, scale=stage.scale,
            offered=int(offered[si]),
            released=int(offered[si] - shed[si]),
            shed=int(shed[si]), delivered=int(delivered[si]),
            undelivered=int(offered[si] - shed[si] - delivered[si]),
            p50_rounds=float(np.percentile(lr, 50)) if lr.size else 0.0,
            p99_rounds=float(np.percentile(lr, 99)) if lr.size else 0.0,
            p999_rounds=float(np.percentile(lr, 99.9)) if lr.size
            else 0.0,
            mean_rounds=float(lr.mean()) if lr.size else 0.0,
            p50_us=0.0, p99_us=0.0, p999_us=0.0,
            offered_per_round=float(offered[si]) / stage.rounds,
            goodput_per_round=float(delivered[si]) / stage.rounds,
            max_queue_depth=max(depths, default=0),
            max_stream_backlog=max(backlogs, default=0),
            end_queue_depth=0))
    totals = {
        "offered": int(offered.sum()), "shed": int(shed.sum()),
        "released": int(offered.sum() - shed.sum()),
        "delivered": int(delivered.sum()),
        "undelivered": int(offered.sum() - shed.sum()
                           - delivered.sum()),
        "rounds": int(total_rounds),
    }
    return LoadReport(stages=stages, totals=totals,
                      run_report=run_report)
