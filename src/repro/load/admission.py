"""Admission control and shed/stall policies (DESIGN.md Sec. 10).

The seam between open-loop arrivals and the protocol's finite resources.
Arrivals land in per-sender FIFO queues held by the harness; every round
the policy decides, per ``(subgroup, sender)`` lane, how many queued
messages to RELEASE into the stream's ready counts and how many to SHED
from the queue tail.  Whatever the policy releases beyond the SMC window
the protocol itself throttles into the stream backlog — that backlog is
the backpressure signal the policies gate on, so admission "lowers to"
the SMC window rather than duplicating it.

The honesty constraint: under overload something must give.  A policy
that never sheds (``AdmitAll``) lets queues and latency grow without
bound — useful as the uncontrolled baseline, and exactly what an honest
report must show as unbounded.  A bounding policy (``WindowSlack``,
``TokenBucket``) keeps p99 and queue depth finite by refusing work,
and the shed count is reported separately from goodput — the harness
never silently converges to closed-loop behavior.

The serve plane has its own resource model (request queues and KV
slots); :class:`ServeAdmission` is the equivalent policy there, lowered
by :meth:`repro.serve.fanout.ReplicatedEngine.run` to queue-tail sheds
and watermark-aware ``stalled`` slots.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np


class AdmissionPolicy:
    """Per-round admission decision over the ``(G, S)`` lane grid.

    ``admit(round_no, queued, backlog, windows)`` receives the post-
    arrival queue depths, the stream's window-throttled backlog from the
    previous round's watermarks, and the per-subgroup SMC windows; it
    returns ``(release, shed)`` counts with ``release + shed <= queued``
    lane-wise.  Implementations may keep state (token buckets); the
    harness calls them once per round in round order."""

    def admit(self, round_no: int, queued: np.ndarray,
              backlog: np.ndarray, windows: np.ndarray
              ) -> Tuple[np.ndarray, np.ndarray]:
        raise NotImplementedError


def _clip_decision(release, shed, queued):
    release = np.minimum(np.maximum(release, 0), queued)
    shed = np.minimum(np.maximum(shed, 0), queued - release)
    return release.astype(np.int64), shed.astype(np.int64)


@dataclasses.dataclass
class AdmitAll(AdmissionPolicy):
    """The uncontrolled baseline: release everything, shed nothing.
    Under overload the stream backlog (and hence latency) grows without
    bound — the behavior an honest saturation report must expose, not
    hide."""

    def admit(self, round_no, queued, backlog, windows):
        return queued.astype(np.int64), np.zeros_like(queued, np.int64)


@dataclasses.dataclass
class WindowSlack(AdmissionPolicy):
    """Backpressure-coupled admission: release only while the stream's
    window-throttled backlog has slack, shed the queue tail beyond a cap.

    Per lane, release ``max(0, inflight_limit - backlog)`` (default
    limit: 2x the subgroup's SMC window — one window in flight, one
    queued behind it), then drop whatever still exceeds ``queue_cap``
    from the TAIL (newest arrivals — the ones that would wait longest).
    Both latency and queue depth are thereby bounded: a released message
    waits at most ``queue_cap`` harness rounds' worth of drain plus
    ``inflight_limit`` in-stream messages, regardless of offered load."""

    inflight_limit: Optional[int] = None
    queue_cap: Optional[int] = 64

    def admit(self, round_no, queued, backlog, windows):
        if self.inflight_limit is not None:
            limit = np.full_like(queued, self.inflight_limit)
        else:
            limit = np.broadcast_to(2 * np.asarray(windows)[:, None],
                                    queued.shape)
        release = np.minimum(queued, np.maximum(limit - backlog, 0))
        if self.queue_cap is None:
            shed = np.zeros_like(queued)
        else:
            shed = np.maximum(queued - release - self.queue_cap, 0)
        return _clip_decision(release, shed, queued)


@dataclasses.dataclass
class TokenBucket(AdmissionPolicy):
    """Classic rate limiter: each lane accrues ``rate`` tokens per round
    up to ``burst``; a release spends one token per message.  Optionally
    tail-drops beyond ``queue_cap`` like :class:`WindowSlack`.  Bounds
    the RELEASED rate (so the stream never saturates if ``rate`` is set
    below capacity) rather than reacting to backlog."""

    rate: float = 1.0
    burst: float = 8.0
    queue_cap: Optional[int] = 64
    _tokens: Optional[np.ndarray] = dataclasses.field(
        default=None, repr=False)

    def admit(self, round_no, queued, backlog, windows):
        if self._tokens is None:
            self._tokens = np.full(queued.shape, float(self.burst))
        self._tokens = np.minimum(self._tokens + self.rate, self.burst)
        release = np.minimum(queued, np.floor(self._tokens).astype(
            np.int64))
        self._tokens = self._tokens - release
        if self.queue_cap is None:
            shed = np.zeros_like(queued)
        else:
            shed = np.maximum(queued - release - self.queue_cap, 0)
        return _clip_decision(release, shed, queued)


@dataclasses.dataclass(frozen=True)
class ServeAdmission:
    """Admission/shed/stall policy for the serve plane, lowered by
    :meth:`repro.serve.fanout.ReplicatedEngine.run`:

    * ``queue_cap`` — per-replica request-queue cap; arrivals beyond it
      are shed from the queue tail (newest first) and recorded with
      their round, bounding both queue depth and admitted-request wait.
    * ``stall_backlog`` — watermark-aware stall: a KV slot whose
      multicast lane has more than this many messages in flight
      (published-but-undelivered plus window-throttled backlog) decodes
      a null round until the watermark catches up — backpressure
      expressed through the slot's SMC window instead of unbounded ring
      occupancy."""

    queue_cap: Optional[int] = None
    stall_backlog: Optional[int] = None
