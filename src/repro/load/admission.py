"""Admission control and shed/stall policies (DESIGN.md Sec. 10).

The seam between open-loop arrivals and the protocol's finite resources.
Arrivals land in per-sender FIFO queues held by the harness; every round
the policy decides, per ``(subgroup, sender)`` lane, how many queued
messages to RELEASE into the stream's ready counts and how many to SHED
from the queue tail.  Whatever the policy releases beyond the SMC window
the protocol itself throttles into the stream backlog — that backlog is
the backpressure signal the policies gate on, so admission "lowers to"
the SMC window rather than duplicating it.

The honesty constraint: under overload something must give.  A policy
that never sheds (``AdmitAll``) lets queues and latency grow without
bound — useful as the uncontrolled baseline, and exactly what an honest
report must show as unbounded.  A bounding policy (``WindowSlack``,
``TokenBucket``) keeps p99 and queue depth finite by refusing work,
and the shed count is reported separately from goodput — the harness
never silently converges to closed-loop behavior.

The serve plane has its own resource model (request queues and KV
slots); :class:`ServeAdmission` is the equivalent policy there, lowered
by :meth:`repro.serve.fanout.ReplicatedEngine.run` to queue-tail sheds
and watermark-aware ``stalled`` slots.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np


class AdmissionPolicy:
    """Per-round admission decision over the ``(G, S)`` lane grid.

    ``admit(round_no, queued, backlog, windows)`` receives the post-
    arrival queue depths, the stream's window-throttled backlog from the
    previous round's watermarks, and the per-subgroup SMC windows; it
    returns ``(release, shed)`` counts with ``release + shed <= queued``
    lane-wise.  Implementations may keep state (token buckets); the
    harness calls them once per round in round order.

    A policy that can run INSIDE the fused load program (the whole
    profile as one device scan — DESIGN.md Sec. 6/10) additionally
    implements the ``fused_key`` / ``device_init`` / ``device_admit``
    triple: ``device_admit`` is the exact ``admit`` arithmetic lowered
    to ``jnp`` over an explicit state carry, and ``fused_key`` is the
    hashable static description the compiled-program cache keys on.
    The built-in policies all lower; a policy that returns ``None``
    from :meth:`fused_key` (the default) falls the harness back to the
    per-round host loop — silently, because the two loops are
    bit-identical by contract."""

    def admit(self, round_no: int, queued: np.ndarray,
              backlog: np.ndarray, windows: np.ndarray
              ) -> Tuple[np.ndarray, np.ndarray]:
        raise NotImplementedError

    def fused_key(self) -> Optional[Tuple]:
        """Hashable static description for the fused-program cache, or
        ``None`` when this policy cannot be lowered in-graph."""
        return None

    def device_init(self, shape: Tuple[int, int]):
        """Initial ``(G, S)``-shaped device state carry (a jnp pytree;
        stateless policies return an empty array)."""
        import jax.numpy as jnp
        return jnp.zeros((0,), jnp.float32)

    def device_admit(self, state, queued, backlog, windows):
        """One round of :meth:`admit` in ``jnp`` arithmetic:
        ``(release, shed, state)`` over int32 lane grids.  Must mirror
        the host formulas bit-for-bit (the fused/unfused LoadReport
        equivalence tests gate on it)."""
        raise NotImplementedError

    def device_commit(self, state) -> None:
        """Install the post-run device state back onto the host policy
        (so a policy object reused after a fused run behaves exactly as
        it would after the host loop).  Stateless policies no-op."""


def _clip_decision(release, shed, queued):
    release = np.minimum(np.maximum(release, 0), queued)
    shed = np.minimum(np.maximum(shed, 0), queued - release)
    return release.astype(np.int64), shed.astype(np.int64)


@dataclasses.dataclass
class AdmitAll(AdmissionPolicy):
    """The uncontrolled baseline: release everything, shed nothing.
    Under overload the stream backlog (and hence latency) grows without
    bound — the behavior an honest saturation report must expose, not
    hide."""

    def admit(self, round_no, queued, backlog, windows):
        return queued.astype(np.int64), np.zeros_like(queued, np.int64)

    def fused_key(self):
        return ("admit-all",)

    def device_admit(self, state, queued, backlog, windows):
        import jax.numpy as jnp
        return queued, jnp.zeros_like(queued), state


@dataclasses.dataclass
class WindowSlack(AdmissionPolicy):
    """Backpressure-coupled admission: release only while the stream's
    window-throttled backlog has slack, shed the queue tail beyond a cap.

    Per lane, release ``max(0, inflight_limit - backlog)`` (default
    limit: 2x the subgroup's SMC window — one window in flight, one
    queued behind it), then drop whatever still exceeds ``queue_cap``
    from the TAIL (newest arrivals — the ones that would wait longest).
    Both latency and queue depth are thereby bounded: a released message
    waits at most ``queue_cap`` harness rounds' worth of drain plus
    ``inflight_limit`` in-stream messages, regardless of offered load."""

    inflight_limit: Optional[int] = None
    queue_cap: Optional[int] = 64

    def admit(self, round_no, queued, backlog, windows):
        if self.inflight_limit is not None:
            limit = np.full_like(queued, self.inflight_limit)
        else:
            limit = np.broadcast_to(2 * np.asarray(windows)[:, None],
                                    queued.shape)
        release = np.minimum(queued, np.maximum(limit - backlog, 0))
        if self.queue_cap is None:
            shed = np.zeros_like(queued)
        else:
            shed = np.maximum(queued - release - self.queue_cap, 0)
        return _clip_decision(release, shed, queued)

    def fused_key(self):
        return ("window-slack", self.inflight_limit, self.queue_cap)

    def device_admit(self, state, queued, backlog, windows):
        import jax.numpy as jnp
        if self.inflight_limit is not None:
            limit = jnp.full_like(queued, self.inflight_limit)
        else:
            limit = jnp.broadcast_to(2 * windows[:, None], queued.shape)
        release = jnp.minimum(queued, jnp.maximum(limit - backlog, 0))
        if self.queue_cap is None:
            shed = jnp.zeros_like(queued)
        else:
            shed = jnp.maximum(queued - release - self.queue_cap, 0)
        return release, shed, state


@dataclasses.dataclass
class TokenBucket(AdmissionPolicy):
    """Classic rate limiter: each lane accrues ``rate`` tokens per round
    up to ``burst``; a release spends one token per message.  Optionally
    tail-drops beyond ``queue_cap`` like :class:`WindowSlack`.  Bounds
    the RELEASED rate (so the stream never saturates if ``rate`` is set
    below capacity) rather than reacting to backlog."""

    rate: float = 1.0
    burst: float = 8.0
    queue_cap: Optional[int] = 64
    # float32, matching the fused program's device carry bit-for-bit
    # (the fused/unfused LoadReport equivalence gates on it)
    _tokens: Optional[np.ndarray] = dataclasses.field(
        default=None, repr=False)

    def admit(self, round_no, queued, backlog, windows):
        if self._tokens is None:
            self._tokens = np.full(queued.shape, np.float32(self.burst),
                                   np.float32)
        self._tokens = np.minimum(self._tokens + np.float32(self.rate),
                                  np.float32(self.burst))
        release = np.minimum(queued, np.floor(self._tokens).astype(
            np.int64))
        self._tokens = (self._tokens
                        - release.astype(np.float32)).astype(np.float32)
        if self.queue_cap is None:
            shed = np.zeros_like(queued)
        else:
            shed = np.maximum(queued - release - self.queue_cap, 0)
        return _clip_decision(release, shed, queued)

    def fused_key(self):
        return ("token-bucket", float(self.rate), float(self.burst),
                self.queue_cap)

    def device_init(self, shape):
        import jax.numpy as jnp
        if self._tokens is not None:
            return jnp.asarray(self._tokens, jnp.float32)
        return jnp.full(shape, jnp.float32(self.burst), jnp.float32)

    def device_admit(self, state, queued, backlog, windows):
        import jax.numpy as jnp
        tokens = jnp.minimum(state + jnp.float32(self.rate),
                             jnp.float32(self.burst))
        release = jnp.minimum(queued,
                              jnp.floor(tokens).astype(queued.dtype))
        tokens = tokens - release.astype(jnp.float32)
        if self.queue_cap is None:
            shed = jnp.zeros_like(queued)
        else:
            shed = jnp.maximum(queued - release - self.queue_cap, 0)
        return release, shed, tokens

    def device_commit(self, state) -> None:
        self._tokens = np.asarray(state, np.float32)


@dataclasses.dataclass(frozen=True)
class ServeAdmission:
    """Admission/shed/stall policy for the serve plane, lowered by
    :meth:`repro.serve.fanout.ReplicatedEngine.run`:

    * ``queue_cap`` — per-replica request-queue cap; arrivals beyond it
      are shed from the queue tail (newest first) and recorded with
      their round, bounding both queue depth and admitted-request wait.
    * ``stall_backlog`` — watermark-aware stall: a KV slot whose
      multicast lane has more than this many messages in flight
      (published-but-undelivered plus window-throttled backlog) decodes
      a null round until the watermark catches up — backpressure
      expressed through the slot's SMC window instead of unbounded ring
      occupancy."""

    queue_cap: Optional[int] = None
    stall_backlog: Optional[int] = None
