"""repro.load — the workload plane (DESIGN.md Sec. 10).

Open-loop traffic for the protocol, DDS, and serve planes: seeded
arrival generators (:mod:`~repro.load.arrivals`), staged ramp profiles
(:mod:`~repro.load.profiles`), admission/shed policies lowering to SMC
window backpressure (:mod:`~repro.load.admission`), per-message
tail-latency accounting from round traces (:mod:`~repro.load.metrics`),
and the harness tying them together (:mod:`~repro.load.harness`)::

    from repro.load import Poisson, WindowSlack, staged_ramp, run_profile

    profile = staged_ramp(Poisson(rate=0.5), overload=5.0, seed=0)
    report = run_profile(api.Group(cfg), profile,
                         admission=WindowSlack(queue_cap=32))
    report.stage("overload").p99_rounds   # bounded by the policy
"""

from repro.load.admission import (AdmissionPolicy, AdmitAll,
                                  ServeAdmission, TokenBucket,
                                  WindowSlack)
from repro.load.arrivals import (ArrivalSpec, Diurnal, OnOff, Poisson,
                                 Trace)
from repro.load.harness import run_profile
from repro.load.metrics import (LoadReport, StageStats, StageTally,
                                build_report, delivered_watermark,
                                sender_app_timeline)
from repro.load.profiles import Profile, Stage, staged_ramp

__all__ = [
    "AdmissionPolicy", "AdmitAll", "ArrivalSpec", "Diurnal",
    "LoadReport", "OnOff", "Poisson", "Profile", "ServeAdmission",
    "Stage", "StageStats", "StageTally", "TokenBucket", "Trace",
    "WindowSlack", "build_report", "delivered_watermark", "run_profile",
    "sender_app_timeline", "staged_ramp",
]
