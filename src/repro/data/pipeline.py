"""Deterministic, sharded, resumable data pipeline.

Design requirements (large-scale runnability):
  * deterministic: batch t is a pure function of (seed, step, view) — any
    worker can reproduce any step, which is what makes elastic re-sharding
    and restart-from-watermark trivial (the checkpoint stores only the
    step counter, never iterator state);
  * sharded: each data-parallel rank materializes only its slice;
  * source-agnostic: synthetic token streams for tests/benches, or a
    memory-mapped token file for real corpora.

The re-shard rule on a view change mirrors virtual synchrony (DESIGN.md):
the new view's ranks re-partition the same deterministic stream, so no
example is lost or double-counted beyond the rolled-back watermark window.
"""

from __future__ import annotations

import dataclasses
import hashlib
from pathlib import Path
from typing import Dict, Iterator, Optional, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seq_len: int
    global_batch: int
    vocab_size: int
    seed: int = 0
    kind: str = "synthetic"        # synthetic | lm_file | mixture
    path: Optional[str] = None     # token file (np.uint16/uint32 memmap)
    # synthetic stream structure (so loss can actually go down):
    n_patterns: int = 512
    pattern_len: int = 64


def _rng_for(cfg: DataConfig, sequence_index: int) -> np.random.Generator:
    """One generator per GLOBAL sequence index — rank-independent, so any
    re-partitioning of ranks yields byte-identical global batches."""
    key = f"{cfg.seed}:{sequence_index}".encode()
    seed = int.from_bytes(hashlib.blake2b(key, digest_size=8).digest(),
                          "little")
    return np.random.default_rng(seed)


class TokenSource:
    """Deterministic random-access token source."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        self._mm = None
        if cfg.kind == "lm_file":
            assert cfg.path, "lm_file needs path"
            self._mm = np.memmap(cfg.path, dtype=np.uint32, mode="r")
        elif cfg.kind == "synthetic":
            rng = np.random.default_rng(cfg.seed)
            # a bank of repeated patterns + noise: predictable structure
            self._patterns = rng.integers(
                0, cfg.vocab_size, size=(cfg.n_patterns, cfg.pattern_len),
                dtype=np.int32)

    def sequence(self, index: int, rng: np.random.Generator) -> np.ndarray:
        cfg = self.cfg
        if self._mm is not None:
            n = len(self._mm) - cfg.seq_len - 1
            off = int(index * 2654435761 % max(n, 1))
            return np.asarray(self._mm[off:off + cfg.seq_len],
                              dtype=np.int32)
        # synthetic: tile patterns chosen by index, 10% noise tokens
        picks = rng.integers(0, cfg.n_patterns,
                             size=cfg.seq_len // cfg.pattern_len + 1)
        seq = self._patterns[picks].reshape(-1)[: cfg.seq_len].copy()
        noise = rng.random(cfg.seq_len) < 0.1
        seq[noise] = rng.integers(0, cfg.vocab_size, size=int(noise.sum()))
        return seq.astype(np.int32)


@dataclasses.dataclass
class ShardedLoader:
    """Batch t for data-parallel rank r of R ranks."""

    cfg: DataConfig
    rank: int
    n_ranks: int

    def __post_init__(self):
        assert self.cfg.global_batch % self.n_ranks == 0, \
            (self.cfg.global_batch, self.n_ranks)
        self.local_batch = self.cfg.global_batch // self.n_ranks
        self.source = TokenSource(self.cfg)

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        base = step * self.cfg.global_batch + self.rank * self.local_batch
        toks = np.stack([
            self.source.sequence(base + i, _rng_for(self.cfg, base + i))
            for i in range(self.local_batch)])
        return {"tokens": toks}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1


def global_batch(cfg: DataConfig, step: int) -> Dict[str, np.ndarray]:
    """The full global batch (single-process training / tests)."""
    loader = ShardedLoader(cfg, rank=0, n_ranks=1)
    return loader.batch(step)


def reshard(cfg: DataConfig, old_ranks: int, new_ranks: int):
    """A view change re-partitions the SAME stream: loader construction is
    all that changes.  Returns a factory for the new view's loaders."""
    del old_ranks
    return lambda rank: ShardedLoader(cfg, rank=rank, n_ranks=new_ranks)
