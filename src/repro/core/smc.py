"""SMC — the small-message multicast ring buffer (paper Sec. 2.3).

Each (subgroup, sender) owns ``w`` fixed-size slots laid out in SST
columns.  A slot is ``(message area, counter)``; the counter's increment
signals a fresh message.  Message index ``k`` lives in slot ``k % w`` and
bumps that slot's counter to ``k // w`` (counters start at -1 == unused).

A slot may be reused only once *every* member has delivered the message it
holds — so sender ``s`` may publish index ``k`` iff ``k < delivered_s + w``
where ``delivered_s`` is the number of s's messages delivered by the
slowest member.  Violating this would overwrite an undelivered message.

Total SMC memory per subgroup (Sec. 4.1.2): ``n * w * (m + 8)`` bytes.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import sst

Array = Any


@dataclasses.dataclass(frozen=True)
class SMCConfig:
    window: int = 100            # w; Sec. 4.1.2 recommends ~100 for 10 KB
    max_msg_size: int = 10240    # slot message area, bytes
    slot_overhead: int = 8       # the slot counter

    @property
    def slot_bytes(self) -> int:
        return self.max_msg_size + self.slot_overhead

    def region_bytes(self, n_nodes: int) -> int:
        """Total pinned SMC memory for one subgroup (n * w * (m + 8))."""
        return n_nodes * self.window * self.slot_bytes


# --- slot arithmetic --------------------------------------------------------

def slot_of(index, window: int):
    return index % window


def counter_for(index, window: int):
    """Counter value a slot holds after message `index` is written to it."""
    return index // window


def publish_cap(delivered_count, window: int):
    """Highest publishable index+1 for a sender given the minimum number of
    its messages delivered across all members."""
    return delivered_count + window


def visible_from_counters(counters, received_count, window: int):
    """Contiguous-scan of a sender's slot counters (paper's receive
    predicate): starting from `received_count` (messages already seen),
    walk forward while the expected slot counter is present.

    counters: (..., w); received_count: (...,) -> new visible count (...,).
    Vectorized: message index k is visible iff counters[k % w] >= k // w;
    we take the longest contiguous run starting at received_count, capped
    at one full window ahead.
    """
    xp = jnp if isinstance(counters, jax.Array) else np
    w = window
    ks = received_count[..., None] + xp.arange(w)          # candidate indexes
    have = xp.take_along_axis(counters, ks % w, axis=-1) >= (ks // w)
    # counters.dtype, not a hard-coded np.int64: under 32-bit JAX an int64
    # astype is silently truncated (with a warning) — the run length fits
    # the counter dtype by construction (<= w).
    run = xp.cumprod(have.astype(counters.dtype), axis=-1).sum(axis=-1)
    return received_count + run


# --- functional publish / receive over an SST table -------------------------

def publish(table, node: int, subgroup: int, new_count, window: int):
    """Write messages [old_count, new_count) into the ring: bump slot
    counters and the published watermark on the node's own row. Functional.
    new_count is the total number of messages published after this call."""
    xp = jnp if isinstance(table["slot_counter"], jax.Array) else np
    old = table["published_num"][node, subgroup] + 1      # count published
    counters = table["slot_counter"]
    if xp is np:
        counters = counters.copy()
        for k in range(int(old), int(new_count)):
            counters[node, subgroup, k % window] = k // window
        out = dict(table, slot_counter=counters)
    else:
        ks = old + jnp.arange(window)
        mask = ks < new_count
        slots = ks % window
        vals = jnp.where(mask, ks // window,
                         counters[node, subgroup, slots])
        out = dict(table,
                   slot_counter=counters.at[node, subgroup, slots].set(vals))
    return _set_watermark(out, node, subgroup, new_count - 1)


def _set_watermark(table, node, subgroup, value):
    col = table["published_num"]
    if isinstance(col, np.ndarray):
        col = col.copy()
        col[node, subgroup] = max(col[node, subgroup], value)
        return dict(table, published_num=col)
    return dict(table, published_num=col.at[node, subgroup].max(value))


def free_slots(published_count, delivered_count, window: int):
    """How many more messages the sender may publish right now."""
    return publish_cap(delivered_count, window) - published_count
