"""Calibrated cost models for the Spindle protocol plane.

Two calibrations are provided:

* ``RDMA_CX6`` — the paper's testbed: 16 machines, 100 Gbps (12.5 GB/s)
  InfiniBand, one-sided RDMA writes.  Constants come straight from the
  paper: Figure 1 gives wire latency 1.73 us @ 1 B rising to 2.46 us
  @ 4 KB; Section 3.2 reports ~1 us of CPU time to post one RDMA write
  and that the baseline predicate thread spends >30% of its time posting.

* ``TPU_ICI`` — the adaptation target: TPU v5e chip-to-chip ICI links.
  Per the system spec: 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
  Collective launch overhead on TPU is of the same order as an RDMA post
  (~1 us), which is exactly why the paper's "small messages are
  latency-bound" regime transfers.

All times are microseconds, all sizes bytes, all bandwidths bytes/us
(= MB/s * 1e-6... i.e. GB/s == 1e3 bytes/us).
"""

from __future__ import annotations

import dataclasses

GB_PER_S = 1e3  # bytes per microsecond


@dataclasses.dataclass(frozen=True)
class NetworkModel:
    """Latency/bandwidth model of one node's NIC + link.

    Wire latency of a single write of ``size`` bytes is
    ``base_latency_us + size * lat_per_byte_us`` (the paper's Fig. 1 line),
    while sustained throughput is limited by ``link_bw`` (full-duplex;
    egress and ingress accounted separately).
    """

    name: str
    post_us: float          # CPU time to post one write/collective
    base_latency_us: float  # wire latency at size ~ 0
    lat_per_byte_us: float  # latency slope (pipelined, != 1/link_bw)
    link_bw: float          # bytes/us, serialization bandwidth per direction
    cacheline: int = 64
    inline_max: int = 0     # writes <= this avoid the payload DMA fetch

    def wire_latency(self, size: int) -> float:
        """One-way latency of a single write of `size` bytes (Fig. 1)."""
        return self.base_latency_us + size * self.lat_per_byte_us

    def serialization(self, size: int) -> float:
        """Link occupancy of a write of `size` bytes."""
        return size / self.link_bw


@dataclasses.dataclass(frozen=True)
class HostModel:
    """CPU-side costs of the polling (predicate) thread."""

    predicate_eval_us: float   # evaluate one predicate over current state
    slot_poll_us: float        # inspect one SMC slot counter
    upcall_us: float           # deliver one message to the application
    upcall_batch_us: float     # fixed overhead of one (batched) upcall
    lock_us: float             # acquire+release the SST lock once
    memcpy_base_us: float      # memcpy latency intercept
    memcpy_per_byte_us: float  # memcpy slope (Fig. 14)
    app_send_api_us: float = 1.0   # slot acquire + send() call overhead

    def memcpy(self, size: int) -> float:
        return self.memcpy_base_us + size * self.memcpy_per_byte_us


@dataclasses.dataclass(frozen=True)
class ChipModel:
    """Compute-side constants used by the roofline (TPU v5e)."""

    name: str
    peak_flops: float   # FLOP/s bf16
    hbm_bw: float       # bytes/s
    ici_bw: float       # bytes/s per link
    hbm_bytes: float    # capacity
    vmem_bytes: float   # VMEM per core


# --- calibrations -----------------------------------------------------------

# Fit of Fig. 1: lat(1 B) = 1.73 us, lat(4 KB) = 2.46 us
#   slope = (2.46 - 1.73) / 4095 = 1.7827e-4 us/B
_RDMA_SLOPE = (2.46 - 1.73) / 4095.0

RDMA_CX6 = NetworkModel(
    name="rdma-cx6-100g",
    post_us=1.0,                 # Sec. 3.2: "posting an RDMA request ... ~1us"
    base_latency_us=1.73,        # Fig. 1 @ 1 B
    lat_per_byte_us=_RDMA_SLOPE,
    link_bw=12.5 * GB_PER_S,     # 100 Gbps
    inline_max=220,              # typical CX-6 max inline
)

TPU_ICI = NetworkModel(
    name="tpu-v5e-ici",
    post_us=1.0,                 # collective launch overhead
    base_latency_us=1.0,         # single-hop ICI latency
    lat_per_byte_us=1.0 / (50.0 * GB_PER_S),
    link_bw=50.0 * GB_PER_S,
)

HOST_X86 = HostModel(
    predicate_eval_us=0.35,
    slot_poll_us=0.008,          # one cache-line read + loop overhead
    upcall_us=0.60,
    upcall_batch_us=0.25,
    lock_us=0.15,
    memcpy_base_us=0.05,
    # Fig. 14: memcpy stays cheap to a few KB then deteriorates; a 10 KB
    # memcpy at ~12 GB/s of single-core copy bandwidth.
    memcpy_per_byte_us=1.0 / (12.0 * GB_PER_S),
)

TPU_V5E = ChipModel(
    name="tpu-v5e",
    peak_flops=197e12,
    hbm_bw=819e9,
    ici_bw=50e9,
    hbm_bytes=16e9,
    vmem_bytes=128 * 1024 * 1024,
)


def roofline_terms(flops: float, hbm_bytes: float, coll_bytes: float,
                   n_chips: int, chip: ChipModel = TPU_V5E) -> dict:
    """The three roofline terms (seconds) per the system spec.

    compute    = HLO_FLOPs        / (chips * peak)
    memory     = HLO_bytes        / (chips * hbm_bw)
    collective = collective_bytes / (chips * link_bw)

    ``flops``/``hbm_bytes``/``coll_bytes`` are *global* (whole-program)
    quantities; cost_analysis on a fully-SPMD program already reports
    per-program numbers which we treat as aggregate over chips.
    """
    compute = flops / (n_chips * chip.peak_flops)
    memory = hbm_bytes / (n_chips * chip.hbm_bw)
    collective = coll_bytes / (n_chips * chip.ici_bw)
    dominant = max(
        ("compute", compute), ("memory", memory), ("collective", collective),
        key=lambda kv: kv[1])[0]
    return {
        "compute_s": compute,
        "memory_s": memory,
        "collective_s": collective,
        "dominant": dominant,
        "bound_s": max(compute, memory, collective),
    }
