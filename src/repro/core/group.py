"""The unified Derecho-style ``Group`` API with pluggable protocol backends.

Derecho (the paper's artifact) exposes one handle: a *group* whose
subgroups you ``send()`` into and receive totally-ordered delivery upcalls
from, while every Spindle optimization stays an internal toggle.  This
module is that seam for the repro: one :class:`GroupConfig` describes a
scenario (membership, subgroups, :class:`~repro.core.simulator.SpindleFlags`,
cost/net models) and :meth:`Group.run` executes it unmodified on any of
three substrates behind the :class:`ProtocolBackend` protocol:

  * ``"des"``    — the calibrated discrete-event simulator
                   (:mod:`repro.core.simulator`): answers *how fast* on the
                   paper's RDMA testbed model.
  * ``"graph"``  — the pure-JAX fused predicate sweep
                   (:mod:`repro.core.sweep`): the send pattern is lowered
                   to an ``app_schedule`` array and scanned in-graph.
  * ``"pallas"`` — the graph protocol with the receive predicate evaluated
                   by the fused Pallas SMC-sweep kernel
                   (:mod:`repro.kernels.smc_sweep`) over real slot-counter
                   rings.

Every backend returns the same :class:`RunReport` (throughput, latency
percentiles, app/null delivery accounting, RDMA-write counts) so Fig.
5-style comparisons work like-for-like across substrates, and every
backend records the same per-subgroup total-order delivery log, so
delivered sequences can be asserted identical across backends.

Usage::

    g = Group(cfg)
    h = g.subgroup(0)
    h.ordered_send(sender=0, n=100)
    h.on_delivery(lambda member, msg: ...)
    report = g.run(backend="des")

Reconfiguration across view changes is driven by
:class:`repro.core.views.MembershipService` — see :meth:`Group.reconfigure`.
"""

from __future__ import annotations

import dataclasses
import functools
import time
from typing import (Any, Callable, Dict, List, Mapping, Optional, Protocol,
                    Tuple)

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import costmodel, delivery as delivery_mod
from repro.core import simulator as sim
from repro.core import sweep as sweep_mod
from repro.core import views as views_mod

Array = Any

# SST row push size (bytes): the coalesced counter row (Sec. 2.2).
_ROW_BYTES = 64


# ---------------------------------------------------------------------------
# Configuration
# ---------------------------------------------------------------------------

# Re-exported so callers need only `repro.api` / `repro.core.group`.
SubgroupSpec = sim.SubgroupSpec
SpindleFlags = sim.SpindleFlags
SenderPattern = sim.SenderPattern


@dataclasses.dataclass(frozen=True)
class GroupConfig:
    """One multicast scenario, independent of the substrate that runs it."""

    members: Tuple[int, ...]                     # top-level membership
    subgroups: Tuple[sim.SubgroupSpec, ...]
    flags: sim.SpindleFlags = sim.SpindleFlags.spindle()
    net: costmodel.NetworkModel = costmodel.RDMA_CX6
    host: costmodel.HostModel = costmodel.HOST_X86
    patterns: Tuple[Tuple[Tuple[int, int], sim.SenderPattern], ...] = ()
    target_delivered: Optional[int] = None
    max_time_us: float = 60e6
    # DES-plane knobs (charged by the des backend only, carried so a
    # SimConfig round-trips losslessly through the Group API)
    llc_bytes: int = 20 * 1024 * 1024
    upcall_extra_us: float = 0.0
    max_sweeps: int = 3_000_000
    idle_tick_us: float = 2.0
    # graph/pallas round budget; None = auto (max sends + settle rounds)
    rounds: Optional[int] = None
    epoch: int = 0                               # bumped by reconfigure()

    def __post_init__(self):
        members = set(self.members)
        for spec in self.subgroups:
            assert set(spec.members) <= members, \
                f"subgroup members {spec.members} outside group {members}"

    @property
    def n_nodes(self) -> int:
        return max(self.members) + 1 if self.members else 0

    def pattern(self, g: int, node: int) -> sim.SenderPattern:
        for (pg, pn), pat in self.patterns:
            if pg == g and pn == node:
                return pat
        return sim.SenderPattern()

    def to_sim_config(self, **overrides) -> sim.SimConfig:
        """Lower to the DES configuration (the ``des`` backend's input)."""
        kw = dict(n_nodes=self.n_nodes, subgroups=self.subgroups,
                  flags=self.flags, net=self.net, host=self.host,
                  patterns=self.patterns,
                  target_delivered=self.target_delivered,
                  max_time_us=self.max_time_us,
                  llc_bytes=self.llc_bytes,
                  upcall_extra_us=self.upcall_extra_us,
                  max_sweeps=self.max_sweeps,
                  idle_tick_us=self.idle_tick_us)
        kw.update(overrides)
        return sim.SimConfig(**kw)

    @classmethod
    def from_sim_config(cls, cfg: sim.SimConfig, **kw) -> "GroupConfig":
        return cls(members=tuple(range(cfg.n_nodes)),
                   subgroups=cfg.subgroups, flags=cfg.flags, net=cfg.net,
                   host=cfg.host, patterns=cfg.patterns,
                   target_delivered=cfg.target_delivered,
                   max_time_us=cfg.max_time_us,
                   llc_bytes=cfg.llc_bytes,
                   upcall_extra_us=cfg.upcall_extra_us,
                   max_sweeps=cfg.max_sweeps,
                   idle_tick_us=cfg.idle_tick_us, **kw)


def single_group(n_nodes: int, n_senders: Optional[int] = None,
                 msg_size: int = 10240, window: int = 100,
                 n_messages: int = 1000,
                 flags: sim.SpindleFlags = sim.SpindleFlags.spindle(),
                 **kw) -> GroupConfig:
    """One subgroup over ``n_nodes`` nodes — the quickstart scenario."""
    senders = tuple(range(n_senders if n_senders is not None else n_nodes))
    spec = sim.SubgroupSpec(members=tuple(range(n_nodes)), senders=senders,
                            msg_size=msg_size, window=window,
                            n_messages=n_messages)
    return GroupConfig(members=tuple(range(n_nodes)), subgroups=(spec,),
                       flags=flags, **kw)


# ---------------------------------------------------------------------------
# The unified run report
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class RunReport:
    """Backend-independent result of one :meth:`Group.run`.

    ``delivered_app_msgs``/``delivered_null_msgs`` are summed over members
    (an app message delivered at k members counts k times, matching the
    simulator's historical accounting); ``nulls_sent`` counts null
    *publishes*.  For the graph/pallas backends the time-domain numbers
    (throughput, latency, duration, rdma_writes) are derived from the same
    calibrated cost model the DES charges, so they are comparable
    like-for-like, not wall-clock measurements.
    """

    backend: str
    throughput_GBps: float
    mean_latency_us: float
    p99_latency_us: float
    duration_us: float
    delivered_app_msgs: int
    delivered_null_msgs: int
    nulls_sent: int
    rdma_writes: int
    rounds: int                         # DES sweeps / graph scan rounds
    per_node_throughput: List[float]
    stalled: bool
    send_batches: List[int] = dataclasses.field(default_factory=list)
    recv_batches: List[int] = dataclasses.field(default_factory=list)
    deliv_batches: List[int] = dataclasses.field(default_factory=list)
    extras: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def summary(self) -> Dict[str, Any]:
        return {
            "backend": self.backend,
            "throughput_GBps": round(self.throughput_GBps, 4),
            "mean_latency_us": round(self.mean_latency_us, 3),
            "p99_latency_us": round(self.p99_latency_us, 3),
            "delivered_app_msgs": self.delivered_app_msgs,
            "delivered_null_msgs": self.delivered_null_msgs,
            "nulls_sent": self.nulls_sent,
            "rdma_writes": self.rdma_writes,
            "stalled": self.stalled,
        }


@dataclasses.dataclass(frozen=True)
class Delivery:
    """One delivered application message (nulls never reach upcalls)."""

    subgroup: int
    seq: int                # round-robin sequence number
    sender_rank: int
    sender_index: int       # per-sender publish index (ring index)


@dataclasses.dataclass
class DeliveryLog:
    """The total-order publish log of one subgroup plus how far each
    member's delivery predicate got into it."""

    n_senders: int
    is_app: List[np.ndarray]            # per sender-rank: nullness per index
    delivered_seq: Dict[int, int]       # member node -> highest delivered seq

    def sequence(self, node: int, *, apps_only: bool = True
                 ) -> List[Tuple[int, int, bool]]:
        """Delivered (sender_rank, sender_index, is_app) at ``node`` in
        delivery order."""
        out = []
        for seq in range(self.delivered_seq.get(node, -1) + 1):
            rank, idx = seq % self.n_senders, seq // self.n_senders
            app = bool(idx < len(self.is_app[rank])
                       and self.is_app[rank][idx])
            if app or not apps_only:
                out.append((rank, idx, app))
        return out

    def app_null_counts(self, node: int) -> Tuple[int, int]:
        hi = self.delivered_seq.get(node, -1)
        batch = delivery_mod.DeliveryBatch(lo_seq=0, hi_seq=hi,
                                           n_senders=self.n_senders)
        return delivery_mod.split_app_and_null(batch, self.is_app)

    def truncate_to_app_target(self, target: int) -> None:
        """Clip each member's delivered prefix at its ``target``-th app
        message — the logical form of ``target_delivered``'s measurement
        window ("end once every member has delivered this many").  Members
        that overshot the target (the DES stops on simulated time, whole
        batches late; the scan runs a fixed round budget) are cut back to
        the same logical point on every backend, so app sequences stay
        comparable.  A member that delivered exactly ``target`` apps keeps
        its trailing nulls (nothing to cut)."""
        hi_all = max(self.delivered_seq.values(), default=-1)
        if hi_all < 0:
            return
        flags = np.zeros(hi_all + 1, dtype=bool)
        for r, log in enumerate(self.is_app):
            seqs = np.arange(len(log)) * self.n_senders + r
            m = seqs <= hi_all
            flags[seqs[m]] = np.asarray(log, dtype=bool)[: len(seqs)][m]
        cum = np.cumsum(flags)
        for node, hi in self.delivered_seq.items():
            if hi >= 0 and cum[hi] > target:
                self.delivered_seq[node] = int(
                    np.searchsorted(cum, target))


# ---------------------------------------------------------------------------
# Backend protocol + registry
# ---------------------------------------------------------------------------


class ProtocolBackend(Protocol):
    """One substrate that can execute a :class:`GroupConfig` scenario."""

    name: str

    def run(self, cfg: GroupConfig,
            counts: Dict[int, np.ndarray]) -> Tuple[RunReport,
                                                    Dict[int, DeliveryLog]]:
        """Execute the scenario.  ``counts[gid]`` is the per-sender-rank
        app-message count for subgroup ``gid``.  Returns the unified report
        plus one delivery log per subgroup."""
        ...


BACKENDS: Dict[str, Callable[[], ProtocolBackend]] = {}


def register_backend(name: str, factory: Callable[[], ProtocolBackend]):
    BACKENDS[name] = factory


def get_backend(backend) -> ProtocolBackend:
    if isinstance(backend, str):
        if backend not in BACKENDS:
            raise KeyError(
                f"unknown backend {backend!r}; have {sorted(BACKENDS)}")
        return BACKENDS[backend]()
    return backend


# ---------------------------------------------------------------------------
# The Group façade
# ---------------------------------------------------------------------------


class SubgroupHandle:
    """Send/upcall handle for one subgroup — the Derecho user surface."""

    def __init__(self, group: "Group", gid: int):
        self.group = group
        self.gid = gid

    @property
    def spec(self) -> sim.SubgroupSpec:
        return self.group.cfg.subgroups[self.gid]

    def send(self, sender: Optional[int] = None, n: int = 1) -> None:
        """Queue ``n`` application messages from ``sender`` (a node id;
        defaults to the subgroup's first sender).  Explicit sends take
        over the whole subgroup: they replace the spec's ``n_messages``
        scenario default AND any per-sender pattern budgets — senders you
        do not ``send()`` to send nothing (nulls cover them)."""
        spec = self.spec
        sender = spec.senders[0] if sender is None else sender
        if sender not in spec.senders:
            raise ValueError(f"node {sender} is not a sender of "
                             f"subgroup {self.gid}")
        rank = spec.senders.index(sender)
        self.group._explicit.setdefault(self.gid, np.zeros(
            len(spec.senders), dtype=np.int64))[rank] += n

    # In this repro every send is totally ordered; the two Derecho entry
    # points are therefore the same operation.
    ordered_send = send

    def on_delivery(self, fn: Callable[[int, Delivery], None]) -> None:
        """Register a delivery upcall ``fn(member_node, Delivery)``; fired
        (app messages only, in total order per member) after each run."""
        self.group._upcalls.setdefault(self.gid, []).append(fn)

    def delivered(self, node: int) -> List[Tuple[int, int, bool]]:
        """Delivered (sender_rank, sender_index, is_app) at ``node`` from
        the last run (apps only)."""
        log = self.group.delivery_logs.get(self.gid)
        if log is None:
            raise RuntimeError("run() first")
        return log.sequence(node)


class Group:
    """The one front door: configure once, run on any backend."""

    def __init__(self, cfg: GroupConfig):
        self.cfg = cfg
        self._explicit: Dict[int, np.ndarray] = {}
        self._upcalls: Dict[int, List[Callable]] = {}
        self.delivery_logs: Dict[int, DeliveryLog] = {}
        self.last_report: Optional[RunReport] = None

    # -- construction helpers ------------------------------------------------

    @classmethod
    def from_sim_config(cls, cfg: sim.SimConfig, **kw) -> "Group":
        return cls(GroupConfig.from_sim_config(cfg, **kw))

    def subgroup(self, gid: int) -> SubgroupHandle:
        if not 0 <= gid < len(self.cfg.subgroups):
            raise IndexError(gid)
        return SubgroupHandle(self, gid)

    @property
    def n_subgroups(self) -> int:
        return len(self.cfg.subgroups)

    def send_counts(self, gid: int,
                    cfg: Optional[GroupConfig] = None) -> np.ndarray:
        """Effective per-sender-rank app-message counts for one subgroup.

        Explicit queued ``send()`` calls take over the WHOLE subgroup: they
        replace both the spec's ``n_messages`` default and any
        ``SenderPattern.n_messages`` budgets (a sender you did not send()
        to sends nothing).  Without explicit sends, pattern budgets
        override the spec default per sender.  Inactive patterns always
        mask to zero."""
        cfg = self.cfg if cfg is None else cfg
        spec = cfg.subgroups[gid]
        explicit = self._explicit.get(gid)
        if explicit is not None and len(explicit) != len(spec.senders):
            raise ValueError(
                f"subgroup {gid} has queued explicit sends for "
                f"{len(explicit)} senders but the (overridden) spec has "
                f"{len(spec.senders)}; drop the override or re-queue")
        if explicit is not None:
            counts = explicit.copy()
        else:
            counts = np.full(len(spec.senders), spec.n_messages,
                             dtype=np.int64)
        for rank, node in enumerate(spec.senders):
            pat = cfg.pattern(gid, node)
            if not pat.active:
                counts[rank] = 0
            elif pat.n_messages is not None and explicit is None:
                counts[rank] = pat.n_messages
        return counts

    # -- running -------------------------------------------------------------

    def run(self, backend="des", **overrides) -> RunReport:
        """Execute the configured scenario on ``backend`` (name or
        :class:`ProtocolBackend` instance) and fire delivery upcalls."""
        cfg = (dataclasses.replace(self.cfg, **overrides) if overrides
               else self.cfg)
        be = get_backend(backend)
        # counts come from the overridden config so per-run overrides to
        # patterns/subgroups behave identically on every backend
        counts = {g: self.send_counts(g, cfg)
                  for g in range(len(cfg.subgroups))}
        report, logs = be.run(cfg, counts)
        self.delivery_logs = logs
        self.last_report = report
        self._fire_upcalls()
        return report

    def run_batch(self, backend="graph", *, windows=None, null_send=None,
                  n_messages=None) -> List[RunReport]:
        """Execute a grid of scenario variants as ONE batched program.

        Each keyword is ``None`` (keep the configured value) or a sequence
        of per-point values; all given grids must share one length B.
        ``windows``/``n_messages`` replace every subgroup's setting at
        that point, ``null_send`` replaces the flag.  On the graph/pallas
        backends the whole grid executes as a single compiled vmapped
        program (schedules padded to a common round budget, per-point
        traces sliced back), producing results identical to B sequential
        :meth:`run` calls — a Fig. 6 window sweep or Fig. 11 null-overhead
        grid becomes one XLA launch instead of B Python runs.  Backends
        without a ``run_batch`` (e.g. ``des``) fall back to a sequential
        loop, keeping cross-backend conformance testable.

        Returns one :class:`RunReport` per point; each report carries its
        delivery logs in ``extras["delivery_logs"]``.  Delivery upcalls do
        not fire (batch runs are measurement sweeps)."""
        grids = {name: list(vals) for name, vals in
                 (("windows", windows), ("null_send", null_send),
                  ("n_messages", n_messages)) if vals is not None}
        if not grids:
            raise ValueError("run_batch needs at least one grid "
                             "(windows=, null_send= or n_messages=)")
        sizes = {len(v) for v in grids.values()}
        if len(sizes) != 1:
            raise ValueError("grid lengths differ: " + str(
                {k: len(v) for k, v in grids.items()}))
        cfgs = []
        for i in range(sizes.pop()):
            cfg = self.cfg
            over: Dict[str, Any] = {}
            if windows is not None or n_messages is not None:
                over["subgroups"] = tuple(
                    dataclasses.replace(
                        s,
                        window=(int(windows[i]) if windows is not None
                                else s.window),
                        n_messages=(int(n_messages[i])
                                    if n_messages is not None
                                    else s.n_messages))
                    for s in cfg.subgroups)
            if null_send is not None:
                over["flags"] = dataclasses.replace(
                    cfg.flags, null_send=bool(null_send[i]))
            cfgs.append(dataclasses.replace(cfg, **over) if over else cfg)
        counts = [{g: self.send_counts(g, c)
                   for g in range(len(c.subgroups))} for c in cfgs]
        be = get_backend(backend)
        if hasattr(be, "run_batch"):
            results = be.run_batch(cfgs, counts)
        else:
            results = [be.run(c, k) for c, k in zip(cfgs, counts)]
        reports = []
        for report, logs in results:
            report.extras["delivery_logs"] = logs
            reports.append(report)
        return reports

    def _fire_upcalls(self):
        for gid, fns in self._upcalls.items():
            log = self.delivery_logs.get(gid)
            if log is None:
                continue
            spec = self.cfg.subgroups[gid]
            for member in spec.members:
                for rank, idx, _ in log.sequence(member):
                    d = Delivery(subgroup=gid,
                                 seq=idx * log.n_senders + rank,
                                 sender_rank=rank, sender_index=idx)
                    for fn in fns:
                        fn(member, d)

    # -- reconfiguration (virtual synchrony) ---------------------------------

    def reconfigure(self, view: "views_mod.View") -> "Group":
        """Install a new membership view: every subgroup is restricted to
        the surviving members (failed senders drop out; the null-send
        scheme covers them until the view installs).  Returns a fresh
        ``Group`` for the new epoch; upcall registrations carry over,
        queued sends and delivery logs do not (messages underway at a view
        change are delivered in the old view or resent in the new one)."""
        alive = set(view.members)
        new_specs = []
        gid_map: Dict[int, int] = {}     # old gid -> new gid
        for gid, spec in enumerate(self.cfg.subgroups):
            members = tuple(m for m in spec.members if m in alive)
            senders = tuple(s for s in spec.senders if s in alive)
            if not members:
                continue                 # every member failed: subgroup dies
            if not senders:
                senders = (members[0],)
            gid_map[gid] = len(new_specs)
            new_specs.append(dataclasses.replace(
                spec, members=members, senders=senders))
        patterns = tuple(((gid_map[g], n), p)
                         for (g, n), p in self.cfg.patterns
                         if g in gid_map and n in alive)
        cfg = dataclasses.replace(
            self.cfg, members=tuple(view.members),
            subgroups=tuple(new_specs), patterns=patterns,
            epoch=self.cfg.epoch + 1)
        g = Group(cfg)
        g._upcalls = {gid_map[gid]: list(fns)
                      for gid, fns in self._upcalls.items()
                      if gid in gid_map}
        return g


# ---------------------------------------------------------------------------
# "des" backend — wraps the discrete-event simulator
# ---------------------------------------------------------------------------


class DESBackend:
    name = "des"

    def run(self, cfg: GroupConfig, counts: Dict[int, np.ndarray]
            ) -> Tuple[RunReport, Dict[int, DeliveryLog]]:
        sim_cfg = self._lower(cfg, counts)
        simulator = sim.Simulator(sim_cfg)
        result = simulator.run()
        logs = self._logs(simulator)
        if cfg.target_delivered is not None:
            for log in logs.values():
                log.truncate_to_app_target(cfg.target_delivered)
        # app/null accounting comes from the (possibly clipped) delivery
        # logs so it always matches what delivered()/upcalls expose;
        # throughput/latency stay the DES's timing truths.
        n_app, n_null = _sum_delivered(logs)
        report = RunReport(
            backend=self.name,
            throughput_GBps=result.throughput_GBps,
            mean_latency_us=result.mean_latency_us,
            p99_latency_us=result.p99_latency_us,
            duration_us=result.duration_us,
            delivered_app_msgs=n_app,
            delivered_null_msgs=n_null,
            nulls_sent=result.nulls_sent,
            rdma_writes=result.rdma_writes,
            rounds=result.sweeps,
            per_node_throughput=result.per_node_throughput,
            stalled=result.stalled,
            send_batches=result.send_batches,
            recv_batches=result.recv_batches,
            deliv_batches=result.deliv_batches,
            extras={"post_time_us": result.post_time_us,
                    "predicate_time_us": result.predicate_time_us,
                    "sender_blocked_us": result.sender_blocked_us},
        )
        return report, logs

    @staticmethod
    def _lower(cfg: GroupConfig, counts: Dict[int, np.ndarray]
               ) -> sim.SimConfig:
        """Per-sender counts lower to ``SenderPattern.n_messages``
        overrides (count 0 = inactive)."""
        patterns = {(g, n): p for (g, n), p in cfg.patterns}
        specs = []
        for gid, spec in enumerate(cfg.subgroups):
            c = counts[gid]
            specs.append(dataclasses.replace(
                spec, n_messages=int(c.max()) if len(c) else 0))
            for rank, node in enumerate(spec.senders):
                base = patterns.get((gid, node), sim.SenderPattern())
                patterns[(gid, node)] = dataclasses.replace(
                    base, active=base.active and int(c[rank]) > 0,
                    n_messages=int(c[rank]))
        return cfg.to_sim_config(
            subgroups=tuple(specs),
            patterns=tuple(patterns.items()))

    @staticmethod
    def _logs(simulator: sim.Simulator) -> Dict[int, DeliveryLog]:
        logs = {}
        for g in simulator.groups:
            is_app = [~np.isnan(g.gen_log[s][: int(g.gen_len[s])])
                      for s in range(g.n_s)]
            delivered = {node: int(g.deliv_seen[g.member_pos[node],
                                                g.member_pos[node]])
                         for node in g.spec.members}
            logs[g.gid] = DeliveryLog(n_senders=g.n_s, is_app=is_app,
                                      delivered_seq=delivered)
        return logs


# ---------------------------------------------------------------------------
# "graph" / "pallas" backends — the fused sweep, compiled once per shape
# ---------------------------------------------------------------------------

# One entry is appended per TRACE of a scan program (jit runs the Python
# body only while compiling).  The hot-path tests assert that a repeated
# Group.run with the same static key leaves this list untouched.
TRACE_EVENTS: List[Tuple[int, int, str]] = []


def _lower_schedule(counts: np.ndarray, rounds: int) -> np.ndarray:
    """(S,) per-sender counts -> (T, S) app_schedule: one message per
    active round until each sender's budget is spent."""
    t = np.arange(rounds)[:, None]
    return (t < counts[None, :]).astype(np.int32)


def _cost_params(cfg: GroupConfig, spec: sim.SubgroupSpec) -> np.ndarray:
    """Lower the per-round cost model to four coefficients consumed as
    vectorized in-graph arithmetic by :func:`_scan_core`:
    ``[base, post, per_msg, wire]``.

    Per round every member pushes its SST row (one coalesced 64 B write per
    peer, the ``base`` term); a sender that published ``k`` app messages
    additionally pushes them as one batched slot write of ``k`` slots per
    peer (the Sec. 3.2 batch-send path: ``post + per_msg * k``).  The round
    takes as long as the busiest node's post+serialization charge plus one
    wire hop — the same calibrated constants the DES charges, so
    graph/pallas reports are comparable like-for-like with the ``des``
    backend.
    """
    n = len(spec.members)
    if n <= 1:
        return np.zeros(4)
    slot = spec.msg_size + 8
    host, net = cfg.host, cfg.net
    base = host.lock_us + 3 * host.predicate_eval_us + \
        (n - 1) * (net.post_us + net.serialization(_ROW_BYTES))
    return np.array([base,
                     (n - 1) * net.post_us,
                     (n - 1) * net.serialization(slot),
                     net.wire_latency(min(slot, 4096))])


def _kernel_receive(ring_window: int):
    """Receive-predicate override for the pallas backend: the fused
    watermark kernel sweeps every (member, sender) ring in one call,
    rebuilding the counter tile inside the kernel — nothing (N*S, W)-shaped
    is materialized in-graph per round.  ``ring_window`` is the static ring
    width (the max window across a batched grid); a ring wider than a
    point's protocol window is harmless — slots are only reused after W
    messages and the publish cap uses the per-point window."""
    from repro.kernels import ops

    def receive(pub_vis, recv_counts):
        n_m, n_s = pub_vis.shape
        visible = ops.smc_sweep_watermark(
            pub_vis.reshape(n_m * n_s), recv_counts.reshape(n_m * n_s),
            window=ring_window)
        return jnp.maximum(
            recv_counts,
            visible.reshape(n_m, n_s).astype(recv_counts.dtype))

    return receive


def _scan_core(n_members: int, n_senders: int, backend: str,
               ring_window: int):
    """The traced body shared by the single-run and batched programs:
    :func:`sweep.scan_rounds` plus the cost model folded in as vectorized
    in-graph arithmetic (formerly a per-round Python loop)."""
    receive_fn = _kernel_receive(ring_window) if backend == "pallas" \
        else None
    fold_cost = _fold_cost(n_members)

    def core(sched, window, null_send, cost):
        TRACE_EVENTS.append((n_members, n_senders, backend))
        state = sweep_mod.SweepState.init(n_members, n_senders)
        state, (batches, app_pub, nulls) = sweep_mod.scan_rounds(
            state, sched, window=window, null_send=null_send,
            receive_fn=receive_fn)
        round_t, round_w = fold_cost(app_pub, cost)
        return batches, app_pub, nulls, round_t, round_w

    return core


def _fold_cost(n_members: int):
    """The cost model as vectorized in-graph arithmetic over the (T, S)
    publish trace: (app_pub, cost coefficients) -> per-round time + RDMA
    writes arrays."""
    row_writes = n_members * (n_members - 1)

    def fold(app_pub, cost):
        # Busiest sender per round: serialization is linear in k, so the
        # max-k sender is the argmax of post + per_msg * k.
        kmax = jnp.max(app_pub, axis=1)                            # (T,)
        busiest = jnp.where(kmax > 0, cost[1] + cost[2] * kmax, 0.0)
        round_t = cost[0] + busiest + cost[3]                      # (T,)
        round_w = row_writes + (n_members - 1) * \
            jnp.sum((app_pub > 0).astype(jnp.int32), axis=1)       # (T,)
        return round_t, round_w

    return fold


@functools.lru_cache(maxsize=None)
def _scan_program(n_members: int, n_senders: int, window: int,
                  null_send: bool, backend: str):
    """Compile-once program for one static scenario shape, cached on
    ``(n_members, n_senders, window, null_send, backend)`` — repeated
    ``Group.run`` calls and benchmark sweeps reuse the jitted scan instead
    of re-tracing it.  (jax additionally keys on the schedule shape, so a
    different round budget recompiles — same scenario, same program.)"""
    core = _scan_core(n_members, n_senders, backend, ring_window=window)

    def fn(sched, cost):
        return core(sched, window, null_send, cost)

    return jax.jit(fn)


@functools.lru_cache(maxsize=None)
def _batch_program(n_members: int, n_senders: int, ring_window: int,
                   backend: str):
    """Compile-once BATCHED program: :func:`sweep.run_batch` (the vmapped
    fused sweep) with the window and null-send flag as per-point traced
    scalars, plus the vmapped cost fold.  ``ring_window`` (the common SMC
    ring width, max of the grid) only matters to the pallas receive
    kernel; the graph backend passes 0 so one cache entry serves every
    grid."""
    receive_fn = _kernel_receive(ring_window) if backend == "pallas" \
        else None
    fold_cost = jax.vmap(_fold_cost(n_members))

    def fn(scheds, windows, null_sends, costs):
        TRACE_EVENTS.append((n_members, n_senders, backend))
        states = sweep_mod.batch_states(n_members, n_senders,
                                        scheds.shape[0])
        _, (batches, app_pub, nulls) = sweep_mod.run_batch(
            states, scheds, windows=windows, null_sends=null_sends,
            receive_fn=receive_fn)
        round_t, round_w = fold_cost(app_pub, costs)
        return batches, app_pub, nulls, round_t, round_w

    return jax.jit(fn)


@dataclasses.dataclass
class _GraphAgg:
    """Accumulates one run's subgroup post-processing into report inputs."""

    duration: float = 0.0
    writes: int = 0
    delivered_app: int = 0
    delivered_null: int = 0
    nulls_sent: int = 0
    rounds: int = 0
    stalled: bool = False
    latencies: List[float] = dataclasses.field(default_factory=list)
    per_node_bytes: Dict[int, float] = dataclasses.field(
        default_factory=dict)
    logs: Dict[int, DeliveryLog] = dataclasses.field(default_factory=dict)


class GraphBackend:
    """Runs the scenario through :func:`repro.core.sweep.scan_rounds`
    under a cached jitted program (see :func:`_scan_program`) that also
    evaluates the cost model in-graph, then reconstructs delivery logs and
    latency round-pairs from the per-round traces with vectorized numpy.
    :meth:`run_batch` executes whole scenario grids as ONE vmapped
    compiled program."""

    name = "graph"

    @staticmethod
    def _check(cfg: GroupConfig) -> None:
        if cfg.target_delivered is not None and len(cfg.subgroups) > 1:
            # SimConfig.target_delivered is a per-member aggregate ACROSS
            # subgroups (Simulator._done); the scan runs each subgroup on
            # its own round timeline, so there is no cross-subgroup order
            # to clip against.  Diverging silently from the des backend
            # would break the conformance contract — refuse instead.
            raise ValueError(
                "target_delivered with multiple subgroups is only "
                "supported on the 'des' backend")

    @staticmethod
    def _rounds_for(cfg: GroupConfig, spec: sim.SubgroupSpec,
                    counts: np.ndarray) -> int:
        """Round budget: settle rounds for visibility/null drain, plus
        slack for ring-window throttling (a small window stretches
        publishing over ~3 extra rounds per window-full of backlog)."""
        if cfg.rounds is not None:
            return cfg.rounds
        max_c = int(counts.max()) if len(counts) else 0
        return max_c + 2 * len(spec.members) + 8 + \
            3 * (max_c // max(spec.window, 1))

    def run(self, cfg: GroupConfig, counts: Dict[int, np.ndarray]
            ) -> Tuple[RunReport, Dict[int, DeliveryLog]]:
        self._check(cfg)
        agg = _GraphAgg()
        wall0 = time.perf_counter()
        for gid, spec in enumerate(cfg.subgroups):
            c = counts[gid]
            rounds = self._rounds_for(cfg, spec, c)
            program = _scan_program(len(spec.members), len(spec.senders),
                                    spec.window, cfg.flags.null_send,
                                    self.name)
            out = program(jnp.asarray(_lower_schedule(c, rounds)),
                          jnp.asarray(_cost_params(cfg, spec), jnp.float32))
            self._accumulate(cfg, spec, gid, c, rounds,
                             [np.asarray(o) for o in out], agg)
        return self._report(agg, wall0), agg.logs

    def run_batch(self, cfgs: List[GroupConfig],
                  counts_list: List[Dict[int, np.ndarray]]
                  ) -> List[Tuple[RunReport, Dict[int, DeliveryLog]]]:
        """Execute B scenario variants as one compiled vmapped program per
        subgroup.  All points must share membership shapes (n_members,
        n_senders per subgroup); schedules are padded to the common round
        budget and each point's traces sliced back to its own budget
        afterwards, so every point's results are identical to a sequential
        :meth:`run` of that point — the scan prefix depends only on the
        schedule prefix."""
        if not cfgs:
            return []
        for cfg in cfgs:
            self._check(cfg)
        b = len(cfgs)
        wall0 = time.perf_counter()
        aggs = [_GraphAgg() for _ in range(b)]
        for gid in range(len(cfgs[0].subgroups)):
            specs = [cfg.subgroups[gid] for cfg in cfgs]
            n_m, n_s = len(specs[0].members), len(specs[0].senders)
            if any(len(s.members) != n_m or len(s.senders) != n_s
                   for s in specs):
                raise ValueError(
                    "run_batch points must share membership shapes; "
                    f"subgroup {gid} differs across the grid")
            rounds = [self._rounds_for(cfg, spec, counts_list[i][gid])
                      for i, (cfg, spec) in enumerate(zip(cfgs, specs))]
            t_max = max(rounds)
            scheds = np.stack([_lower_schedule(counts_list[i][gid], t_max)
                               for i in range(b)])
            windows = np.asarray([s.window for s in specs], np.int32)
            nulls_on = np.asarray([cfg.flags.null_send for cfg in cfgs])
            costs = np.stack([_cost_params(cfg, spec) for cfg, spec
                              in zip(cfgs, specs)]).astype(np.float32)
            ring = int(windows.max()) if self.name == "pallas" else 0
            program = _batch_program(n_m, n_s, ring, self.name)
            outs = [np.asarray(o) for o in program(
                jnp.asarray(scheds), jnp.asarray(windows),
                jnp.asarray(nulls_on), jnp.asarray(costs))]
            for i in range(b):
                point = [o[i][: rounds[i]] for o in outs]
                self._accumulate(cfgs[i], specs[i], gid,
                                 counts_list[i][gid], rounds[i], point,
                                 aggs[i])
        # one wall clock covers the whole grid — stamp it under a batch
        # key so nobody mistakes it for a per-point cost
        return [(self._report(agg, wall0, wall_key="batch_wall_s"),
                 agg.logs) for agg in aggs]

    def _accumulate(self, cfg: GroupConfig, spec: sim.SubgroupSpec,
                    gid: int, c: np.ndarray, rounds: int,
                    arrays: List[np.ndarray], agg: _GraphAgg) -> None:
        """Host-side post-processing of one subgroup's per-round traces."""
        batches, app_pub, nulls, round_t, round_w = arrays
        log, lat_pairs = self._reconstruct(spec, batches, app_pub, nulls)
        if cfg.target_delivered is not None:
            log.truncate_to_app_target(cfg.target_delivered)
        agg.logs[gid] = log
        agg.rounds += rounds
        agg.nulls_sent += int(nulls.sum())
        agg.writes += int(round_w.astype(np.int64).sum())
        end_time = np.cumsum(round_t.astype(np.float64))
        if rounds:
            agg.duration = max(agg.duration, float(end_time[-1]))
        if len(lat_pairs):
            pr, dr = lat_pairs[:, 0], lat_pairs[:, 1]
            start = np.where(pr > 0, end_time[np.maximum(pr - 1, 0)], 0.0)
            agg.latencies.extend((end_time[dr] - start).tolist())
        for node in spec.members:
            a, nl = log.app_null_counts(node)
            agg.delivered_app += a
            agg.delivered_null += nl
            agg.per_node_bytes[node] = \
                agg.per_node_bytes.get(node, 0.0) + a * spec.msg_size
        total_app = int(c.sum())
        need = total_app if cfg.target_delivered is None else \
            min(cfg.target_delivered, total_app)
        if any(log.app_null_counts(node)[0] < need
               for node in spec.members):
            agg.stalled = True

    def _report(self, agg: _GraphAgg, wall0: float,
                wall_key: str = "wall_s") -> RunReport:
        per_node = [b / agg.duration / 1e3
                    for b in agg.per_node_bytes.values()
                    if agg.duration > 0 and b > 0]
        lat = np.array(agg.latencies) if agg.latencies else np.array([0.0])
        return RunReport(
            backend=self.name,
            throughput_GBps=float(np.mean(per_node)) if per_node else 0.0,
            mean_latency_us=float(lat.mean()),
            p99_latency_us=float(np.percentile(lat, 99)),
            duration_us=agg.duration,
            delivered_app_msgs=agg.delivered_app,
            delivered_null_msgs=agg.delivered_null,
            nulls_sent=agg.nulls_sent,
            rdma_writes=agg.writes,
            rounds=agg.rounds,
            per_node_throughput=per_node,
            stalled=agg.stalled,
            extras={wall_key: time.perf_counter() - wall0},
        )

    @staticmethod
    def _reconstruct(spec: sim.SubgroupSpec, batches: np.ndarray,
                     app_pub: np.ndarray, nulls: np.ndarray):
        """Rebuild the per-sender nullness log and (publish_round,
        delivery_round) latency samples from the per-round trace, fully
        vectorized (``repeat``/``cumsum``/``searchsorted`` — no
        per-message Python loop).  Within a round a sender publishes its
        app messages before its nulls (matching :func:`sweep.sweep`'s
        ``published + app_pub + nulls``).  Returns the log plus a (K, 2)
        int array of latency round-pairs sampled at member position 0
        (as the DES does)."""
        n_s = len(spec.senders)
        rounds = batches.shape[0]
        is_app: List[np.ndarray] = []
        pub_round: List[np.ndarray] = []
        for s in range(n_s):
            a = app_pub[:, s].astype(np.int64)
            total = a + nulls[:, s].astype(np.int64)
            rnd = np.repeat(np.arange(rounds), total)
            start = np.cumsum(total) - total          # exclusive prefix
            offset = np.arange(total.sum()) - np.repeat(start, total)
            is_app.append(offset < np.repeat(a, total))
            pub_round.append(rnd)
        delivered_num = np.cumsum(batches, axis=0) - 1   # (T, N)
        final = delivered_num[-1] if rounds else \
            np.full(len(spec.members), -1)
        delivered = {node: int(final[pos])
                     for pos, node in enumerate(spec.members)}
        lat = np.zeros((0, 2), np.int64)
        if rounds and int(final[0]) >= 0:
            col = delivered_num[:, 0]
            seqs = np.arange(int(final[0]) + 1)
            ranks, idxs = seqs % n_s, seqs // n_s
            maxlen = max(len(x) for x in is_app)
            flags = np.zeros((n_s, maxlen), bool)
            rnds = np.zeros((n_s, maxlen), np.int64)
            for s in range(n_s):
                flags[s, : len(is_app[s])] = is_app[s]
                rnds[s, : len(pub_round[s])] = pub_round[s]
            m = flags[ranks, idxs]
            lat = np.stack([rnds[ranks[m], idxs[m]],
                            np.searchsorted(col, seqs[m])], axis=1)
        log = DeliveryLog(n_senders=n_s, is_app=is_app,
                          delivered_seq=delivered)
        return log, lat


class PallasBackend(GraphBackend):
    """The graph protocol with the receive predicate evaluated by the
    fused Pallas SMC-sweep kernel — the structural analogue of keeping the
    SMC polling area cache-resident.  The kernel consumes per-sender
    published watermarks and rebuilds the slot-counter tile inside the
    kernel (:func:`repro.kernels.smc_sweep.smc_sweep_watermark_pallas`),
    so the hot loop no longer materializes the (N*S, W) ring in-graph
    every round; it compiles to Mosaic on TPU and interprets elsewhere.
    The receive closure is installed by :func:`_kernel_receive` via the
    cached scan programs."""

    name = "pallas"


def _sum_delivered(logs: Mapping[int, DeliveryLog]) -> Tuple[int, int]:
    a = n = 0
    for log in logs.values():
        for node in log.delivered_seq:
            da, dn = log.app_null_counts(node)
            a, n = a + da, n + dn
    return a, n


register_backend("des", DESBackend)
register_backend("graph", GraphBackend)
register_backend("pallas", PallasBackend)
