"""The unified Derecho-style ``Group`` API with pluggable protocol backends.

Derecho (the paper's artifact) exposes one handle: a *group* whose
subgroups you ``send()`` into and receive totally-ordered delivery upcalls
from, while every Spindle optimization stays an internal toggle.  This
module is that seam for the repro: one :class:`GroupConfig` describes a
scenario (membership, subgroups, :class:`~repro.core.simulator.SpindleFlags`,
cost/net models) and :meth:`Group.run` executes it unmodified on any of
three substrates behind the :class:`ProtocolBackend` protocol:

  * ``"des"``    — the calibrated discrete-event simulator
                   (:mod:`repro.core.simulator`): answers *how fast* on the
                   paper's RDMA testbed model.
  * ``"graph"``  — the pure-JAX fused predicate sweep
                   (:mod:`repro.core.sweep`): the send pattern is lowered
                   to an ``app_schedule`` array and scanned in-graph.
  * ``"pallas"`` — the graph protocol with the receive predicate evaluated
                   by the fused Pallas SMC-sweep kernel
                   (:mod:`repro.kernels.smc_sweep`) over real slot-counter
                   rings.

Every backend returns the same :class:`RunReport` (throughput, latency
percentiles, app/null delivery accounting, RDMA-write counts) so Fig.
5-style comparisons work like-for-like across substrates, and every
backend records the same per-subgroup total-order delivery log, so
delivered sequences can be asserted identical across backends.

Usage::

    g = Group(cfg)
    h = g.subgroup(0)
    h.ordered_send(sender=0, n=100)
    h.on_delivery(lambda member, msg: ...)
    report = g.run(backend="des")

Reconfiguration across view changes is driven by
:class:`repro.core.views.MembershipService` — see :meth:`Group.reconfigure`.
"""

from __future__ import annotations

import dataclasses
import time
from typing import (Any, Callable, Dict, List, Mapping, Optional, Protocol,
                    Tuple)

import numpy as np

from repro.core import costmodel, delivery as delivery_mod
from repro.core import simulator as sim
from repro.core import sweep as sweep_mod
from repro.core import views as views_mod

Array = Any

# SST row push size (bytes): the coalesced counter row (Sec. 2.2).
_ROW_BYTES = 64


# ---------------------------------------------------------------------------
# Configuration
# ---------------------------------------------------------------------------

# Re-exported so callers need only `repro.api` / `repro.core.group`.
SubgroupSpec = sim.SubgroupSpec
SpindleFlags = sim.SpindleFlags
SenderPattern = sim.SenderPattern


@dataclasses.dataclass(frozen=True)
class GroupConfig:
    """One multicast scenario, independent of the substrate that runs it."""

    members: Tuple[int, ...]                     # top-level membership
    subgroups: Tuple[sim.SubgroupSpec, ...]
    flags: sim.SpindleFlags = sim.SpindleFlags.spindle()
    net: costmodel.NetworkModel = costmodel.RDMA_CX6
    host: costmodel.HostModel = costmodel.HOST_X86
    patterns: Tuple[Tuple[Tuple[int, int], sim.SenderPattern], ...] = ()
    target_delivered: Optional[int] = None
    max_time_us: float = 60e6
    # DES-plane knobs (charged by the des backend only, carried so a
    # SimConfig round-trips losslessly through the Group API)
    llc_bytes: int = 20 * 1024 * 1024
    upcall_extra_us: float = 0.0
    max_sweeps: int = 3_000_000
    idle_tick_us: float = 2.0
    # graph/pallas round budget; None = auto (max sends + settle rounds)
    rounds: Optional[int] = None
    epoch: int = 0                               # bumped by reconfigure()

    def __post_init__(self):
        members = set(self.members)
        for spec in self.subgroups:
            assert set(spec.members) <= members, \
                f"subgroup members {spec.members} outside group {members}"

    @property
    def n_nodes(self) -> int:
        return max(self.members) + 1 if self.members else 0

    def pattern(self, g: int, node: int) -> sim.SenderPattern:
        for (pg, pn), pat in self.patterns:
            if pg == g and pn == node:
                return pat
        return sim.SenderPattern()

    def to_sim_config(self, **overrides) -> sim.SimConfig:
        """Lower to the DES configuration (the ``des`` backend's input)."""
        kw = dict(n_nodes=self.n_nodes, subgroups=self.subgroups,
                  flags=self.flags, net=self.net, host=self.host,
                  patterns=self.patterns,
                  target_delivered=self.target_delivered,
                  max_time_us=self.max_time_us,
                  llc_bytes=self.llc_bytes,
                  upcall_extra_us=self.upcall_extra_us,
                  max_sweeps=self.max_sweeps,
                  idle_tick_us=self.idle_tick_us)
        kw.update(overrides)
        return sim.SimConfig(**kw)

    @classmethod
    def from_sim_config(cls, cfg: sim.SimConfig, **kw) -> "GroupConfig":
        return cls(members=tuple(range(cfg.n_nodes)),
                   subgroups=cfg.subgroups, flags=cfg.flags, net=cfg.net,
                   host=cfg.host, patterns=cfg.patterns,
                   target_delivered=cfg.target_delivered,
                   max_time_us=cfg.max_time_us,
                   llc_bytes=cfg.llc_bytes,
                   upcall_extra_us=cfg.upcall_extra_us,
                   max_sweeps=cfg.max_sweeps,
                   idle_tick_us=cfg.idle_tick_us, **kw)


def single_group(n_nodes: int, n_senders: Optional[int] = None,
                 msg_size: int = 10240, window: int = 100,
                 n_messages: int = 1000,
                 flags: sim.SpindleFlags = sim.SpindleFlags.spindle(),
                 **kw) -> GroupConfig:
    """One subgroup over ``n_nodes`` nodes — the quickstart scenario."""
    senders = tuple(range(n_senders if n_senders is not None else n_nodes))
    spec = sim.SubgroupSpec(members=tuple(range(n_nodes)), senders=senders,
                            msg_size=msg_size, window=window,
                            n_messages=n_messages)
    return GroupConfig(members=tuple(range(n_nodes)), subgroups=(spec,),
                       flags=flags, **kw)


# ---------------------------------------------------------------------------
# The unified run report
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class RunReport:
    """Backend-independent result of one :meth:`Group.run`.

    ``delivered_app_msgs``/``delivered_null_msgs`` are summed over members
    (an app message delivered at k members counts k times, matching the
    simulator's historical accounting); ``nulls_sent`` counts null
    *publishes*.  For the graph/pallas backends the time-domain numbers
    (throughput, latency, duration, rdma_writes) are derived from the same
    calibrated cost model the DES charges, so they are comparable
    like-for-like, not wall-clock measurements.
    """

    backend: str
    throughput_GBps: float
    mean_latency_us: float
    p99_latency_us: float
    duration_us: float
    delivered_app_msgs: int
    delivered_null_msgs: int
    nulls_sent: int
    rdma_writes: int
    rounds: int                         # DES sweeps / graph scan rounds
    per_node_throughput: List[float]
    stalled: bool
    send_batches: List[int] = dataclasses.field(default_factory=list)
    recv_batches: List[int] = dataclasses.field(default_factory=list)
    deliv_batches: List[int] = dataclasses.field(default_factory=list)
    extras: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def summary(self) -> Dict[str, Any]:
        return {
            "backend": self.backend,
            "throughput_GBps": round(self.throughput_GBps, 4),
            "mean_latency_us": round(self.mean_latency_us, 3),
            "p99_latency_us": round(self.p99_latency_us, 3),
            "delivered_app_msgs": self.delivered_app_msgs,
            "delivered_null_msgs": self.delivered_null_msgs,
            "nulls_sent": self.nulls_sent,
            "rdma_writes": self.rdma_writes,
            "stalled": self.stalled,
        }


@dataclasses.dataclass(frozen=True)
class Delivery:
    """One delivered application message (nulls never reach upcalls)."""

    subgroup: int
    seq: int                # round-robin sequence number
    sender_rank: int
    sender_index: int       # per-sender publish index (ring index)


@dataclasses.dataclass
class DeliveryLog:
    """The total-order publish log of one subgroup plus how far each
    member's delivery predicate got into it."""

    n_senders: int
    is_app: List[np.ndarray]            # per sender-rank: nullness per index
    delivered_seq: Dict[int, int]       # member node -> highest delivered seq

    def sequence(self, node: int, *, apps_only: bool = True
                 ) -> List[Tuple[int, int, bool]]:
        """Delivered (sender_rank, sender_index, is_app) at ``node`` in
        delivery order."""
        out = []
        for seq in range(self.delivered_seq.get(node, -1) + 1):
            rank, idx = seq % self.n_senders, seq // self.n_senders
            app = bool(idx < len(self.is_app[rank])
                       and self.is_app[rank][idx])
            if app or not apps_only:
                out.append((rank, idx, app))
        return out

    def app_null_counts(self, node: int) -> Tuple[int, int]:
        hi = self.delivered_seq.get(node, -1)
        batch = delivery_mod.DeliveryBatch(lo_seq=0, hi_seq=hi,
                                           n_senders=self.n_senders)
        return delivery_mod.split_app_and_null(batch, self.is_app)

    def truncate_to_app_target(self, target: int) -> None:
        """Clip each member's delivered prefix at its ``target``-th app
        message — the logical form of ``target_delivered``'s measurement
        window ("end once every member has delivered this many").  Members
        that overshot the target (the DES stops on simulated time, whole
        batches late; the scan runs a fixed round budget) are cut back to
        the same logical point on every backend, so app sequences stay
        comparable.  A member that delivered exactly ``target`` apps keeps
        its trailing nulls (nothing to cut)."""
        hi_all = max(self.delivered_seq.values(), default=-1)
        if hi_all < 0:
            return
        flags = np.zeros(hi_all + 1, dtype=bool)
        for r, log in enumerate(self.is_app):
            seqs = np.arange(len(log)) * self.n_senders + r
            m = seqs <= hi_all
            flags[seqs[m]] = np.asarray(log, dtype=bool)[: len(seqs)][m]
        cum = np.cumsum(flags)
        for node, hi in self.delivered_seq.items():
            if hi >= 0 and cum[hi] > target:
                self.delivered_seq[node] = int(
                    np.searchsorted(cum, target))


# ---------------------------------------------------------------------------
# Backend protocol + registry
# ---------------------------------------------------------------------------


class ProtocolBackend(Protocol):
    """One substrate that can execute a :class:`GroupConfig` scenario."""

    name: str

    def run(self, cfg: GroupConfig,
            counts: Dict[int, np.ndarray]) -> Tuple[RunReport,
                                                    Dict[int, DeliveryLog]]:
        """Execute the scenario.  ``counts[gid]`` is the per-sender-rank
        app-message count for subgroup ``gid``.  Returns the unified report
        plus one delivery log per subgroup."""
        ...


BACKENDS: Dict[str, Callable[[], ProtocolBackend]] = {}


def register_backend(name: str, factory: Callable[[], ProtocolBackend]):
    BACKENDS[name] = factory


def get_backend(backend) -> ProtocolBackend:
    if isinstance(backend, str):
        if backend not in BACKENDS:
            raise KeyError(
                f"unknown backend {backend!r}; have {sorted(BACKENDS)}")
        return BACKENDS[backend]()
    return backend


# ---------------------------------------------------------------------------
# The Group façade
# ---------------------------------------------------------------------------


class SubgroupHandle:
    """Send/upcall handle for one subgroup — the Derecho user surface."""

    def __init__(self, group: "Group", gid: int):
        self.group = group
        self.gid = gid

    @property
    def spec(self) -> sim.SubgroupSpec:
        return self.group.cfg.subgroups[self.gid]

    def send(self, sender: Optional[int] = None, n: int = 1) -> None:
        """Queue ``n`` application messages from ``sender`` (a node id;
        defaults to the subgroup's first sender).  Explicit sends take
        over the whole subgroup: they replace the spec's ``n_messages``
        scenario default AND any per-sender pattern budgets — senders you
        do not ``send()`` to send nothing (nulls cover them)."""
        spec = self.spec
        sender = spec.senders[0] if sender is None else sender
        if sender not in spec.senders:
            raise ValueError(f"node {sender} is not a sender of "
                             f"subgroup {self.gid}")
        rank = spec.senders.index(sender)
        self.group._explicit.setdefault(self.gid, np.zeros(
            len(spec.senders), dtype=np.int64))[rank] += n

    # In this repro every send is totally ordered; the two Derecho entry
    # points are therefore the same operation.
    ordered_send = send

    def on_delivery(self, fn: Callable[[int, Delivery], None]) -> None:
        """Register a delivery upcall ``fn(member_node, Delivery)``; fired
        (app messages only, in total order per member) after each run."""
        self.group._upcalls.setdefault(self.gid, []).append(fn)

    def delivered(self, node: int) -> List[Tuple[int, int, bool]]:
        """Delivered (sender_rank, sender_index, is_app) at ``node`` from
        the last run (apps only)."""
        log = self.group.delivery_logs.get(self.gid)
        if log is None:
            raise RuntimeError("run() first")
        return log.sequence(node)


class Group:
    """The one front door: configure once, run on any backend."""

    def __init__(self, cfg: GroupConfig):
        self.cfg = cfg
        self._explicit: Dict[int, np.ndarray] = {}
        self._upcalls: Dict[int, List[Callable]] = {}
        self.delivery_logs: Dict[int, DeliveryLog] = {}
        self.last_report: Optional[RunReport] = None

    # -- construction helpers ------------------------------------------------

    @classmethod
    def from_sim_config(cls, cfg: sim.SimConfig, **kw) -> "Group":
        return cls(GroupConfig.from_sim_config(cfg, **kw))

    def subgroup(self, gid: int) -> SubgroupHandle:
        if not 0 <= gid < len(self.cfg.subgroups):
            raise IndexError(gid)
        return SubgroupHandle(self, gid)

    @property
    def n_subgroups(self) -> int:
        return len(self.cfg.subgroups)

    def send_counts(self, gid: int,
                    cfg: Optional[GroupConfig] = None) -> np.ndarray:
        """Effective per-sender-rank app-message counts for one subgroup.

        Explicit queued ``send()`` calls take over the WHOLE subgroup: they
        replace both the spec's ``n_messages`` default and any
        ``SenderPattern.n_messages`` budgets (a sender you did not send()
        to sends nothing).  Without explicit sends, pattern budgets
        override the spec default per sender.  Inactive patterns always
        mask to zero."""
        cfg = self.cfg if cfg is None else cfg
        spec = cfg.subgroups[gid]
        explicit = self._explicit.get(gid)
        if explicit is not None and len(explicit) != len(spec.senders):
            raise ValueError(
                f"subgroup {gid} has queued explicit sends for "
                f"{len(explicit)} senders but the (overridden) spec has "
                f"{len(spec.senders)}; drop the override or re-queue")
        if explicit is not None:
            counts = explicit.copy()
        else:
            counts = np.full(len(spec.senders), spec.n_messages,
                             dtype=np.int64)
        for rank, node in enumerate(spec.senders):
            pat = cfg.pattern(gid, node)
            if not pat.active:
                counts[rank] = 0
            elif pat.n_messages is not None and explicit is None:
                counts[rank] = pat.n_messages
        return counts

    # -- running -------------------------------------------------------------

    def run(self, backend="des", **overrides) -> RunReport:
        """Execute the configured scenario on ``backend`` (name or
        :class:`ProtocolBackend` instance) and fire delivery upcalls."""
        cfg = (dataclasses.replace(self.cfg, **overrides) if overrides
               else self.cfg)
        be = get_backend(backend)
        # counts come from the overridden config so per-run overrides to
        # patterns/subgroups behave identically on every backend
        counts = {g: self.send_counts(g, cfg)
                  for g in range(len(cfg.subgroups))}
        report, logs = be.run(cfg, counts)
        self.delivery_logs = logs
        self.last_report = report
        self._fire_upcalls()
        return report

    def _fire_upcalls(self):
        for gid, fns in self._upcalls.items():
            log = self.delivery_logs.get(gid)
            if log is None:
                continue
            spec = self.cfg.subgroups[gid]
            for member in spec.members:
                for rank, idx, _ in log.sequence(member):
                    d = Delivery(subgroup=gid,
                                 seq=idx * log.n_senders + rank,
                                 sender_rank=rank, sender_index=idx)
                    for fn in fns:
                        fn(member, d)

    # -- reconfiguration (virtual synchrony) ---------------------------------

    def reconfigure(self, view: "views_mod.View") -> "Group":
        """Install a new membership view: every subgroup is restricted to
        the surviving members (failed senders drop out; the null-send
        scheme covers them until the view installs).  Returns a fresh
        ``Group`` for the new epoch; upcall registrations carry over,
        queued sends and delivery logs do not (messages underway at a view
        change are delivered in the old view or resent in the new one)."""
        alive = set(view.members)
        new_specs = []
        gid_map: Dict[int, int] = {}     # old gid -> new gid
        for gid, spec in enumerate(self.cfg.subgroups):
            members = tuple(m for m in spec.members if m in alive)
            senders = tuple(s for s in spec.senders if s in alive)
            if not members:
                continue                 # every member failed: subgroup dies
            if not senders:
                senders = (members[0],)
            gid_map[gid] = len(new_specs)
            new_specs.append(dataclasses.replace(
                spec, members=members, senders=senders))
        patterns = tuple(((gid_map[g], n), p)
                         for (g, n), p in self.cfg.patterns
                         if g in gid_map and n in alive)
        cfg = dataclasses.replace(
            self.cfg, members=tuple(view.members),
            subgroups=tuple(new_specs), patterns=patterns,
            epoch=self.cfg.epoch + 1)
        g = Group(cfg)
        g._upcalls = {gid_map[gid]: list(fns)
                      for gid, fns in self._upcalls.items()
                      if gid in gid_map}
        return g


# ---------------------------------------------------------------------------
# "des" backend — wraps the discrete-event simulator
# ---------------------------------------------------------------------------


class DESBackend:
    name = "des"

    def run(self, cfg: GroupConfig, counts: Dict[int, np.ndarray]
            ) -> Tuple[RunReport, Dict[int, DeliveryLog]]:
        sim_cfg = self._lower(cfg, counts)
        simulator = sim.Simulator(sim_cfg)
        result = simulator.run()
        logs = self._logs(simulator)
        if cfg.target_delivered is not None:
            for log in logs.values():
                log.truncate_to_app_target(cfg.target_delivered)
        # app/null accounting comes from the (possibly clipped) delivery
        # logs so it always matches what delivered()/upcalls expose;
        # throughput/latency stay the DES's timing truths.
        n_app, n_null = _sum_delivered(logs)
        report = RunReport(
            backend=self.name,
            throughput_GBps=result.throughput_GBps,
            mean_latency_us=result.mean_latency_us,
            p99_latency_us=result.p99_latency_us,
            duration_us=result.duration_us,
            delivered_app_msgs=n_app,
            delivered_null_msgs=n_null,
            nulls_sent=result.nulls_sent,
            rdma_writes=result.rdma_writes,
            rounds=result.sweeps,
            per_node_throughput=result.per_node_throughput,
            stalled=result.stalled,
            send_batches=result.send_batches,
            recv_batches=result.recv_batches,
            deliv_batches=result.deliv_batches,
            extras={"post_time_us": result.post_time_us,
                    "predicate_time_us": result.predicate_time_us,
                    "sender_blocked_us": result.sender_blocked_us},
        )
        return report, logs

    @staticmethod
    def _lower(cfg: GroupConfig, counts: Dict[int, np.ndarray]
               ) -> sim.SimConfig:
        """Per-sender counts lower to ``SenderPattern.n_messages``
        overrides (count 0 = inactive)."""
        patterns = {(g, n): p for (g, n), p in cfg.patterns}
        specs = []
        for gid, spec in enumerate(cfg.subgroups):
            c = counts[gid]
            specs.append(dataclasses.replace(
                spec, n_messages=int(c.max()) if len(c) else 0))
            for rank, node in enumerate(spec.senders):
                base = patterns.get((gid, node), sim.SenderPattern())
                patterns[(gid, node)] = dataclasses.replace(
                    base, active=base.active and int(c[rank]) > 0,
                    n_messages=int(c[rank]))
        return cfg.to_sim_config(
            subgroups=tuple(specs),
            patterns=tuple(patterns.items()))

    @staticmethod
    def _logs(simulator: sim.Simulator) -> Dict[int, DeliveryLog]:
        logs = {}
        for g in simulator.groups:
            is_app = [~np.isnan(g.gen_log[s][: int(g.gen_len[s])])
                      for s in range(g.n_s)]
            delivered = {node: int(g.deliv_seen[g.member_pos[node],
                                                g.member_pos[node]])
                         for node in g.spec.members}
            logs[g.gid] = DeliveryLog(n_senders=g.n_s, is_app=is_app,
                                      delivered_seq=delivered)
        return logs


# ---------------------------------------------------------------------------
# "graph" / "pallas" backends — the fused sweep, lowered to round schedules
# ---------------------------------------------------------------------------


def _lower_schedule(counts: np.ndarray, rounds: int) -> np.ndarray:
    """(S,) per-sender counts -> (T, S) app_schedule: one message per
    active round until each sender's budget is spent."""
    t = np.arange(rounds)[:, None]
    return (t < counts[None, :]).astype(np.int32)


def _round_cost_us(cfg: GroupConfig, spec: sim.SubgroupSpec,
                   app_pub: np.ndarray) -> Tuple[float, int]:
    """Cost-model time + RDMA writes for one fused round of one subgroup.

    Per round every member pushes its SST row (one coalesced 64 B write per
    peer); a sender that published ``k`` app messages additionally pushes
    them as one batched slot write of ``k`` slots per peer (the Sec. 3.2
    batch-send path).  The round takes as long as the busiest node's
    post+serialization charge plus one wire hop — the same calibrated
    constants the DES charges, so graph/pallas reports are comparable
    like-for-like with the ``des`` backend.
    """
    n = len(spec.members)
    if n <= 1:
        return 0.0, 0
    slot = spec.msg_size + 8
    row_writes = n * (n - 1)
    slot_writes = int(np.count_nonzero(app_pub)) * (n - 1)
    host, net = cfg.host, cfg.net
    base = host.lock_us + 3 * host.predicate_eval_us + \
        (n - 1) * (net.post_us + net.serialization(_ROW_BYTES))
    busiest = max([0.0] + [
        (n - 1) * (net.post_us + net.serialization(int(k) * slot))
        for k in app_pub if k > 0])
    t = base + busiest + net.wire_latency(min(slot, 4096))
    return t, row_writes + slot_writes


class GraphBackend:
    """Runs the scenario through :func:`repro.core.sweep.sweep` via
    ``lax.scan`` (the same lowering as :func:`sweep.run_rounds`), tracing
    per-round app/null publishes so delivery logs and latency can be
    reconstructed exactly."""

    name = "graph"

    def _receive_fn(self, spec: sim.SubgroupSpec):
        return None                      # sweep's native jnp consumption

    def run(self, cfg: GroupConfig, counts: Dict[int, np.ndarray]
            ) -> Tuple[RunReport, Dict[int, DeliveryLog]]:
        import jax
        import jax.numpy as jnp

        if cfg.target_delivered is not None and len(cfg.subgroups) > 1:
            # SimConfig.target_delivered is a per-member aggregate ACROSS
            # subgroups (Simulator._done); the scan runs each subgroup on
            # its own round timeline, so there is no cross-subgroup order
            # to clip against.  Diverging silently from the des backend
            # would break the conformance contract — refuse instead.
            raise ValueError(
                "target_delivered with multiple subgroups is only "
                "supported on the 'des' backend")

        logs: Dict[int, DeliveryLog] = {}
        duration = 0.0
        writes = 0
        delivered_app = 0
        delivered_null = 0
        nulls_sent = 0
        latencies: List[float] = []
        per_node_bytes: Dict[int, float] = {}
        rounds_total = 0
        stalled = False
        wall0 = time.perf_counter()

        for gid, spec in enumerate(cfg.subgroups):
            c = counts[gid]
            n_m, n_s = len(spec.members), len(spec.senders)
            max_c = int(c.max()) if len(c) else 0
            # settle rounds for visibility/null drain, plus slack for
            # ring-window throttling (a small window stretches publishing
            # over ~3 extra rounds per window-full of backlog)
            rounds = cfg.rounds if cfg.rounds is not None else \
                max_c + 2 * n_m + 8 + 3 * (max_c // max(spec.window, 1))
            sched = _lower_schedule(c, rounds)
            state = sweep_mod.SweepState.init(n_m, n_s)
            receive_fn = self._receive_fn(spec)

            def body(carry, ready):
                st, backlog = carry
                # window-throttled messages stay queued (backlog), exactly
                # like the DES app queue — sweep() only publishes what the
                # ring-reuse cap admits
                want = backlog + ready
                new, batch = sweep_mod.sweep(
                    st, want, window=spec.window,
                    null_send=cfg.flags.null_send, receive_fn=receive_fn)
                pub = new.app_sent - st.app_sent
                return (new, want - pub), (batch, pub,
                                           new.nulls_sent - st.nulls_sent)

            # one scan for both paths: the kernel receive closure is pure
            # traceable JAX (interpret-mode pallas_call included), so the
            # pallas backend compiles once instead of re-tracing per round
            carry = (state, jnp.zeros((n_s,), jnp.int32))
            (state, _), (batches, app_pub, nulls) = jax.lax.scan(
                body, carry, jnp.asarray(sched))
            batches = np.asarray(batches)
            app_pub = np.asarray(app_pub)
            nulls = np.asarray(nulls)

            log, lat_rounds = self._reconstruct(spec, state, batches,
                                                app_pub, nulls)
            if cfg.target_delivered is not None:
                log.truncate_to_app_target(cfg.target_delivered)
            logs[gid] = log
            rounds_total += rounds
            nulls_sent += int(nulls.sum())

            # cost-model time + writes per round
            round_times = []
            for r in range(rounds):
                t_r, w_r = _round_cost_us(cfg, spec, app_pub[r])
                round_times.append(t_r)
                writes += w_r
            end_time = np.cumsum(round_times)
            duration = max(duration, float(end_time[-1]) if rounds else 0.0)
            latencies.extend(
                float(end_time[dr] - (end_time[pr - 1] if pr else 0.0))
                for pr, dr in lat_rounds)

            for node in spec.members:
                a, nl = log.app_null_counts(node)
                delivered_app += a
                delivered_null += nl
                per_node_bytes[node] = per_node_bytes.get(node, 0.0) + \
                    a * spec.msg_size
            total_app = int(c.sum())
            need = total_app if cfg.target_delivered is None else \
                min(cfg.target_delivered, total_app)
            if any(log.app_null_counts(node)[0] < need
                   for node in spec.members):
                stalled = True

        per_node = [b / duration / 1e3 for b in per_node_bytes.values()
                    if duration > 0 and b > 0]
        lat = np.array(latencies) if latencies else np.array([0.0])
        report = RunReport(
            backend=self.name,
            throughput_GBps=float(np.mean(per_node)) if per_node else 0.0,
            mean_latency_us=float(lat.mean()),
            p99_latency_us=float(np.percentile(lat, 99)),
            duration_us=duration,
            delivered_app_msgs=delivered_app,
            delivered_null_msgs=delivered_null,
            nulls_sent=nulls_sent,
            rdma_writes=writes,
            rounds=rounds_total,
            per_node_throughput=per_node,
            stalled=stalled,
            extras={"wall_s": time.perf_counter() - wall0},
        )
        return report, logs

    @staticmethod
    def _reconstruct(spec: sim.SubgroupSpec, state, batches: np.ndarray,
                     app_pub: np.ndarray, nulls: np.ndarray):
        """Rebuild the per-sender nullness log and (publish_round,
        delivery_round) latency samples from the per-round trace.  Within a
        round a sender publishes its app messages before its nulls
        (matching :func:`sweep.sweep`'s ``published + app_pub + nulls``)."""
        n_s = len(spec.senders)
        rounds = batches.shape[0]
        is_app: List[List[bool]] = [[] for _ in range(n_s)]
        pub_round: List[List[int]] = [[] for _ in range(n_s)]
        for r in range(rounds):
            for s in range(n_s):
                for _ in range(int(app_pub[r, s])):
                    is_app[s].append(True)
                    pub_round[s].append(r)
                for _ in range(int(nulls[r, s])):
                    is_app[s].append(False)
                    pub_round[s].append(r)
        delivered_num = np.cumsum(batches, axis=0) - 1   # (T, N)
        final = delivered_num[-1] if rounds else \
            np.full(len(spec.members), -1)
        delivered = {node: int(final[pos])
                     for pos, node in enumerate(spec.members)}
        # latency samples at member position 0 (as the DES does)
        lat = []
        if rounds:
            col = delivered_num[:, 0]
            for seq in range(int(final[0]) + 1):
                rank, idx = seq % n_s, seq // n_s
                if not is_app[rank][idx]:
                    continue
                dr = int(np.searchsorted(col, seq))
                lat.append((pub_round[rank][idx], dr))
        log = DeliveryLog(
            n_senders=n_s,
            is_app=[np.array(a, dtype=bool) for a in is_app],
            delivered_seq=delivered)
        return log, lat


class PallasBackend(GraphBackend):
    """The graph protocol with the receive predicate evaluated by the
    fused Pallas SMC-sweep kernel over real slot-counter rings — the
    structural analogue of keeping the SMC polling area cache-resident."""

    name = "pallas"

    def _receive_fn(self, spec: sim.SubgroupSpec):
        from repro.kernels import ops, smc_sweep as ss

        window = spec.window

        def receive(pub_vis, recv_counts):
            import jax.numpy as jnp
            n_m, n_s = pub_vis.shape
            counters = ss.counters_from_counts(
                pub_vis.reshape(n_m * n_s), window)
            visible = ops.smc_sweep(counters,
                                    recv_counts.reshape(n_m * n_s))
            return jnp.maximum(recv_counts,
                               visible.reshape(n_m, n_s).astype(
                                   recv_counts.dtype))

        return receive


def _sum_delivered(logs: Mapping[int, DeliveryLog]) -> Tuple[int, int]:
    a = n = 0
    for log in logs.values():
        for node in log.delivered_seq:
            da, dn = log.app_null_counts(node)
            a, n = a + da, n + dn
    return a, n


register_backend("des", DESBackend)
register_backend("graph", GraphBackend)
register_backend("pallas", PallasBackend)
