"""The unified Derecho-style ``Group`` API with pluggable protocol backends.

Derecho (the paper's artifact) exposes one handle: a *group* whose
subgroups you ``send()`` into and receive totally-ordered delivery upcalls
from, while every Spindle optimization stays an internal toggle.  This
module is that seam for the repro: one :class:`GroupConfig` describes a
scenario (membership, subgroups, :class:`~repro.core.simulator.SpindleFlags`,
cost/net models) and :meth:`Group.run` executes it unmodified on any of
three substrates behind the :class:`ProtocolBackend` protocol:

  * ``"des"``    — the calibrated discrete-event simulator
                   (:mod:`repro.core.simulator`): answers *how fast* on the
                   paper's RDMA testbed model.
  * ``"graph"``  — the pure-JAX fused predicate sweep
                   (:mod:`repro.core.sweep`): the send pattern is lowered
                   to an ``app_schedule`` array and scanned in-graph.
  * ``"pallas"`` — the graph protocol with the receive predicate evaluated
                   by the fused Pallas SMC-sweep kernel
                   (:mod:`repro.kernels.smc_sweep`) over real slot-counter
                   rings.

Every backend returns the same :class:`RunReport` (throughput, latency
percentiles, app/null delivery accounting, RDMA-write counts) so Fig.
5-style comparisons work like-for-like across substrates, and every
backend records the same per-subgroup total-order delivery log, so
delivered sequences can be asserted identical across backends.

Usage::

    g = Group(cfg)
    h = g.subgroup(0)
    h.ordered_send(sender=0, n=100)
    h.on_delivery(lambda member, msg: ...)
    report = g.run(backend="des")

Reconfiguration across view changes is driven by
:class:`repro.core.views.MembershipService` — see :meth:`Group.reconfigure`.
"""

from __future__ import annotations

import collections
import dataclasses
import functools
import time
from typing import (Any, Callable, Deque, Dict, List, Mapping, Optional,
                    Protocol, Tuple)

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import costmodel, delivery as delivery_mod
from repro.core import desgraph as desgraph_mod
from repro.core import desreplay as desreplay_mod
from repro.core import placement as placement_mod
from repro.core import simulator as sim
from repro.core import sst
from repro.core import sweep as sweep_mod
from repro.core import views as views_mod

Array = Any

# SST row push size (bytes): the coalesced counter row (Sec. 2.2).
_ROW_BYTES = 64


# ---------------------------------------------------------------------------
# Configuration
# ---------------------------------------------------------------------------

# Re-exported so callers need only `repro.api` / `repro.core.group`.
SubgroupSpec = sim.SubgroupSpec
SpindleFlags = sim.SpindleFlags
SenderPattern = sim.SenderPattern


@dataclasses.dataclass(frozen=True)
class GroupConfig:
    """One multicast scenario, independent of the substrate that runs it."""

    members: Tuple[int, ...]                     # top-level membership
    subgroups: Tuple[sim.SubgroupSpec, ...]
    flags: sim.SpindleFlags = sim.SpindleFlags.spindle()
    net: costmodel.NetworkModel = costmodel.RDMA_CX6
    host: costmodel.HostModel = costmodel.HOST_X86
    patterns: Tuple[Tuple[Tuple[int, int], sim.SenderPattern], ...] = ()
    target_delivered: Optional[int] = None
    max_time_us: float = 60e6
    # DES-plane knobs (charged by the des backend only, carried so a
    # SimConfig round-trips losslessly through the Group API)
    llc_bytes: int = 20 * 1024 * 1024
    upcall_extra_us: float = 0.0
    max_sweeps: int = 3_000_000
    idle_tick_us: float = 2.0
    # graph/pallas round budget; None = auto (max sends + settle rounds)
    rounds: Optional[int] = None
    epoch: int = 0                               # bumped by reconfigure()

    def __post_init__(self):
        members = set(self.members)
        for spec in self.subgroups:
            assert set(spec.members) <= members, \
                f"subgroup members {spec.members} outside group {members}"

    @property
    def n_nodes(self) -> int:
        return max(self.members) + 1 if self.members else 0

    def pattern(self, g: int, node: int) -> sim.SenderPattern:
        for (pg, pn), pat in self.patterns:
            if pg == g and pn == node:
                return pat
        return sim.SenderPattern()

    def to_sim_config(self, **overrides) -> sim.SimConfig:
        """Lower to the DES configuration (the ``des`` backend's input)."""
        kw = dict(n_nodes=self.n_nodes, subgroups=self.subgroups,
                  flags=self.flags, net=self.net, host=self.host,
                  patterns=self.patterns,
                  target_delivered=self.target_delivered,
                  max_time_us=self.max_time_us,
                  llc_bytes=self.llc_bytes,
                  upcall_extra_us=self.upcall_extra_us,
                  max_sweeps=self.max_sweeps,
                  idle_tick_us=self.idle_tick_us)
        kw.update(overrides)
        return sim.SimConfig(**kw)

    @classmethod
    def from_sim_config(cls, cfg: sim.SimConfig, **kw) -> "GroupConfig":
        return cls(members=tuple(range(cfg.n_nodes)),
                   subgroups=cfg.subgroups, flags=cfg.flags, net=cfg.net,
                   host=cfg.host, patterns=cfg.patterns,
                   target_delivered=cfg.target_delivered,
                   max_time_us=cfg.max_time_us,
                   llc_bytes=cfg.llc_bytes,
                   upcall_extra_us=cfg.upcall_extra_us,
                   max_sweeps=cfg.max_sweeps,
                   idle_tick_us=cfg.idle_tick_us, **kw)


def single_group(n_nodes: int, n_senders: Optional[int] = None,
                 msg_size: int = 10240, window: int = 100,
                 n_messages: int = 1000,
                 flags: sim.SpindleFlags = sim.SpindleFlags.spindle(),
                 **kw) -> GroupConfig:
    """One subgroup over ``n_nodes`` nodes — the quickstart scenario."""
    senders = tuple(range(n_senders if n_senders is not None else n_nodes))
    spec = sim.SubgroupSpec(members=tuple(range(n_nodes)), senders=senders,
                            msg_size=msg_size, window=window,
                            n_messages=n_messages)
    return GroupConfig(members=tuple(range(n_nodes)), subgroups=(spec,),
                       flags=flags, **kw)


# ---------------------------------------------------------------------------
# The unified run report
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class RunReport:
    """Backend-independent result of one :meth:`Group.run`.

    ``delivered_app_msgs``/``delivered_null_msgs`` are summed over members
    (an app message delivered at k members counts k times, matching the
    simulator's historical accounting); ``nulls_sent`` counts null
    *publishes*.  For the graph/pallas backends the time-domain numbers
    (throughput, latency, duration, rdma_writes) are derived from the same
    calibrated cost model the DES charges, so they are comparable
    like-for-like, not wall-clock measurements.
    """

    backend: str
    throughput_GBps: float
    mean_latency_us: float
    p99_latency_us: float
    duration_us: float
    delivered_app_msgs: int
    delivered_null_msgs: int
    nulls_sent: int
    rdma_writes: int
    rounds: int                         # DES sweeps / graph scan rounds
    per_node_throughput: List[float]
    stalled: bool
    send_batches: List[int] = dataclasses.field(default_factory=list)
    recv_batches: List[int] = dataclasses.field(default_factory=list)
    deliv_batches: List[int] = dataclasses.field(default_factory=list)
    extras: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def summary(self) -> Dict[str, Any]:
        return {
            "backend": self.backend,
            "throughput_GBps": round(self.throughput_GBps, 4),
            "mean_latency_us": round(self.mean_latency_us, 3),
            "p99_latency_us": round(self.p99_latency_us, 3),
            "delivered_app_msgs": self.delivered_app_msgs,
            "delivered_null_msgs": self.delivered_null_msgs,
            "nulls_sent": self.nulls_sent,
            "rdma_writes": self.rdma_writes,
            "stalled": self.stalled,
        }


@dataclasses.dataclass(frozen=True)
class Delivery:
    """One delivered application message (nulls never reach upcalls)."""

    subgroup: int
    seq: int                # round-robin sequence number
    sender_rank: int
    sender_index: int       # per-sender publish index (ring index)


@dataclasses.dataclass
class DeliveryLog:
    """The total-order publish log of one subgroup plus how far each
    member's delivery predicate got into it."""

    n_senders: int
    is_app: List[np.ndarray]            # per sender-rank: nullness per index
    delivered_seq: Dict[int, int]       # member node -> highest delivered seq

    def sequence(self, node: int, *, apps_only: bool = True
                 ) -> List[Tuple[int, int, bool]]:
        """Delivered (sender_rank, sender_index, is_app) at ``node`` in
        delivery order."""
        out = []
        for seq in range(self.delivered_seq.get(node, -1) + 1):
            rank, idx = seq % self.n_senders, seq // self.n_senders
            app = bool(idx < len(self.is_app[rank])
                       and self.is_app[rank][idx])
            if app or not apps_only:
                out.append((rank, idx, app))
        return out

    def app_null_counts(self, node: int) -> Tuple[int, int]:
        hi = self.delivered_seq.get(node, -1)
        batch = delivery_mod.DeliveryBatch(lo_seq=0, hi_seq=hi,
                                           n_senders=self.n_senders)
        return delivery_mod.split_app_and_null(batch, self.is_app)

    def app_flags_upto(self, hi: int) -> np.ndarray:
        """Nullness of seqs ``0..hi`` in the total order (False for seqs
        beyond any sender's logged publishes)."""
        flags = np.zeros(max(hi + 1, 0), dtype=bool)
        for r, log in enumerate(self.is_app):
            seqs = np.arange(len(log)) * self.n_senders + r
            m = seqs <= hi
            flags[seqs[m]] = np.asarray(log, dtype=bool)[: len(seqs)][m]
        return flags

    def truncate_to_app_target(self, target: int) -> None:
        """Clip each member's delivered prefix at its ``target``-th app
        message — the logical form of ``target_delivered``'s measurement
        window ("end once every member has delivered this many").  Members
        that overshot the target (the DES stops on simulated time, whole
        batches late; the scan runs a fixed round budget) are cut back to
        the same logical point on every backend, so app sequences stay
        comparable.  A member that delivered exactly ``target`` apps keeps
        its trailing nulls (nothing to cut)."""
        hi_all = max(self.delivered_seq.values(), default=-1)
        if hi_all < 0:
            return
        cum = np.cumsum(self.app_flags_upto(hi_all))
        for node, hi in self.delivered_seq.items():
            if hi >= 0 and cum[hi] > target:
                self.delivered_seq[node] = int(
                    np.searchsorted(cum, target))


# ---------------------------------------------------------------------------
# Backend protocol + registry
# ---------------------------------------------------------------------------


class ProtocolBackend(Protocol):
    """One substrate that can execute a :class:`GroupConfig` scenario."""

    name: str

    def run(self, cfg: GroupConfig,
            counts: Dict[int, np.ndarray]) -> Tuple[RunReport,
                                                    Dict[int, DeliveryLog]]:
        """Execute the scenario.  ``counts[gid]`` is the per-sender-rank
        app-message count for subgroup ``gid``.  Returns the unified report
        plus one delivery log per subgroup."""
        ...


BACKENDS: Dict[str, Callable[[], ProtocolBackend]] = {}


def register_backend(name: str, factory: Callable[[], ProtocolBackend]):
    BACKENDS[name] = factory


def get_backend(backend) -> ProtocolBackend:
    if isinstance(backend, str):
        if backend not in BACKENDS:
            raise KeyError(
                f"unknown backend {backend!r}; have {sorted(BACKENDS)}")
        return BACKENDS[backend]()
    return backend


# ---------------------------------------------------------------------------
# The Group façade
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class EpochCarry:
    """What one membership epoch hands the next across the
    virtual-synchrony cut (DESIGN.md Sec. 7).

    Every field is indexed by the NEW view's subgroup ids and sender
    ranks (the closing epoch's ranks remapped through the surviving
    membership).  ``resend[g][s]`` is how many of sender s's app
    messages were underway at the cut — enqueued in the closing epoch
    but not stable at the ragged trim — and must be re-published in the
    new view; per-sender FIFO order is preserved by construction because
    the resend set is the *tail* of that sender's sequence.
    ``stable_apps[g][s]`` is the closing epoch's delta of apps delivered
    everywhere (what the serve plane rebases its slot holds by);
    ``app_base[g][s]`` the cumulative count across ALL prior epochs —
    the global FIFO position of the new epoch's k-th app from s is
    ``app_base[g][s] + k``, and this is the monotone watermark the
    view-change soaks assert never regresses.  ``cut_seq[g]`` is the
    ragged-trim seq in the CLOSING subgroup's total order (diagnostics;
    new-epoch seqs restart at 0)."""

    from_epoch: int
    cut_seq: Tuple[int, ...]
    resend: Tuple[np.ndarray, ...]
    stable_apps: Tuple[np.ndarray, ...]
    app_base: Tuple[np.ndarray, ...]

    def total_resend(self) -> int:
        return int(sum(r.sum() for r in self.resend))


class SubgroupHandle:
    """Send/upcall handle for one subgroup — the Derecho user surface."""

    def __init__(self, group: "Group", gid: int):
        self.group = group
        self.gid = gid

    @property
    def spec(self) -> sim.SubgroupSpec:
        return self.group.cfg.subgroups[self.gid]

    def send(self, sender: Optional[int] = None, n: int = 1) -> None:
        """Queue ``n`` application messages from ``sender`` (a node id;
        defaults to the subgroup's first sender).  Explicit sends take
        over the whole subgroup: they replace the spec's ``n_messages``
        scenario default AND any per-sender pattern budgets — senders you
        do not ``send()`` to send nothing (nulls cover them)."""
        spec = self.spec
        sender = spec.senders[0] if sender is None else sender
        if sender not in spec.senders:
            raise ValueError(f"node {sender} is not a sender of "
                             f"subgroup {self.gid}")
        rank = spec.senders.index(sender)
        self.group._explicit.setdefault(self.gid, np.zeros(
            len(spec.senders), dtype=np.int64))[rank] += n

    # In this repro every send is totally ordered; the two Derecho entry
    # points are therefore the same operation.
    ordered_send = send

    def on_delivery(self, fn: Callable[[int, Delivery], None]) -> None:
        """Register a delivery upcall ``fn(member_node, Delivery)``; fired
        (app messages only, in total order per member) after each run."""
        self.group._upcalls.setdefault(self.gid, []).append(fn)

    def delivered(self, node: int) -> List[Tuple[int, int, bool]]:
        """Delivered (sender_rank, sender_index, is_app) at ``node`` from
        the last run (apps only)."""
        log = self.group.delivery_logs.get(self.gid)
        if log is None:
            raise RuntimeError("run() first")
        return log.sequence(node)


class Group:
    """The one front door: configure once, run on any backend."""

    def __init__(self, cfg: GroupConfig):
        self.cfg = cfg
        self._explicit: Dict[int, np.ndarray] = {}
        self._upcalls: Dict[int, List[Callable]] = {}
        self.delivery_logs: Dict[int, DeliveryLog] = {}
        self.last_report: Optional[RunReport] = None
        # virtual-synchrony epoch carry (set by a cut, consumed by the
        # next epoch's runs/streams — DESIGN.md Sec. 7)
        self.carry: Optional[EpochCarry] = None
        # old gid -> new gid / old->new sender rank maps, populated by
        # reconfigure() on the group it RETURNS (None on fresh groups)
        self._gid_map: Optional[Dict[int, int]] = None
        self._sender_maps: Optional[Dict[int, List[Tuple[int, int]]]] = None

    # -- construction helpers ------------------------------------------------

    @classmethod
    def from_sim_config(cls, cfg: sim.SimConfig, **kw) -> "Group":
        return cls(GroupConfig.from_sim_config(cfg, **kw))

    def subgroup(self, gid: int) -> SubgroupHandle:
        if not 0 <= gid < len(self.cfg.subgroups):
            raise IndexError(gid)
        return SubgroupHandle(self, gid)

    @property
    def n_subgroups(self) -> int:
        return len(self.cfg.subgroups)

    def send_counts(self, gid: int,
                    cfg: Optional[GroupConfig] = None) -> np.ndarray:
        """Effective per-sender-rank app-message counts for one subgroup.

        Explicit queued ``send()`` calls take over the WHOLE subgroup: they
        replace both the spec's ``n_messages`` default and any
        ``SenderPattern.n_messages`` budgets (a sender you did not send()
        to sends nothing).  Without explicit sends, pattern budgets
        override the spec default per sender.  Inactive patterns always
        mask to zero.  A virtual-synchrony ``carry`` (resend counts from
        the previous epoch's cut) is added ON TOP of whatever the above
        computes — resends are obligations of the new view, not scenario
        traffic, so they ride every backend's schedule identically (the
        des/graph/pallas conformance of post-cut runs is free)."""
        cfg = self.cfg if cfg is None else cfg
        spec = cfg.subgroups[gid]
        explicit = self._explicit.get(gid)
        if explicit is not None and len(explicit) != len(spec.senders):
            raise ValueError(
                f"subgroup {gid} has queued explicit sends for "
                f"{len(explicit)} senders but the (overridden) spec has "
                f"{len(spec.senders)}; drop the override or re-queue")
        if explicit is not None:
            counts = explicit.copy()
        else:
            counts = np.full(len(spec.senders), spec.n_messages,
                             dtype=np.int64)
        for rank, node in enumerate(spec.senders):
            pat = cfg.pattern(gid, node)
            if not pat.active:
                counts[rank] = 0
            elif pat.n_messages is not None and explicit is None:
                counts[rank] = pat.n_messages
        if self.carry is not None:
            resend = self.carry.resend[gid]
            if len(resend) != len(spec.senders):
                raise ValueError(
                    f"subgroup {gid} carries resends for {len(resend)} "
                    f"senders but the (overridden) spec has "
                    f"{len(spec.senders)}; a sender-set override cannot "
                    "silently drop the previous epoch's resend set")
            counts = counts + resend.astype(counts.dtype)
        return counts

    # -- running -------------------------------------------------------------

    def run(self, backend="des", **overrides) -> RunReport:
        """Execute the configured scenario on ``backend`` (name or
        :class:`ProtocolBackend` instance) and fire delivery upcalls."""
        cfg = (dataclasses.replace(self.cfg, **overrides) if overrides
               else self.cfg)
        be = get_backend(backend)
        # counts come from the overridden config so per-run overrides to
        # patterns/subgroups behave identically on every backend
        counts = {g: self.send_counts(g, cfg)
                  for g in range(len(cfg.subgroups))}
        report, logs = be.run(cfg, counts)
        self.delivery_logs = logs
        self.last_report = report
        self._fire_upcalls()
        return report

    def run_batch(self, backend="graph", *, windows=None, null_send=None,
                  n_messages=None) -> List[RunReport]:
        """Execute a grid of scenario variants as ONE batched program.

        Each keyword is ``None`` (keep the configured value) or a sequence
        of per-point values; all given grids must share one length B.
        ``windows``/``n_messages`` replace every subgroup's setting at
        that point, ``null_send`` replaces the flag.  On the graph/pallas
        backends the whole grid executes as a single compiled program —
        every point, every subgroup — sharded across ``jax.devices()``
        via shard_map when the batch divides over more than one device
        (plain vmap on a single device; see
        :mod:`repro.core.placement`).  Schedules are padded to a common
        round budget and per-point traces sliced back, producing results
        identical to B sequential :meth:`run` calls — a Fig. 6 window
        sweep or Fig. 11 null-overhead grid becomes one XLA launch
        instead of B Python runs.  Backends without a ``run_batch``
        (e.g. ``des``) fall back to a sequential loop, keeping
        cross-backend conformance testable.

        Returns one :class:`RunReport` per point; each report carries its
        delivery logs in ``extras["delivery_logs"]``.  Delivery upcalls do
        not fire (batch runs are measurement sweeps)."""
        grids = {name: list(vals) for name, vals in
                 (("windows", windows), ("null_send", null_send),
                  ("n_messages", n_messages)) if vals is not None}
        if not grids:
            raise ValueError("run_batch needs at least one grid "
                             "(windows=, null_send= or n_messages=)")
        sizes = {len(v) for v in grids.values()}
        if len(sizes) != 1:
            raise ValueError("grid lengths differ: " + str(
                {k: len(v) for k, v in grids.items()}))
        cfgs = []
        for i in range(sizes.pop()):
            cfg = self.cfg
            over: Dict[str, Any] = {}
            if windows is not None or n_messages is not None:
                over["subgroups"] = tuple(
                    dataclasses.replace(
                        s,
                        window=(int(windows[i]) if windows is not None
                                else s.window),
                        n_messages=(int(n_messages[i])
                                    if n_messages is not None
                                    else s.n_messages))
                    for s in cfg.subgroups)
            if null_send is not None:
                over["flags"] = dataclasses.replace(
                    cfg.flags, null_send=bool(null_send[i]))
            cfgs.append(dataclasses.replace(cfg, **over) if over else cfg)
        counts = [{g: self.send_counts(g, c)
                   for g in range(len(c.subgroups))} for c in cfgs]
        be = get_backend(backend)
        if hasattr(be, "run_batch"):
            results = be.run_batch(cfgs, counts)
        else:
            results = [be.run(c, k) for c, k in zip(cfgs, counts)]
        reports = []
        for report, logs in results:
            report.extras["delivery_logs"] = logs
            reports.append(report)
        return reports

    def stream(self, backend="graph") -> "GroupStream":
        """Open a streaming session over this scenario: feed per-round
        per-sender app-message counts with :meth:`GroupStream.step` (all
        G subgroups sweep as ONE stacked compiled program per round) and
        close with :meth:`GroupStream.finish` for the same
        :class:`RunReport`/delivery logs a scheduled run produces.  This
        is the serve-plane entry point (DESIGN.md Sec. 6): message
        arrivals that only exist at runtime — a decode loop's admissions
        and emitted tokens — ride the multicast substrate round by
        round instead of as a precomputed schedule."""
        return GroupStream(self, backend)

    def _fire_upcalls(self):
        for gid, fns in self._upcalls.items():
            log = self.delivery_logs.get(gid)
            if log is None:
                continue
            spec = self.cfg.subgroups[gid]
            for member in spec.members:
                for rank, idx, _ in log.sequence(member):
                    d = Delivery(subgroup=gid,
                                 seq=idx * log.n_senders + rank,
                                 sender_rank=rank, sender_index=idx)
                    for fn in fns:
                        fn(member, d)

    # -- reconfiguration (virtual synchrony) ---------------------------------

    def reconfigure(self, view: "views_mod.View") -> "Group":
        """Install a new membership view: every subgroup is restricted to
        the surviving members (failed senders drop out; the null-send
        scheme covers them until the view installs).  Returns a fresh
        ``Group`` for the new epoch.

        What crosses the epoch boundary (DESIGN.md Sec. 7): upcall
        registrations, and QUEUED explicit sends — messages handed to
        ``send()`` but never yet underway are the head of the
        virtual-synchrony resend set, remapped to the surviving sender
        ranks (a failed sender's queue dies with it).  Delivery logs do
        NOT carry: each epoch's log is its own total order.  In-flight
        state — messages *published* but not yet stable — is carried by
        the streaming path (:meth:`GroupStream.reconfigure`), which
        computes the cut and installs its resend decision as ``carry``
        on the Group it hands back; scheduled runs of a carried Group
        add those resends to every sender's counts on every backend
        (:meth:`send_counts`)."""
        alive = set(view.members)
        new_specs = []
        gid_map: Dict[int, int] = {}     # old gid -> new gid
        sender_maps: Dict[int, List[Tuple[int, int]]] = {}
        for gid, spec in enumerate(self.cfg.subgroups):
            members = tuple(m for m in spec.members if m in alive)
            senders = tuple(s for s in spec.senders if s in alive)
            if not members:
                continue                 # every member failed: subgroup dies
            sender_maps[gid] = [(spec.senders.index(s), new_rank)
                                for new_rank, s in enumerate(senders)]
            if not senders:
                senders = (members[0],)
            gid_map[gid] = len(new_specs)
            new_specs.append(dataclasses.replace(
                spec, members=members, senders=senders))
        patterns = tuple(((gid_map[g], n), p)
                         for (g, n), p in self.cfg.patterns
                         if g in gid_map and n in alive)
        cfg = dataclasses.replace(
            self.cfg, members=tuple(view.members),
            subgroups=tuple(new_specs), patterns=patterns,
            epoch=self.cfg.epoch + 1)
        g = Group(cfg)
        g._upcalls = {gid_map[gid]: list(fns)
                      for gid, fns in self._upcalls.items()
                      if gid in gid_map}
        for gid, new_gid in gid_map.items():
            queued = self._explicit.get(gid)
            if queued is None:
                continue
            remapped = np.zeros(len(new_specs[new_gid].senders), np.int64)
            for old_rank, new_rank in sender_maps[gid]:
                remapped[new_rank] = queued[old_rank]
            if remapped.any():
                g._explicit[new_gid] = remapped
        g._gid_map = gid_map
        g._sender_maps = sender_maps
        return g


# ---------------------------------------------------------------------------
# "des" / "des-loop" backends — the discrete-event simulator.  "des" is
# the two-phase simulate-then-execute split (DESIGN.md Sec. 12):
# repro.core.desgraph timestamps the event timeline, repro.core.desreplay
# replays the emitted graph.  "des-loop" is the legacy single-phase
# event loop, kept for differential testing — both produce bit-identical
# results by construction.
# ---------------------------------------------------------------------------


def _des_logs(groups) -> Dict[int, DeliveryLog]:
    """Delivery logs from final per-subgroup DES state (either phase-1
    ``DesGraph.groups`` or the legacy ``Simulator.groups``)."""
    logs = {}
    for g in groups:
        is_app = [~np.isnan(g.gen_log[s][: int(g.gen_len[s])])
                  for s in range(g.n_s)]
        delivered = {node: int(g.deliv_seen[g.member_pos[node],
                                            g.member_pos[node]])
                     for node in g.spec.members}
        logs[g.gid] = DeliveryLog(n_senders=g.n_s, is_app=is_app,
                                  delivered_seq=delivered)
    return logs


def _des_report(name: str, cfg: GroupConfig, result: sim.SimResult,
                groups) -> Tuple[RunReport, Dict[int, DeliveryLog]]:
    """Shared DES report assembly — both the two-phase ``des`` path and
    the legacy ``des-loop`` lower their :class:`SimResult` + final group
    state through this, so bit-identity between them is a statement
    about the simulators, not the reporting glue."""
    logs = _des_logs(groups)
    if cfg.target_delivered is not None:
        for log in logs.values():
            log.truncate_to_app_target(cfg.target_delivered)
    # app/null accounting comes from the (possibly clipped) delivery
    # logs so it always matches what delivered()/upcalls expose;
    # throughput/latency stay the DES's timing truths.
    n_app, n_null = _sum_delivered(logs)
    report = RunReport(
        backend=name,
        throughput_GBps=result.throughput_GBps,
        mean_latency_us=result.mean_latency_us,
        p99_latency_us=result.p99_latency_us,
        duration_us=result.duration_us,
        delivered_app_msgs=n_app,
        delivered_null_msgs=n_null,
        nulls_sent=result.nulls_sent,
        rdma_writes=result.rdma_writes,
        rounds=result.sweeps,
        per_node_throughput=result.per_node_throughput,
        stalled=result.stalled,
        send_batches=result.send_batches,
        recv_batches=result.recv_batches,
        deliv_batches=result.deliv_batches,
        extras={"post_time_us": result.post_time_us,
                "predicate_time_us": result.predicate_time_us,
                "sender_blocked_us": result.sender_blocked_us},
    )
    return report, logs


class DESLoopBackend:
    """The legacy single-phase DES event loop (``des-loop``), retained
    for differential testing of the two-phase ``des`` path
    (DESIGN.md Sec. 12).  Not streamable — use ``des`` for that."""

    name = "des-loop"

    def run(self, cfg: GroupConfig, counts: Dict[int, np.ndarray]
            ) -> Tuple[RunReport, Dict[int, DeliveryLog]]:
        sim_cfg = self._lower(cfg, counts)
        simulator = sim.Simulator(sim_cfg)
        result = simulator.run()
        return _des_report(self.name, cfg, result, simulator.groups)

    @staticmethod
    def _lower(cfg: GroupConfig, counts: Dict[int, np.ndarray]
               ) -> sim.SimConfig:
        """Per-sender counts lower to ``SenderPattern.n_messages``
        overrides (count 0 = inactive)."""
        patterns = {(g, n): p for (g, n), p in cfg.patterns}
        specs = []
        for gid, spec in enumerate(cfg.subgroups):
            c = counts[gid]
            specs.append(dataclasses.replace(
                spec, n_messages=int(c.max()) if len(c) else 0))
            for rank, node in enumerate(spec.senders):
                base = patterns.get((gid, node), sim.SenderPattern())
                patterns[(gid, node)] = dataclasses.replace(
                    base, active=base.active and int(c[rank]) > 0,
                    n_messages=int(c[rank]))
        return cfg.to_sim_config(
            subgroups=tuple(specs),
            patterns=tuple(patterns.items()))


# ---------------------------------------------------------------------------
# "graph" / "pallas" backends — the fused STACKED sweep: one compiled
# program per whole-group scenario shape (all subgroups padded + masked),
# one device-sharded program per scenario grid
# ---------------------------------------------------------------------------

# One entry is appended per TRACE of a stacked program (jit runs the
# Python body only while compiling): the padded stack shape
# (G, N_max, S_max), the per-subgroup window tuple, and the backend name.
# The hot-path tests assert that a repeated Group.run with the same static
# key leaves this list untouched, the stacked tests that a G-subgroup run
# appends exactly ONE entry, and the view-change soaks that a
# shape-preserving reconfigure appends NONE (the per-subgroup sizes are
# traced validity masks, not part of the key).
#
# Bounded: a long-lived open-loop process (the workload plane drives
# streams for hours — DESIGN.md Sec. 10) would otherwise grow this list
# by one entry per distinct compile forever.  The cap is far above any
# real session's distinct-shape count, so the delta assertions above are
# unaffected; use :func:`trace_snapshot` / :func:`trace_reset` (also
# re-exported from :mod:`repro.api`) rather than touching the deque.
TRACE_MAXLEN = 4096
TRACE_EVENTS: Deque[Tuple[Tuple[int, ...], Tuple[int, ...], str]] = \
    collections.deque(maxlen=TRACE_MAXLEN)


def trace_snapshot() -> Tuple[Tuple[Tuple[int, ...], Tuple[int, ...], str],
                              ...]:
    """Immutable copy of the compile-trace history (newest last).  The
    supported way to measure "how many programs did this sweep trace":
    take a snapshot before, subtract its length after."""
    return tuple(TRACE_EVENTS)


def trace_reset() -> int:
    """Clear the compile-trace history; returns how many entries were
    dropped.  Does NOT evict compiled programs — a cleared history only
    forgets that past traces happened."""
    n = len(TRACE_EVENTS)
    TRACE_EVENTS.clear()
    return n


def _lower_schedule(counts: np.ndarray, rounds: int) -> np.ndarray:
    """(S,) per-sender counts -> (T, S) app_schedule: one message per
    active round until each sender's budget is spent."""
    t = np.arange(rounds)[:, None]
    return (t < counts[None, :]).astype(np.int32)


def _cost_params(cfg: GroupConfig, spec: sim.SubgroupSpec) -> np.ndarray:
    """Lower the per-round cost model to six coefficients consumed as
    vectorized in-graph arithmetic by :func:`_fold_cost`:
    ``[base, post, per_msg, wire, row_writes, peers]``.

    Per round every member pushes its SST row (one coalesced 64 B write per
    peer, the ``base`` term); a sender that published ``k`` app messages
    additionally pushes them as one batched slot write of ``k`` slots per
    peer (the Sec. 3.2 batch-send path: ``post + per_msg * k``).  The round
    takes as long as the busiest node's post+serialization charge plus one
    wire hop — the same calibrated constants the DES charges, so
    graph/pallas reports are comparable like-for-like with the ``des``
    backend.  ``row_writes`` (= n*(n-1)) and ``peers`` (= n-1) carry the
    membership size into the fold so one shape-agnostic fold serves every
    subgroup of a padded stack.
    """
    n = len(spec.members)
    if n <= 1:
        return np.zeros(6)
    slot = spec.msg_size + 8
    host, net = cfg.host, cfg.net
    base = host.lock_us + 3 * host.predicate_eval_us + \
        (n - 1) * (net.post_us + net.serialization(_ROW_BYTES))
    return np.array([base,
                     (n - 1) * net.post_us,
                     (n - 1) * net.serialization(slot),
                     net.wire_latency(min(slot, 4096)),
                     n * (n - 1),
                     n - 1])


def _fold_cost(app_pub, cost):
    """The cost model as vectorized in-graph arithmetic over the (T, S)
    publish trace: (app_pub, cost coefficients) -> per-round time + RDMA
    writes arrays.  Shape-agnostic in the membership size (carried in the
    coefficients), so it vmaps over subgroup stacks and scenario grids."""
    # Busiest sender per round: serialization is linear in k, so the
    # max-k sender is the argmax of post + per_msg * k.
    kmax = jnp.max(app_pub, axis=1)                            # (T,)
    busiest = jnp.where(kmax > 0, cost[1] + cost[2] * kmax, 0.0)
    round_t = cost[0] + busiest + cost[3]                      # (T,)
    round_w = cost[4].astype(jnp.int32) + cost[5].astype(jnp.int32) * \
        jnp.sum((app_pub > 0).astype(jnp.int32), axis=1)       # (T,)
    return round_t, round_w


# Jitted once: _aggregate folds every stream's (G, T, S) trace through
# this on the host path, and an eager vmap would re-trace per call —
# measurably slower than the fold itself on serve-plane shapes.
_fold_cost_stacked = jax.jit(jax.vmap(_fold_cost))


def fold_cost_np(app_pub: np.ndarray, cost: np.ndarray) -> np.ndarray:
    """Host-side mirror of :func:`_fold_cost`'s time term over one
    subgroup's (T, S) publish trace -> (T,) per-round microseconds.
    Kept adjacent to the in-graph fold so the two cannot drift; the
    workload plane's latency accountant (DESIGN.md Sec. 10) uses it to
    convert round-granular latencies into the calibrated cost model's
    time units without re-entering jax."""
    app_pub = np.asarray(app_pub)
    kmax = app_pub.max(axis=1) if app_pub.size else \
        np.zeros(app_pub.shape[0])
    busiest = np.where(kmax > 0, cost[1] + cost[2] * kmax, 0.0)
    return cost[0] + busiest + cost[3]


def _kernel_receive(ring_window: int):
    """Receive-predicate override for the pallas backend: the fused
    watermark kernel sweeps every (member, sender) ring in one call,
    rebuilding the counter tile inside the kernel — nothing (N*S, W)-shaped
    is materialized in-graph per round.  ``ring_window`` is the static ring
    width (the max window across a stacked group / batched grid); a ring
    wider than a subgroup's protocol window is harmless — slots are only
    reused after W messages and the publish cap uses the per-subgroup
    window.  ``valid`` masks padded (member, sender) lanes of a stacked
    subgroup plane (None when unpadded)."""
    from repro.kernels import ops

    def receive(pub_vis, recv_counts, valid=None):
        n_m, n_s = pub_vis.shape
        flat_valid = None if valid is None else valid.reshape(n_m * n_s)
        visible = ops.smc_sweep_watermark(
            pub_vis.reshape(n_m * n_s), recv_counts.reshape(n_m * n_s),
            window=ring_window, valid=flat_valid)
        return jnp.maximum(
            recv_counts,
            visible.reshape(n_m, n_s).astype(recv_counts.dtype))

    return receive


def _stack_masks(members: Tuple[int, ...], senders: Tuple[int, ...]):
    """(G, N_max)/(G, S_max) suffix-padding validity masks — or
    ``(None, None)`` for a homogeneous stack (every subgroup fills the
    padded shape), which keeps the cheaper unmasked sweep arithmetic on
    the G=1 and equal-sized-topics hot paths."""
    n_max, s_max = max(members), max(senders)
    member_masks = np.arange(n_max)[None, :] < np.asarray(members)[:, None]
    sender_masks = np.arange(s_max)[None, :] < np.asarray(senders)[:, None]
    if member_masks.all() and sender_masks.all():
        return None, None
    return member_masks, sender_masks


@functools.lru_cache(maxsize=None)
def _scan_program(n_subgroups: int, n_max: int, s_max: int,
                  windows: Tuple[int, ...], masked: bool, null_send: bool,
                  backend: str):
    """Compile-once STACKED program for one whole-group scenario shape,
    cached on the PADDED stack shape ``(G, N_max, S_max)`` plus the
    per-subgroup windows and ``(null_send, backend)`` — the unit of
    compilation is the group, not the subgroup: all G subgroups execute
    as one fused program (:func:`sweep.run_stacked`), with the cost
    model folded in as vectorized in-graph arithmetic.

    The exact per-subgroup member/sender sizes are NOT in the key: when
    ``masked``, they enter as traced ``(G, N_max)``/``(G, S_max)``
    validity-mask inputs, so a view change that re-shapes subgroups
    inside an unchanged padded stack — a member fails in one subgroup
    while another still sets N_max — reuses the compiled program instead
    of re-stacking from scratch (DESIGN.md Sec. 7).  Repeated
    ``Group.run`` calls and benchmark sweeps reuse the jitted program
    instead of re-tracing it.  (jax additionally keys on the schedule
    shape, so a different round budget recompiles — same scenario, same
    program.)"""
    ring = max(windows) if backend == "pallas" else 0
    receive_fn = _kernel_receive(ring) if backend == "pallas" else None
    win_arr = np.asarray(windows, np.int32)

    def fn(scheds, costs, *masks):
        TRACE_EVENTS.append(((n_subgroups, n_max, s_max), windows,
                             backend))
        mm, sm = masks if masked else (None, None)
        states = sweep_mod.batch_states(n_max, s_max, n_subgroups)
        _, (batches, app_pub, nulls) = sweep_mod.run_stacked(
            states, scheds, windows=win_arr, null_send=null_send,
            member_masks=mm, sender_masks=sm,
            receive_fn=receive_fn)
        round_t, round_w = jax.vmap(_fold_cost)(app_pub, costs)
        return batches, app_pub, nulls, round_t, round_w

    return jax.jit(fn)


@functools.lru_cache(maxsize=None)
def _batch_program(members: Tuple[int, ...], senders: Tuple[int, ...],
                   ring_window: int, backend: str, n_shards: int):
    """Compile-once BATCHED stacked program: B grid points x G subgroups
    as one device-sharded compiled program.  Windows and null-send flags
    are per-point traced values; ``ring_window`` (the common SMC ring
    width, max of the grid) only matters to the pallas receive kernel (the
    graph backend passes 0 so one cache entry serves every grid).  When
    ``n_shards > 1`` the leading grid axis is shard_mapped across devices
    (:func:`repro.core.placement.shard_over_batch`); on a single device it
    degrades to the plain vmapped program."""
    receive_fn = _kernel_receive(ring_window) if backend == "pallas" \
        else None
    n_max, s_max = max(members), max(senders)
    member_masks, sender_masks = _stack_masks(members, senders)

    def fn(scheds, windows, null_sends, costs):
        TRACE_EVENTS.append((members, senders, backend))
        b = scheds.shape[0]
        states = jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x, (b,) + x.shape),
            sweep_mod.batch_states(n_max, s_max, len(members)))
        _, (batches, app_pub, nulls) = sweep_mod.run_stacked_batch(
            states, scheds, windows=windows, null_sends=null_sends,
            member_masks=member_masks, sender_masks=sender_masks,
            receive_fn=receive_fn)
        round_t, round_w = jax.vmap(jax.vmap(_fold_cost))(app_pub, costs)
        return batches, app_pub, nulls, round_t, round_w

    if n_shards > 1:
        fn = placement_mod.shard_over_batch(fn, n_shards, n_batched_args=4)
    return jax.jit(fn)


@functools.lru_cache(maxsize=None)
def _stream_program(n_subgroups: int, n_max: int, s_max: int,
                    windows: Tuple[int, ...], masked: bool,
                    null_send: bool, backend: str):
    """Compile-once STREAMING program: ONE protocol round for all G
    subgroups of a scenario shape, carrying (states, backlogs) across
    calls.  Same padded-shape static key and same masked stacking as
    :func:`_scan_program` — so a stream that survives a shape-preserving
    view change (:meth:`GroupStream.reconfigure`) keeps dispatching the
    SAME compiled program in the new epoch; the round arithmetic is the
    scan body itself (:func:`repro.core.sweep.step_backlog`), so T
    streamed rounds are bit-identical to one T-round scan fed the same
    ready rows.  A whole streamed session — however many rounds, across
    however many same-shape epochs — traces exactly once."""
    ring = max(windows) if backend == "pallas" else 0
    receive_fn = _kernel_receive(ring) if backend == "pallas" else None
    win_arr = np.asarray(windows, np.int32)

    def fn(states, backlogs, ready, *masks):
        TRACE_EVENTS.append(((n_subgroups, n_max, s_max), windows,
                             backend))
        mm, sm = masks if masked else (None, None)
        return sweep_mod.stream_stacked(
            states, backlogs, ready, windows=win_arr, null_send=null_send,
            member_masks=mm, sender_masks=sm,
            receive_fn=receive_fn)

    return jax.jit(fn)


# Programs that EMBED the stream round body inside a larger compiled
# loop (e.g. the fused serve plane: decode + multicast sweep + watermark
# gating scanned device-resident, repro.serve.fused).  Keyed by the
# caller's full static tuple — scenario shape AND whatever the fused
# body bakes in (model config, round budgets) — so a warm run is pure
# dispatch: same workload shape, same program, zero re-traces.  The
# builder appends its own TRACE_EVENTS entry when traced, exactly like
# _scan_program/_stream_program, so the bench's one-program assertions
# cover fused runs too.
_FUSED_PROGRAMS: Dict[Tuple, Any] = {}


def fused_stream_program(key: Tuple, build: Callable[[], Any]):
    """Compile-once cache for stream-composed fused programs.  ``key``
    must be a hashable static description of everything ``build()``'s
    program closes over; ``build`` is called once per key and must
    return the jitted program."""
    prog = _FUSED_PROGRAMS.get(key)
    if prog is None:
        prog = _FUSED_PROGRAMS[key] = build()
    return prog


@dataclasses.dataclass
class _GraphAgg:
    """Accumulates one run's subgroup post-processing into report inputs."""

    duration: float = 0.0
    writes: int = 0
    delivered_app: int = 0
    delivered_null: int = 0
    nulls_sent: int = 0
    rounds: int = 0
    stalled: bool = False
    latencies: List[float] = dataclasses.field(default_factory=list)
    per_node_bytes: Dict[int, float] = dataclasses.field(
        default_factory=dict)
    logs: Dict[int, DeliveryLog] = dataclasses.field(default_factory=dict)


class GraphBackend:
    """Runs the scenario through :func:`repro.core.sweep.run_stacked`
    under a cached jitted program (see :func:`_scan_program`) whose unit
    of compilation is the whole GROUP: all G subgroups, padded to a
    common (G, N_max, S_max) with validity masks, execute as one fused
    program with the cost model evaluated in-graph; delivery logs and
    latency round-pairs are then reconstructed per subgroup from the
    sliced per-round traces with vectorized numpy.  :meth:`run_batch`
    executes whole scenario grids as ONE compiled program, shard_mapped
    across devices when more than one is available."""

    name = "graph"

    @staticmethod
    def _rounds_for(cfg: GroupConfig, spec: sim.SubgroupSpec,
                    counts: np.ndarray) -> int:
        """Round budget: settle rounds for visibility/null drain, plus
        slack for ring-window throttling (a small window stretches
        publishing over ~3 extra rounds per window-full of backlog)."""
        if cfg.rounds is not None:
            return cfg.rounds
        max_c = int(counts.max()) if len(counts) else 0
        return max_c + 2 * len(spec.members) + 8 + \
            3 * (max_c // max(spec.window, 1))

    # -- stacking: one group scenario -> padded program inputs ---------------

    def _stack(self, cfg: GroupConfig, counts: Dict[int, np.ndarray]):
        """Lower one scenario to the stacked program's static key and
        padded inputs: per-subgroup shape tuples, round budgets, a
        (G, T_max, S_max) schedule stack and (G, 6) cost coefficients."""
        members = tuple(len(s.members) for s in cfg.subgroups)
        senders = tuple(len(s.senders) for s in cfg.subgroups)
        windows = tuple(s.window for s in cfg.subgroups)
        rounds = tuple(self._rounds_for(cfg, spec, counts[g])
                       for g, spec in enumerate(cfg.subgroups))
        t_max, s_max = max(rounds), max(senders)
        scheds = np.zeros((len(members), t_max, s_max), np.int32)
        for g in range(len(members)):
            scheds[g, :, : senders[g]] = _lower_schedule(counts[g], t_max)
        costs = np.stack([_cost_params(cfg, spec)
                          for spec in cfg.subgroups]).astype(np.float32)
        return members, senders, windows, rounds, scheds, costs

    def run(self, cfg: GroupConfig, counts: Dict[int, np.ndarray]
            ) -> Tuple[RunReport, Dict[int, DeliveryLog]]:
        agg = _GraphAgg()
        wall0 = time.perf_counter()
        if cfg.subgroups:
            members, senders, windows, rounds, scheds, costs = \
                self._stack(cfg, counts)
            member_masks, sender_masks = _stack_masks(members, senders)
            masked = member_masks is not None
            program = _scan_program(len(members), max(members),
                                    max(senders), windows, masked,
                                    cfg.flags.null_send, self.name)
            args = [jnp.asarray(scheds), jnp.asarray(costs)]
            if masked:
                args += [jnp.asarray(member_masks),
                         jnp.asarray(sender_masks)]
            outs = [np.asarray(o) for o in program(*args)]
            self._finalize(cfg, counts, outs, rounds, agg)
        return self._report(agg, wall0), agg.logs

    def run_batch(self, cfgs: List[GroupConfig],
                  counts_list: List[Dict[int, np.ndarray]]
                  ) -> List[Tuple[RunReport, Dict[int, DeliveryLog]]]:
        """Execute B scenario variants as ONE compiled stacked program —
        every grid point, every subgroup, one dispatch — sharded over
        ``jax.devices()`` when the batch divides across more than one
        (vmap on a single device).  All points must share membership
        shapes (n_members, n_senders per subgroup); schedules are padded
        to the common round budget and each point's traces sliced back to
        its own budget afterwards, so every point's results are identical
        to a sequential :meth:`run` of that point — the scan prefix
        depends only on the schedule prefix."""
        if not cfgs:
            return []
        base = cfgs[0]
        for i, cfg in enumerate(cfgs[1:], start=1):
            if len(cfg.subgroups) != len(base.subgroups):
                raise ValueError(
                    f"run_batch points must share membership shapes; grid "
                    f"point {i} has {len(cfg.subgroups)} subgroups, grid "
                    f"point 0 has {len(base.subgroups)}")
            for gid, (s0, si) in enumerate(zip(base.subgroups,
                                               cfg.subgroups)):
                if (len(si.members) != len(s0.members)
                        or len(si.senders) != len(s0.senders)):
                    raise ValueError(
                        "run_batch points must share membership shapes; "
                        f"subgroup {gid} at grid point {i} has "
                        f"{len(si.members)} members / {len(si.senders)} "
                        f"senders vs grid point 0's {len(s0.members)} / "
                        f"{len(s0.senders)}")
        b = len(cfgs)
        wall0 = time.perf_counter()
        stacks = [self._stack(cfg, counts_list[i])
                  for i, cfg in enumerate(cfgs)]
        members, senders = stacks[0][0], stacks[0][1]
        t_max = max(max(st[3]) for st in stacks)
        s_max = max(senders)
        scheds = np.zeros((b, len(members), t_max, s_max), np.int32)
        for i, st in enumerate(stacks):
            scheds[i, :, : st[4].shape[1]] = st[4]
        windows = np.asarray([st[2] for st in stacks], np.int32)  # (B, G)
        nulls_on = np.asarray([cfg.flags.null_send for cfg in cfgs])
        costs = np.stack([st[5] for st in stacks])                # (B, G, 6)
        ring = int(windows.max()) if self.name == "pallas" else 0
        n_shards = placement_mod.shard_count(b)
        program = _batch_program(members, senders, ring, self.name,
                                 n_shards)
        outs = [np.asarray(o) for o in program(
            jnp.asarray(scheds), jnp.asarray(windows),
            jnp.asarray(nulls_on), jnp.asarray(costs))]
        results = []
        for i in range(b):
            agg = _GraphAgg()
            self._finalize(cfgs[i], counts_list[i],
                           [o[i] for o in outs], stacks[i][3], agg)
            # one wall clock covers the whole grid — stamp it under a
            # batch key so nobody mistakes it for a per-point cost
            results.append((self._report(agg, wall0,
                                         wall_key="batch_wall_s"),
                            agg.logs))
        return results

    # -- host-side post-processing -------------------------------------------

    def _finalize(self, cfg: GroupConfig, counts: Dict[int, np.ndarray],
                  outs: List[np.ndarray], rounds: Tuple[int, ...],
                  agg: _GraphAgg) -> None:
        """Slice one run's stacked (G, T_max, ...) traces back to each
        subgroup's own round budget and real membership, reconstruct the
        delivery logs, apply the target-delivered measurement window, and
        accumulate report inputs."""
        parts = []
        for gid, spec in enumerate(cfg.subgroups):
            n_g, s_g, t_g = len(spec.members), len(spec.senders), rounds[gid]
            point = [outs[0][gid, :t_g, :n_g], outs[1][gid, :t_g, :s_g],
                     outs[2][gid, :t_g, :s_g], outs[3][gid, :t_g],
                     outs[4][gid, :t_g]]
            log, lat = self._reconstruct(spec, point[0], point[1], point[2])
            parts.append((gid, spec, point, log, lat))
        cross_target = (cfg.target_delivered is not None
                        and len(cfg.subgroups) > 1)
        if cfg.target_delivered is not None:
            if cross_target:
                _clip_target_stacked(cfg, parts)
            else:
                parts[0][3].truncate_to_app_target(cfg.target_delivered)
        for gid, spec, point, log, lat in parts:
            self._account(cfg, spec, gid, counts[gid], rounds[gid], point,
                          log, lat, agg,
                          per_subgroup_stall=not cross_target)
        if cross_target:
            agg.stalled = agg.stalled or _stalled_across_subgroups(
                cfg, counts, agg.logs)

    def _account(self, cfg: GroupConfig, spec: sim.SubgroupSpec,
                 gid: int, c: np.ndarray, rounds: int,
                 arrays: List[np.ndarray], log: DeliveryLog,
                 lat_pairs: np.ndarray, agg: _GraphAgg, *,
                 per_subgroup_stall: bool = True) -> None:
        """Accumulate one subgroup's post-processed traces into the
        report inputs."""
        batches, app_pub, nulls, round_t, round_w = arrays
        agg.logs[gid] = log
        agg.rounds += rounds
        agg.nulls_sent += int(nulls.sum())
        agg.writes += int(round_w.astype(np.int64).sum())
        end_time = np.cumsum(round_t.astype(np.float64))
        if rounds:
            agg.duration = max(agg.duration, float(end_time[-1]))
        if len(lat_pairs):
            pr, dr = lat_pairs[:, 0], lat_pairs[:, 1]
            start = np.where(pr > 0, end_time[np.maximum(pr - 1, 0)], 0.0)
            agg.latencies.extend((end_time[dr] - start).tolist())
        for node in spec.members:
            a, nl = log.app_null_counts(node)
            agg.delivered_app += a
            agg.delivered_null += nl
            agg.per_node_bytes[node] = \
                agg.per_node_bytes.get(node, 0.0) + a * spec.msg_size
        if per_subgroup_stall:
            total_app = int(c.sum())
            need = total_app if cfg.target_delivered is None else \
                min(cfg.target_delivered, total_app)
            if any(log.app_null_counts(node)[0] < need
                   for node in spec.members):
                agg.stalled = True

    def _report(self, agg: _GraphAgg, wall0: float,
                wall_key: str = "wall_s") -> RunReport:
        per_node = [b / agg.duration / 1e3
                    for b in agg.per_node_bytes.values()
                    if agg.duration > 0 and b > 0]
        lat = np.array(agg.latencies) if agg.latencies else np.array([0.0])
        return RunReport(
            backend=self.name,
            throughput_GBps=float(np.mean(per_node)) if per_node else 0.0,
            mean_latency_us=float(lat.mean()),
            p99_latency_us=float(np.percentile(lat, 99)),
            duration_us=agg.duration,
            delivered_app_msgs=agg.delivered_app,
            delivered_null_msgs=agg.delivered_null,
            nulls_sent=agg.nulls_sent,
            rdma_writes=agg.writes,
            rounds=agg.rounds,
            per_node_throughput=per_node,
            stalled=agg.stalled,
            extras={wall_key: time.perf_counter() - wall0},
        )

    @staticmethod
    def _reconstruct(spec: sim.SubgroupSpec, batches: np.ndarray,
                     app_pub: np.ndarray, nulls: np.ndarray):
        """Rebuild the per-sender nullness log and (publish_round,
        delivery_round) latency samples from the per-round trace, fully
        vectorized (``repeat``/``cumsum``/``searchsorted`` — no
        per-message Python loop).  Within a round a sender publishes its
        app messages before its nulls (matching :func:`sweep.sweep`'s
        ``published + app_pub + nulls``).  Returns the log plus a (K, 2)
        int array of latency round-pairs sampled at member position 0
        (as the DES does)."""
        n_s = len(spec.senders)
        rounds = batches.shape[0]
        is_app: List[np.ndarray] = []
        pub_round: List[np.ndarray] = []
        for s in range(n_s):
            a = app_pub[:, s].astype(np.int64)
            total = a + nulls[:, s].astype(np.int64)
            rnd = np.repeat(np.arange(rounds), total)
            start = np.cumsum(total) - total          # exclusive prefix
            offset = np.arange(total.sum()) - np.repeat(start, total)
            is_app.append(offset < np.repeat(a, total))
            pub_round.append(rnd)
        delivered_num = np.cumsum(batches, axis=0) - 1   # (T, N)
        final = delivered_num[-1] if rounds else \
            np.full(len(spec.members), -1)
        delivered = {node: int(final[pos])
                     for pos, node in enumerate(spec.members)}
        lat = np.zeros((0, 2), np.int64)
        if rounds and int(final[0]) >= 0:
            col = delivered_num[:, 0]
            seqs = np.arange(int(final[0]) + 1)
            ranks, idxs = seqs % n_s, seqs // n_s
            maxlen = max(len(x) for x in is_app)
            flags = np.zeros((n_s, maxlen), bool)
            rnds = np.zeros((n_s, maxlen), np.int64)
            for s in range(n_s):
                flags[s, : len(is_app[s])] = is_app[s]
                rnds[s, : len(pub_round[s])] = pub_round[s]
            m = flags[ranks, idxs]
            lat = np.stack([rnds[ranks[m], idxs[m]],
                            np.searchsorted(col, seqs[m])], axis=1)
        log = DeliveryLog(n_senders=n_s, is_app=is_app,
                          delivered_seq=delivered)
        return log, lat


def _clip_target_stacked(cfg: GroupConfig, parts) -> None:
    """Apply the ``target_delivered`` measurement window to a
    multi-subgroup stacked run.

    The stacked program executes every subgroup on ONE shared round
    timeline, so — like the DES's per-member aggregate across subgroups
    (``Simulator._done``) — the window is cross-subgroup: for each member,
    find the earliest shared round at which its app deliveries summed over
    its subgroups reach the target, clip each subgroup's delivered prefix
    for that member to its value at that round, then clip within-subgroup
    overshoot at the target exactly as the des backend does.  The des
    backend stops on simulated time (whole batches late, per-subgroup
    interleaving timing-dependent), so cross-backend conformance here is
    prefix-consistency of each subgroup's total order plus the target
    guarantee — not bit-identical cut points (those are only guaranteed
    between graph/pallas runs and against sequential stacked runs)."""
    target = cfg.target_delivered
    per_member: Dict[int, List[Tuple[DeliveryLog, int, np.ndarray,
                                     np.ndarray]]] = {}
    for gid, spec, point, log, lat in parts:
        batches = point[0]
        if not len(batches):
            continue
        delivered_num = np.cumsum(batches.astype(np.int64), axis=0) - 1
        hi = int(delivered_num.max(initial=-1))
        # app_cum[k] = app messages among the first k seqs of the order
        app_cum = np.concatenate(
            [[0], np.cumsum(log.app_flags_upto(hi))]).astype(np.int64)
        for pos, node in enumerate(spec.members):
            col = delivered_num[:, pos]                       # (t_g,)
            apps = app_cum[col + 1]         # apps delivered by round r
            per_member.setdefault(node, []).append((log, node, col, apps))
    for node, entries in per_member.items():
        t_shared = max(len(col) for _, _, col, _ in entries)
        total = np.zeros(t_shared, np.int64)
        for _, _, col, apps in entries:
            pad = t_shared - len(apps)
            total += np.concatenate(
                [apps, np.full(pad, apps[-1] if len(apps) else 0)])
        hit = np.nonzero(total >= target)[0]
        if not len(hit):
            continue                     # target never reached: keep all
        cut = int(hit[0])
        for log, node_, col, _ in entries:
            log.delivered_seq[node_] = int(col[min(cut, len(col) - 1)])
    for gid, spec, point, log, lat in parts:
        log.truncate_to_app_target(target)


def _stalled_across_subgroups(cfg: GroupConfig,
                              counts: Dict[int, np.ndarray],
                              logs: Mapping[int, DeliveryLog]) -> bool:
    """Multi-subgroup target_delivered stall check: a member stalls when
    its app deliveries summed over its subgroups fall short of the target
    (capped by what its subgroups could supply at all)."""
    delivered: Dict[int, int] = {}
    avail: Dict[int, int] = {}
    for gid, spec in enumerate(cfg.subgroups):
        total_app = int(counts[gid].sum())
        for node in spec.members:
            delivered[node] = delivered.get(node, 0) + \
                logs[gid].app_null_counts(node)[0]
            avail[node] = avail.get(node, 0) + total_app
    return any(delivered[node] < min(cfg.target_delivered, avail[node])
               for node in delivered)


class PallasBackend(GraphBackend):
    """The graph protocol with the receive predicate evaluated by the
    fused Pallas SMC-sweep kernel — the structural analogue of keeping the
    SMC polling area cache-resident.  The kernel consumes per-sender
    published watermarks and rebuilds the slot-counter tile inside the
    kernel (:func:`repro.kernels.smc_sweep.smc_sweep_watermark_pallas`),
    so the hot loop no longer materializes the (N*S, W) ring in-graph
    every round; it compiles to Mosaic on TPU and interprets elsewhere.
    In a stacked multi-subgroup program the kernel sweeps the padded
    (member, sender) plane of every subgroup with an explicit lane
    validity mask.  The receive closure is installed by
    :func:`_kernel_receive` via the cached scan programs."""

    name = "pallas"


class DESBackend(GraphBackend):
    """The two-phase DES (DESIGN.md Sec. 12) — the default ``des`` path.

    Scheduled runs execute phase 1 (:func:`repro.core.desgraph.simulate`,
    the slimmed event-level pass emitting the compact event graph) then
    phase 2 (:func:`repro.core.desreplay.replay`, the vectorized
    reconstruction), bit-identical to the legacy ``des-loop`` — that
    split is what makes 256–4096-node fleets conformance-testable.

    Streaming (:class:`GroupStream`) runs on the numpy round mirror
    (``stream_numpy``): the same :func:`repro.core.sweep.step_backlog`
    arithmetic evaluated host-side in int32, driven through the exact
    GraphBackend trim/carry/log machinery inherited here — so streamed
    des rounds, cut epochs and :class:`EpochCarry` contents are
    bit-identical to graph/pallas streams fed the same ready rows, not
    merely order-invariant.
    """

    name = "des"
    # GroupStream: dispatch rounds to the numpy mirror, not a jitted
    # program (repro.core.desreplay.stream_program_np)
    stream_numpy = True

    def run(self, cfg: GroupConfig, counts: Dict[int, np.ndarray]
            ) -> Tuple[RunReport, Dict[int, DeliveryLog]]:
        sim_cfg = DESLoopBackend._lower(cfg, counts)
        graph = desgraph_mod.simulate(sim_cfg)
        result = desreplay_mod.replay(graph)
        return _des_report(self.name, cfg, result, graph.groups)

    def run_batch(self, cfgs: List[GroupConfig],
                  counts_list: List[Dict[int, np.ndarray]]
                  ) -> List[Tuple[RunReport, Dict[int, DeliveryLog]]]:
        """Sequential per-point runs (the DES has no batched program);
        overrides the inherited compiled grid so grids stay comparable
        point-for-point with the other backends."""
        return [self.run(c, k) for c, k in zip(cfgs, counts_list)]


# ---------------------------------------------------------------------------
# Streaming execution — per-round message counts on the stacked substrate
# ---------------------------------------------------------------------------


def _trees_equal(a, b) -> bool:
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(jax.tree_util.tree_leaves(a),
                               jax.tree_util.tree_leaves(b)))


@dataclasses.dataclass(frozen=True)
class StreamView:
    """Host-side watermark snapshot after one streamed round.

    ``delivered_num[g, m]`` is member position ``m``'s highest delivered
    total-order seq in subgroup ``g``; ``published[g, s]`` sender rank
    ``s``'s total publishes (apps + nulls); ``backlog[g, s]`` its
    window-throttled still-queued app messages.  Padded lanes beyond a
    subgroup's real ``n_members``/``n_senders`` carry garbage — always
    slice with the per-subgroup sizes (as the helpers here do).
    """

    round: int
    delivered_num: np.ndarray            # (G, N_max)
    published: np.ndarray                # (G, S_max)
    backlog: np.ndarray                  # (G, S_max)
    n_members: Tuple[int, ...]
    n_senders: Tuple[int, ...]
    # the round's publish trace (None on a bare GroupStream.view() —
    # only a step() carries what it just published)
    app_pub: Optional[np.ndarray] = None     # (G, S_max)
    nulls: Optional[np.ndarray] = None       # (G, S_max)

    def sender_delivered(self, gid: int) -> np.ndarray:
        """(S_g,) — how many of each sender rank's publishes (apps and
        nulls) EVERY real member of subgroup ``gid`` has delivered: the
        per-sender delivery watermark (seq ``i*S + s`` delivered means
        sender ``s``'s first ``i+1`` publishes are)."""
        n_g, s_g = self.n_members[gid], self.n_senders[gid]
        d = int(self.delivered_num[gid, :n_g].min())
        ranks = np.arange(s_g)
        return np.where(d >= ranks, (d - ranks) // s_g + 1, 0)

    def sender_drained(self, gid: int) -> np.ndarray:
        """(S_g,) bool — sender rank has no queued backlog and every one
        of its publishes so far is delivered at every member of ``gid``
        (the slot-free condition of the serve plane)."""
        s_g = self.n_senders[gid]
        return ((self.backlog[gid, :s_g] == 0)
                & (self.sender_delivered(gid)
                   >= self.published[gid, :s_g]))


class GroupStream:
    """Streaming execution of one :class:`Group` scenario.

    Where :meth:`Group.run` lowers a fixed per-sender message count to a
    schedule upfront, a stream accepts the (G, S_max) app-message counts
    of each round as they happen — the entry point for workloads whose
    send pattern only exists at runtime (the serve plane's decode loop,
    DESIGN.md Sec. 6).  Every :meth:`step` sweeps ALL subgroups as the
    same ONE stacked compiled program (cached per scenario shape in
    :func:`_stream_program`; the first round traces, every later round is
    pure dispatch — a whole session appends exactly one
    :data:`TRACE_EVENTS` entry) and returns the :class:`StreamView`
    watermarks the caller can gate on.  :meth:`finish` drains to
    quiescence and post-processes the accumulated round traces through
    the exact :class:`GraphBackend` machinery scheduled runs use, so the
    resulting :class:`RunReport` and delivery logs are comparable
    like-for-like with ``run``/``run_batch`` (graph and pallas streams
    fed identical rounds are bit-identical)."""

    def __init__(self, group: Group, backend="graph"):
        be = get_backend(backend)
        if not isinstance(be, GraphBackend):
            raise ValueError(
                "streaming runs on the stacked graph/pallas/des "
                f"substrate; got {getattr(be, 'name', backend)!r}")
        cfg = group.cfg
        if not cfg.subgroups:
            raise ValueError("no subgroups")
        self.group = group
        self.backend = be
        # des streams round on the host-side numpy mirror of the same
        # int32 sweep arithmetic (DESIGN.md Sec. 12) — bit-identical
        # rounds, no compiled program
        self._numpy = bool(getattr(be, "stream_numpy", False))
        self._n = tuple(len(s.members) for s in cfg.subgroups)
        self._s = tuple(len(s.senders) for s in cfg.subgroups)
        self._w = tuple(s.window for s in cfg.subgroups)
        self.n_max, self.s_max = max(self._n), max(self._s)
        member_masks, sender_masks = _stack_masks(self._n, self._s)
        if self._numpy:
            self._mask_args: Tuple = () if member_masks is None else (
                np.asarray(member_masks), np.asarray(sender_masks))
            self._program = desreplay_mod.stream_program_np(
                self._w, cfg.flags.null_send)
            self._states = desreplay_mod.batch_states_np(
                self.n_max, self.s_max, len(self._n))
            self._backlogs = np.zeros((len(self._n), self.s_max),
                                      np.int32)
        else:
            self._mask_args = () if member_masks is None else (
                jnp.asarray(member_masks), jnp.asarray(sender_masks))
            self._program = _stream_program(len(self._n), self.n_max,
                                            self.s_max, self._w,
                                            bool(self._mask_args),
                                            cfg.flags.null_send, be.name)
            self._states = sweep_mod.batch_states(self.n_max, self.s_max,
                                                  len(self._n))
            self._backlogs = jnp.zeros((len(self._n), self.s_max),
                                       jnp.int32)
        self._costs = np.stack([_cost_params(cfg, spec)
                                for spec in cfg.subgroups]).astype(
                                    np.float32)
        self._enqueued = [np.zeros(s, np.int64) for s in self._s]
        # virtual-synchrony epoch carry (DESIGN.md Sec. 7): the previous
        # epoch's resend set starts out as this epoch's backlog — the
        # undelivered tail re-publishes ahead of new traffic, per-sender
        # FIFO intact — and counts as enqueued here (it must deliver in
        # THIS view).
        self.carry = group.carry
        self.closed = False
        if self.carry is not None:
            backlogs0 = np.zeros((len(self._n), self.s_max), np.int32)
            for g, resent in enumerate(self.carry.resend):
                backlogs0[g, : len(resent)] = resent
                self._enqueued[g] += resent.astype(np.int64)
            self._backlogs = (backlogs0 if self._numpy
                              else jnp.asarray(backlogs0))
        # running per-sender publish totals, kept host-side so watermark
        # queries (app_publish_index) answer the common "not published
        # yet" case in O(1) instead of re-scanning the round traces
        self._app_cum = np.zeros((len(self._n), self.s_max), np.int64)
        self._pub_cum = np.zeros((len(self._n), self.s_max), np.int64)
        self._batches: List[np.ndarray] = []
        self._app_pub: List[np.ndarray] = []
        self._nulls: List[np.ndarray] = []
        self._wall0 = time.perf_counter()
        self.rounds = 0

    @property
    def shape(self) -> Tuple[int, int]:
        """(G, S_max) — what :meth:`step` expects."""
        return len(self._n), self.s_max

    @property
    def n_members(self) -> Tuple[int, ...]:
        """Per-subgroup real member counts (lanes beyond are padding)."""
        return self._n

    @property
    def n_senders(self) -> Tuple[int, ...]:
        """Per-subgroup real sender counts (lanes beyond are padding)."""
        return self._s

    @property
    def windows(self) -> Tuple[int, ...]:
        """Per-subgroup SMC window (the backpressure bound an admission
        policy throttles against — DESIGN.md Sec. 10)."""
        return self._w

    @property
    def cost_params(self) -> np.ndarray:
        """(G, 6) cost-model coefficients (see :func:`_cost_params`),
        consumable by :func:`fold_cost_np` for host-side time folds."""
        return self._costs.copy()

    def traces(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """The accumulated round traces, stacked: ``(batches (G, T, N),
        app_pub (G, T, S), nulls (G, T, S))`` for the T rounds streamed
        so far.  This is the raw material of per-message latency
        reconstruction (delivery watermark per round x per-sender publish
        trace — DESIGN.md Sec. 10); empty T=0 arrays before any step."""
        g, s = self.shape
        if not self.rounds:
            z = np.zeros((g, 0, self.n_max), np.int64)
            return z, np.zeros((g, 0, s), np.int64), \
                np.zeros((g, 0, s), np.int64)
        return (np.stack(self._batches, axis=1),
                np.stack(self._app_pub, axis=1),
                np.stack(self._nulls, axis=1))

    def absorb(self, states, backlogs, batches, app_pub, nulls,
               enqueued) -> None:
        """Install round traces that were executed OUTSIDE this stream —
        inside one fused compiled program that embedded the stream round
        body (:func:`repro.core.sweep.step_backlog` via
        :func:`fused_stream_program`; the fused serve plane,
        DESIGN.md Sec. 6) — as if :meth:`step` had streamed them.

        ``states``/``backlogs`` are the post-run carry (same stacked
        layout :meth:`step` maintains); ``batches``/``app_pub``/``nulls``
        the per-round traces as ``(T, G, ...)`` arrays or length-T lists
        of per-round ``(G, ...)`` rows; ``enqueued`` the per-subgroup
        per-rank app totals the rounds enqueued.  After absorbing,
        :meth:`finish` post-processes through the exact
        :class:`GraphBackend` machinery — a fused run's report and
        delivery logs are the per-round dispatch loop's by construction.
        Only valid on a stream with no rounds streamed yet; an epoch
        CARRY is fine — the wedge-capable fused serve plane absorbs
        each post-cut epoch into the reconfigured stream, whose
        carry-seeded backlog/enqueued state the fused program took as
        its initial operands (``enqueued`` must then count only the
        absorbed rounds' events, which add onto the carry seed)."""
        if self.rounds or self.closed:
            raise RuntimeError("absorb needs a stream with no rounds "
                               "streamed (fresh or carry-seeded)")
        g, s_max = self.shape
        batches = [np.asarray(b, np.int64) for b in batches]
        app_pub = [np.asarray(p, np.int64) for p in app_pub]
        nulls = [np.asarray(x, np.int64) for x in nulls]
        if len(batches) != len(app_pub) or len(batches) != len(nulls):
            raise ValueError("trace lengths disagree")
        for b, p, x in zip(batches, app_pub, nulls):
            if b.shape != (g, self.n_max) or p.shape != (g, s_max) \
                    or x.shape != (g, s_max):
                raise ValueError("trace rows must be (G, N_max)/"
                                 "(G, S_max) shaped")
        if self._numpy:
            self._states = jax.tree_util.tree_map(
                lambda x: np.asarray(x, np.int32), states)
            self._backlogs = np.asarray(backlogs, np.int32)
        else:
            self._states = jax.tree_util.tree_map(jnp.asarray, states)
            self._backlogs = jnp.asarray(backlogs, jnp.int32)
        self._batches, self._app_pub, self._nulls = batches, app_pub, \
            nulls
        for p, x in zip(app_pub, nulls):
            self._app_cum += p
            self._pub_cum += p + x
        for gid, s_g in enumerate(self._s):
            self._enqueued[gid] += np.asarray(enqueued[gid],
                                              np.int64)[:s_g]
        self.rounds = len(batches)

    def step(self, ready) -> StreamView:
        """One protocol round: ``ready[g, s]`` app messages become ready
        at sender rank ``s`` of subgroup ``g`` (padded lanes must be 0).
        Window-throttled messages are carried in the backlog, exactly as
        the scheduled scan does."""
        if self.closed:
            raise RuntimeError(
                "stream closed by a view change; continue on the stream "
                "reconfigure() returned")
        ready = np.asarray(ready, np.int32)
        if ready.shape != self.shape:
            raise ValueError(f"ready must be {self.shape}, got "
                             f"{ready.shape}")
        for g, s_g in enumerate(self._s):
            if ready[g, s_g:].any():
                raise ValueError(
                    f"subgroup {g} has {s_g} senders but ready names "
                    f"padded lanes {np.nonzero(ready[g, s_g:])[0] + s_g}")
            self._enqueued[g] += ready[g, :s_g].astype(np.int64)
        (self._states, self._backlogs), (batch, pub, nulls) = \
            self._program(self._states, self._backlogs,
                          ready if self._numpy else jnp.asarray(ready),
                          *self._mask_args)
        pub, nulls = np.asarray(pub), np.asarray(nulls)
        self._batches.append(np.asarray(batch))
        self._app_pub.append(pub)
        self._nulls.append(nulls)
        self._app_cum += pub
        self._pub_cum += pub + nulls
        self.rounds += 1
        return dataclasses.replace(self.view(), app_pub=pub, nulls=nulls)

    def view(self) -> StreamView:
        return StreamView(
            round=self.rounds,
            delivered_num=np.asarray(self._states.delivered_num),
            published=np.asarray(self._states.published),
            backlog=np.asarray(self._backlogs),
            n_members=self._n, n_senders=self._s)

    def app_publish_index(self, gid: int, rank: int,
                          k: int) -> Optional[int]:
        """Publish index (0-based, counting apps AND nulls) of sender
        ``rank``'s ``k``-th app publish (1-based) in subgroup ``gid``,
        from the accumulated round traces — or None if fewer than ``k``
        apps have been published yet.  The serve fan-out pins its
        slot-release watermarks on this (apps precede nulls within a
        round, matching the sweep's ``published + app_pub + nulls``).

        The common "still window-throttled" answer is O(1) (running
        totals); the trace scan runs only once a hold's k-th app has
        actually published — once per query target, not per round."""
        if k <= 0 or self._app_cum[gid, rank] < k:
            return None
        apps = np.asarray([r[gid, rank] for r in self._app_pub], np.int64)
        nulls = np.asarray([r[gid, rank] for r in self._nulls], np.int64)
        app_cum = np.cumsum(apps)
        r = int(np.searchsorted(app_cum, k))
        pub_before = int(np.cumsum(apps + nulls)[r] - apps[r] - nulls[r])
        return pub_before + int(k - (app_cum[r] - apps[r])) - 1

    def quiescent(self, view: Optional[StreamView] = None) -> bool:
        """No backlog anywhere and every PUBLISHED message delivered by
        every real member.

        Stricter than "the round-robin prefix is delivered": a sender
        whose last window-throttled app publishes just as delivery
        catches up sits beyond the rr prefix for a round or two until
        the null-send scheme covers the lagging ranks — the prefix test
        would call that quiescent and strand the message (the
        virtual-synchrony resend tests caught exactly this timing).
        With null-send on, an undelivered published message always makes
        progress, so requiring ``delivered >= every sender's last
        published seq`` still terminates; with null-send off it may
        never hold, which the :meth:`finish` fixed-point exit handles."""
        v = self.view() if view is None else view
        for g, (n_g, s_g) in enumerate(zip(self._n, self._s)):
            if v.backlog[g, :s_g].any():
                return False
            counts = v.published[g, :s_g].astype(np.int64)
            if not counts.any():
                continue
            ranks = np.arange(s_g)
            last_seq = (counts - 1) * s_g + ranks
            need = int(last_seq[counts > 0].max())
            if (v.delivered_num[g, :n_g] < need).any():
                return False
        return True

    def finish(self, settle_max: Optional[int] = None
               ) -> Tuple[RunReport, Dict[int, DeliveryLog]]:
        """Drain with zero-ready rounds until quiescent, then reconstruct
        delivery logs and the unified report from the accumulated traces.
        Also installs the logs on the owning Group and fires its delivery
        upcalls, mirroring :meth:`Group.run`.

        The drain is not a fixed budget: a window-throttled backlog of B
        messages needs ~3·B/window rounds, so the loop instead runs until
        quiescence or a protocol FIXED POINT (a zero-ready round that
        changes nothing can never be followed by one that does — every
        predicate is monotone in the state).  The fixed-point exit covers
        scenarios that can never quiesce, e.g. ``null_send=False`` with
        uneven sender counts.  ``settle_max`` optionally caps the drain
        (the capped-off remainder reports as ``stalled``)."""
        if self.closed:
            raise RuntimeError(
                "stream closed by a view change; finish the stream "
                "reconfigure() returned")
        zeros = np.zeros(self.shape, np.int32)
        settled = 0
        while not self.quiescent():
            if settle_max is not None and settled >= settle_max:
                break
            prev_states, prev_backlogs = self._states, self._backlogs
            self.step(zeros)
            settled += 1
            if settle_max is None and _trees_equal(
                    (prev_states, prev_backlogs),
                    (self._states, self._backlogs)):
                break                        # fixed point: done evolving
        agg = self._aggregate()
        if self.rounds and np.asarray(self._backlogs).any():
            agg.stalled = True                # gave up with work queued
        report = self.backend._report(agg, self._wall0)
        report.extras["streamed_rounds"] = self.rounds
        self.group.delivery_logs = agg.logs
        self.group.last_report = report
        self.group._fire_upcalls()
        return report, agg.logs

    def _aggregate(self, app_pub=None, nulls=None) -> _GraphAgg:
        """Run the accumulated round traces through the exact
        :class:`GraphBackend` post-processing a scheduled run uses.
        ``app_pub``/``nulls`` accept already-stacked (G, T, S) traces so
        the cut path, which needs them for the stable-apps computation
        anyway, does not stack them twice."""
        agg = _GraphAgg()
        if self.rounds:
            batches = np.stack(self._batches, axis=1)       # (G, T, N)
            if app_pub is None:
                app_pub = np.stack(self._app_pub, axis=1)   # (G, T, S)
            if nulls is None:
                nulls = np.stack(self._nulls, axis=1)
            round_t, round_w = _fold_cost_stacked(
                jnp.asarray(app_pub), jnp.asarray(self._costs))
            outs = [batches, app_pub, nulls,
                    np.asarray(round_t), np.asarray(round_w)]
            counts = {g: self._enqueued[g] for g in range(len(self._s))}
            self.backend._finalize(self.group.cfg, counts, outs,
                                   (self.rounds,) * len(self._n), agg)
        return agg

    # -- the virtual-synchrony cut (view changes mid-stream) -----------------

    def reconfigure(self, view: "views_mod.View") -> "GroupStream":
        """Close this epoch at the virtual-synchrony cut and hand its
        in-flight state to a new stream for ``view`` (DESIGN.md Sec. 7).

        Wedge semantics: no settle rounds run — the cut is taken from the
        SST watermarks exactly as they stand, like a real wedge that
        cannot wait out a failed node.  Per subgroup the ragged trim is
        the highest seq received by every SURVIVING member
        (:func:`repro.core.sst.ragged_trim`); every surviving member's
        delivery advances exactly TO the trim, so the closing epoch's
        log is identical at every survivor (*everywhere* — and nobody
        rolls back, because a member's delivered watermark is a min over
        its stale view of the same monotone column), while everything
        beyond the trim is delivered *nowhere*.  Undelivered app
        messages of surviving senders — published-but-unstable plus the
        window-throttled backlog — become the new stream's initial
        backlog: the FIFO tail, resent in the new view.  A failed
        sender's unstable messages die with it.

        The closing epoch's cut-clipped logs and report are installed on
        the owning Group and its upcalls fire, mirroring :meth:`finish`
        (the report carries ``extras["view_change"]``).  The returned
        stream belongs to ``self.group.reconfigure(view)`` and carries
        an :class:`EpochCarry`; when the padded stack shape survives the
        change it keeps dispatching the SAME cached one-round program —
        a view change is a watermark hand-off, not a fresh-epoch
        restart."""
        if self.closed:
            raise RuntimeError("stream already closed by a view change")
        cfg = self.group.cfg
        alive = set(view.members)
        new_group = self.group.reconfigure(view)
        gid_map, sender_maps = new_group._gid_map, new_group._sender_maps
        received = np.asarray(self._states.received_num)    # (G, N_max)
        t = self.rounds
        app_pub = (np.stack(self._app_pub, axis=1) if t else
                   np.zeros((len(self._n), 0, self.s_max), np.int64))
        nulls = (np.stack(self._nulls, axis=1) if t else
                 np.zeros((len(self._n), 0, self.s_max), np.int64))
        cut_seqs: Dict[int, int] = {}
        stable: Dict[int, np.ndarray] = {}
        for gid, spec in enumerate(cfg.subgroups):
            n_g, s_g = self._n[gid], self._s[gid]
            alive_pos = np.asarray([m in alive for m in spec.members])
            cut = sst.ragged_trim(received[gid, :n_g], alive_pos)
            pubs_at_cut = sst.sender_counts(np.asarray(cut + 1), s_g)
            stable[gid] = np.asarray(
                [delivery_mod.apps_in_publish_prefix(
                    app_pub[gid, :, s], nulls[gid, :, s],
                    int(pubs_at_cut[s])) for s in range(s_g)], np.int64)
            cut_seqs[gid] = cut
        resend_t, stable_t, base_t, cut_t = [], [], [], []
        for old_gid in sorted(gid_map):
            new_gid = gid_map[old_gid]
            s_new = len(new_group.cfg.subgroups[new_gid].senders)
            resend = np.zeros(s_new, np.int64)
            stb = np.zeros(s_new, np.int64)
            base = np.zeros(s_new, np.int64)
            for old_rank, new_rank in sender_maps[old_gid]:
                stb[new_rank] = stable[old_gid][old_rank]
                resend[new_rank] = (self._enqueued[old_gid][old_rank]
                                    - stb[new_rank])
                prev = (int(self.carry.app_base[old_gid][old_rank])
                        if self.carry is not None else 0)
                base[new_rank] = prev + stb[new_rank]
            resend_t.append(resend)
            stable_t.append(stb)
            base_t.append(base)
            cut_t.append(cut_seqs[old_gid])
        new_group.carry = EpochCarry(
            from_epoch=cfg.epoch, cut_seq=tuple(cut_t),
            resend=tuple(resend_t), stable_apps=tuple(stable_t),
            app_base=tuple(base_t))
        self._close_at_cut(cut_seqs, alive, new_group.carry,
                           app_pub, nulls, stable)
        return new_group.stream(backend=self.backend.name)

    def _close_at_cut(self, cut_seqs: Dict[int, int], alive,
                      carry: EpochCarry, app_pub, nulls,
                      stable_by_old_rank: Dict[int, np.ndarray]) -> None:
        """Finalize the closing epoch's logs/report with every surviving
        member's delivery advanced to the ragged trim."""
        cfg = self.group.cfg
        agg = self._aggregate(app_pub, nulls)
        for gid, spec in enumerate(cfg.subgroups):
            log = agg.logs.get(gid)
            if log is None:
                continue
            for node in spec.members:
                if node in alive:
                    log.delivered_seq[node] = cut_seqs[gid]
        # re-derive the log-dependent accounting after the cut advance
        # (the in-protocol numbers were computed from the pre-wedge
        # watermarks; latency samples keep their in-protocol rounds —
        # cut-advanced deliveries have no delivery round to sample)
        agg.delivered_app = agg.delivered_null = 0
        agg.per_node_bytes = {}
        for gid, spec in enumerate(cfg.subgroups):
            log = agg.logs.get(gid)
            if log is None:
                continue
            for node in spec.members:
                n_app, n_null = log.app_null_counts(node)
                agg.delivered_app += n_app
                agg.delivered_null += n_null
                agg.per_node_bytes[node] = \
                    agg.per_node_bytes.get(node, 0.0) + \
                    n_app * spec.msg_size
        report = self.backend._report(agg, self._wall0)
        report.extras["streamed_rounds"] = self.rounds
        report.extras["view_change"] = {
            "cut_seq": {g: int(c) for g, c in cut_seqs.items()},
            "resend_msgs": carry.total_resend(),
            # Stable app counts in the OLD view's rank space (the carry's
            # stable_apps are remapped to the new view and drop failed
            # senders): a failed sender's stable prefix is only visible
            # here.  The serve plane reads it to account a dead slot's
            # delivered apps; gradsync reads it to cap a dead
            # contributor's deliverable watermark.
            "stable_apps_by_old_rank": {
                g: s.copy() for g, s in stable_by_old_rank.items()},
        }
        self.group.delivery_logs = agg.logs
        self.group.last_report = report
        self.group._fire_upcalls()
        self.closed = True


def _sum_delivered(logs: Mapping[int, DeliveryLog]) -> Tuple[int, int]:
    a = n = 0
    for log in logs.values():
        for node in log.delivered_seq:
            da, dn = log.app_null_counts(node)
            a, n = a + da, n + dn
    return a, n


register_backend("des", DESBackend)
register_backend("des-loop", DESLoopBackend)
register_backend("graph", GraphBackend)
register_backend("pallas", PallasBackend)
