"""repro.core — Spindle: atomic-multicast optimizations (Jha, Rosa, Birman
2021) reproduced as composable JAX modules plus a calibrated protocol DES.

Layout:
  costmodel  — RDMA (paper testbed) + TPU v5e hardware constants
  sst        — monotonic shared state table (Sec. 2.2) + shard_map push
  smc        — ring-buffer small-message multicast (Sec. 2.3)
  nullsend   — the null-send rule and its batched form (Sec. 3.3)
  delivery   — round-robin total-order delivery predicates (Secs. 2.4/3.2)
  sweep      — the fused predicate sweep as a pure-JAX protocol round
  simulator  — discrete-event reproduction of the paper's evaluation
  gradsync   — the techniques applied to gradient synchronization
  dds        — OMG-DDS pub/sub layer with the paper's four QoS levels
  views      — virtual-synchrony membership for the elastic runtime
  group      — the unified Derecho-style Group API: one GroupConfig, three
               pluggable protocol backends (des / graph / pallas), one
               RunReport (see also repro.api)
"""

from repro.core import (costmodel, dds, delivery, gradsync, group, nullsend,
                        smc, simulator, sst, sweep, views)

__all__ = ["costmodel", "dds", "delivery", "gradsync", "group", "nullsend",
           "smc", "simulator", "sst", "sweep", "views"]
