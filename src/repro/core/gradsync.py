"""Spindle-style gradient synchronization — the paper's techniques applied
to the data-parallel reduction path of a training step.

Mapping (DESIGN.md Sec. 2):

* **Opportunistic batching** -> *fused gradient buckets*: instead of one
  collective per parameter tensor (the per-event baseline — the analogue of
  an ack per message), every ready gradient is coalesced into a small
  number of large buckets, each reduced with ONE collective.  Bucket sizes
  are self-balancing (a bucket closes when it reaches ``target_bytes``,
  never waits), and the bucket *order* is the deterministic round-robin
  delivery order, so every worker applies updates identically.

* **Ack coalescing via monotonicity** -> step/bucket watermarks: workers
  advance a monotonic ``delivered_step`` counter once per applied batch of
  buckets, not per tensor (see :class:`SyncState`).

* **Null-sends** -> *null rounds* for elastic/straggling workers: a worker
  that cannot contribute a gradient this round contributes an explicit
  zero with a validity flag; the deterministic round-robin application
  never stalls, and the mean is rescaled by the live count
  (:func:`psum_with_validity`).

* **Gradient compression** (beyond-paper distributed-optimization trick):
  reduce-scatter in accumulation dtype, int8-quantize the owned shard,
  all-gather the quantized shards — with error feedback carried to the
  next step (:func:`compressed_psum_mean`).

Everything here is pure-JAX and jit/shard_map friendly; ``axis_name`` is
the data-parallel mesh axis (or a tuple of axes, e.g. ``('pod','data')``).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Mapping, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Array = Any
PyTree = Any


# ---------------------------------------------------------------------------
# Bucket plan — the SMC "ring slots" of the gradient plane
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class BucketPlan:
    """A static partition of a gradient pytree into contiguous buckets."""

    treedef: Any
    leaf_shapes: Tuple[Tuple[int, ...], ...]
    leaf_dtypes: Tuple[Any, ...]
    leaf_sizes: Tuple[int, ...]
    # bucket b covers leaves [starts[b], starts[b+1])
    starts: Tuple[int, ...]

    @property
    def n_buckets(self) -> int:
        return len(self.starts) - 1

    def bucket_leaves(self, b: int) -> range:
        return range(self.starts[b], self.starts[b + 1])

    def bucket_bytes(self, b: int) -> int:
        return sum(self.leaf_sizes[i] * np.dtype(self.leaf_dtypes[i]).itemsize
                   for i in self.bucket_leaves(b))


def make_plan(tree: PyTree, target_bytes: int = 32 * 1024 * 1024,
              pad_to: int = 1) -> BucketPlan:
    """Greedy bucketization in deterministic leaf order (the delivery
    order).  A bucket closes as soon as it reaches target_bytes —
    opportunistic, never waiting for a "full" batch."""
    leaves, treedef = jax.tree.flatten(tree)
    shapes = tuple(tuple(l.shape) for l in leaves)
    dtypes = tuple(l.dtype for l in leaves)
    sizes = tuple(int(np.prod(s, dtype=np.int64)) if s else 1 for s in shapes)
    starts = [0]
    acc = 0
    for i, l in enumerate(leaves):
        acc += sizes[i] * np.dtype(dtypes[i]).itemsize
        if acc >= target_bytes:
            starts.append(i + 1)
            acc = 0
    if starts[-1] != len(leaves):
        starts.append(len(leaves))
    del pad_to
    return BucketPlan(treedef=treedef, leaf_shapes=shapes,
                      leaf_dtypes=dtypes, leaf_sizes=sizes,
                      starts=tuple(starts))


def flatten_buckets(grads: PyTree, plan: BucketPlan) -> List[Array]:
    leaves = jax.tree.leaves(grads)
    assert len(leaves) == len(plan.leaf_sizes), "plan/tree mismatch"
    out = []
    for b in range(plan.n_buckets):
        parts = [leaves[i].reshape(-1) for i in plan.bucket_leaves(b)]
        out.append(jnp.concatenate(parts) if len(parts) > 1 else parts[0])
    return out


def unflatten_buckets(buckets: Sequence[Array], plan: BucketPlan) -> PyTree:
    leaves = []
    for b, buf in enumerate(buckets):
        off = 0
        for i in plan.bucket_leaves(b):
            n = plan.leaf_sizes[i]
            leaves.append(buf[off:off + n].reshape(plan.leaf_shapes[i])
                          .astype(plan.leaf_dtypes[i]))
            off += n
    return jax.tree.unflatten(plan.treedef, leaves)


# ---------------------------------------------------------------------------
# Reduction modes
# ---------------------------------------------------------------------------

def _axis_size(axis_name) -> Array:
    if isinstance(axis_name, (tuple, list)):
        size = 1
        for a in axis_name:
            size = size * jax.lax.psum(1, a) if False else size
        # psum(1) per axis composes; simpler:
        return jax.lax.psum(1, tuple(axis_name))
    return jax.lax.psum(1, axis_name)


def per_tensor_psum_mean(grads: PyTree, axis_name) -> PyTree:
    """Baseline: one collective per tensor (the per-event ack analogue)."""
    n = _axis_size(axis_name)
    return jax.tree.map(lambda g: jax.lax.psum(g, axis_name) / n, grads)


def fused_psum_mean(grads: PyTree, plan: BucketPlan, axis_name) -> PyTree:
    """Spindle: opportunistic fused-bucket reduction — every ready gradient
    coalesced, one collective per bucket."""
    n = _axis_size(axis_name)
    buckets = flatten_buckets(grads, plan)
    reduced = [jax.lax.psum(b, axis_name) / n for b in buckets]
    return unflatten_buckets(reduced, plan)


def psum_with_validity(grads: PyTree, valid: Array, axis_name,
                       plan: Optional[BucketPlan] = None) -> Tuple[PyTree, Array]:
    """Null-round elastic reduction: stragglers contribute a null (zeroed)
    gradient with ``valid=0``; the mean is over live contributors only, and
    the round-robin application order never stalls (Sec. 3.3 adaptation).

    Returns (mean_grads, live_count)."""
    valid_f = valid.astype(jnp.float32)
    count = jax.lax.psum(valid_f, axis_name)
    denom = jnp.maximum(count, 1.0)

    def _mask(g):
        return g * valid_f.astype(g.dtype)

    masked = jax.tree.map(_mask, grads)
    if plan is None:
        summed = jax.tree.map(lambda g: jax.lax.psum(g, axis_name), masked)
    else:
        buckets = flatten_buckets(masked, plan)
        summed = unflatten_buckets(
            [jax.lax.psum(b, axis_name) for b in buckets], plan)
    return jax.tree.map(lambda g: g / denom.astype(g.dtype), summed), count


# ---------------------------------------------------------------------------
# int8 compressed reduction with error feedback (beyond-paper)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class CompressionState:
    """Error-feedback residuals, one per bucket (same shapes as buckets)."""

    residuals: List[Array]

    @classmethod
    def init(cls, plan: BucketPlan, dtype=jnp.float32) -> "CompressionState":
        res = [jnp.zeros(sum(plan.leaf_sizes[i]
                             for i in plan.bucket_leaves(b)), dtype)
               for b in range(plan.n_buckets)]
        return cls(residuals=res)


def _quantize_int8(x: Array) -> Tuple[Array, Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compressed_psum_mean(
        grads: PyTree, plan: BucketPlan, state: CompressionState,
        axis_name, axis_index: Array) -> Tuple[PyTree, CompressionState]:
    """reduce_scatter(f32) -> int8-quantize own shard -> all_gather(int8),
    with error feedback.  Wire bytes: N*4/W (RS) + N (AG, int8) versus
    N*4/W + N*4 uncompressed — the all-gather leg shrinks 4x.

    Must run inside shard_map over `axis_name`; `axis_index` is
    ``lax.axis_index(axis_name)``.
    """
    n = _axis_size(axis_name)
    buckets = flatten_buckets(grads, plan)
    out = []
    new_res = []
    for b, (buf, res) in enumerate(zip(buckets, state.residuals)):
        buf = buf.astype(jnp.float32) + res
        pad = (-buf.shape[0]) % n
        bufp = jnp.pad(buf, (0, pad))
        # reduce_scatter: each worker owns one shard of the bucket sum
        shard = jax.lax.psum_scatter(
            bufp.reshape(n, -1), axis_name, scatter_dimension=0,
            tiled=False) / n
        q, scale = _quantize_int8(shard)
        # error feedback: what quantization lost comes back next step
        err_shard = shard - q.astype(jnp.float32) * scale
        # scatter the residual back to full-bucket layout (only own shard
        # is nonzero locally — exact because each worker re-applies its own)
        res_full = jnp.zeros_like(bufp).reshape(n, -1).at[axis_index].set(
            err_shard).reshape(-1)
        new_res.append(res_full[: buf.shape[0]])
        qg = jax.lax.all_gather(q, axis_name)            # (n, shard) int8
        sg = jax.lax.all_gather(scale, axis_name)        # (n,) f32
        full = (qg.astype(jnp.float32) * sg[:, None]).reshape(-1)
        out.append(full[: buf.shape[0]])
    return unflatten_buckets(out, plan), CompressionState(residuals=new_res)


# ---------------------------------------------------------------------------
# BucketSyncStream — bucket reduction routed through the multicast cut
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AppliedRound:
    """One optimizer round applied in delivery order.

    ``contributors`` are the nodes whose full bucket set went stable (the
    round's mean is over exactly these); ``voided`` are dead contributors
    whose buckets died beyond their final stable watermark — the
    null-round rescaling of :func:`psum_with_validity`, applied at the
    cut instead of at publish time.  ``update`` is the mean over
    contributors' update pytrees (None when every contributor voided).
    """

    step: int
    contributors: Tuple[int, ...]
    voided: Tuple[int, ...] = ()
    update: Any = None


class BucketSyncStream:
    """Bucket reduction routed through a live multicast
    :class:`~repro.core.group.GroupStream`, so an elastic-training view
    change exercises the SAME wedge/ragged-trim/:class:`EpochCarry`
    algorithm as the stream and serve planes (DESIGN.md Sec. 7).

    Mapping: workers are the one subgroup's members AND senders; one
    optimizer round = one :meth:`contribute` call publishing
    ``n_buckets`` app messages per contributing worker (the fused
    buckets of :func:`fused_psum_mean`, one message per bucket).  A
    round's update applies — identically at every worker, in ledger
    (total) order — once every contributor's full bucket set is
    DELIVERED at every member, read off the stream's delivery watermark
    exactly like a serve slot release.  Across a view change the cut
    decides each in-flight round: a surviving contributor's unstable
    buckets ride the resend backlog into the new view (the round applies
    later, unchanged); a FAILED contributor's unstable tail dies with it
    and the round applies with that contribution voided — the mean
    rescales over the survivors, which is :func:`psum_with_validity`'s
    null-round semantics enforced by the cut rather than by an explicit
    zero send.  ``app_base`` stays monotone per worker across
    consecutive cuts, so the applied watermark never rolls back — the
    restart-free elastic resize (contrast ``delivered_step`` rollback in
    the pre-cut :class:`SyncState` path).

    Duck-types the stream side of
    :meth:`repro.core.views.MembershipService.reconfigure_stream`
    (``reconfigure(view)``), which is how
    :class:`repro.train.elastic.ElasticRuntime` drives it.
    """

    def __init__(self, members: Sequence[int], *, n_buckets: int,
                 window: int = 8, backend: str = "graph",
                 msg_size: int = 1 << 20):
        from repro.core import group as group_mod
        from repro.core import simulator as sim
        if n_buckets < 1:
            raise ValueError("need at least one bucket per round")
        members = tuple(sorted(members))
        self.n_buckets = int(n_buckets)
        self.backend = backend
        spec = sim.SubgroupSpec(members=members, senders=members,
                                msg_size=msg_size, window=window,
                                n_messages=0)
        cfg = group_mod.GroupConfig(members=members, subgroups=(spec,))
        self._stream = group_mod.Group(cfg).stream(backend=backend)
        # cumulative (cross-epoch) per-node app accounting: enq = buckets
        # ever contributed, base = stable at the last cut, dead = a dead
        # node's final deliverable cap (its stable count at its cut)
        self._enq: Dict[int, int] = {m: 0 for m in members}
        self._base: Dict[int, int] = {m: 0 for m in members}
        self._dead: Dict[int, int] = {}
        # FIFO ledger of pending rounds: {"step", "targets": {node:
        # cumulative enq after this round}, "updates": {node: pytree}}
        self._ledger: List[Dict[str, Any]] = []
        self._next_step = 0
        self.applied: List[AppliedRound] = []

    # -- introspection -------------------------------------------------------

    @property
    def members(self) -> Tuple[int, ...]:
        return self._stream.group.cfg.subgroups[0].members

    @property
    def _senders(self) -> Tuple[int, ...]:
        return self._stream.group.cfg.subgroups[0].senders

    @property
    def applied_step(self) -> int:
        """Rounds applied everywhere — the monotone watermark the
        elastic runtime exposes as every live worker's
        ``delivered_step``."""
        return len(self.applied)

    @property
    def group(self):
        return self._stream.group

    # -- the contribution plane ---------------------------------------------

    def contribute(self, contributions: Mapping[int, PyTree]) -> None:
        """One optimizer round: each contributing worker publishes its
        ``n_buckets`` bucket messages.  Workers absent from
        ``contributions`` publish nothing this round (nulls cover their
        ranks — the straggler case); an empty mapping is a pure drain
        round that only advances delivery.  Newly applied rounds land in
        :attr:`applied` (see :meth:`poll`)."""
        senders = self._senders
        rank = {m: r for r, m in enumerate(senders)}
        g, s_max = self._stream.shape
        ready = np.zeros((g, s_max), np.int64)
        targets: Dict[int, int] = {}
        updates: Dict[int, PyTree] = {}
        for node in sorted(contributions):
            if node not in rank:
                raise ValueError(
                    f"node {node} is not a live member of the current "
                    "view (dead contributors cannot publish)")
            ready[0, rank[node]] = self.n_buckets
            self._enq[node] += self.n_buckets
            targets[node] = self._enq[node]
            updates[node] = contributions[node]
        if targets:
            self._ledger.append({"step": self._next_step,
                                 "targets": targets, "updates": updates})
            self._next_step += 1
        self._stream.step(ready)
        self.poll()

    def _delivered_apps(self) -> Dict[int, int]:
        """Cumulative app messages delivered-everywhere per node: the
        cross-epoch base plus the current epoch's in-protocol apps
        (delivery watermark converted through the publish traces, apps
        before nulls — the same arithmetic as the cut's stable count)."""
        from repro.core import delivery as delivery_mod
        out = dict(self._dead)
        senders = self._senders
        d = self._stream.view().sender_delivered(0)
        if self._stream.rounds:
            _, app_pub, nulls = self._stream.traces()
        for r, node in enumerate(senders):
            apps = 0
            if self._stream.rounds:
                apps = delivery_mod.apps_in_publish_prefix(
                    app_pub[0, :, r], nulls[0, :, r], int(d[r]))
            out[node] = self._base[node] + apps
        return out

    def poll(self) -> List[AppliedRound]:
        """Apply every head-of-ledger round whose contributors are all
        accounted for — delivered everywhere, or dead with the target
        beyond their final stable cap (voided).  Rounds apply strictly
        in ledger order: the multicast total order IS the optimizer
        order.  Returns the newly applied rounds."""
        newly: List[AppliedRound] = []
        delivered = self._delivered_apps()
        while self._ledger:
            head = self._ledger[0]
            voided, pending = [], False
            for node, tgt in head["targets"].items():
                if delivered.get(node, 0) >= tgt:
                    continue              # full bucket set stable
                if node in self._dead:
                    voided.append(node)   # tail died at the cut
                    continue
                pending = True
                break
            if pending:
                break
            contributors = tuple(n for n in head["targets"]
                                 if n not in voided)
            update = None
            if contributors:
                trees = [head["updates"][n] for n in contributors]
                update = jax.tree.map(
                    lambda *xs: sum(xs) / len(xs), *trees)
            newly.append(AppliedRound(step=head["step"],
                                      contributors=contributors,
                                      voided=tuple(sorted(voided)),
                                      update=update))
            self._ledger.pop(0)
        self.applied.extend(newly)
        return newly

    # -- the cut --------------------------------------------------------------

    def reconfigure(self, view) -> "BucketSyncStream":
        """Carry the reduction across a virtual-synchrony cut.

        The inner stream wedges and trims exactly as any stream
        (:meth:`GroupStream.reconfigure`): survivors' unstable buckets
        become resend backlog, their ``app_base`` advances by what went
        stable (monotone — no watermark rollback), and a dead worker's
        stable count at the cut (the closing report's
        ``stable_apps_by_old_rank``) becomes its final deliverable CAP:
        ledger rounds needing more than the cap apply with that
        contribution voided.  Joiners in ``view`` become senders of the
        new epoch with zero base/backlog (Group.reconfigure only
        shrinks subgroups, so the joined epoch's group is rebuilt here
        with the carry expanded onto the wider rank space).  Mutates in
        place and returns ``self`` — this object IS the stream handle
        the membership service hands back."""
        from repro.core import group as group_mod
        old_senders = self._senders
        old_stream = self._stream
        new_stream = old_stream.reconfigure(view)
        vc = old_stream.group.last_report.extras["view_change"]
        stable_old = vc["stable_apps_by_old_rank"][0]
        alive = set(view.members)
        for old_rank, node in enumerate(old_senders):
            cum_stable = self._base[node] + int(stable_old[old_rank])
            self._base[node] = cum_stable
            if node not in alive:
                self._dead[node] = cum_stable
        joiners = [m for m in view.members
                   if m not in self._enq and m not in self._dead]
        for m in joiners:
            self._enq[m] = self._base[m] = 0
        if joiners:
            surv_group = new_stream.group
            carry = surv_group.carry
            surv_senders = surv_group.cfg.subgroups[0].senders
            spec = surv_group.cfg.subgroups[0]
            all_members = tuple(sorted(set(spec.members) | set(joiners)))
            import dataclasses as _dc
            cfg = _dc.replace(
                surv_group.cfg, members=all_members,
                subgroups=(_dc.replace(spec, members=all_members,
                                       senders=all_members),))
            expanded = group_mod.Group(cfg)
            k = len(all_members)
            resend = np.zeros(k, np.int64)
            stb = np.zeros(k, np.int64)
            base = np.zeros(k, np.int64)
            pos = {m: i for i, m in enumerate(all_members)}
            for r, node in enumerate(surv_senders):
                resend[pos[node]] = carry.resend[0][r]
                stb[pos[node]] = carry.stable_apps[0][r]
                base[pos[node]] = carry.app_base[0][r]
            expanded.carry = group_mod.EpochCarry(
                from_epoch=carry.from_epoch, cut_seq=carry.cut_seq,
                resend=(resend,), stable_apps=(stb,), app_base=(base,))
            new_stream = expanded.stream(backend=self.backend)
        self._stream = new_stream
        # the cut may itself have advanced delivery to the trim
        self.poll()
        return self

    def finish(self):
        """Drain the stream to quiescence and apply every remaining
        ledger round.  Returns the final epoch's
        :class:`~repro.core.group.RunReport`."""
        report, _logs = self._stream.finish()
        self.poll()
        assert not self._ledger, (
            "quiescent stream left unapplied rounds: a live "
            "contributor's buckets never delivered")
        return report

@dataclasses.dataclass
class SyncState:
    """Per-worker monotonic counters mirrored via the SST pattern.

    ``sent_step``      — rounds this worker contributed (app or null),
    ``delivered_step`` — last optimizer step applied everywhere (the
                         checkpoint watermark: restore resumes here),
    ``null_rounds``    — rounds filled with a null contribution.
    """

    sent_step: int = 0
    delivered_step: int = 0
    null_rounds: int = 0

    def advance(self, *, null: bool = False) -> "SyncState":
        return SyncState(self.sent_step + 1, self.delivered_step,
                         self.null_rounds + (1 if null else 0))

    def deliver(self, step: int) -> "SyncState":
        if step < self.delivered_step:
            raise ValueError("delivered_step must be monotonic")
        return SyncState(self.sent_step, step, self.null_rounds)
