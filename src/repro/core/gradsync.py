"""Spindle-style gradient synchronization — the paper's techniques applied
to the data-parallel reduction path of a training step.

Mapping (DESIGN.md Sec. 2):

* **Opportunistic batching** -> *fused gradient buckets*: instead of one
  collective per parameter tensor (the per-event baseline — the analogue of
  an ack per message), every ready gradient is coalesced into a small
  number of large buckets, each reduced with ONE collective.  Bucket sizes
  are self-balancing (a bucket closes when it reaches ``target_bytes``,
  never waits), and the bucket *order* is the deterministic round-robin
  delivery order, so every worker applies updates identically.

* **Ack coalescing via monotonicity** -> step/bucket watermarks: workers
  advance a monotonic ``delivered_step`` counter once per applied batch of
  buckets, not per tensor (see :class:`SyncState`).

* **Null-sends** -> *null rounds* for elastic/straggling workers: a worker
  that cannot contribute a gradient this round contributes an explicit
  zero with a validity flag; the deterministic round-robin application
  never stalls, and the mean is rescaled by the live count
  (:func:`psum_with_validity`).

* **Gradient compression** (beyond-paper distributed-optimization trick):
  reduce-scatter in accumulation dtype, int8-quantize the owned shard,
  all-gather the quantized shards — with error feedback carried to the
  next step (:func:`compressed_psum_mean`).

Everything here is pure-JAX and jit/shard_map friendly; ``axis_name`` is
the data-parallel mesh axis (or a tuple of axes, e.g. ``('pod','data')``).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Array = Any
PyTree = Any


# ---------------------------------------------------------------------------
# Bucket plan — the SMC "ring slots" of the gradient plane
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class BucketPlan:
    """A static partition of a gradient pytree into contiguous buckets."""

    treedef: Any
    leaf_shapes: Tuple[Tuple[int, ...], ...]
    leaf_dtypes: Tuple[Any, ...]
    leaf_sizes: Tuple[int, ...]
    # bucket b covers leaves [starts[b], starts[b+1])
    starts: Tuple[int, ...]

    @property
    def n_buckets(self) -> int:
        return len(self.starts) - 1

    def bucket_leaves(self, b: int) -> range:
        return range(self.starts[b], self.starts[b + 1])

    def bucket_bytes(self, b: int) -> int:
        return sum(self.leaf_sizes[i] * np.dtype(self.leaf_dtypes[i]).itemsize
                   for i in self.bucket_leaves(b))


def make_plan(tree: PyTree, target_bytes: int = 32 * 1024 * 1024,
              pad_to: int = 1) -> BucketPlan:
    """Greedy bucketization in deterministic leaf order (the delivery
    order).  A bucket closes as soon as it reaches target_bytes —
    opportunistic, never waiting for a "full" batch."""
    leaves, treedef = jax.tree.flatten(tree)
    shapes = tuple(tuple(l.shape) for l in leaves)
    dtypes = tuple(l.dtype for l in leaves)
    sizes = tuple(int(np.prod(s, dtype=np.int64)) if s else 1 for s in shapes)
    starts = [0]
    acc = 0
    for i, l in enumerate(leaves):
        acc += sizes[i] * np.dtype(dtypes[i]).itemsize
        if acc >= target_bytes:
            starts.append(i + 1)
            acc = 0
    if starts[-1] != len(leaves):
        starts.append(len(leaves))
    del pad_to
    return BucketPlan(treedef=treedef, leaf_shapes=shapes,
                      leaf_dtypes=dtypes, leaf_sizes=sizes,
                      starts=tuple(starts))


def flatten_buckets(grads: PyTree, plan: BucketPlan) -> List[Array]:
    leaves = jax.tree.leaves(grads)
    assert len(leaves) == len(plan.leaf_sizes), "plan/tree mismatch"
    out = []
    for b in range(plan.n_buckets):
        parts = [leaves[i].reshape(-1) for i in plan.bucket_leaves(b)]
        out.append(jnp.concatenate(parts) if len(parts) > 1 else parts[0])
    return out


def unflatten_buckets(buckets: Sequence[Array], plan: BucketPlan) -> PyTree:
    leaves = []
    for b, buf in enumerate(buckets):
        off = 0
        for i in plan.bucket_leaves(b):
            n = plan.leaf_sizes[i]
            leaves.append(buf[off:off + n].reshape(plan.leaf_shapes[i])
                          .astype(plan.leaf_dtypes[i]))
            off += n
    return jax.tree.unflatten(plan.treedef, leaves)


# ---------------------------------------------------------------------------
# Reduction modes
# ---------------------------------------------------------------------------

def _axis_size(axis_name) -> Array:
    if isinstance(axis_name, (tuple, list)):
        size = 1
        for a in axis_name:
            size = size * jax.lax.psum(1, a) if False else size
        # psum(1) per axis composes; simpler:
        return jax.lax.psum(1, tuple(axis_name))
    return jax.lax.psum(1, axis_name)


def per_tensor_psum_mean(grads: PyTree, axis_name) -> PyTree:
    """Baseline: one collective per tensor (the per-event ack analogue)."""
    n = _axis_size(axis_name)
    return jax.tree.map(lambda g: jax.lax.psum(g, axis_name) / n, grads)


def fused_psum_mean(grads: PyTree, plan: BucketPlan, axis_name) -> PyTree:
    """Spindle: opportunistic fused-bucket reduction — every ready gradient
    coalesced, one collective per bucket."""
    n = _axis_size(axis_name)
    buckets = flatten_buckets(grads, plan)
    reduced = [jax.lax.psum(b, axis_name) / n for b in buckets]
    return unflatten_buckets(reduced, plan)


def psum_with_validity(grads: PyTree, valid: Array, axis_name,
                       plan: Optional[BucketPlan] = None) -> Tuple[PyTree, Array]:
    """Null-round elastic reduction: stragglers contribute a null (zeroed)
    gradient with ``valid=0``; the mean is over live contributors only, and
    the round-robin application order never stalls (Sec. 3.3 adaptation).

    Returns (mean_grads, live_count)."""
    valid_f = valid.astype(jnp.float32)
    count = jax.lax.psum(valid_f, axis_name)
    denom = jnp.maximum(count, 1.0)

    def _mask(g):
        return g * valid_f.astype(g.dtype)

    masked = jax.tree.map(_mask, grads)
    if plan is None:
        summed = jax.tree.map(lambda g: jax.lax.psum(g, axis_name), masked)
    else:
        buckets = flatten_buckets(masked, plan)
        summed = unflatten_buckets(
            [jax.lax.psum(b, axis_name) for b in buckets], plan)
    return jax.tree.map(lambda g: g / denom.astype(g.dtype), summed), count


# ---------------------------------------------------------------------------
# int8 compressed reduction with error feedback (beyond-paper)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class CompressionState:
    """Error-feedback residuals, one per bucket (same shapes as buckets)."""

    residuals: List[Array]

    @classmethod
    def init(cls, plan: BucketPlan, dtype=jnp.float32) -> "CompressionState":
        res = [jnp.zeros(sum(plan.leaf_sizes[i]
                             for i in plan.bucket_leaves(b)), dtype)
               for b in range(plan.n_buckets)]
        return cls(residuals=res)


def _quantize_int8(x: Array) -> Tuple[Array, Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compressed_psum_mean(
        grads: PyTree, plan: BucketPlan, state: CompressionState,
        axis_name, axis_index: Array) -> Tuple[PyTree, CompressionState]:
    """reduce_scatter(f32) -> int8-quantize own shard -> all_gather(int8),
    with error feedback.  Wire bytes: N*4/W (RS) + N (AG, int8) versus
    N*4/W + N*4 uncompressed — the all-gather leg shrinks 4x.

    Must run inside shard_map over `axis_name`; `axis_index` is
    ``lax.axis_index(axis_name)``.
    """
    n = _axis_size(axis_name)
    buckets = flatten_buckets(grads, plan)
    out = []
    new_res = []
    for b, (buf, res) in enumerate(zip(buckets, state.residuals)):
        buf = buf.astype(jnp.float32) + res
        pad = (-buf.shape[0]) % n
        bufp = jnp.pad(buf, (0, pad))
        # reduce_scatter: each worker owns one shard of the bucket sum
        shard = jax.lax.psum_scatter(
            bufp.reshape(n, -1), axis_name, scatter_dimension=0,
            tiled=False) / n
        q, scale = _quantize_int8(shard)
        # error feedback: what quantization lost comes back next step
        err_shard = shard - q.astype(jnp.float32) * scale
        # scatter the residual back to full-bucket layout (only own shard
        # is nonzero locally — exact because each worker re-applies its own)
        res_full = jnp.zeros_like(bufp).reshape(n, -1).at[axis_index].set(
            err_shard).reshape(-1)
        new_res.append(res_full[: buf.shape[0]])
        qg = jax.lax.all_gather(q, axis_name)            # (n, shard) int8
        sg = jax.lax.all_gather(scale, axis_name)        # (n,) f32
        full = (qg.astype(jnp.float32) * sg[:, None]).reshape(-1)
        out.append(full[: buf.shape[0]])
    return unflatten_buckets(out, plan), CompressionState(residuals=new_res)


# ---------------------------------------------------------------------------
# SyncState — monotonic watermarks for the host runtime (SST analogue)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SyncState:
    """Per-worker monotonic counters mirrored via the SST pattern.

    ``sent_step``      — rounds this worker contributed (app or null),
    ``delivered_step`` — last optimizer step applied everywhere (the
                         checkpoint watermark: restore resumes here),
    ``null_rounds``    — rounds filled with a null contribution.
    """

    sent_step: int = 0
    delivered_step: int = 0
    null_rounds: int = 0

    def advance(self, *, null: bool = False) -> "SyncState":
        return SyncState(self.sent_step + 1, self.delivered_step,
                         self.null_rounds + (1 if null else 0))

    def deliver(self, step: int) -> "SyncState":
        if step < self.delivered_step:
            raise ValueError("delivered_step must be monotonic")
        return SyncState(self.sent_step, step, self.null_rounds)
