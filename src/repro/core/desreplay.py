"""Phase 2 of the two-phase DES: replay the event graph vectorized.

DESIGN.md Sec. 12: phase 1 (:mod:`repro.core.desgraph`) assigns every
event a timestamp and emits a compact :class:`~repro.core.desgraph.DesGraph`;
this module turns that graph back into the user-facing results —

* :func:`replay` reconstructs per-message latency samples from the
  recorded delivery events (same member-0 sampling point, same float
  subtraction, same ordering as the legacy loop) and assembles the
  :class:`repro.core.simulator.SimResult` bit-identically to
  ``Simulator.run()``;
* the ``*_np`` functions are a numpy mirror of the round-level
  :mod:`repro.core.sweep` arithmetic.  Every operation is int32
  integer math, so a streamed des round is bit-identical to the XLA
  ``stream_stacked`` round by construction — that is what makes cut
  epochs (wedge watermarks, ragged trim, :class:`~repro.core.group.EpochCarry`)
  bit-comparable across des/graph/pallas instead of merely
  order-invariant: :class:`repro.core.group.GroupStream` drives this
  mirror through the exact same host-side trim/carry/log machinery the
  compiled backends use.
"""

from __future__ import annotations

import math
from typing import List, Tuple

import jax
import numpy as np

from repro.core import nullsend, simulator as sim, sst
from repro.core import sweep as sweep_mod

__all__ = ["replay", "sweep_np", "step_backlog_np", "stream_stacked_np",
           "stream_program_np", "batch_states_np"]


# ---------------------------------------------------------------------------
# Scheduled-run replay: DesGraph -> SimResult
# ---------------------------------------------------------------------------


def replay(graph) -> sim.SimResult:
    """Replay a :class:`~repro.core.desgraph.DesGraph` into the
    :class:`~repro.core.simulator.SimResult` the legacy single-phase
    ``Simulator.run()`` would have produced — bit-identical, including
    the float latency/throughput fields (DESIGN.md Sec. 12).

    Latencies re-derive from the recorded delivery events at member
    position 0 (the DES's sampling point): the generation-time log is
    append-only, so slicing it at replay time reads the same values the
    legacy loop read at event time.
    """
    cfg = graph.cfg
    groups = graph.groups
    lats: List[float] = []
    at_zero = np.nonzero(graph.deliv_member == 0)[0]
    for i in at_zero.tolist():
        g = groups[int(graph.deliv_gid[i])]
        lo = int(graph.deliv_lo[i])
        hi = int(graph.deliv_hi[i])
        t = float(graph.deliv_time[i])
        for s in range(g.n_s):
            k0 = max(0, math.ceil((lo - s) / g.n_s))
            k1 = (hi - s) // g.n_s
            if k1 < k0:
                continue
            seg = g.gen_log[s][k0:k1 + 1]
            app_mask = ~np.isnan(seg)
            if app_mask.any():
                lats.extend((t - seg[app_mask]).tolist())

    per_node = []
    dur_all = 0.0
    delivered = 0
    for g in groups:
        delivered += int(g.delivered_app.sum())
    for node in range(cfg.n_nodes):
        b = 0.0
        end = 0.0
        for g in graph.node_groups[node]:
            me = g.member_pos[node]
            b += float(g.delivered_app[me]) * g.spec.msg_size
            end = max(end, float(g.last_delivery_time[me]))
        start = graph.first_gen if math.isfinite(graph.first_gen) else 0.0
        if end > start and b > 0:
            per_node.append(b / (end - start) / 1e3)
            dur_all = max(dur_all, end - start)
    lat = np.array(lats) if lats else np.array([0.0])
    return sim.SimResult(
        throughput_GBps=float(np.mean(per_node)) if per_node else 0.0,
        mean_latency_us=float(lat.mean()),
        p99_latency_us=float(np.percentile(lat, 99)),
        duration_us=dur_all,
        delivered_app_msgs=delivered,
        nulls_sent=graph.nulls_sent,
        rdma_writes=graph.rdma_writes,
        post_time_us=float(graph.post_time.sum()),
        predicate_time_us=float(graph.pred_time.sum()),
        send_batches=graph.send_batches,
        recv_batches=graph.recv_batches,
        deliv_batches=graph.deliv_batches,
        sweeps=graph.sweeps,
        sender_blocked_us=float(graph.sender_blocked.sum()),
        per_node_throughput=per_node,
        stalled=graph.stalled,
    )


# ---------------------------------------------------------------------------
# Numpy mirror of the round-level sweep (the des stream substrate)
# ---------------------------------------------------------------------------
#
# Same formulas as repro.core.sweep.sweep / step_backlog / stream_stacked,
# evaluated host-side in numpy int32.  Integer arithmetic has no rounding,
# so these are bit-identical to the compiled programs on the same inputs —
# asserted by the conformance suite, relied on by the bit-comparable cut
# semantics of DESIGN.md Sec. 12.


def sweep_np(state: sweep_mod.SweepState, app_ready, *, window=1 << 30,
             null_send=True, member_mask=None, sender_mask=None
             ) -> Tuple[sweep_mod.SweepState, np.ndarray]:
    """Numpy form of :func:`repro.core.sweep.sweep` (one fused round)."""
    n_members = state.recv_counts.shape[0]
    n_senders = state.published.shape[0]
    ranks = np.arange(n_senders)
    masked = member_mask is not None or sender_mask is not None
    if masked:
        member_mask = (np.ones(n_members, bool) if member_mask is None
                       else np.asarray(member_mask))
        sender_mask = (np.ones(n_senders, bool) if sender_mask is None
                       else np.asarray(sender_mask))
        s_eff = int(sender_mask.sum())
        big = np.iinfo(np.int32).max

        def prefix(counts):
            return sst.rr_prefix_masked(counts, sender_mask, s_eff)
    else:
        prefix = sst.rr_prefix

    # --- receive predicate ---
    recv_counts = np.maximum(state.recv_counts, state.pub_vis)
    received_num = (np.asarray(prefix(recv_counts)) - 1).astype(np.int32)
    received_num = np.maximum(received_num, state.received_num)

    # --- null predicate ---
    if not null_send:
        nulls = np.zeros_like(state.published)
    else:
        sender_rows = recv_counts[:n_senders]
        have = sender_rows > 0
        if masked:
            have = have & sender_mask[None, :]
        tgt = nullsend.null_target(
            ranks[:, None], sender_rows - 1, ranks[None, :])
        tgt = np.where(have, tgt, 0)
        tgt = np.where(ranks[None, :] == ranks[:, None], 0, tgt)
        target = np.max(tgt, axis=-1)
        next_idx = state.published + app_ready
        nulls = np.maximum(target - next_idx, 0)
        nulls = np.where(app_ready > 0, 0, nulls)
        if masked:
            nulls = np.where(sender_mask, nulls, 0)

    # --- send predicate, ring-window capped ---
    diag = np.arange(n_members)
    deliv_vis_now = state.deliv_vis.copy()
    deliv_vis_now[diag, diag] = state.delivered_num
    if masked:
        deliv_vis_now = np.where(member_mask[None, :], deliv_vis_now, big)
    min_seq = deliv_vis_now.min(axis=1)[:n_senders]
    if masked:
        deliv_counts = sst.sender_counts_masked(min_seq + 1, s_eff,
                                                n_senders)
    else:
        deliv_counts = sst.sender_counts(min_seq + 1, n_senders)
    own_deliv = deliv_counts[ranks, ranks]
    cap = own_deliv + window
    sendable = np.clip(cap - state.published, 0, None)
    app_pub = np.minimum(app_ready, sendable)
    if masked:
        app_pub = np.where(sender_mask, app_pub, 0)
    published = state.published + app_pub + nulls

    # own publishes are received locally immediately
    own = np.zeros_like(recv_counts)
    own[ranks, ranks] = published
    recv_counts = np.maximum(recv_counts, own)
    received_num = np.maximum(
        received_num, (np.asarray(prefix(recv_counts)) - 1).astype(np.int32))

    # --- delivery predicate ---
    recv_vis = state.recv_vis.copy()
    recv_vis[diag, diag] = received_num
    recv_vis_eff = np.where(member_mask[None, :], recv_vis, big) \
        if masked else recv_vis
    stable = recv_vis_eff.min(axis=1)
    delivered_num = np.maximum(state.delivered_num, stable)
    batch = delivered_num - state.delivered_num

    def i32(x):
        return np.asarray(x, np.int32)

    new = sweep_mod.SweepState(
        published=i32(published),
        pub_vis=i32(np.maximum(state.pub_vis, published[None, :])),
        recv_counts=i32(recv_counts),
        received_num=i32(received_num),
        recv_vis=i32(np.maximum(recv_vis, received_num[None, :])),
        delivered_num=i32(delivered_num),
        deliv_vis=i32(np.maximum(state.deliv_vis,
                                 delivered_num[None, :])),
        app_sent=i32(state.app_sent + app_pub),
        nulls_sent=i32(state.nulls_sent + nulls),
    )
    return new, i32(batch)


def step_backlog_np(state, backlog, ready, *, window=1 << 30,
                    null_send=True, member_mask=None, sender_mask=None):
    """Numpy form of :func:`repro.core.sweep.step_backlog` — the round
    body the des :class:`~repro.core.group.GroupStream` steps."""
    want = backlog + ready
    new, batch = sweep_np(state, want, window=window, null_send=null_send,
                          member_mask=member_mask, sender_mask=sender_mask)
    pub = new.app_sent - state.app_sent
    return (new, np.asarray(want - pub, np.int32)), \
        (batch, pub, new.nulls_sent - state.nulls_sent)


def stream_stacked_np(states, backlogs, ready, *, windows, null_send,
                      member_masks=None, sender_masks=None):
    """Numpy form of :func:`repro.core.sweep.stream_stacked`: one round
    of all G stacked subgroups, looped host-side per subgroup."""
    g = states.recv_counts.shape[0]
    windows = np.asarray(windows)
    backlogs = np.asarray(backlogs)
    ready = np.asarray(ready)
    new_states, new_backlogs = [], []
    batches, pubs, nulls_out = [], [], []
    for i in range(g):
        st = jax.tree_util.tree_map(lambda x: np.asarray(x)[i], states)
        mm = None if member_masks is None else np.asarray(member_masks)[i]
        sm = None if sender_masks is None else np.asarray(sender_masks)[i]
        (nst, nbk), (batch, pub, nl) = step_backlog_np(
            st, backlogs[i], ready[i], window=int(windows[i]),
            null_send=null_send, member_mask=mm, sender_mask=sm)
        new_states.append(nst)
        new_backlogs.append(nbk)
        batches.append(batch)
        pubs.append(pub)
        nulls_out.append(nl)
    states_out = jax.tree_util.tree_map(
        lambda *xs: np.stack(xs), *new_states)
    return ((states_out, np.stack(new_backlogs)),
            (np.stack(batches), np.stack(pubs), np.stack(nulls_out)))


def stream_program_np(windows, null_send: bool):
    """The des stream's round program: same call signature as the jitted
    :func:`repro.core.group._stream_program` closure (``fn(states,
    backlogs, ready, *masks)``), evaluated host-side in numpy.  No
    compile, no trace — and bit-identical outputs on the same inputs,
    so :class:`~repro.core.group.GroupStream` runs unmodified on it."""
    win = np.asarray(windows, np.int32)

    def fn(states, backlogs, ready, *masks):
        mm, sm = masks if masks else (None, None)
        return stream_stacked_np(states, backlogs, ready, windows=win,
                                 null_send=null_send,
                                 member_masks=mm, sender_masks=sm)

    return fn


def batch_states_np(n_members: int, n_senders: int,
                    batch: int) -> sweep_mod.SweepState:
    """Numpy form of :func:`repro.core.sweep.batch_states`: a fresh
    stacked state with (G,)-leading int32 numpy leaves."""
    g = batch
    return sweep_mod.SweepState(
        published=np.zeros((g, n_senders), np.int32),
        pub_vis=np.zeros((g, n_members, n_senders), np.int32),
        recv_counts=np.zeros((g, n_members, n_senders), np.int32),
        received_num=np.full((g, n_members), -1, np.int32),
        recv_vis=np.full((g, n_members, n_members), -1, np.int32),
        delivered_num=np.full((g, n_members), -1, np.int32),
        deliv_vis=np.full((g, n_members, n_members), -1, np.int32),
        app_sent=np.zeros((g, n_senders), np.int32),
        nulls_sent=np.zeros((g, n_senders), np.int32),
    )
