"""An OMG-DDS-style publish/subscribe layer over the Spindle multicast
(paper Sec. 4.6).

The DDS maps DCPS onto the underlying group-communication system by forming
one top-level domain containing every participant, then one subgroup per
*topic* whose members are exactly the processes that publish or subscribe
to it.  Publishers construct samples **in place** in SMC slots (Sec. 3.1)
and mark them ready; delivery upcalls hand subscribers pointers (or copies,
per QoS).

Four QoS levels (Sec. 4.6):

  * UNORDERED        — delivered without waiting for stability; discarded
                       after the upcall.
  * ATOMIC_MULTICAST — Derecho atomic multicast; discarded after upcall.
  * VOLATILE         — additionally copied into subscriber memory (late
                       joiners can catch up).
  * LOGGED           — additionally appended to an SSD log.
"""

from __future__ import annotations

import dataclasses
import enum
import warnings
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core import simulator as sim


class QoS(enum.Enum):
    UNORDERED = "unordered"
    ATOMIC_MULTICAST = "atomic"
    VOLATILE = "volatile"
    LOGGED = "logged"


def qos_flags(qos: QoS, base: Optional[sim.SpindleFlags] = None,
              ) -> sim.SpindleFlags:
    """Translate a QoS level into protocol flags layered on `base`."""
    base = base if base is not None else sim.SpindleFlags.spindle()
    if qos is QoS.UNORDERED:
        return dataclasses.replace(base, wait_stability=False)
    if qos is QoS.ATOMIC_MULTICAST:
        return base
    if qos is QoS.VOLATILE:
        return dataclasses.replace(base, memcpy_delivery=True)
    if qos is QoS.LOGGED:
        return dataclasses.replace(base, memcpy_delivery=True,
                                   disk_append=True)
    raise ValueError(qos)


@dataclasses.dataclass(frozen=True)
class Topic:
    """One DDS topic == one subgroup of its publishers + subscribers."""

    name: str
    topic_id: int                       # 8-bit topic number per OMG DDS
    publishers: Tuple[int, ...]         # node ids
    subscribers: Tuple[int, ...]
    sample_size: int = 10240
    qos: QoS = QoS.ATOMIC_MULTICAST
    window: int = 100

    def __post_init__(self):
        if not 0 <= self.topic_id < 256:
            raise ValueError("OMG DDS topic numbers are 8-bit")

    @property
    def members(self) -> Tuple[int, ...]:
        return tuple(sorted(set(self.publishers) | set(self.subscribers)))


@dataclasses.dataclass
class Domain:
    """A DDS domain: the top-level group plus its topics."""

    n_nodes: int
    topics: List[Topic] = dataclasses.field(default_factory=list)

    def create_topic(self, name: str, publishers: Sequence[int],
                     subscribers: Sequence[int], *, sample_size: int = 10240,
                     qos: QoS = QoS.ATOMIC_MULTICAST,
                     window: int = 100) -> Topic:
        if len(self.topics) >= 256:
            raise ValueError("domain is limited to 256 topics (8-bit ids)")
        for t in self.topics:
            if t.name == name:
                raise ValueError(f"duplicate topic {name!r}")
        topic = Topic(name=name, topic_id=len(self.topics),
                      publishers=tuple(publishers),
                      subscribers=tuple(subscribers),
                      sample_size=sample_size, qos=qos, window=window)
        self.topics.append(topic)
        return topic

    def group(self, *, samples_per_publisher: int = 1000,
              spindle: bool = True,
              target_delivered: Optional[int] = None, **kw):
        """Build the unified :class:`repro.core.group.Group` for this
        domain: one subgroup per topic, QoS lowered to protocol flags.
        Run it on any backend via ``domain.group().run(backend=...)``.

        On the graph/pallas backends a many-topic domain lowers to ONE
        stacked compiled program — all topics' subgroups padded to a
        common shape and swept together — so a DDS workload with dozens
        of topics costs one dispatch per run, not one per topic.

        All topics must share a QoS for a single run (the protocol flags
        are global); benchmark each QoS level separately as the paper does.
        """
        from repro.core import group as group_mod

        if not self.topics:
            raise ValueError("no topics")
        qos = self.topics[0].qos
        if any(t.qos is not qos for t in self.topics):
            raise ValueError("benchmark one QoS level per run")
        base = (sim.SpindleFlags.spindle() if spindle
                else sim.SpindleFlags.baseline())
        flags = qos_flags(qos, base)
        subgroups = tuple(
            sim.SubgroupSpec(members=t.members, senders=t.publishers,
                             msg_size=t.sample_size, window=t.window,
                             n_messages=samples_per_publisher)
            for t in self.topics)
        cfg = group_mod.GroupConfig(
            members=tuple(range(self.n_nodes)), subgroups=subgroups,
            flags=flags, target_delivered=target_delivered, **kw)
        return group_mod.Group(cfg)

    def sim_config(self, *, samples_per_publisher: int = 1000,
                   spindle: bool = True,
                   target_delivered: Optional[int] = None,
                   **kw) -> sim.SimConfig:
        """Deprecated: use ``domain.group(...).run(backend="des")``.

        Kept as a thin shim over the Group API so existing callers and
        saved scripts keep working; it returns the same SimConfig the des
        backend would lower to.  The deprecation warns exactly once per
        process — a script looping over scenarios gets one nudge, not one
        per call.
        """
        global _SIM_CONFIG_WARNED
        if not _SIM_CONFIG_WARNED:
            _SIM_CONFIG_WARNED = True
            warnings.warn(
                "Domain.sim_config is deprecated; use Domain.group() and "
                "Group.run(backend=...) instead", DeprecationWarning,
                stacklevel=2)
        g = self.group(samples_per_publisher=samples_per_publisher,
                       spindle=spindle, target_delivered=target_delivered)
        return g.cfg.to_sim_config(**kw)


# Module-level so the once-ness survives Domain instances; tests reset it.
_SIM_CONFIG_WARNED = False


def single_topic_domain(n_nodes: int, n_subscribers: int,
                        qos: QoS = QoS.ATOMIC_MULTICAST,
                        sample_size: int = 10240) -> Domain:
    """The paper's DDS benchmark: one publisher, varying subscribers,
    everyone on distinct nodes."""
    assert n_subscribers + 1 <= n_nodes
    d = Domain(n_nodes=n_nodes)
    d.create_topic("bench", publishers=[0],
                   subscribers=list(range(1, 1 + n_subscribers)),
                   sample_size=sample_size, qos=qos)
    return d


def many_topic_domain(n_nodes: int, n_topics: int, *,
                      subscribers_per_topic: int = 2,
                      qos: QoS = QoS.ATOMIC_MULTICAST,
                      sample_size: int = 4096,
                      window: int = 16) -> Domain:
    """The many-group dimension the stacked backend targets: ``n_topics``
    topics striped round-robin over the nodes (topic t is published by
    node ``t % n_nodes`` to the next ``subscribers_per_topic`` nodes).
    On graph/pallas the whole domain runs as one stacked program."""
    assert n_nodes >= 2 and subscribers_per_topic + 1 <= n_nodes
    d = Domain(n_nodes=n_nodes)
    for t in range(n_topics):
        pub = t % n_nodes
        subs = [(pub + 1 + k) % n_nodes
                for k in range(subscribers_per_topic)]
        d.create_topic(f"topic-{t}", publishers=[pub], subscribers=subs,
                       sample_size=sample_size, qos=qos, window=window)
    return d
