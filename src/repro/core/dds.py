"""An OMG-DDS-style publish/subscribe layer over the Spindle multicast
(paper Sec. 4.6).

The DDS maps DCPS onto the underlying group-communication system by forming
one top-level domain containing every participant, then one subgroup per
*topic* whose members are exactly the processes that publish or subscribe
to it.  Publishers construct samples **in place** in SMC slots (Sec. 3.1)
and mark them ready; delivery upcalls hand subscribers pointers (or copies,
per QoS).

Four QoS levels (Sec. 4.6):

  * UNORDERED        — delivered without waiting for stability; discarded
                       after the upcall.
  * ATOMIC_MULTICAST — Derecho atomic multicast; discarded after upcall.
  * VOLATILE         — additionally copied into subscriber memory (late
                       joiners can catch up).
  * LOGGED           — additionally appended to an SSD log.
"""

from __future__ import annotations

import dataclasses
import enum
import warnings
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import simulator as sim


class QoS(enum.Enum):
    UNORDERED = "unordered"
    ATOMIC_MULTICAST = "atomic"
    VOLATILE = "volatile"
    LOGGED = "logged"


def qos_flags(qos: QoS, base: Optional[sim.SpindleFlags] = None,
              ) -> sim.SpindleFlags:
    """Translate a QoS level into protocol flags layered on `base`."""
    base = base if base is not None else sim.SpindleFlags.spindle()
    if qos is QoS.UNORDERED:
        return dataclasses.replace(base, wait_stability=False)
    if qos is QoS.ATOMIC_MULTICAST:
        return base
    if qos is QoS.VOLATILE:
        return dataclasses.replace(base, memcpy_delivery=True)
    if qos is QoS.LOGGED:
        return dataclasses.replace(base, memcpy_delivery=True,
                                   disk_append=True)
    raise ValueError(qos)


@dataclasses.dataclass(frozen=True)
class Topic:
    """One DDS topic == one subgroup of its publishers + subscribers."""

    name: str
    topic_id: int                       # 8-bit topic number per OMG DDS
    publishers: Tuple[int, ...]         # node ids
    subscribers: Tuple[int, ...]
    sample_size: int = 10240
    qos: QoS = QoS.ATOMIC_MULTICAST
    window: int = 100

    def __post_init__(self):
        if not 0 <= self.topic_id < 256:
            raise ValueError("OMG DDS topic numbers are 8-bit")

    @property
    def members(self) -> Tuple[int, ...]:
        return tuple(sorted(set(self.publishers) | set(self.subscribers)))


@dataclasses.dataclass
class Domain:
    """A DDS domain: the top-level group plus its topics."""

    n_nodes: int
    topics: List[Topic] = dataclasses.field(default_factory=list)

    def create_topic(self, name: str, publishers: Sequence[int],
                     subscribers: Sequence[int], *, sample_size: int = 10240,
                     qos: QoS = QoS.ATOMIC_MULTICAST,
                     window: int = 100) -> Topic:
        if len(self.topics) >= 256:
            raise ValueError("domain is limited to 256 topics (8-bit ids)")
        for t in self.topics:
            if t.name == name:
                raise ValueError(f"duplicate topic {name!r}")
        topic = Topic(name=name, topic_id=len(self.topics),
                      publishers=tuple(publishers),
                      subscribers=tuple(subscribers),
                      sample_size=sample_size, qos=qos, window=window)
        self.topics.append(topic)
        return topic

    def group(self, *, samples_per_publisher: int = 1000,
              spindle: bool = True,
              target_delivered: Optional[int] = None, **kw):
        """Build the unified :class:`repro.core.group.Group` for this
        domain: one subgroup per topic, QoS lowered to protocol flags.
        Run it on any backend via ``domain.group().run(backend=...)``.

        On the graph/pallas backends a many-topic domain lowers to ONE
        stacked compiled program — all topics' subgroups padded to a
        common shape and swept together — so a DDS workload with dozens
        of topics costs one dispatch per run, not one per topic.

        All topics must share a QoS for a single run (the protocol flags
        are global); benchmark each QoS level separately as the paper does.
        """
        from repro.core import group as group_mod

        if not self.topics:
            raise ValueError("no topics")
        qos = self.topics[0].qos
        if any(t.qos is not qos for t in self.topics):
            raise ValueError("benchmark one QoS level per run")
        base = (sim.SpindleFlags.spindle() if spindle
                else sim.SpindleFlags.baseline())
        flags = qos_flags(qos, base)
        subgroups = tuple(
            sim.SubgroupSpec(members=t.members, senders=t.publishers,
                             msg_size=t.sample_size, window=t.window,
                             n_messages=samples_per_publisher)
            for t in self.topics)
        cfg = group_mod.GroupConfig(
            members=tuple(range(self.n_nodes)), subgroups=subgroups,
            flags=flags, target_delivered=target_delivered, **kw)
        return group_mod.Group(cfg)

    def bind(self, *, backend: str = "graph", spindle: bool = True,
             **kw) -> "BoundDomain":
        """Open a STREAMING session over this domain: per-round
        per-publisher sample counts in, one stacked compiled program per
        round (DESIGN.md Sec. 6).

        Where :meth:`group` fixes ``samples_per_publisher`` upfront (a
        benchmark-scenario schedule), a bound domain accepts each round's
        message counts as they happen — the data plane for workloads
        whose publish pattern only exists at runtime, e.g. the serve
        fan-out (:mod:`repro.serve.fanout`).  All topics still lower to
        ONE stacked program; every streamed round is a single dispatch
        across every topic.
        """
        g = self.group(samples_per_publisher=0, spindle=spindle, **kw)
        return BoundDomain(self, g.stream(backend=backend))

    def sim_config(self, *, samples_per_publisher: int = 1000,
                   spindle: bool = True,
                   target_delivered: Optional[int] = None,
                   **kw) -> sim.SimConfig:
        """Deprecated: use ``domain.group(...).run(backend="des")``.

        Kept as a thin shim over the Group API so existing callers and
        saved scripts keep working; it returns the same SimConfig the des
        backend would lower to.  The deprecation warns exactly once per
        process — a script looping over scenarios gets one nudge, not one
        per call.
        """
        global _SIM_CONFIG_WARNED
        if not _SIM_CONFIG_WARNED:
            _SIM_CONFIG_WARNED = True
            warnings.warn(
                "Domain.sim_config is deprecated; use Domain.group() and "
                "Group.run(backend=...) instead", DeprecationWarning,
                stacklevel=2)
        g = self.group(samples_per_publisher=samples_per_publisher,
                       spindle=spindle, target_delivered=target_delivered)
        return g.cfg.to_sim_config(**kw)


@dataclasses.dataclass
class BoundDomain:
    """A domain bound to a :class:`repro.core.group.GroupStream`: the
    topic-name-keyed front of the streaming entry point.

    ``push_round({topic_name: per_publisher_counts})`` publishes one
    round of samples (topics omitted from the mapping publish nothing
    that round — the null-send scheme covers their publishers) and
    returns the :class:`repro.core.group.StreamView` watermarks;
    ``finish()`` drains and returns the unified report plus per-TOPIC
    delivery logs keyed by topic name.
    """

    domain: Domain
    stream: "object"                     # repro.core.group.GroupStream

    def __post_init__(self):
        self._gid = {t.name: g for g, t in enumerate(self.domain.topics)}

    @property
    def round(self) -> int:
        return self.stream.rounds

    def push_round(self, counts_by_topic=None):
        """One streamed round.  ``counts_by_topic`` maps topic name ->
        per-publisher sample counts (a scalar broadcasts over the topic's
        publishers; a sequence gives rank-ordered per-publisher counts,
        publisher order as declared in :meth:`Domain.create_topic`)."""
        ready = np.zeros(self.stream.shape, np.int32)
        for name, counts in (counts_by_topic or {}).items():
            if name not in self._gid:
                raise KeyError(f"unknown topic {name!r}; have "
                               f"{sorted(self._gid)}")
            gid = self._gid[name]
            n_pub = len(self.domain.topics[gid].publishers)
            counts = np.asarray(counts, np.int32)
            if counts.ndim == 0:
                counts = np.full(n_pub, int(counts), np.int32)
            if counts.shape != (n_pub,):
                raise ValueError(
                    f"topic {name!r} has {n_pub} publishers, got counts "
                    f"of shape {counts.shape}")
            ready[gid, :n_pub] = counts
        return self.stream.step(ready)

    def push_matrix(self, ready):
        """One streamed round from a raw ``(G, S_max)`` ready matrix —
        the workload plane's per-round push path (DESIGN.md Sec. 10):
        an open-loop harness that already holds the whole domain's
        arrival matrix skips the per-topic dict round-trip and dispatches
        it directly.  Rows are topic-indexed in declaration order
        (``gid_of``); padded publisher lanes must be zero (the stream
        validates)."""
        return self.stream.step(ready)

    def gid_of(self, name: str) -> int:
        """Subgroup row of topic ``name`` in the stream's (G, S_max)
        matrices (declaration order)."""
        return self._gid[name]

    def topic_backlogs(self, view=None) -> Dict[str, np.ndarray]:
        """Per-topic window-throttled backlog, keyed by topic name:
        the SMC backpressure signal an admission policy gates on
        (DESIGN.md Sec. 10).  ``view`` defaults to the stream's current
        watermarks."""
        v = self.stream.view() if view is None else view
        return {t.name: v.backlog[g, : len(t.publishers)].copy()
                for g, t in enumerate(self.domain.topics)}

    def finish(self, settle_max=None):
        """Drain to quiescence; returns ``(RunReport, {topic_name:
        DeliveryLog})``."""
        report, logs = self.stream.finish(settle_max=settle_max)
        named = {t.name: logs[g]
                 for g, t in enumerate(self.domain.topics) if g in logs}
        return report, named

    def reconfigure(self, view):
        """Drive a mid-stream view change through the virtual-synchrony
        cut (DESIGN.md Sec. 7): topics are restricted to the surviving
        members — a topic every member of which failed is dropped; a
        topic whose publishers all failed keeps its first member as a
        silent publisher slot, mirroring
        :meth:`repro.core.group.Group.reconfigure` so topic indices stay
        aligned with the stream's subgroup ids — and the in-flight
        samples cross the cut exactly as
        :meth:`repro.core.group.GroupStream.reconfigure` decides
        (delivered everywhere at the ragged trim, or resent by their
        surviving publishers in the new view's stream).

        Returns ``(new_bound, old_report, {topic_name: DeliveryLog})``:
        the re-bound domain to continue pushing rounds into, plus the
        closing epoch's report and cut-clipped per-topic logs."""
        alive = set(view.members)
        new_domain = Domain(n_nodes=self.domain.n_nodes)
        for t in self.domain.topics:
            members = [m for m in t.members if m in alive]
            if not members:
                continue                 # every member failed: topic dies
            pubs = tuple(p for p in t.publishers if p in alive) \
                or (members[0],)
            subs = tuple(s for s in t.subscribers if s in alive)
            new_domain.topics.append(dataclasses.replace(
                t, publishers=pubs, subscribers=subs))
        new_stream = self.stream.reconfigure(view)
        old_report = self.stream.group.last_report
        old_named = {t.name: self.stream.group.delivery_logs[g]
                     for g, t in enumerate(self.domain.topics)
                     if g in self.stream.group.delivery_logs}
        return BoundDomain(new_domain, new_stream), old_report, old_named


# Module-level so the once-ness survives Domain instances; tests reset it.
_SIM_CONFIG_WARNED = False


def single_topic_domain(n_nodes: int, n_subscribers: int,
                        qos: QoS = QoS.ATOMIC_MULTICAST,
                        sample_size: int = 10240) -> Domain:
    """The paper's DDS benchmark: one publisher, varying subscribers,
    everyone on distinct nodes."""
    assert n_subscribers + 1 <= n_nodes
    d = Domain(n_nodes=n_nodes)
    d.create_topic("bench", publishers=[0],
                   subscribers=list(range(1, 1 + n_subscribers)),
                   sample_size=sample_size, qos=qos)
    return d


def many_topic_domain(n_nodes: int, n_topics: int, *,
                      subscribers_per_topic: int = 2,
                      qos: QoS = QoS.ATOMIC_MULTICAST,
                      sample_size: int = 4096,
                      window: int = 16) -> Domain:
    """The many-group dimension the stacked backend targets: ``n_topics``
    topics striped round-robin over the nodes (topic t is published by
    node ``t % n_nodes`` to the next ``subscribers_per_topic`` nodes).
    On graph/pallas the whole domain runs as one stacked program."""
    assert n_nodes >= 2 and subscribers_per_topic + 1 <= n_nodes
    d = Domain(n_nodes=n_nodes)
    for t in range(n_topics):
        pub = t % n_nodes
        subs = [(pub + 1 + k) % n_nodes
                for k in range(subscribers_per_topic)]
        d.create_topic(f"topic-{t}", publishers=[pub], subscribers=subs,
                       sample_size=sample_size, qos=qos, window=window)
    return d
