"""The null-send scheme (paper Sec. 3.3).

Rule: *when a sender node receives a message, it sends a single null iff
that null (its own next message, M(i, l)) would precede the received
message M(j, k) in the delivery order*:

    send null  <=>  l < k  or  (l == k and i < j)

Batched form (the paper combines null-sends with batching: "After the
receiver predicate finishes an iteration, it sends the determined number of
nulls as a single integer"): bring the own next index ``l`` up to the first
value that does NOT precede the latest received message:

    target(i | j, k) = k + 1 if i < j else k

Properties (proved in the paper; checked by hypothesis tests here):
  1. Sender-invariance: active senders keep streaming when others lag.
  2. Low-overhead:      with everyone streaming, few/no nulls are sent.
  3. Correctness:       the delivery pipeline never stalls (<= 1 round skew).
  4. Quiescence:        no application messages  =>  eventually no nulls.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

Array = Any


def precedes(k1, i1, k2, i2):
    """M(i1,k1) < M(i2,k2) in round-robin delivery order."""
    return (k1 < k2) | ((k1 == k2) & (i1 < i2))


def null_target(own_rank, recv_index, recv_rank):
    """Smallest own next-index l such that M(own_rank, l) does not precede
    M(recv_rank, recv_index)."""
    xp = jnp if any(isinstance(x, jax.Array)
                    for x in (own_rank, recv_index, recv_rank)) else np
    return recv_index + xp.where(xp.asarray(own_rank) < recv_rank, 1, 0)


def nulls_needed(own_rank, own_next_index, recv_counts) -> Array:
    """Batched null-send decision after one receiver-predicate iteration.

    own_next_index: l = number of messages this node has sent (app + null).
    recv_counts: (S,) per-sender received counts (sender s's next expected
        index); the latest received message from s is M(s, recv_counts[s]-1).

    Returns the number of nulls to publish *now* (a single integer, sent in
    one write).  Zero when nothing received or we are already caught up —
    this is what makes the scheme quiescent.
    """
    xp = jnp if isinstance(recv_counts, jax.Array) else np
    recv_counts = xp.asarray(recv_counts)
    s = recv_counts.shape[-1]
    ranks = xp.arange(s)
    have = recv_counts > 0
    tgt = null_target(own_rank, recv_counts - 1, ranks)
    tgt = xp.where(have, tgt, 0)
    # Never respond to our own messages.
    tgt = xp.where(ranks == own_rank, 0, tgt)
    target = xp.max(tgt, axis=-1)
    return xp.maximum(target - own_next_index, 0)
