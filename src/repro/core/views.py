"""Virtual-synchrony views (paper Secs. 2.1, 3.3) adapted to elastic
training membership.

Derecho evolves a top-level group through a sequence of *views* using
partition-free state-machine replication: each view has a fixed, ordered
membership; failures/joins/leaves trigger a view change; messages underway
at a view change are either delivered everywhere or nowhere and resent in
the next view.

Training adaptation: a view == a training *epoch of membership*.  The
members are worker hosts, the round-robin "senders" are the data-parallel
participants, and the cleanup guarantee becomes: an optimizer step is
either applied by every worker or rolled back to the checkpoint watermark
(``delivered_step`` in :class:`repro.core.gradsync.SyncState`).

The protocol below is the standard monotone two-phase install driven
through SST-style state: every row only ever increases, so acknowledgments
coalesce and stale reads are harmless — which is precisely why it composes
with the Spindle optimizations.

The wedge/ragged-trim half of virtual synchrony — what happens to
messages *underway* at the view change — lives where the in-flight state
lives: :meth:`repro.core.group.GroupStream.reconfigure` computes the cut
from the stream's SST watermarks (:func:`repro.core.sst.ragged_trim`)
and carries the resend counts into the next view;
:meth:`MembershipService.reconfigure_stream` drives that end-to-end
(DESIGN.md Sec. 7).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, FrozenSet, List, Optional, Sequence, Tuple


@dataclasses.dataclass(frozen=True)
class View:
    """One membership epoch."""

    vid: int
    members: Tuple[int, ...]           # ordered — defines delivery ranks
    senders: Tuple[int, ...]           # active data-parallel participants
    joiners: Tuple[int, ...] = ()      # members new in this view

    def __post_init__(self):
        assert tuple(sorted(set(self.members))) == tuple(sorted(self.members))
        assert set(self.senders) <= set(self.members)

    @property
    def leader(self) -> int:
        return self.members[0]

    def rank(self, node: int) -> int:
        return self.members.index(node)


@dataclasses.dataclass
class _NodeRow:
    """SST row for membership: all fields are monotone."""

    suspected: set = dataclasses.field(default_factory=set)  # grows only
    proposed_vid: int = 0        # highest view id this node has proposed/acked
    wedged_vid: int = -1         # highest view this node stopped sending in
    installed_vid: int = 0
    committed_step: int = 0      # checkpoint watermark at wedge time


class MembershipService:
    """A deterministic, in-process view-change engine.

    On a real cluster this state machine runs over the distributed SST
    (every mutation below is a monotone own-row update + push); here the
    rows live in one address space so the trainer and tests can drive
    failures, joins and elastic resizes deterministically.
    """

    def __init__(self, initial_members: Sequence[int],
                 senders: Optional[Sequence[int]] = None):
        members = tuple(sorted(initial_members))
        self.view = View(vid=0, members=members,
                         senders=tuple(senders) if senders else members)
        self.rows: Dict[int, _NodeRow] = {m: _NodeRow() for m in members}
        self.history: List[View] = [self.view]
        self.pending_joins: List[int] = []

    # -- failure detection -------------------------------------------------

    def suspect(self, reporter: int, failed: int):
        """A heartbeat watermark stopped advancing: report a suspicion.
        Suspicions are monotone (never retracted within a view)."""
        if failed not in self.view.members:
            return
        self.rows[reporter].suspected.add(failed)

    def request_join(self, node: int):
        if node not in self.view.members and node not in self.pending_joins:
            self.pending_joins.append(node)
            # Joiner order (and hence the new view's rank assignment) must
            # not depend on request arrival order — different nodes observe
            # joins in different orders, and a dict/arrival-ordered list
            # here would give them different views.  Keep the pending list
            # canonically sorted so every replica of this state machine
            # installs the identical View.
            self.pending_joins.sort()

    # -- the two-phase monotone view change ---------------------------------

    def _survivors(self) -> Tuple[int, ...]:
        all_susp = set()
        for m in self.view.members:
            all_susp |= self.rows[m].suspected
        return tuple(m for m in self.view.members if m not in all_susp)

    def needs_change(self) -> bool:
        return bool(self._survivors() != self.view.members
                    or self.pending_joins)

    def propose_and_install(self, committed_steps: Dict[int, int]) -> View:
        """Run a full view change: wedge -> agree on watermark -> install.

        committed_steps[node] = that node's delivered_step watermark.  The
        new view's members resume from min over survivors — the virtual
        synchrony cleanup: steps beyond the watermark are either already
        applied everywhere or discarded and redone.
        """
        if not self.needs_change():
            return self.view
        survivors = self._survivors()
        if not survivors:
            raise RuntimeError("total failure: no survivors")
        next_vid = self.view.vid + 1
        # Phase 1: wedge — survivors stop sending in the old view and
        # publish their watermark (monotone row updates).
        for m in survivors:
            row = self.rows[m]
            row.wedged_vid = max(row.wedged_vid, self.view.vid)
            row.proposed_vid = max(row.proposed_vid, next_vid)
            row.committed_step = max(row.committed_step,
                                     committed_steps.get(m, 0))
        # Phase 2: the surviving leader installs once every survivor has
        # acked (proposed_vid reached next_vid) — trivially true here, on a
        # cluster this is the poll of the proposed_vid column.
        assert all(self.rows[m].proposed_vid >= next_vid for m in survivors)
        joiners = tuple(self.pending_joins)
        members = tuple(sorted(set(survivors) | set(joiners)))
        self.pending_joins = []
        new_view = View(vid=next_vid, members=members, senders=members,
                        joiners=joiners)
        for j in joiners:
            self.rows[j] = _NodeRow()
        for m in members:
            self.rows[m].installed_vid = next_vid
            self.rows[m].suspected = set()
        self.view = new_view
        self.history.append(new_view)
        return new_view

    def restart_watermark(self) -> int:
        """The step every member of the current view resumes from."""
        old = set(self.history[-2].members) if len(self.history) > 1 else set()
        carriers = [m for m in self.view.members if m in old] or \
            list(self.view.members)
        return min(self.rows[m].committed_step for m in carriers)

    # -- Group-API integration ----------------------------------------------

    def reconfigure(self, group, committed_steps: Dict[int, int]):
        """Drive one view change end-to-end against a
        :class:`repro.core.group.Group`: run the two-phase install, then
        restrict every subgroup of ``group`` to the new membership.

        Returns ``(view, new_group)``; ``new_group is group`` when no
        change was pending.  This is the seam the elastic runtime uses —
        suspicions/joins accumulate here, the multicast sessions re-form
        through the Group façade.
        """
        if not self.needs_change():
            return self.view, group
        view = self.propose_and_install(committed_steps)
        return view, group.reconfigure(view)

    def reconfigure_stream(self, stream, committed_steps: Dict[int, int]):
        """Drive one view change against a LIVE
        :class:`repro.core.group.GroupStream`: wedge (two-phase install),
        then hand the stream's in-flight state across the
        virtual-synchrony cut (DESIGN.md Sec. 7).

        Where :meth:`reconfigure` rebuilds a scheduled :class:`Group`
        from scratch, this is the failure path the paper's robustness
        claims rest on — messages underway at the view change are
        delivered everywhere-or-nowhere at the ragged trim
        (:func:`repro.core.sst.ragged_trim` over the stream's SST
        watermarks) and the undelivered remainder is resent by the
        surviving senders in the new view (the new stream starts with
        those resend counts as its backlog).

        Returns ``(view, new_stream)``; ``new_stream is stream`` when no
        change was pending.  The old stream is closed: its epoch's
        delivery logs (cut-clipped) and report are installed on its
        owning Group exactly as ``finish()`` would.
        """
        if not self.needs_change():
            return self.view, stream
        view = self.propose_and_install(committed_steps)
        return view, stream.reconfigure(view)
