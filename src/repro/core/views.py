"""Virtual-synchrony views (paper Secs. 2.1, 3.3) adapted to elastic
training membership.

Derecho evolves a top-level group through a sequence of *views* using
partition-free state-machine replication: each view has a fixed, ordered
membership; failures/joins/leaves trigger a view change; messages underway
at a view change are either delivered everywhere or nowhere and resent in
the next view.

Training adaptation: a view == a training *epoch of membership*.  The
members are worker hosts, the round-robin "senders" are the data-parallel
participants, and the cleanup guarantee becomes: an optimizer step is
either applied by every worker or rolled back to the checkpoint watermark
(``delivered_step`` in :class:`repro.core.gradsync.SyncState`).

The protocol below is the standard monotone two-phase install driven
through SST-style state: every row only ever increases, so acknowledgments
coalesce and stale reads are harmless — which is precisely why it composes
with the Spindle optimizations.

The wedge/ragged-trim half of virtual synchrony — what happens to
messages *underway* at the view change — lives where the in-flight state
lives: :meth:`repro.core.group.GroupStream.reconfigure` computes the cut
from the stream's SST watermarks (:func:`repro.core.sst.ragged_trim`)
and carries the resend counts into the next view;
:meth:`MembershipService.reconfigure_stream` drives that end-to-end
(DESIGN.md Sec. 7).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, FrozenSet, List, Optional, Sequence, Tuple


class TotalFailureError(RuntimeError):
    """Every member of the current view is suspected.

    There is no survivor set to wedge, so no cut exists: the caller must
    restart from a checkpoint (train plane) or cold-start the domain
    (serve plane).  Raised instead of installing an empty view so the
    failure is explicit rather than a downstream shape error.
    """


class WedgeAborted(RuntimeError):
    """Cascading suspicions kept re-entering the wedge past the retry
    bound (``max_wedge_retries``): every attempt to agree on a survivor
    set was invalidated by a new suspicion before install.  On a real
    cluster this is the pathological churn case where the membership
    service cannot stabilize; surfacing it beats spinning forever.
    """


@dataclasses.dataclass(frozen=True)
class View:
    """One membership epoch."""

    vid: int
    members: Tuple[int, ...]           # ordered — defines delivery ranks
    senders: Tuple[int, ...]           # active data-parallel participants
    joiners: Tuple[int, ...] = ()      # members new in this view

    def __post_init__(self):
        assert tuple(sorted(set(self.members))) == tuple(sorted(self.members))
        assert set(self.senders) <= set(self.members)

    @property
    def leader(self) -> int:
        return self.members[0]

    def rank(self, node: int) -> int:
        return self.members.index(node)


@dataclasses.dataclass
class _NodeRow:
    """SST row for membership: all fields are monotone."""

    suspected: set = dataclasses.field(default_factory=set)  # grows only
    proposed_vid: int = 0        # highest view id this node has proposed/acked
    wedged_vid: int = -1         # highest view this node stopped sending in
    installed_vid: int = 0
    committed_step: int = 0      # checkpoint watermark at wedge time


class MembershipService:
    """A deterministic, in-process view-change engine.

    On a real cluster this state machine runs over the distributed SST
    (every mutation below is a monotone own-row update + push); here the
    rows live in one address space so the trainer and tests can drive
    failures, joins and elastic resizes deterministically.
    """

    def __init__(self, initial_members: Sequence[int],
                 senders: Optional[Sequence[int]] = None):
        members = tuple(sorted(initial_members))
        self.view = View(vid=0, members=members,
                         senders=tuple(senders) if senders else members)
        self.rows: Dict[int, _NodeRow] = {m: _NodeRow() for m in members}
        self.history: List[View] = [self.view]
        self.pending_joins: List[int] = []
        # Nodes that were a member of SOME past view (for distinguishing a
        # benign stale suspicion from a reporter bug), plus a log of the
        # stale reports so chaos schedules that race a kill against an
        # install can verify no report was silently dropped.
        self._ever_members: set = set(members)
        self.stale_suspicions: List[Tuple[int, int, int]] = []  # (reporter, failed, vid)
        self.wedge_retries: int = 0   # total re-entered wedges (diagnostics)

    # -- failure detection -------------------------------------------------

    def suspect(self, reporter: int, failed: int):
        """A heartbeat watermark stopped advancing: report a suspicion.
        Suspicions are monotone (never retracted within a view).

        A suspicion of a node that was *already removed* by an earlier
        install is an idempotent no-op — the report simply raced the
        install — but it is recorded in :attr:`stale_suspicions` so fault
        schedules can assert nothing was lost.  A suspicion of a node
        that was NEVER a member of any view is a reporter bug (a wild
        pointer into the membership space), not a benign race: raise.
        """
        if failed in self.view.members:
            self.rows[reporter].suspected.add(failed)
            return
        if failed in self.pending_joins:
            # The joiner died before its view installed: cancel the join
            # (it never carried state, so nothing to cut) and record it.
            self.pending_joins.remove(failed)
            self.stale_suspicions.append((reporter, failed, self.view.vid))
            return
        if failed in self._ever_members:
            self.stale_suspicions.append((reporter, failed, self.view.vid))
            return
        raise ValueError(
            f"suspect({reporter} -> {failed}): node {failed} was never a "
            "member of any view — a suspicion of an unknown node is a "
            "reporter bug, not a report racing an install")

    def request_join(self, node: int):
        if node not in self.view.members and node not in self.pending_joins:
            self.pending_joins.append(node)
            # Joiner order (and hence the new view's rank assignment) must
            # not depend on request arrival order — different nodes observe
            # joins in different orders, and a dict/arrival-ordered list
            # here would give them different views.  Keep the pending list
            # canonically sorted so every replica of this state machine
            # installs the identical View.
            self.pending_joins.sort()

    # -- the two-phase monotone view change ---------------------------------

    def _survivors(self) -> Tuple[int, ...]:
        all_susp = set()
        for m in self.view.members:
            all_susp |= self.rows[m].suspected
        return tuple(m for m in self.view.members if m not in all_susp)

    def needs_change(self) -> bool:
        return bool(self._survivors() != self.view.members
                    or self.pending_joins)

    def propose_and_install(
            self, committed_steps: Dict[int, int], *,
            during_wedge: Optional[Callable[["MembershipService", int], None]] = None,
            max_wedge_retries: int = 8) -> View:
        """Run a full view change: wedge -> agree on watermark -> install.

        committed_steps[node] = that node's delivered_step watermark.  The
        new view's members resume from min over survivors — the virtual
        synchrony cleanup: steps beyond the watermark are either already
        applied everywhere or discarded and redone.

        **Cascading suspicions.**  On a real cluster new ``suspect()``
        reports can land while the wedge is in progress (a second node
        times out exactly because the first failure stalled it).
        ``during_wedge(service, attempt)`` is the deterministic stand-in
        for that concurrency: it is invoked after each wedge attempt and
        may call :meth:`suspect` / :meth:`request_join`.  If the survivor
        set shrank, the install is NOT performed — the late suspicions
        are *folded into the pending cut* and the wedge re-enters with
        the smaller survivor set.  Exactly one view is installed for the
        whole cascade (one ``vid`` consumed, one cut computed over the
        final survivors), never a doomed intermediate view.  Folding is
        safe for the stream cut because removing a node from the
        min-over-survivors can only RAISE the stable frontier
        (:func:`repro.core.sst.cascading_trim`): no watermark ever rolls
        back.  After ``max_wedge_retries`` re-entries the change aborts
        with :class:`WedgeAborted`; an empty survivor set at any attempt
        raises :class:`TotalFailureError`.
        """
        if not self.needs_change():
            return self.view
        next_vid = self.view.vid + 1
        attempt = 0
        while True:
            survivors = self._survivors()
            if not survivors:
                raise TotalFailureError("total failure: no survivors")
            # Phase 1: wedge — survivors stop sending in the old view and
            # publish their watermark (monotone row updates).
            for m in survivors:
                row = self.rows[m]
                row.wedged_vid = max(row.wedged_vid, self.view.vid)
                row.proposed_vid = max(row.proposed_vid, next_vid)
                row.committed_step = max(row.committed_step,
                                         committed_steps.get(m, 0))
            # Late suspicions landing while the wedge is in progress fold
            # into THIS pending change instead of installing a doomed
            # intermediate view.
            if during_wedge is not None:
                during_wedge(self, attempt)
                if self._survivors() != survivors:
                    attempt += 1
                    self.wedge_retries += 1
                    if attempt > max_wedge_retries:
                        raise WedgeAborted(
                            f"view change v{self.view.vid}->v{next_vid} "
                            f"re-entered the wedge {attempt} times "
                            f"(max_wedge_retries={max_wedge_retries}): "
                            "suspicions are arriving faster than the wedge "
                            "can stabilize")
                    continue
            # Phase 2: the surviving leader installs once every survivor has
            # acked (proposed_vid reached next_vid) — trivially true here, on
            # a cluster this is the poll of the proposed_vid column.
            assert all(self.rows[m].proposed_vid >= next_vid
                       for m in survivors)
            joiners = tuple(self.pending_joins)
            members = tuple(sorted(set(survivors) | set(joiners)))
            self.pending_joins = []
            new_view = View(vid=next_vid, members=members, senders=members,
                            joiners=joiners)
            for j in joiners:
                self.rows[j] = _NodeRow()
            for m in members:
                self.rows[m].installed_vid = next_vid
                self.rows[m].suspected = set()
            self._ever_members |= set(members)
            self.view = new_view
            self.history.append(new_view)
            return new_view

    def restart_watermark(self) -> int:
        """The step every member of the current view resumes from."""
        old = set(self.history[-2].members) if len(self.history) > 1 else set()
        carriers = [m for m in self.view.members if m in old] or \
            list(self.view.members)
        return min(self.rows[m].committed_step for m in carriers)

    # -- Group-API integration ----------------------------------------------

    def reconfigure(self, group, committed_steps: Dict[int, int], **wedge_kw):
        """Drive one view change end-to-end against a
        :class:`repro.core.group.Group`: run the two-phase install, then
        restrict every subgroup of ``group`` to the new membership.

        Returns ``(view, new_group)``; ``new_group is group`` when no
        change was pending.  This is the seam the elastic runtime uses —
        suspicions/joins accumulate here, the multicast sessions re-form
        through the Group façade.  ``wedge_kw`` (``during_wedge``,
        ``max_wedge_retries``) forwards to :meth:`propose_and_install`.
        """
        if not self.needs_change():
            return self.view, group
        view = self.propose_and_install(committed_steps, **wedge_kw)
        return view, group.reconfigure(view)

    def reconfigure_stream(self, stream, committed_steps: Dict[int, int],
                           **wedge_kw):
        """Drive one view change against a LIVE
        :class:`repro.core.group.GroupStream`: wedge (two-phase install),
        then hand the stream's in-flight state across the
        virtual-synchrony cut (DESIGN.md Sec. 7).

        Where :meth:`reconfigure` rebuilds a scheduled :class:`Group`
        from scratch, this is the failure path the paper's robustness
        claims rest on — messages underway at the view change are
        delivered everywhere-or-nowhere at the ragged trim
        (:func:`repro.core.sst.ragged_trim` over the stream's SST
        watermarks) and the undelivered remainder is resent by the
        surviving senders in the new view (the new stream starts with
        those resend counts as its backlog).

        Suspicions that land during the wedge (``during_wedge`` in
        ``wedge_kw``) fold into this single cut: the stream's trim is
        computed once, over the FINAL survivor set, after the wedge
        stabilizes — and since shrinking the survivor set can only raise
        the min-over-survivors frontier, folding never rolls a delivery
        watermark back (:func:`repro.core.sst.cascading_trim`).

        Returns ``(view, new_stream)``; ``new_stream is stream`` when no
        change was pending.  The old stream is closed: its epoch's
        delivery logs (cut-clipped) and report are installed on its
        owning Group exactly as ``finish()`` would.
        """
        if not self.needs_change():
            return self.view, stream
        view = self.propose_and_install(committed_steps, **wedge_kw)
        return view, stream.reconfigure(view)
