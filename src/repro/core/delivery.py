"""Delivery predicate + total-order delivery (paper Secs. 2.4, 3.2, 3.5).

A message with seq ``s`` is deliverable once every subgroup member's
``received_num >= s``.  The Spindle delivery predicate takes the *minimum*
of the received_num column and delivers everything up to it in one batch,
in round-robin order — opportunistic batching at the delivery stage.

Receiver-delay mitigation (Sec. 3.5) is expressed as two delivery modes:
  * ``upcall_each``   — one upcall per message (baseline),
  * ``upcall_batch``  — one upcall per deliverable batch,
optionally with ``memcpy_out`` (copy the payload out of the ring and return
immediately, Sec. 4.4).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import sst

Array = Any


def stable_seq(received_num_column):
    """Highest seq received by *all* members (their received_num min).

    received_num_column: (n_members, ...) -> (...,)
    """
    xp = jnp if isinstance(received_num_column, jax.Array) else np
    return xp.min(received_num_column, axis=0)


def deliverable_range(delivered_num, received_num_column):
    """[lo, hi] inclusive seq range newly deliverable; empty if lo > hi."""
    hi = stable_seq(received_num_column)
    lo = delivered_num + 1
    return lo, hi


@dataclasses.dataclass
class DeliveryBatch:
    """A resolved batch of deliverable messages in delivery order."""

    lo_seq: int
    hi_seq: int
    n_senders: int

    def __len__(self) -> int:
        return max(0, self.hi_seq - self.lo_seq + 1)

    def messages(self):
        """Yield (seq, sender_rank, sender_index) in delivery order."""
        for s in range(self.lo_seq, self.hi_seq + 1):
            yield s, s % self.n_senders, s // self.n_senders


def split_app_and_null(batch: DeliveryBatch, null_watermarks) -> tuple:
    """Count application vs null messages in a batch.

    null_watermarks[s] = number of *application* messages sender s had sent
    when it appended its nulls is protocol-dependent; the simulator tracks
    exact per-(sender, index) nullness instead.  This helper exists for the
    in-graph path where nulls carry a zero payload flag.
    """
    raise NotImplementedError(
        "exact nullness is tracked by the caller; see simulator.py")


def deliver(batch: DeliveryBatch,
            upcall: Callable[[int, int, int], None],
            batched: bool = True,
            batch_upcall: Optional[Callable[[DeliveryBatch], None]] = None):
    """Run delivery upcalls for a batch (host-side plumbing)."""
    if batched and batch_upcall is not None:
        batch_upcall(batch)
        return
    for seq, rank, idx in batch.messages():
        upcall(seq, rank, idx)
