"""Delivery predicate + total-order delivery (paper Secs. 2.4, 3.2, 3.5).

A message with seq ``s`` is deliverable once every subgroup member's
``received_num >= s``.  The Spindle delivery predicate takes the *minimum*
of the received_num column and delivers everything up to it in one batch,
in round-robin order — opportunistic batching at the delivery stage.

Receiver-delay mitigation (Sec. 3.5) is expressed as two delivery modes:
  * ``upcall_each``   — one upcall per message (baseline),
  * ``upcall_batch``  — one upcall per deliverable batch,
optionally with ``memcpy_out`` (copy the payload out of the ring and return
immediately, Sec. 4.4).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import sst

Array = Any


def stable_seq(received_num_column):
    """Highest seq received by *all* members (their received_num min).

    received_num_column: (n_members, ...) -> (...,)
    """
    xp = jnp if isinstance(received_num_column, jax.Array) else np
    return xp.min(received_num_column, axis=0)


def deliverable_range(delivered_num, received_num_column):
    """[lo, hi] inclusive seq range newly deliverable; empty if lo > hi."""
    hi = stable_seq(received_num_column)
    lo = delivered_num + 1
    return lo, hi


@dataclasses.dataclass
class DeliveryBatch:
    """A resolved batch of deliverable messages in delivery order."""

    lo_seq: int
    hi_seq: int
    n_senders: int

    def __len__(self) -> int:
        return max(0, self.hi_seq - self.lo_seq + 1)

    def messages(self):
        """Yield (seq, sender_rank, sender_index) in delivery order."""
        for s in range(self.lo_seq, self.hi_seq + 1):
            yield s, s % self.n_senders, s // self.n_senders


def split_app_and_null(batch: DeliveryBatch, is_app) -> tuple:
    """Count (application, null) messages in a delivery batch.

    is_app[rank] is a per-sender boolean sequence over publish indexes
    (True = application payload, False = null).  Both Group backends
    produce these logs — the DES from its generation log (NaN = null), the
    graph/pallas backends from the per-round app/null publish trace — so
    the :class:`repro.core.group.RunReport` app/null accounting is exact
    on every substrate.  Indexes past a sender's log (published-but-
    untracked tail) count as nulls.

    Vectorized: the batch's [lo, hi] seq range decomposes into one
    contiguous per-sender index range via the round-robin count arithmetic
    (:func:`repro.core.sst.sender_counts`), so no per-message loop.
    """
    total = len(batch)
    if total == 0:
        return 0, 0
    lo_counts = sst.sender_counts(np.asarray(batch.lo_seq),
                                  batch.n_senders)
    hi_counts = sst.sender_counts(np.asarray(batch.hi_seq + 1),
                                  batch.n_senders)
    n_app = sum(
        int(np.count_nonzero(np.asarray(is_app[r], dtype=bool)
                             [int(lo_counts[r]):int(hi_counts[r])]))
        for r in range(batch.n_senders))
    return n_app, total - n_app


def apps_in_publish_prefix(app_pub, nulls, n_publishes) -> int:
    """Application messages among one sender's first ``n_publishes``
    publishes, given its per-round publish trace.

    app_pub/nulls: (T,) per-round app/null publish counts for ONE sender
    rank (the stacked traces, sliced).  Within a round a sender publishes
    its apps before its nulls (matching :func:`repro.core.sweep.sweep`'s
    ``published + app_pub + nulls``), so of round r's publishes the apps
    occupy positions ``[cum_before_r, cum_before_r + app_pub[r])``.

    This is the per-sender half of the virtual-synchrony cut (DESIGN.md
    Sec. 7): with ``n_publishes`` = the sender's publish count at the
    ragged trim (:func:`repro.core.sst.ragged_trim` +
    :func:`repro.core.sst.sender_counts`), the result is how many of its
    app messages are stable — delivered everywhere in the closing view —
    and everything after that must be resent in the next one.
    """
    app_pub = np.asarray(app_pub, dtype=np.int64)
    nulls = np.asarray(nulls, dtype=np.int64)
    total = app_pub + nulls
    before = np.cumsum(total) - total            # exclusive prefix
    taken = np.clip(n_publishes - before, 0, app_pub)
    return int(taken.sum())


def deliver(batch: DeliveryBatch,
            upcall: Callable[[int, int, int], None],
            batched: bool = True,
            batch_upcall: Optional[Callable[[DeliveryBatch], None]] = None):
    """Run delivery upcalls for a batch (host-side plumbing)."""
    if batched and batch_upcall is not None:
        batch_upcall(batch)
        return
    for seq, rank, idx in batch.messages():
        upcall(seq, rank, idx)
