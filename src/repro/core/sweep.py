"""The fused predicate sweep as a pure-JAX protocol round.

On TPU there is no polling thread; the analogue of Derecho's single
predicate thread (Sec. 2.4) is a single fused program that evaluates every
node's send/receive/null/delivery predicates over SST arrays in one step —
vectorized across nodes, jit/scan-able, with *one-round-delayed* visibility
standing in for wire latency.

This module is the composable, in-graph form of the protocol: the DES in
:mod:`repro.core.simulator` answers "how fast", this answers "is the logic
a fixed point of the monotonic predicates" — and it is what the hypothesis
property tests drive (no-stall, <=1-round skew, quiescence, total order).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp

from repro.core import nullsend, sst

Array = Any


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class SweepState:
    """Protocol state for one subgroup with S senders and N members.

    Visibility model: ``*_vis`` arrays are what each node currently *sees*
    of the others' rows (its local SST copy); authoritative rows are the
    diagonal / own entries.  :func:`sweep` returns the post-round state and
    a new visibility that lags by exactly one round — the jit analogue of
    the wire.
    """

    published: Array      # (S,)   authoritative per-sender counts
    pub_vis: Array        # (N, S) node's view of published counts
    recv_counts: Array    # (N, S) per-node processed per-sender counts
    received_num: Array   # (N,)   rr-prefix seq per node
    recv_vis: Array       # (N, N) node's view of others' received_num
    delivered_num: Array  # (N,)   per-node delivered seq
    deliv_vis: Array      # (N, N)
    app_sent: Array       # (S,)   app messages published so far
    nulls_sent: Array     # (S,)

    @classmethod
    def init(cls, n_members: int, n_senders: int) -> "SweepState":
        z = jnp.zeros
        return cls(
            published=z((n_senders,), jnp.int32),
            pub_vis=z((n_members, n_senders), jnp.int32),
            recv_counts=z((n_members, n_senders), jnp.int32),
            received_num=jnp.full((n_members,), -1, jnp.int32),
            recv_vis=jnp.full((n_members, n_members), -1, jnp.int32),
            delivered_num=jnp.full((n_members,), -1, jnp.int32),
            deliv_vis=jnp.full((n_members, n_members), -1, jnp.int32),
            app_sent=z((n_senders,), jnp.int32),
            nulls_sent=z((n_senders,), jnp.int32),
        )


def sweep(state: SweepState, app_ready: Array, *, window=1 << 30,
          null_send=True, receive_fn=None
          ) -> Tuple[SweepState, Array]:
    """One fused protocol round for every node simultaneously.

    app_ready: (S,) int32 — app messages each sender wants to publish this
    round (the send predicate's queue).  Sender rank i is member i (the
    first S members are the senders, matching Derecho's rank ordering).

    ``window`` and ``null_send`` may be Python values (static, baked into
    the trace) or scalar arrays (traced) — the latter is what lets
    :func:`run_batch` vmap one compiled program over a window/flag grid.

    receive_fn: optional ``(pub_vis, recv_counts) -> new recv_counts``
    override for the receive predicate's consumption step.  The default is
    the in-graph ``max`` merge; the pallas Group backend substitutes the
    fused SMC slot-counter kernel here (same fixed point, evaluated over
    the real ring data structure).

    Returns (new_state, delivered_batch_sizes (N,)).
    """
    n_members = state.recv_counts.shape[0]
    n_senders = state.published.shape[0]
    ranks = jnp.arange(n_senders)

    # --- receive predicate (all nodes): consume everything visible -------
    if receive_fn is None:
        recv_counts = jnp.maximum(state.recv_counts, state.pub_vis)
    else:
        recv_counts = receive_fn(state.pub_vis, state.recv_counts)
    received_num = (sst.rr_prefix(recv_counts) - 1).astype(jnp.int32)
    received_num = jnp.maximum(received_num, state.received_num)

    # --- null predicate (sender nodes) -----------------------------------
    if isinstance(null_send, bool) and not null_send:
        nulls = jnp.zeros_like(state.published)
    else:
        sender_rows = recv_counts[:n_senders]                  # (S, S)
        have = sender_rows > 0
        tgt = nullsend.null_target(
            ranks[:, None], sender_rows - 1, ranks[None, :])
        tgt = jnp.where(have, tgt, 0)
        tgt = jnp.where(ranks[None, :] == ranks[:, None], 0, tgt)
        target = jnp.max(tgt, axis=-1)                         # (S,)
        next_idx = state.published + app_ready                 # after sends
        nulls = jnp.maximum(target - next_idx, 0)
        nulls = jnp.where(app_ready > 0, 0, nulls)
        # traced flag (run_batch grids): a disabled point masks to zero
        nulls = jnp.where(jnp.asarray(null_send), nulls, 0)

    # --- send predicate (sender nodes), ring-window capped ----------------
    diag = jnp.arange(n_members)
    deliv_vis_now = state.deliv_vis.at[diag, diag].set(state.delivered_num)
    min_seq = deliv_vis_now.min(axis=1)[:n_senders]            # (S,)
    deliv_counts = sst.sender_counts(min_seq + 1, n_senders)   # (S, S)
    own_deliv = deliv_counts[ranks, ranks]
    cap = own_deliv + window
    sendable = jnp.clip(cap - state.published, 0)
    app_pub = jnp.minimum(app_ready, sendable)
    published = state.published + app_pub + nulls

    # own publishes are received locally immediately
    own = jnp.zeros_like(recv_counts).at[ranks, ranks].set(published)
    recv_counts = jnp.maximum(recv_counts, own)
    received_num = jnp.maximum(
        received_num, (sst.rr_prefix(recv_counts) - 1).astype(jnp.int32))

    # --- delivery predicate: min over *visible* received_num --------------
    # own entry is authoritative; other members' entries lag one round
    recv_vis = state.recv_vis.at[diag, diag].set(received_num)
    stable = recv_vis.min(axis=1)                              # (N,)
    delivered_num = jnp.maximum(state.delivered_num, stable)
    batch = delivered_num - state.delivered_num

    # --- "wire": visibility catches up to this round's authoritative rows -
    new = SweepState(
        published=published,
        pub_vis=jnp.maximum(state.pub_vis, published[None, :]),
        recv_counts=recv_counts,
        received_num=received_num,
        recv_vis=jnp.maximum(recv_vis, received_num[None, :]),
        delivered_num=delivered_num,
        deliv_vis=jnp.maximum(state.deliv_vis, delivered_num[None, :]),
        app_sent=state.app_sent + app_pub,
        nulls_sent=state.nulls_sent + nulls,
    )
    return new, batch


def run_rounds(state: SweepState, app_schedule: Array, *,
               window: int = 1 << 30, null_send: bool = True
               ) -> Tuple[SweepState, Array]:
    """lax.scan over rounds.  app_schedule: (T, S) messages ready per round.
    Returns final state and (T, N) delivered batch sizes."""

    def body(st, ready):
        st, batch = sweep(st, ready, window=window, null_send=null_send)
        return st, batch

    return jax.lax.scan(body, state, app_schedule)


def scan_rounds(state: SweepState, app_schedule: Array, *,
                window=1 << 30, null_send=True, receive_fn=None
                ) -> Tuple[SweepState, Tuple[Array, Array, Array]]:
    """lax.scan with a send-queue backlog and full per-round traces.

    Window-throttled messages are requeued, not dropped — the DES app-queue
    semantics the Group backends need.  app_schedule: (T, S) app messages
    becoming ready per round.  ``window``/``null_send`` may be traced
    scalars (see :func:`sweep`).

    Returns (final_state, (delivered_batches (T, N), app_published (T, S),
    nulls_published (T, S))) — everything delivery-log reconstruction and
    the in-graph cost model consume, as arrays.
    """
    n_senders = state.published.shape[0]

    def body(carry, ready):
        st, backlog = carry
        want = backlog + ready
        new, batch = sweep(st, want, window=window, null_send=null_send,
                           receive_fn=receive_fn)
        pub = new.app_sent - st.app_sent
        return (new, want - pub), (batch, pub,
                                   new.nulls_sent - st.nulls_sent)

    carry = (state, jnp.zeros((n_senders,), jnp.int32))
    (state, _), traces = jax.lax.scan(body, carry, app_schedule)
    return state, traces


def run_batch(states: SweepState, app_schedules: Array, *, windows: Array,
              null_sends: Array, receive_fn=None
              ) -> Tuple[SweepState, Tuple[Array, Array, Array]]:
    """Batched multi-scenario execution: vmap of :func:`scan_rounds`.

    One compiled program sweeps B scenario points at once instead of B
    sequential Python runs — the systematic-batching lesson (Sec. 3.1–3.2)
    applied to the coordination substrate itself.

    states: a SweepState whose leaves carry a leading (B,) axis (see
    :func:`batch_states`); app_schedules: (B, T, S) schedules padded to a
    common round budget; windows: (B,) int32 ring windows; null_sends:
    (B,) bool flags.  Returns batched final states and (B, T, ...) traces.
    """
    def one(st, sched, w, nf):
        return scan_rounds(st, sched, window=w, null_send=nf,
                           receive_fn=receive_fn)

    return jax.vmap(one)(states, app_schedules, jnp.asarray(windows),
                         jnp.asarray(null_sends))


def batch_states(n_members: int, n_senders: int, batch: int) -> SweepState:
    """A fresh SweepState broadcast over a leading (B,) axis, the carry
    layout :func:`run_batch` expects."""
    state = SweepState.init(n_members, n_senders)
    return jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x, (batch,) + x.shape), state)
