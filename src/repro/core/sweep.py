"""The fused predicate sweep as a pure-JAX protocol round.

On TPU there is no polling thread; the analogue of Derecho's single
predicate thread (Sec. 2.4) is a single fused program that evaluates every
node's send/receive/null/delivery predicates over SST arrays in one step —
vectorized across nodes, jit/scan-able, with *one-round-delayed* visibility
standing in for wire latency.

This module is the composable, in-graph form of the protocol: the DES in
:mod:`repro.core.simulator` answers "how fast", this answers "is the logic
a fixed point of the monotonic predicates" — and it is what the seeded
property tests drive (no-stall, <=1-round skew, quiescence, total order).

The receive predicate's consumption step is pluggable via ``receive_fn``
with the 3-arg contract ``(pub_vis, recv_counts, valid) -> new
recv_counts`` (``valid`` = the (N, S) padded-lane validity mask, or None
when unpadded); see :func:`sweep` and DESIGN.md Sec. 3.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp

from repro.core import nullsend, sst

Array = Any


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class SweepState:
    """Protocol state for one subgroup with S senders and N members.

    Visibility model: ``*_vis`` arrays are what each node currently *sees*
    of the others' rows (its local SST copy); authoritative rows are the
    diagonal / own entries.  :func:`sweep` returns the post-round state and
    a new visibility that lags by exactly one round — the jit analogue of
    the wire.
    """

    published: Array      # (S,)   authoritative per-sender counts
    pub_vis: Array        # (N, S) node's view of published counts
    recv_counts: Array    # (N, S) per-node processed per-sender counts
    received_num: Array   # (N,)   rr-prefix seq per node
    recv_vis: Array       # (N, N) node's view of others' received_num
    delivered_num: Array  # (N,)   per-node delivered seq
    deliv_vis: Array      # (N, N)
    app_sent: Array       # (S,)   app messages published so far
    nulls_sent: Array     # (S,)

    @classmethod
    def init(cls, n_members: int, n_senders: int) -> "SweepState":
        z = jnp.zeros
        return cls(
            published=z((n_senders,), jnp.int32),
            pub_vis=z((n_members, n_senders), jnp.int32),
            recv_counts=z((n_members, n_senders), jnp.int32),
            received_num=jnp.full((n_members,), -1, jnp.int32),
            recv_vis=jnp.full((n_members, n_members), -1, jnp.int32),
            delivered_num=jnp.full((n_members,), -1, jnp.int32),
            deliv_vis=jnp.full((n_members, n_members), -1, jnp.int32),
            app_sent=z((n_senders,), jnp.int32),
            nulls_sent=z((n_senders,), jnp.int32),
        )


def sweep(state: SweepState, app_ready: Array, *, window=1 << 30,
          null_send=True, receive_fn=None, member_mask=None,
          sender_mask=None) -> Tuple[SweepState, Array]:
    """One fused protocol round for every node simultaneously.

    app_ready: (S,) int32 — app messages each sender wants to publish this
    round (the send predicate's queue).  Sender rank i is member i (the
    first S members are the senders, matching Derecho's rank ordering).

    ``window`` and ``null_send`` may be Python values (static, baked into
    the trace) or scalar arrays (traced) — the latter is what lets
    :func:`run_batch` vmap one compiled program over a window/flag grid.

    receive_fn: optional ``(pub_vis, recv_counts, valid) -> new
    recv_counts`` override for the receive predicate's consumption step
    (``valid`` is the (N, S) validity mask, or None when unpadded).  The
    default is the in-graph ``max`` merge; the pallas Group backend
    substitutes the fused SMC slot-counter kernel here (same fixed point,
    evaluated over the real ring data structure).

    member_mask/sender_mask: optional (N,)/(S,) bool validity masks for
    padded stacked execution — padding must be a SUFFIX (real members are
    positions 0..N_g-1, real senders ranks 0..S_g-1).  Masked slots never
    publish, never receive, and never hold back any min-reduction; the
    round-robin order is over the real sender count (a traced scalar), so
    the active sub-array evolves bit-identically to an unpadded sweep.

    Returns (new_state, delivered_batch_sizes (N,)).
    """
    n_members = state.recv_counts.shape[0]
    n_senders = state.published.shape[0]
    ranks = jnp.arange(n_senders)
    masked = member_mask is not None or sender_mask is not None
    if masked:
        member_mask = (jnp.ones(n_members, bool) if member_mask is None
                       else jnp.asarray(member_mask))
        sender_mask = (jnp.ones(n_senders, bool) if sender_mask is None
                       else jnp.asarray(sender_mask))
        s_eff = jnp.sum(sender_mask.astype(jnp.int32))
        big = jnp.iinfo(jnp.int32).max

        def prefix(counts):
            return sst.rr_prefix_masked(counts, sender_mask, s_eff)
    else:
        prefix = sst.rr_prefix

    # --- receive predicate (all nodes): consume everything visible -------
    if receive_fn is None:
        recv_counts = jnp.maximum(state.recv_counts, state.pub_vis)
    else:
        valid = (member_mask[:, None] & sender_mask[None, :]) if masked \
            else None
        recv_counts = receive_fn(state.pub_vis, state.recv_counts, valid)
    received_num = (prefix(recv_counts) - 1).astype(jnp.int32)
    received_num = jnp.maximum(received_num, state.received_num)

    # --- null predicate (sender nodes) -----------------------------------
    if isinstance(null_send, bool) and not null_send:
        nulls = jnp.zeros_like(state.published)
    else:
        sender_rows = recv_counts[:n_senders]                  # (S, S)
        have = sender_rows > 0
        if masked:
            have = have & sender_mask[None, :]
        tgt = nullsend.null_target(
            ranks[:, None], sender_rows - 1, ranks[None, :])
        tgt = jnp.where(have, tgt, 0)
        tgt = jnp.where(ranks[None, :] == ranks[:, None], 0, tgt)
        target = jnp.max(tgt, axis=-1)                         # (S,)
        next_idx = state.published + app_ready                 # after sends
        nulls = jnp.maximum(target - next_idx, 0)
        nulls = jnp.where(app_ready > 0, 0, nulls)
        # traced flag (run_batch grids): a disabled point masks to zero
        nulls = jnp.where(jnp.asarray(null_send), nulls, 0)
        if masked:
            nulls = jnp.where(sender_mask, nulls, 0)

    # --- send predicate (sender nodes), ring-window capped ----------------
    diag = jnp.arange(n_members)
    deliv_vis_now = state.deliv_vis.at[diag, diag].set(state.delivered_num)
    if masked:
        deliv_vis_now = jnp.where(member_mask[None, :], deliv_vis_now, big)
    min_seq = deliv_vis_now.min(axis=1)[:n_senders]            # (S,)
    if masked:
        deliv_counts = sst.sender_counts_masked(min_seq + 1, s_eff,
                                                n_senders)     # (S, S)
    else:
        deliv_counts = sst.sender_counts(min_seq + 1, n_senders)
    own_deliv = deliv_counts[ranks, ranks]
    cap = own_deliv + window
    sendable = jnp.clip(cap - state.published, 0)
    app_pub = jnp.minimum(app_ready, sendable)
    if masked:
        app_pub = jnp.where(sender_mask, app_pub, 0)
    published = state.published + app_pub + nulls

    # own publishes are received locally immediately
    own = jnp.zeros_like(recv_counts).at[ranks, ranks].set(published)
    recv_counts = jnp.maximum(recv_counts, own)
    received_num = jnp.maximum(
        received_num, (prefix(recv_counts) - 1).astype(jnp.int32))

    # --- delivery predicate: min over *visible* received_num --------------
    # own entry is authoritative; other members' entries lag one round
    recv_vis = state.recv_vis.at[diag, diag].set(received_num)
    recv_vis_eff = jnp.where(member_mask[None, :], recv_vis, big) \
        if masked else recv_vis
    stable = recv_vis_eff.min(axis=1)                          # (N,)
    delivered_num = jnp.maximum(state.delivered_num, stable)
    batch = delivered_num - state.delivered_num

    # --- "wire": visibility catches up to this round's authoritative rows -
    new = SweepState(
        published=published,
        pub_vis=jnp.maximum(state.pub_vis, published[None, :]),
        recv_counts=recv_counts,
        received_num=received_num,
        recv_vis=jnp.maximum(recv_vis, received_num[None, :]),
        delivered_num=delivered_num,
        deliv_vis=jnp.maximum(state.deliv_vis, delivered_num[None, :]),
        app_sent=state.app_sent + app_pub,
        nulls_sent=state.nulls_sent + nulls,
    )
    return new, batch


def run_rounds(state: SweepState, app_schedule: Array, *,
               window: int = 1 << 30, null_send: bool = True
               ) -> Tuple[SweepState, Array]:
    """lax.scan over rounds.  app_schedule: (T, S) messages ready per round.
    Returns final state and (T, N) delivered batch sizes."""

    def body(st, ready):
        st, batch = sweep(st, ready, window=window, null_send=null_send)
        return st, batch

    return jax.lax.scan(body, state, app_schedule)


def step_backlog(state: SweepState, backlog: Array, ready: Array, *,
                 window=1 << 30, null_send=True, receive_fn=None,
                 member_mask=None, sender_mask=None):
    """One protocol round with the DES app-queue semantics: messages the
    ring window throttles are requeued into ``backlog``, not dropped.

    This is the body :func:`scan_rounds` scans AND the per-round step the
    streaming entry points drive (:class:`repro.core.group.GroupStream`),
    so a streamed sequence of rounds is bit-identical to the scanned
    schedule by construction — same function, same arithmetic.

    Returns ``((new_state, new_backlog), (delivered_batch (N,),
    app_published (S,), nulls_published (S,)))``.
    """
    want = backlog + ready
    new, batch = sweep(state, want, window=window, null_send=null_send,
                       receive_fn=receive_fn, member_mask=member_mask,
                       sender_mask=sender_mask)
    pub = new.app_sent - state.app_sent
    return (new, want - pub), (batch, pub, new.nulls_sent - state.nulls_sent)


def scan_rounds(state: SweepState, app_schedule: Array, *,
                window=1 << 30, null_send=True, receive_fn=None,
                member_mask=None, sender_mask=None, backlog0=None
                ) -> Tuple[SweepState, Tuple[Array, Array, Array]]:
    """lax.scan over :func:`step_backlog` with full per-round traces.

    Window-throttled messages are requeued, not dropped — the DES app-queue
    semantics the Group backends need.  app_schedule: (T, S) app messages
    becoming ready per round.  ``window``/``null_send`` may be traced
    scalars, and ``member_mask``/``sender_mask`` padded-validity masks
    (see :func:`sweep`).  ``receive_fn``, when given, must follow the
    3-arg contract ``(pub_vis, recv_counts, valid) -> new recv_counts``
    documented on :func:`sweep`.

    ``backlog0`` is the epoch-carry initial backlog (DESIGN.md Sec. 7):
    a new view's scan starts with the previous view's undelivered app
    messages already queued — per-sender resend counts from the
    virtual-synchrony cut — so they publish ahead of (well, merged
    FIFO-consistently with) the new view's own schedule.  ``None`` means
    a fresh epoch (zeros); a scan with ``backlog0=b`` is bit-identical
    to one whose round-0 schedule row is incremented by ``b``
    (``step_backlog`` merges ``backlog + ready`` before the sweep).

    Returns (final_state, (delivered_batches (T, N), app_published (T, S),
    nulls_published (T, S))) — everything delivery-log reconstruction and
    the in-graph cost model consume, as arrays.
    """
    n_senders = state.published.shape[0]

    def body(carry, ready):
        st, backlog = carry
        return step_backlog(st, backlog, ready, window=window,
                            null_send=null_send, receive_fn=receive_fn,
                            member_mask=member_mask,
                            sender_mask=sender_mask)

    if backlog0 is None:
        backlog0 = jnp.zeros((n_senders,), jnp.int32)
    carry = (state, jnp.asarray(backlog0, jnp.int32))
    (state, _), traces = jax.lax.scan(body, carry, app_schedule)
    return state, traces


def quiescent_stacked(states: SweepState, backlogs: Array,
                      n_members=None, n_senders=None) -> Array:
    """In-graph quiescence over a stacked (G-leading) state: no backlog
    anywhere and every PUBLISHED message delivered by every real member
    — the same strict test :meth:`repro.core.group.GroupStream.quiescent`
    applies host-side (delivered >= every sender's last published seq,
    not merely the rr prefix; see that method for why the prefix test
    strands window-throttled tails).  This is the loop-exit predicate of
    device-resident drains (the fused serve program scans rounds until
    this holds, with zero host round-trips — DESIGN.md Sec. 6).

    ``n_members``/``n_senders`` optionally mask padded lanes ((G,) int
    real counts); ``None`` means the stack is homogeneous/unpadded.
    Returns a scalar bool array.
    """
    g, n_max = states.delivered_num.shape
    s_max = states.published.shape[1]
    ranks = jnp.arange(s_max)
    pub = states.published                              # (G, S)
    sender_valid = pub > 0
    backlog_ok = jnp.asarray(backlogs) == 0
    if n_senders is not None:
        lane = ranks[None, :] < jnp.asarray(n_senders)[:, None]
        sender_valid = sender_valid & lane
        backlog_ok = backlog_ok | ~lane
    last_seq = (pub - 1) * (jnp.asarray(n_senders)[:, None]
                            if n_senders is not None else s_max) \
        + ranks[None, :]
    need = jnp.max(jnp.where(sender_valid, last_seq, -1), axis=1)  # (G,)
    deliv = states.delivered_num                        # (G, N)
    if n_members is not None:
        rows = jnp.arange(n_max)[None, :] < jnp.asarray(n_members)[:, None]
        deliv = jnp.where(rows, deliv, jnp.iinfo(jnp.int32).max)
    return jnp.all(backlog_ok) & jnp.all(deliv >= need[:, None])


def batch_states(n_members: int, n_senders: int, batch: int) -> SweepState:
    """A fresh SweepState broadcast over a leading (B,) axis — the carry
    layout :func:`run_stacked` expects over its subgroup axis (and, with a
    second broadcast, :func:`run_stacked_batch` over (B, G))."""
    state = SweepState.init(n_members, n_senders)
    return jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x, (batch,) + x.shape), state)


# ---------------------------------------------------------------------------
# Stacked multi-subgroup execution (paper Sec. 2.4, taken across subgroups)
# ---------------------------------------------------------------------------
#
# A whole group — G subgroups padded to a common (N_max, S_max) with
# validity masks — sweeps as ONE program: vmap over the subgroup axis of
# the masked scan.  The subgroups are protocol-independent, so each padded
# lane evolves bit-identically to its own unpadded run; the Group backends
# slice each subgroup's traces back to its own round budget afterwards.

def run_stacked(states: SweepState, app_schedules: Array, *, windows: Array,
                null_send, member_masks=None, sender_masks=None,
                receive_fn=None, backlogs0=None
                ) -> Tuple[SweepState, Tuple[Array, Array, Array]]:
    """All G subgroups of one group scenario in a single fused scan.

    states: SweepState with leading (G,) leaves over the padded
    (N_max, S_max) shape (see :func:`batch_states`); app_schedules:
    (G, T, S_max) padded schedules; windows: (G,) int32 per-subgroup ring
    windows; null_send: one scalar flag (a group-level setting — traced
    OK); member_masks/sender_masks: (G, N_max)/(G, S_max) bool validity,
    or None when every subgroup already fills the padded shape (a
    homogeneous stack skips the masked arithmetic entirely); backlogs0:
    (G, S_max) int32 epoch-carry initial backlogs (the previous view's
    resend counts — see :func:`scan_rounds`), or None for fresh epochs.
    Returns stacked final states and (G, T, ...) traces.
    """
    g, n_max = states.recv_counts.shape[0], states.recv_counts.shape[1]
    s_max = states.published.shape[1]
    if backlogs0 is None:
        backlogs0 = jnp.zeros((g, s_max), jnp.int32)
    if member_masks is None and sender_masks is None:
        def one_unmasked(st, sched, w, b0):
            return scan_rounds(st, sched, window=w, null_send=null_send,
                               receive_fn=receive_fn, backlog0=b0)

        return jax.vmap(one_unmasked)(states, app_schedules,
                                      jnp.asarray(windows),
                                      jnp.asarray(backlogs0))

    if member_masks is None:
        member_masks = jnp.ones((g, n_max), bool)
    if sender_masks is None:
        sender_masks = jnp.ones((g, s_max), bool)

    def one(st, sched, w, mm, sm, b0):
        return scan_rounds(st, sched, window=w, null_send=null_send,
                           receive_fn=receive_fn, member_mask=mm,
                           sender_mask=sm, backlog0=b0)

    return jax.vmap(one)(states, app_schedules, jnp.asarray(windows),
                         jnp.asarray(member_masks),
                         jnp.asarray(sender_masks),
                         jnp.asarray(backlogs0))


def stream_stacked(states: SweepState, backlogs: Array, ready: Array, *,
                   windows: Array, null_send, member_masks=None,
                   sender_masks=None, receive_fn=None):
    """ONE round of all G subgroups — the streaming form of
    :func:`run_stacked` (same per-subgroup :func:`step_backlog`, so T
    streamed rounds are bit-identical to one T-round stacked scan fed the
    same per-round ``ready`` rows).

    states: SweepState with leading (G,) leaves; backlogs: (G, S_max)
    int32 window-throttled carry-over; ready: (G, S_max) int32 app
    messages becoming ready this round (padded lanes must be 0).
    Returns ``((states, backlogs), (batch (G, N_max), app_pub (G, S_max),
    nulls (G, S_max)))``.
    """
    g = states.recv_counts.shape[0]
    n_max = states.recv_counts.shape[1]
    s_max = states.published.shape[1]
    if member_masks is None and sender_masks is None:
        def one_unmasked(st, bk, rd, w):
            return step_backlog(st, bk, rd, window=w, null_send=null_send,
                                receive_fn=receive_fn)

        return jax.vmap(one_unmasked)(states, backlogs, ready,
                                      jnp.asarray(windows))
    if member_masks is None:
        member_masks = jnp.ones((g, n_max), bool)
    if sender_masks is None:
        sender_masks = jnp.ones((g, s_max), bool)

    def one(st, bk, rd, w, mm, sm):
        return step_backlog(st, bk, rd, window=w, null_send=null_send,
                            receive_fn=receive_fn, member_mask=mm,
                            sender_mask=sm)

    return jax.vmap(one)(states, backlogs, ready, jnp.asarray(windows),
                         jnp.asarray(member_masks),
                         jnp.asarray(sender_masks))


def run_stacked_batch(states: SweepState, app_schedules: Array, *,
                      windows: Array, null_sends: Array, member_masks=None,
                      sender_masks=None, receive_fn=None
                      ) -> Tuple[SweepState, Tuple[Array, Array, Array]]:
    """B scenario points x G subgroups as one doubly-batched program.

    states: SweepState with leading (B, G) leaves; app_schedules:
    (B, G, T, S_max); windows: (B, G) int32; null_sends: (B,) bool; masks:
    (G, N_max)/(G, S_max) shared across points (run_batch grids never vary
    membership shapes), or None for a homogeneous unpadded stack.  The
    caller may shard the leading B axis across devices (see
    :mod:`repro.core.placement`) — every point is independent, so the
    program is embarrassingly data-parallel.
    """
    def point(st, sched, w, nf):
        return run_stacked(st, sched, windows=w, null_send=nf,
                           member_masks=member_masks,
                           sender_masks=sender_masks, receive_fn=receive_fn)

    return jax.vmap(point)(states, app_schedules, jnp.asarray(windows),
                           jnp.asarray(null_sends))
