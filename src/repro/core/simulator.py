"""Deterministic discrete-event simulator of the Derecho/Spindle protocol.

This reproduces the paper's evaluation on CPU: N logical nodes run the
atomic-multicast protocol (SST + SMC + predicate sweeps) against the
calibrated RDMA cost model from :mod:`repro.core.costmodel`.  Every Spindle
optimization is a toggle, so the baseline and each incremental stage
(Fig. 5) are simulated like-for-like:

  * ``batch_receive`` / ``batch_delivery`` / ``batch_send`` — opportunistic
    batching per stage (Sec. 3.2).  Off = one event per predicate
    evaluation + an ack per event, as in baseline Derecho.
  * ``null_send`` — the null-send scheme (Sec. 3.3).
  * ``early_lock_release`` — restructured predicates: all RDMA posts happen
    after the lock is released, so the application thread prepares new
    messages concurrently with posting (Sec. 3.4).
  * ``batched_upcall`` / ``memcpy_delivery`` / ``memcpy_send`` — receiver
    delay mitigation (Secs. 3.5, 4.4).

The simulator is a sequential DES over per-node predicate-thread clocks:
the earliest node runs one *sweep* (evaluate all predicates over a snapshot
of its local SST copy), costs are charged per the cost model, and pushes
become timestamped wire writes applied at the destination with monotone
max-merge.  Per-pair FIFO ordering models RDMA's ordering guarantee.
"""

from __future__ import annotations

import dataclasses
import heapq
import itertools
import math
from collections import deque
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import costmodel, nullsend, smc, sst

# ---------------------------------------------------------------------------
# Configuration
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SubgroupSpec:
    members: Tuple[int, ...]          # node ids
    senders: Tuple[int, ...]          # subset of members, in rank order
    msg_size: int = 10240
    window: int = 100
    n_messages: int = 1000            # per sender (app messages)

    def __post_init__(self):
        assert set(self.senders) <= set(self.members)


@dataclasses.dataclass(frozen=True)
class SenderPattern:
    """Application sending behaviour for one (subgroup, sender)."""

    inter_send_delay_us: float = 0.0  # busy-wait after each send
    active: bool = True               # False => never sends (nulls cover it)
    # Per-sender app-message budget; None = the SubgroupSpec's n_messages.
    # The Group API lowers explicit per-sender send() counts through this.
    n_messages: Optional[int] = None


@dataclasses.dataclass(frozen=True)
class SpindleFlags:
    batch_receive: bool = True
    batch_delivery: bool = True
    batch_send: bool = True
    null_send: bool = True
    early_lock_release: bool = True
    batched_upcall: bool = True
    memcpy_delivery: bool = False
    memcpy_send: bool = False
    # DDS QoS knobs (Sec. 4.6): unordered skips the cross-node stability
    # wait (deliver in local receive order); disk_append models the
    # logged-storage QoS (SSD append in the delivery path).
    wait_stability: bool = True
    disk_append: bool = False

    @classmethod
    def baseline(cls) -> "SpindleFlags":
        return cls(batch_receive=False, batch_delivery=False,
                   batch_send=False, null_send=False,
                   early_lock_release=False, batched_upcall=False)

    @classmethod
    def spindle(cls) -> "SpindleFlags":
        return cls()


@dataclasses.dataclass(frozen=True)
class SimConfig:
    n_nodes: int
    subgroups: Tuple[SubgroupSpec, ...]
    flags: SpindleFlags = SpindleFlags.spindle()
    net: costmodel.NetworkModel = costmodel.RDMA_CX6
    host: costmodel.HostModel = costmodel.HOST_X86
    llc_bytes: int = 20 * 1024 * 1024
    upcall_extra_us: float = 0.0      # Sec. 3.5 delay-injection experiment
    max_time_us: float = 60e6
    max_sweeps: int = 3_000_000
    idle_tick_us: float = 2.0
    # Paper Sec. 4.2.1: "We measure bandwidth after a fixed number of
    # messages have been delivered."  When set, the run ends once every
    # member has delivered this many app messages (delayed/inactive senders
    # then do not drag the measurement window out).
    target_delivered: Optional[int] = None
    # patterns[(g, sender_node)] overrides the default continuous pattern
    patterns: Tuple[Tuple[Tuple[int, int], SenderPattern], ...] = ()

    def pattern(self, g: int, node: int) -> SenderPattern:
        for (pg, pn), pat in self.patterns:
            if pg == g and pn == node:
                return pat
        return SenderPattern()


# ---------------------------------------------------------------------------
# Results
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class SimResult:
    throughput_GBps: float            # delivered app bytes/node/us -> GB/s
    mean_latency_us: float
    p99_latency_us: float
    duration_us: float
    delivered_app_msgs: int
    nulls_sent: int
    rdma_writes: int
    post_time_us: float               # predicate-thread time posting writes
    predicate_time_us: float          # total predicate-thread busy time
    send_batches: List[int]
    recv_batches: List[int]
    deliv_batches: List[int]
    sweeps: int
    sender_blocked_us: float          # app-thread time waiting for a slot
    per_node_throughput: List[float]
    stalled: bool                     # ended without delivering everything

    def summary(self) -> Dict[str, float]:
        return {
            "throughput_GBps": round(self.throughput_GBps, 4),
            "mean_latency_us": round(self.mean_latency_us, 3),
            "p99_latency_us": round(self.p99_latency_us, 3),
            "nulls_sent": self.nulls_sent,
            "rdma_writes": self.rdma_writes,
            "post_time_us": round(self.post_time_us, 1),
            "mean_send_batch": round(float(np.mean(self.send_batches)), 2) if self.send_batches else 0.0,
            "mean_recv_batch": round(float(np.mean(self.recv_batches)), 2) if self.recv_batches else 0.0,
            "mean_deliv_batch": round(float(np.mean(self.deliv_batches)), 2) if self.deliv_batches else 0.0,
            "stalled": self.stalled,
        }


# ---------------------------------------------------------------------------
# Per-subgroup runtime state
# ---------------------------------------------------------------------------


class _Group:
    """Mutable protocol state for one subgroup."""

    def __init__(self, gid: int, spec: SubgroupSpec, cfg: SimConfig):
        self.gid = gid
        self.spec = spec
        n_m, n_s = len(spec.members), len(spec.senders)
        self.n_m, self.n_s = n_m, n_s
        self.member_pos = {n: i for i, n in enumerate(spec.members)}
        self.sender_rank = {n: i for i, n in enumerate(spec.senders)}
        # viewer-indexed local SST copies (viewer = member position)
        self.pub_seen = np.zeros((n_m, n_s), dtype=np.int64)      # counts
        self.recv_counts = np.zeros((n_m, n_s), dtype=np.int64)   # processed
        self.recv_seen = np.full((n_m, n_m), -1, dtype=np.int64)  # seq
        self.deliv_seen = np.full((n_m, n_m), -1, dtype=np.int64)
        # authoritative own state per sender
        self.published = np.zeros(n_s, dtype=np.int64)            # counts
        self.queued: List[deque] = [deque() for _ in range(n_s)]  # gen times
        self.generated = np.zeros(n_s, dtype=np.int64)
        self.next_ready = np.zeros(n_s, dtype=np.float64)
        # delivery-side
        self.delivered_app = np.zeros(n_m, dtype=np.int64)
        self.last_delivery_time = np.zeros(n_m, dtype=np.float64)
        # publish-order log per sender; NaN == null message
        self.gen_log: List[np.ndarray] = [
            np.full(256, np.nan) for _ in range(n_s)]
        self.gen_len = np.zeros(n_s, dtype=np.int64)
        self.active = np.array([cfg.pattern(gid, n).active
                                for n in spec.senders], dtype=bool)
        # per-sender app budget: pattern override, else the spec default
        self.msgs = np.array([
            (cfg.pattern(gid, n).n_messages
             if cfg.pattern(gid, n).n_messages is not None
             else spec.n_messages)
            for n in spec.senders], dtype=np.int64)
        self.total_app = int((self.msgs * self.active).sum())
        self.smc = smc.SMCConfig(window=spec.window,
                                 max_msg_size=spec.msg_size)

    def log_append(self, s: int, values: np.ndarray):
        need = int(self.gen_len[s]) + len(values)
        log = self.gen_log[s]
        if need > len(log):
            grown = np.full(max(need, 2 * len(log)), np.nan)
            grown[: len(log)] = log
            self.gen_log[s] = grown
            log = grown
        log[int(self.gen_len[s]): need] = values
        self.gen_len[s] = need

    def app_done(self, s: int) -> bool:
        return (not self.active[s]) or self.generated[s] >= self.msgs[s]


# ---------------------------------------------------------------------------
# The simulator
# ---------------------------------------------------------------------------


class Simulator:
    def __init__(self, cfg: SimConfig):
        self.cfg = cfg
        self.groups = [
            _Group(g, spec, cfg) for g, spec in enumerate(cfg.subgroups)]
        n = cfg.n_nodes
        # wire state: per (src, dst) FIFO of (arrival_us, apply_fn)
        self.wire: Dict[Tuple[int, int], deque] = {}
        self.inflight = 0
        self.link_free = np.zeros(n, dtype=np.float64)   # egress NIC clock
        self.pair_last = np.zeros((n, n), dtype=np.float64)
        self.app_block_until = np.zeros(n, dtype=np.float64)
        # metrics
        self.rdma_writes = 0
        self.post_time = np.zeros(n)
        self.pred_time = np.zeros(n)
        self.nulls_sent = 0
        self.send_batches: List[int] = []
        self.recv_batches: List[int] = []
        self.deliv_batches: List[int] = []
        self.latencies: List[float] = []
        self.sender_blocked = np.zeros(n)
        self.lock_busy = np.zeros(n)    # time the SST lock was held
        self.first_gen = math.inf
        self.sweeps = 0
        self.idle_streak = 0
        # SMC polling area -> cache behaviour (Sec. 4.1.2 decline at large w)
        area = sum(g.smc.region_bytes(g.n_m) for g in self.groups)
        self.poll_mult = 6.0 if area > cfg.llc_bytes else 1.0
        # groups a node participates in / sends in
        self.node_groups: List[List[_Group]] = [[] for _ in range(n)]
        for g in self.groups:
            for m in g.spec.members:
                self.node_groups[m].append(g)

    # -- wire ----------------------------------------------------------------

    def _post(self, src: int, t_post: float, dsts: Sequence[int],
              size: int, make_apply) -> float:
        """Post one write of `size` bytes to each dst. Returns the time the
        predicate thread finishes posting. make_apply: dst -> callable."""
        net = self.cfg.net
        t = t_post
        for dst in dsts:
            t += net.post_us
            self.rdma_writes += 1
            self.post_time[src] += net.post_us
            # serialization on the egress link, then (small-size) wire latency
            self.link_free[src] = max(self.link_free[src], t) + \
                net.serialization(size)
            arrival = self.link_free[src] + \
                net.wire_latency(min(size, 4096))
            arrival = max(arrival, self.pair_last[src, dst])  # FIFO per pair
            self.pair_last[src, dst] = arrival
            self.wire.setdefault((src, dst), deque()).append(
                (arrival, make_apply(dst)))
            self.inflight += 1
        return t

    def _drain(self, node: int, now: float):
        for src in range(self.cfg.n_nodes):
            q = self.wire.get((src, node))
            if not q:
                continue
            while q and q[0][0] <= now:
                _, fn = q.popleft()
                fn()
                self.inflight -= 1

    def _next_arrival(self, node: int) -> float:
        best = math.inf
        for src in range(self.cfg.n_nodes):
            q = self.wire.get((src, node))
            if q:
                best = min(best, q[0][0])
        return best

    # -- application thread ---------------------------------------------------

    def _cap(self, g: _Group, me: int, s: int) -> int:
        """Ring-reuse cap: highest publishable count for sender rank s."""
        deliv_counts = sst.sender_counts(g.deliv_seen[me] + 1, g.n_s)[:, s]
        return smc.publish_cap(int(deliv_counts.min()), g.spec.window)

    def _generate(self, g: _Group, node: int, now: float):
        """Advance the app thread of `node` (a sender in g) to `now`: queue
        every message whose ready-time has passed and that has a free slot."""
        s = g.sender_rank[node]
        if not g.active[s]:
            return
        me = g.member_pos[node]
        cap = self._cap(g, me, s)
        gen_floor = self.app_block_until[node]
        while (g.generated[s] < g.msgs[s]
               and int(g.published[s]) + len(g.queued[s]) < cap):
            ready = max(float(g.next_ready[s]), gen_floor)
            if ready > now:
                break
            if self.first_gen > ready:
                self.first_gen = ready
            g.queued[s].append(ready)
            g.generated[s] += 1
            delay = self.cfg.pattern(g.gid, node).inter_send_delay_us
            # in-place construction = writing msg_size bytes into the slot
            # plus slot-acquire/send-call overhead; with memcpy_send the
            # payload is additionally staged from an external buffer (4.4)
            construct = self.cfg.host.memcpy(g.spec.msg_size) + \
                self.cfg.host.app_send_api_us
            if self.cfg.flags.memcpy_send:
                construct += self.cfg.host.memcpy(g.spec.msg_size)
            # Sec. 3.4: message preparation shares the SST lock with the
            # predicate thread.  With a fair mutex the app gets the lock
            # between predicate critical sections, so its effective share
            # of wall time is (1 - lock_frac), where lock_frac is capped
            # by fairness (~55%).  Restructured predicates (early release)
            # exclude RDMA-post time from the critical section, shrinking
            # lock_frac — that is the Sec. 3.4 speedup mechanism.
            if now > 1.0:
                lock_frac = min(self.lock_busy[node] / now, 0.55)
                construct /= (1.0 - lock_frac)
            g.next_ready[s] = ready + max(delay + construct, 1e-3)

    # -- one predicate sweep ---------------------------------------------------

    def _sweep(self, node: int, now: float) -> Tuple[float, bool]:
        """Run one full predicate sweep for `node` starting at `now`.
        Returns (duration_us, did_work)."""
        cfg, host, flags = self.cfg, self.cfg.host, self.cfg.flags
        t = now
        did_work = False
        posts: List[Tuple[Sequence[int], int, object]] = []  # deferred

        def emit(dsts, size, make_apply, t_now):
            """Queue or post a write, honoring the lock-restructuring flag."""
            if flags.early_lock_release:
                # cost is charged when the deferred posts run (after unlock)
                posts.append((dsts, size, make_apply))
                return t_now
            return self._post(node, t_now, dsts, size, make_apply)

        for g in self.node_groups[node]:
            me = g.member_pos[node]
            t += host.lock_us + 3 * host.predicate_eval_us

            # ---- receive predicate ----
            if g.n_s:
                counts = g.pub_seen[me]
                fresh = np.maximum(counts - g.recv_counts[me], 0)
                if not flags.batch_receive:
                    fresh = np.minimum(fresh, 1)
                n_new = int(fresh.sum())
                t += host.slot_poll_us * self.poll_mult * (n_new + g.n_s)
                if n_new > 0:
                    did_work = True
                    self.recv_batches.append(n_new)
                    g.recv_counts[me] += fresh
                    new_recv = int(sst.rr_prefix(g.recv_counts[me])) - 1
                    if new_recv > g.recv_seen[me, me]:
                        g.recv_seen[me, me] = new_recv
                        others = [m for m in g.spec.members if m != node]
                        if others:
                            # the SST row push carries the coalesced counter;
                            # baseline acks more often because its sweeps
                            # consume at most one message per sender
                            t = emit(others, 64,
                                     self._mk_recv(g, me, new_recv), t)

            # ---- null-send predicate (Sec. 3.3) ----
            if flags.null_send and node in g.sender_rank and g.n_s > 1:
                s = g.sender_rank[node]
                next_idx = int(g.published[s]) + len(g.queued[s])
                n_nulls = int(nullsend.nulls_needed(
                    s, next_idx, g.recv_counts[me]))
                if n_nulls > 0 and not g.queued[s]:
                    did_work = True
                    self.nulls_sent += n_nulls
                    g.log_append(s, np.full(n_nulls, np.nan))
                    g.published[s] += n_nulls
                    g.pub_seen[me, s] = g.published[s]
                    others = [m for m in g.spec.members if m != node]
                    # "sends the determined number of nulls as a single
                    # integer" — one small write per member
                    t = emit(others, 64,
                             self._mk_pub(g, s, int(g.published[s])), t)

            # ---- delivery predicate ----
            if flags.wait_stability:
                stable = int(np.min(g.recv_seen[me]))
            else:  # unordered QoS: deliver in local receive order
                stable = int(g.recv_seen[me, me])
            lo = int(g.deliv_seen[me, me]) + 1
            if stable >= lo:
                n_deliv = (stable - lo + 1) if flags.batch_delivery else 1
                hi = lo + n_deliv - 1
                did_work = True
                self.deliv_batches.append(n_deliv)
                # resolve app vs null + latency, vectorized per sender
                n_app = 0
                for s in range(g.n_s):
                    k0 = max(0, math.ceil((lo - s) / g.n_s))
                    k1 = (hi - s) // g.n_s
                    if k1 < k0:
                        continue
                    seg = g.gen_log[s][k0:k1 + 1]
                    app_mask = ~np.isnan(seg)
                    cnt = int(app_mask.sum())
                    n_app += cnt
                    if cnt and me == 0:   # latency sampled at one receiver
                        self.latencies.extend((t - seg[app_mask]).tolist())
                g.delivered_app[me] += n_app
                if flags.batched_upcall:
                    t += host.upcall_batch_us + n_app * (
                        0.25 * host.upcall_us + cfg.upcall_extra_us)
                else:
                    t += n_app * (host.upcall_us + cfg.upcall_extra_us)
                if flags.memcpy_delivery:
                    t += n_app * host.memcpy(g.spec.msg_size)
                if flags.disk_append:   # logged-storage QoS: SSD append
                    t += n_app * (1.0 + g.spec.msg_size / (2.5 * 1e3))
                g.deliv_seen[me, me] = hi
                g.last_delivery_time[me] = t
                others = [m for m in g.spec.members if m != node]
                if others:
                    t = emit(others, 64, self._mk_deliv(g, me, hi), t)

            # ---- send predicate ----
            if node in g.sender_rank:
                s = g.sender_rank[node]
                self._generate(g, node, t)
                if g.queued[s]:
                    cap = self._cap(g, me, s)
                    n_send = int(min(len(g.queued[s]),
                                     cap - int(g.published[s])))
                    if not flags.batch_send:
                        n_send = min(n_send, 1)
                    if n_send > 0:
                        did_work = True
                        self.send_batches.append(n_send)
                        times = np.array([g.queued[s].popleft()
                                          for _ in range(n_send)])
                        g.log_append(s, times)
                        start_slot = int(g.published[s]) % g.spec.window
                        wraps = 2 if start_slot + n_send > g.spec.window else 1
                        g.published[s] += n_send
                        g.pub_seen[me, s] = g.published[s]
                        others = [m for m in g.spec.members if m != node]
                        pub = int(g.published[s])
                        if flags.batch_send:
                            # 1 write per member (2 on ring wraparound);
                            # whole slots pushed incl. leftover space
                            sizes = [(n_send - n_send // 2), n_send // 2] \
                                if wraps == 2 else [n_send]
                            for nw in sizes:
                                if nw:
                                    t = emit(others, nw * g.smc.slot_bytes,
                                             self._mk_pub(g, s, pub), t)
                        else:
                            for _ in range(n_send):
                                t = emit(others, g.smc.slot_bytes,
                                         self._mk_pub(g, s, pub), t)
                # app-thread slot-wait accounting
                if (not g.app_done(s) and not g.queued[s]
                        and g.next_ready[s] <= t):
                    self.sender_blocked[node] += max(t - now, 0.0)

        # ---- deferred posts: lock released first (Sec. 3.4) ----
        if flags.early_lock_release:
            self.app_block_until[node] = t   # app proceeds from lock release
            self.lock_busy[node] += t - now  # lock held: logic only
            for dsts, size, make_apply in posts:
                t = self._post(node, t, dsts, size, make_apply)
        else:
            # posts already happened inside the locked region; the app
            # thread could not prepare messages during any of it
            self.app_block_until[node] = t
            self.lock_busy[node] += t - now  # lock held: logic + posts

        self.pred_time[node] += t - now
        return t - now, did_work

    # write constructors — monotone max-merge applications ---------------------

    def _mk_recv(self, g: _Group, src_pos: int, val: int):
        def make(dst: int):
            dpos = g.member_pos[dst]

            def apply():
                g.recv_seen[dpos, src_pos] = max(
                    g.recv_seen[dpos, src_pos], val)
            return apply
        return make

    def _mk_deliv(self, g: _Group, src_pos: int, val: int):
        def make(dst: int):
            dpos = g.member_pos[dst]

            def apply():
                g.deliv_seen[dpos, src_pos] = max(
                    g.deliv_seen[dpos, src_pos], val)
            return apply
        return make

    def _mk_pub(self, g: _Group, sender: int, val: int):
        def make(dst: int):
            dpos = g.member_pos[dst]

            def apply():
                g.pub_seen[dpos, sender] = max(g.pub_seen[dpos, sender], val)
            return apply
        return make

    # -- main loop --------------------------------------------------------------

    def _done(self) -> bool:
        if self.cfg.target_delivered is not None:
            per_member = np.zeros(self.cfg.n_nodes, dtype=np.int64)
            involved = np.zeros(self.cfg.n_nodes, dtype=bool)
            for g in self.groups:
                for node in g.spec.members:
                    per_member[node] += g.delivered_app[g.member_pos[node]]
                    involved[node] = True
            return bool(np.all(per_member[involved]
                               >= self.cfg.target_delivered))
        for g in self.groups:
            if g.total_app and np.any(g.delivered_app < g.total_app):
                return False
        return True

    def run(self) -> SimResult:
        cfg = self.cfg
        # Explicit (time, node, seq) heap key — deterministic tie-break
        # shared with the two-phase event pass (repro.core.desgraph,
        # DESIGN.md Sec. 12): same-timestamp pops order by node id, never
        # by heapq insertion accidents, so permuting subgroup declaration
        # order cannot reorder the event timeline.
        seq = itertools.count()
        heap = [(0.0, node, next(seq)) for node in range(cfg.n_nodes)
                if self.node_groups[node]]
        heapq.heapify(heap)
        n_live = len(heap)
        while heap and self.sweeps < cfg.max_sweeps:
            now, node, _ = heapq.heappop(heap)
            if now > cfg.max_time_us:
                break
            self._drain(node, now)
            dur, did_work = self._sweep(node, now)
            self.sweeps += 1
            if did_work:
                self.idle_streak = 0
            else:
                self.idle_streak += 1
            if self._done():
                break
            # stall/quiescence detection: nothing in flight, nobody worked
            if (self.idle_streak > 30 * n_live and self.inflight == 0
                    and not self._any_app_pending()):
                break
            if did_work:
                nxt = now + max(dur, 0.05)
            else:
                pend = self._next_arrival(node)
                app = math.inf
                for g in self.node_groups[node]:
                    if node in g.sender_rank and not g.app_done(
                            g.sender_rank[node]):
                        app = min(app, float(
                            g.next_ready[g.sender_rank[node]]))
                nxt = min(pend, app)
                if not math.isfinite(nxt):
                    nxt = now + 50 * cfg.idle_tick_us
                nxt = max(nxt, now + cfg.idle_tick_us)
            heapq.heappush(heap, (nxt, node, next(seq)))
        return self._result()

    def _any_app_pending(self) -> bool:
        for g in self.groups:
            for s in range(g.n_s):
                if g.active[s] and (g.generated[s] < g.msgs[s]
                                    or g.queued[s]):
                    return True
        return False

    def _result(self) -> SimResult:
        per_node = []
        dur_all = 0.0
        delivered = 0
        for g in self.groups:
            delivered += int(g.delivered_app.sum())
        for node in range(self.cfg.n_nodes):
            b = 0.0
            end = 0.0
            for g in self.node_groups[node]:
                me = g.member_pos[node]
                b += float(g.delivered_app[me]) * g.spec.msg_size
                end = max(end, float(g.last_delivery_time[me]))
            start = self.first_gen if math.isfinite(self.first_gen) else 0.0
            if end > start and b > 0:
                per_node.append(b / (end - start) / 1e3)  # bytes/us -> GB/s
                dur_all = max(dur_all, end - start)
        lat = np.array(self.latencies) if self.latencies else np.array([0.0])
        return SimResult(
            throughput_GBps=float(np.mean(per_node)) if per_node else 0.0,
            mean_latency_us=float(lat.mean()),
            p99_latency_us=float(np.percentile(lat, 99)),
            duration_us=dur_all,
            delivered_app_msgs=delivered,
            nulls_sent=self.nulls_sent,
            rdma_writes=self.rdma_writes,
            post_time_us=float(self.post_time.sum()),
            predicate_time_us=float(self.pred_time.sum()),
            send_batches=self.send_batches,
            recv_batches=self.recv_batches,
            deliv_batches=self.deliv_batches,
            sweeps=self.sweeps,
            sender_blocked_us=float(self.sender_blocked.sum()),
            per_node_throughput=per_node,
            stalled=not self._done(),
        )


# ---------------------------------------------------------------------------
# Convenience builders
# ---------------------------------------------------------------------------


def single_subgroup(n_nodes: int, n_senders: Optional[int] = None,
                    msg_size: int = 10240, window: int = 100,
                    n_messages: int = 1000,
                    flags: SpindleFlags = SpindleFlags.spindle(),
                    **kw) -> SimConfig:
    senders = tuple(range(n_senders if n_senders is not None else n_nodes))
    spec = SubgroupSpec(members=tuple(range(n_nodes)), senders=senders,
                        msg_size=msg_size, window=window,
                        n_messages=n_messages)
    return SimConfig(n_nodes=n_nodes, subgroups=(spec,), flags=flags, **kw)


def run(cfg: SimConfig) -> SimResult:
    return Simulator(cfg).run()
