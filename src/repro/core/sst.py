"""SST — the Shared State Table (paper Sec. 2.2), adapted to JAX.

The SST models each node's local protocol state as a fixed set of
*monotonic* variables (counters that only grow, booleans that only flip
false->true).  Each node owns one row; remote rows are read from a local
copy that is refreshed by one-sided pushes.  Monotonicity is what makes
every Spindle optimization sound:

* pushes can be coalesced (advance a counter by +k in one write),
* a racing local update between lock-release and push is simply absorbed
  into the same push (Sec. 3.4),
* merging any stale/fresh mixture of copies with elementwise ``max`` is
  always safe.

Adaptation note (DESIGN.md Sec. 2): RDMA's cache-line atomicity and
write-ordering guarantees have no user-visible TPU analogue, so the
in-graph SST expresses the "guard" pattern as a data dependency instead:
``push_rows`` returns the merged table and every reader consumes it.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Mapping, Sequence

import jax
import jax.numpy as jnp
import numpy as np

Array = Any


# ---------------------------------------------------------------------------
# Schema
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SSTColumn:
    """One monotonic state variable, replicated per node (= per row)."""

    name: str
    shape: tuple = ()            # trailing shape of the per-row entry
    # int32, not int64: under 32-bit JAX builds (jax_enable_x64 off, the
    # default) an int64 schema would be silently downcast on the first
    # device transfer; declaring int32 keeps host and device tables
    # byte-identical.  Counters here are bounded by message counts, far
    # below 2**31.
    dtype: Any = np.int32
    init: int = -1               # paper: counters start from -1

    def empty(self, n_nodes: int, xp=np) -> Array:
        return xp.full((n_nodes,) + self.shape, self.init, dtype=self.dtype)


@dataclasses.dataclass(frozen=True)
class SSTSchema:
    columns: tuple

    def __post_init__(self):
        names = [c.name for c in self.columns]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate SST columns: {names}")

    def column(self, name: str) -> SSTColumn:
        for c in self.columns:
            if c.name == name:
                return c
        raise KeyError(name)

    def make_table(self, n_nodes: int, xp=np) -> Dict[str, Array]:
        """A full table: dict col -> (n_nodes, *shape)."""
        return {c.name: c.empty(n_nodes, xp) for c in self.columns}

    def row_bytes(self) -> int:
        return sum(int(np.prod(c.shape, dtype=np.int64)) *
                   np.dtype(c.dtype).itemsize for c in self.columns)


def multicast_schema(n_subgroups: int, window: int,
                     max_msg_size: int) -> SSTSchema:
    """The schema of Table 1: received_num / delivered_num per subgroup,
    plus SMC slot counters (payload bytes are accounted, not stored)."""
    return SSTSchema(columns=(
        SSTColumn("received_num", (n_subgroups,)),
        SSTColumn("delivered_num", (n_subgroups,)),
        # Published-message watermark per subgroup: the contiguous-scan of
        # the per-slot counters (Sec. 2.3) reduces to this integer; the
        # window/ring constraint is enforced in smc.py.
        SSTColumn("published_num", (n_subgroups,)),
        # Slot counters kept explicitly so the receive predicate's
        # slot-polling cost and the ring reuse rule are faithful.
        SSTColumn("slot_counter", (n_subgroups, window)),
    ))


# ---------------------------------------------------------------------------
# Monotone merge + row push (functional core; numpy or jnp)
# ---------------------------------------------------------------------------

def merge_tables(local: Mapping[str, Array],
                 incoming: Mapping[str, Array]) -> Dict[str, Array]:
    """Elementwise monotone merge — always safe for SST data."""
    return {k: jnp.maximum(local[k], incoming[k])
            if isinstance(local[k], jax.Array) or isinstance(incoming[k], jax.Array)
            else np.maximum(local[k], incoming[k])
            for k in local}


def update_own_row(table: Dict[str, Array], node: int, col: str,
                   value: Array, *, check: bool = True) -> Dict[str, Array]:
    """Functionally update node's own row of `col`.  Monotonicity is
    asserted for host (numpy) tables; jnp tables use max-merge."""
    cur = table[col][node]
    if isinstance(table[col], np.ndarray):
        if check and np.any(np.asarray(value) < cur):
            raise ValueError(
                f"non-monotonic SST update to {col}[{node}]: {cur} -> {value}")
        out = dict(table)
        new_col = table[col].copy()
        new_col[node] = value
        out[col] = new_col
        return out
    out = dict(table)
    out[col] = table[col].at[node].set(jnp.maximum(cur, value))
    return out


# ---------------------------------------------------------------------------
# Round-robin sequence arithmetic (Sec. 2.1 delivery order)
# ---------------------------------------------------------------------------
# Messages are M(i, k): sender rank i, sender index k.  Total order:
#   M(i1,k1) < M(i2,k2)  <=>  k1 < k2 or (k1 == k2 and i1 < i2)
# seq_num(i, k) = k * n_senders + i.

def seq_of(rank, index, n_senders: int):
    return index * n_senders + rank


def rank_of(seq, n_senders: int):
    return seq % n_senders


def index_of(seq, n_senders: int):
    return seq // n_senders


def rr_prefix(counts) -> Array:
    """Highest N such that the first N messages of the round-robin order
    are all present, given per-sender received counts.

    counts: (..., S) integer array; returns (...) array.
    ``received_num`` (a seq number) is then ``rr_prefix(counts) - 1``.
    """
    xp = jnp if isinstance(counts, jax.Array) else np
    m = xp.min(counts, axis=-1, keepdims=True)          # complete rounds
    ge = counts >= (m + 1)                               # can extend round m
    # run-length of True from rank 0: cumprod trick
    run = xp.cumprod(ge.astype(counts.dtype), axis=-1)
    extra = xp.sum(run, axis=-1)
    s = counts.shape[-1]
    return xp.squeeze(m, -1) * s + extra


def ragged_trim(received_num, alive) -> int:
    """The virtual-synchrony cut seq (paper Secs. 2.1, 3.3; DESIGN.md
    Sec. 7): the highest seq received by EVERY surviving member.

    received_num: (N,) per-member rr-prefix seq watermarks (the SST
    ``received_num`` column); alive: (N,) bool — True for members of the
    next view.  Messages with seq <= the trim are deliverable everywhere
    among the survivors (each member's *delivered* watermark is a min
    over its stale view of this column, so it can never exceed the trim
    — wedging delivers FORWARD to the trim, it never rolls a survivor
    back); messages beyond it are delivered nowhere and must be resent
    in the next view.  With no survivors the trim is -1 (nothing is
    stable for a view that no longer has observers).
    """
    received_num = np.asarray(received_num)
    alive = np.asarray(alive, dtype=bool)
    if not alive.any():
        return -1
    return int(received_num[alive].min())


def cascading_trim(received_num, alive_stages) -> list:
    """Fold a cascade of suspicion waves into one cut (DESIGN.md Sec. 7).

    ``alive_stages`` is the survivor mask after each successive wave of
    suspicions that landed while the wedge was in progress; each stage
    must be a subset of the previous one (suspicions are monotone within
    a view — a stage that *gains* a survivor is a caller bug and
    raises).  Returns the per-stage :func:`ragged_trim` values.

    The sequence is non-decreasing by construction while survivors
    remain: removing a member from the min-over-survivors can only RAISE
    the stable frontier.  That monotonicity is exactly why
    :meth:`repro.core.views.MembershipService.propose_and_install` may
    fold late suspicions into the pending cut instead of installing a
    doomed intermediate view — the final stage's trim (the one the
    installed view uses) covers every message any earlier stage would
    have delivered, so no delivery watermark ever rolls back.  The
    intermediate values exist for diagnostics: the chaos harness asserts
    the monotone property on every sampled cascade.  A stage with no
    survivors yields -1 (total failure; the membership service raises
    before using such a stage).
    """
    received_num = np.asarray(received_num)
    trims: list = []
    prev = None
    for alive in alive_stages:
        alive = np.asarray(alive, dtype=bool)
        if prev is not None and bool((alive & ~prev).any()):
            raise ValueError(
                "cascade stages must only shrink the survivor set "
                "(suspicions are monotone within a view)")
        trims.append(ragged_trim(received_num, alive))
        if (prev is not None and trims[-1] >= 0
                and trims[-1] < trims[-2]):  # pragma: no cover - by construction
            raise AssertionError("cascading trim rolled a watermark back")
        prev = alive
    return trims


def sender_counts(seq_prefix, n_senders: int):
    """Inverse-ish of rr_prefix: per-sender message counts contained in the
    first ``seq_prefix`` messages of the round-robin order."""
    xp = jnp if isinstance(seq_prefix, jax.Array) else np
    seq_prefix = xp.asarray(seq_prefix)
    full = seq_prefix[..., None] // n_senders
    rem = seq_prefix[..., None] % n_senders
    ranks = xp.arange(n_senders)
    return full + (ranks < rem)


# -- masked (padded-slot) forms for stacked multi-subgroup execution --------
#
# When several subgroups run as one program their sender axes are padded to
# a common S_max; the round-robin order of each subgroup is still over its
# OWN sender count.  ``mask`` marks the real sender slots (always a prefix:
# ranks 0..s_eff-1) and ``s_eff`` is their (possibly traced) count.  With a
# full mask these reduce exactly to rr_prefix / sender_counts.

def rr_prefix_masked(counts, mask, s_eff) -> Array:
    """:func:`rr_prefix` over the masked prefix of the sender axis.

    counts: (..., S) integer; mask: (S,) or (..., S) bool, True on the
    first ``s_eff`` slots; s_eff: scalar (traced OK).  Padded slots never
    extend the prefix and never hold it back.  Like the unmasked forms,
    dispatches on the input type (jnp under trace, numpy host-side) so
    the des stream's numpy round mirror (DESIGN.md Sec. 12) shares the
    exact trim arithmetic the compiled backends run.
    """
    xp = jnp if isinstance(counts, jax.Array) else np
    counts = xp.asarray(counts)
    mask = xp.asarray(mask)
    big = xp.iinfo(counts.dtype).max
    m = xp.min(xp.where(mask, counts, big), axis=-1, keepdims=True)
    ge = (counts >= m + 1) & mask
    run = xp.cumprod(ge.astype(counts.dtype), axis=-1)
    extra = xp.sum(run, axis=-1)
    return xp.squeeze(m, -1) * s_eff + extra


def sender_counts_masked(seq_prefix, s_eff, n_slots: int) -> Array:
    """:func:`sender_counts` with a traced effective sender count, padded
    to ``n_slots`` columns (entries at ranks >= s_eff are meaningless and
    must be masked by the caller).  xp-dispatched like
    :func:`rr_prefix_masked`."""
    xp = jnp if isinstance(seq_prefix, jax.Array) else np
    seq_prefix = xp.asarray(seq_prefix)
    full = seq_prefix[..., None] // s_eff
    rem = seq_prefix[..., None] % s_eff
    ranks = xp.arange(n_slots)
    return full + (ranks < rem)


# ---------------------------------------------------------------------------
# In-graph SST: shard_map push of every node's own row
# ---------------------------------------------------------------------------

def push_rows(own_row: Dict[str, Array], local_copy: Dict[str, Array],
              axis_name: str) -> Dict[str, Array]:
    """Inside shard_map: every participant contributes its own row (leading
    axis 1) and receives the monotone-merged full table.

    This is the TPU analogue of "push my row to every subgroup member":
    one fused all-gather replaces n-1 one-sided writes, and the monotone
    ``max`` with the stale local copy makes re-delivery/reordering harmless
    (exactly the property Sec. 3.4 exploits).
    """
    gathered = {k: jax.lax.all_gather(v[0], axis_name) for k, v in own_row.items()}
    return {k: jnp.maximum(gathered[k], local_copy[k]) for k in gathered}


def make_push_rows(mesh: jax.sharding.Mesh, axis_name: str) -> Callable:
    """A jittable, mesh-closed version of :func:`push_rows`.

    own_row entries are sharded (one row per device along ``axis_name``);
    local_copy entries are replicated full tables.
    """
    from jax import shard_map
    from jax.sharding import PartitionSpec as P

    def _inner(own_row, local_copy):
        return push_rows(own_row, local_copy, axis_name)

    row_spec = P(axis_name)
    full_spec = P()

    # A PartitionSpec acts as a pytree prefix: row_spec covers every leaf of
    # own_row, full_spec every leaf of local_copy.
    fn = shard_map(_inner, mesh=mesh, in_specs=(row_spec, full_spec),
                   out_specs=full_spec)
    return jax.jit(fn)
