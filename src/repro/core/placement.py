"""Device-placement policy for the stacked batch programs.

The batched stacked sweep (:func:`repro.core.sweep.run_stacked_batch`)
is embarrassingly data-parallel over its leading scenario-grid axis, so a
grid of B points can run one shard per device instead of one vmapped
program on a single device — the ROADMAP's sharding/multi-device step,
wired through the same `shard_map` machinery the training plane already
uses (:mod:`repro.core.gradsync`, :mod:`repro.launch.mesh`).

Policy (see :func:`shard_count`, DESIGN.md Sec. 3): shard over the
LARGEST device count that evenly divides the batch — deterministic per
process, so it is safe inside compile-cache keys; when that is 1 (single
device, or an indivisible batch) callers fall back to plain vmap —
graceful degradation on a CPU-only host.  The mesh reuses
:func:`repro.launch.mesh.make_smoke_mesh`'s "whatever devices exist"
construction (and its ``data`` axis name) when every device participates,
trimming to a prefix of ``jax.devices()`` otherwise.

The shard_map wrapper passes ``check_rep=False``: shard_map's replication
analysis has no rule for ``pallas_call``, so the pallas backend's sharded
grid would crash with the check on — and nothing here relies on
replication tracking (every output is sharded exactly like the inputs;
there is no cross-shard communication to analyze).
"""

from __future__ import annotations

from typing import Callable

import jax
import numpy as np

from repro.launch import mesh as mesh_mod

# The batch axis rides the launch-plane's data-parallel axis name so the
# same mesh conventions serve both planes.
BATCH_AXIS = "data"


def shard_count(batch: int) -> int:
    """How many devices a B-point grid will shard over: the largest device
    count that evenly divides ``batch`` (1 = vmap fallback).  Deterministic
    per process — safe to use in compile-cache keys."""
    n_dev = len(jax.devices())
    if batch <= 0 or n_dev <= 1:
        return 1
    for d in range(min(n_dev, batch), 0, -1):
        if batch % d == 0:
            return d
    return 1


def batch_mesh(n_shards: int) -> jax.sharding.Mesh:
    """A 1-D mesh over the first ``n_shards`` devices, axis ``data``."""
    devices = jax.devices()
    if n_shards == len(devices):
        return mesh_mod.make_smoke_mesh()
    return jax.sharding.Mesh(np.asarray(devices[:n_shards]), (BATCH_AXIS,))


def shard_over_batch(fn: Callable, n_shards: int,
                     n_batched_args: int) -> Callable:
    """shard_map ``fn`` over the leading batch axis of its first
    ``n_batched_args`` positional arguments (every output is batched too).

    Each shard sees its ``B/n_shards`` slice of the grid; since grid
    points are independent there is no cross-shard communication — the
    sharded program is the vmapped program, n_shards times narrower.
    """
    try:
        from jax import shard_map            # jax >= 0.5 spelling
    except ImportError:                      # this container's 0.4.x
        from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    mesh = batch_mesh(n_shards)
    axis = mesh.axis_names[0]
    spec = P(axis)
    in_specs = tuple(spec for _ in range(n_batched_args))
    try:
        # check_rep's replication analysis has no rule for pallas_call,
        # so the pallas backend's sharded grid would crash with it on —
        # and nothing here relies on replication tracking (every output
        # is sharded like the inputs).
        return shard_map(fn, mesh=mesh, in_specs=in_specs, out_specs=spec,
                         check_rep=False)
    except TypeError:                        # kwarg renamed in newer jax
        return shard_map(fn, mesh=mesh, in_specs=in_specs, out_specs=spec)
