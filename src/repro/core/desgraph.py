"""Phase 1 of the two-phase DES: timestamp events, emit an event graph.

DESIGN.md Sec. 12: the legacy :class:`repro.core.simulator.Simulator`
charges the full per-predicate Python machinery per event — every wire
write allocates a closure per destination and every drain scans per-pair
deques — which caps cross-backend conformance at toy fleet sizes.  This
module keeps the *identical* event-level timeline (same heap order, same
IEEE-754 cost arithmetic, same SST max-merge semantics) but replaces the
per-destination Python objects with vectorized *wire streams*:

* one :class:`_Stream` per (subgroup, source) carries every SST write
  the node broadcasts as a ``(value, cell, arrival-vector)`` record —
  the n-1 per-destination closures of ``Simulator._post`` become one
  numpy cumsum over the egress-link serialization chain;
* ``head_in[dst, src]`` holds the earliest pending arrival per ordered
  pair, so draining a node is one vectorized due-scan plus one
  ``bisect`` per due stream; each consumed record applies under the
  monotone-max guard, exactly the legacy per-record SST max-merge;
* the heap uses the explicit ``(time, node, seq)`` tie-break key shared
  with the legacy loop, so permuting subgroup declaration order cannot
  reorder same-timestamp events.

The output is a :class:`DesGraph` — per-sweep, per-delivery and
per-publish event arrays plus the final per-subgroup protocol state —
which :mod:`repro.core.desreplay` (phase 2) replays vectorized into the
delivery logs, latencies and :class:`repro.core.simulator.SimResult`
bit-identically to the legacy single-phase loop.
"""

from __future__ import annotations

import dataclasses
import heapq
import itertools
import math
from typing import Dict, List, Tuple

import numpy as np

from repro.core import nullsend, simulator as sim, sst

__all__ = ["DesGraph", "Phase1", "simulate"]


class _Stream:
    """All SST counter writes from one source node in one subgroup.

    Every counter a node broadcasts (its receive/delivery watermarks,
    its publish count) shares the same destination set, so one merged
    stream per (subgroup, source) carries them all: per record the
    written value plus its destination ``(mat, col)`` cell and the
    ``(ndst,)`` arrival-time vector.  Arrivals per destination are
    nondecreasing (the FIFO ``pair_last`` clamp), so the drain's
    due-scan per destination stops at the first not-yet-due record;
    applying each consumed record under the monotone-max guard is
    exactly the legacy per-record max-merge.
    ``ptr`` is the per-destination count of consumed records; records
    every destination has consumed are pruned in batches, with the
    trigger scaled to the destination count so retained wire state stays
    O(recent) even at 4096 nodes.
    """

    __slots__ = ("g", "p", "dsts", "ptr", "vals", "mats", "cols",
                 "arrs", "base", "nrec", "prune_at")

    def __init__(self, g, p: int, dsts: np.ndarray):
        self.g = g
        self.p = p                      # member position of the source
        self.dsts = dsts
        self.ptr = np.zeros(len(dsts), dtype=np.int64)
        self.vals: List[int] = []
        self.mats: List[np.ndarray] = []
        self.cols: List[int] = []
        self.arrs: List[np.ndarray] = []    # per record: (ndst,) float64
        self.base = 0                   # absolute index of vals[0]
        self.nrec = 0
        self.prune_at = max(8, 16384 // max(len(dsts), 1))


@dataclasses.dataclass
class DesGraph:
    """The compact event/delivery graph phase 1 emits (DESIGN.md Sec. 12).

    Event arrays are in timeline order.  ``groups`` are the final
    :class:`repro.core.simulator._Group` states (gen logs, SST copies,
    delivery watermarks) — phase 2 reads, never mutates, them.
    """

    cfg: sim.SimConfig
    groups: List
    node_groups: List
    # per-sweep events
    sweep_node: np.ndarray       # (E,) int32
    sweep_time: np.ndarray       # (E,) float64 — sweep start
    sweep_dur: np.ndarray        # (E,) float64
    sweep_work: np.ndarray       # (E,) bool
    # per-delivery events (one per delivery-predicate firing)
    deliv_gid: np.ndarray        # (D,) int32
    deliv_member: np.ndarray     # (D,) int32 — member position
    deliv_lo: np.ndarray         # (D,) int64 — first delivered seq
    deliv_hi: np.ndarray         # (D,) int64 — last delivered seq
    deliv_napp: np.ndarray       # (D,) int64 — app messages in [lo, hi]
    deliv_time: np.ndarray       # (D,) float64 — pre-upcall timestamp
    # per-publish events (apps and nulls)
    pub_gid: np.ndarray          # (P,) int32
    pub_rank: np.ndarray         # (P,) int32 — sender rank
    pub_count: np.ndarray        # (P,) int64
    pub_is_null: np.ndarray      # (P,) bool
    pub_time: np.ndarray         # (P,) float64
    # batch-size traces (legacy order)
    send_batches: List[int]
    recv_batches: List[int]
    deliv_batches: List[int]
    # scalar / per-node accounting
    rdma_writes: int
    nulls_sent: int
    sweeps: int
    post_time: np.ndarray
    pred_time: np.ndarray
    sender_blocked: np.ndarray
    lock_busy: np.ndarray
    first_gen: float
    stalled: bool


class Phase1(sim.Simulator):
    """The slimmed event-level pass (DESIGN.md Sec. 12, phase 1).

    Inherits configuration lowering, per-subgroup state, the app thread
    and the cost model from :class:`repro.core.simulator.Simulator`;
    overrides only the wire (`_post`/`_drain`/`_next_arrival`) with the
    vectorized stream machinery and the sweep/run loop with versions
    that record the event graph instead of doing per-event Python work.
    """

    def __init__(self, cfg: sim.SimConfig):
        super().__init__(cfg)
        n = cfg.n_nodes
        # earliest pending arrival per (dst, src); inf = nothing in flight
        self.head_in = np.full((n, n), np.inf)
        self._streams: Dict[Tuple[int, int], _Stream] = {}
        # per node: its (gid, member position) pairs — the drain derives
        # each due pair's stream key and destination slot from these
        # instead of materializing O(N^2) registration entries
        self._node_ginfo: List[List[Tuple[int, int]]] = [
            [(g.gid, g.member_pos[node]) for g in self.node_groups[node]]
            for node in range(n)]
        # event records (lists while building; arrays in the DesGraph)
        self._ev_sweep: List[Tuple[int, float, float, bool]] = []
        self._ev_deliv: List[Tuple[int, int, int, int, int, float]] = []
        self._ev_pub: List[Tuple[int, int, int, bool, float]] = []

    # -- wire streams --------------------------------------------------------

    def _stream_for(self, g, p: int, src: int) -> _Stream:
        key = (g.gid, src)
        st = self._streams.get(key)
        if st is None:
            dsts = np.array([m for m in g.spec.members if m != src],
                            dtype=np.int64)
            st = _Stream(g, p, dsts)
            self._streams[key] = st
        return st

    def _post_record(self, src: int, t0: float, st: _Stream, size: int,
                     val: int, mat: np.ndarray, col: int) -> float:
        """One write of ``size`` bytes to every stream destination —
        ``Simulator._post`` with the per-destination loop replaced by
        cumsum chains over the identical float arithmetic.

        The egress-link recurrence ``L_i = fl(max(L_{i-1}, t_i) + ser)``
        splits into two exactly-vectorizable regimes: with ``ser >=
        post_us`` the link is busy from the second post onward (a pure
        serialization cumsum), otherwise a busy cumsum prefix is
        followed by an idle-forever tail ``fl(t_i + ser)`` — both by
        monotonicity of IEEE rounding, so the chain is bit-identical to
        the sequential loop.
        """
        n = len(st.dsts)
        if n == 0:
            return t0
        net = self.cfg.net
        post_us = net.post_us
        ser = net.serialization(size)
        # predicate-thread post clock: t_i = t0 + i * post_us, sequential
        tc = np.empty(n + 1)
        tc[0] = t0
        tc[1:] = post_us
        np.cumsum(tc, out=tc)
        link0 = self.link_free[src]
        if ser >= post_us:
            L = np.empty(n)
            L[0] = max(link0, tc[1]) + ser
            L[1:] = ser
            np.cumsum(L, out=L)
        else:
            B = np.empty(n + 1)
            B[0] = link0
            B[1:] = ser
            np.cumsum(B, out=B)
            idle = B[:-1] < tc[1:]
            j = int(np.argmax(idle)) if idle.any() else n
            L = np.empty(n)
            L[:j] = B[1:j + 1]
            L[j:] = tc[j + 1:] + ser
        self.link_free[src] = L[-1]
        wl = net.wire_latency(min(size, 4096))
        arr = np.maximum(L + wl, self.pair_last[src, st.dsts])
        self.pair_last[src, st.dsts] = arr
        pc = np.empty(n + 1)
        pc[0] = self.post_time[src]
        pc[1:] = post_us
        self.post_time[src] = np.cumsum(pc)[-1]
        self.rdma_writes += n
        self.inflight += n
        st.vals.append(val)
        st.mats.append(mat)
        st.cols.append(col)
        st.arrs.append(arr)
        st.nrec += 1
        if st.nrec - st.base >= st.prune_at:
            mn = int(st.ptr.min())
            if mn > st.base:
                cut = mn - st.base
                del st.vals[:cut]
                del st.mats[:cut]
                del st.cols[:cut]
                del st.arrs[:cut]
                st.base = mn
        self.head_in[st.dsts, src] = np.minimum(
            self.head_in[st.dsts, src], arr)
        return tc[-1]

    def _drain(self, node: int, now: float):
        """Apply every due write for ``node``: a vectorized due-scan over
        ``head_in``, a first-not-due scan per due stream, and a
        monotone-max apply per consumed record."""
        row = self.head_in[node]
        due = np.nonzero(row <= now)[0]
        if not len(due):
            return
        streams = self._streams
        ginfo = self._node_ginfo[node]
        consumed = 0
        for src in due.tolist():
            best = math.inf
            for gid, q in ginfo:
                st = streams.get((gid, src))
                if st is None:
                    continue
                base, nrec = st.base, st.nrec
                j = q - 1 if q > st.p else q
                k = k0 = int(st.ptr[j])
                arrs = st.arrs
                while k < nrec and arrs[k - base][j] <= now:
                    k += 1
                if k > k0:
                    consumed += k - k0
                    mats, cols, vals = st.mats, st.cols, st.vals
                    for i in range(k0 - base, k - base):
                        m, c, v = mats[i], cols[i], vals[i]
                        if v > m[q, c]:
                            m[q, c] = v
                    st.ptr[j] = k
                if k < nrec:
                    a = arrs[k - base][j]
                    if a < best:
                        best = a
            row[src] = best
        self.inflight -= consumed

    def _next_arrival(self, node: int) -> float:
        return float(self.head_in[node].min())

    # -- one predicate sweep (event-recording form of Simulator._sweep) ------

    def _sweep(self, node: int, now: float) -> Tuple[float, bool]:
        cfg, host, flags = self.cfg, self.cfg.host, self.cfg.flags
        t = now
        did_work = False
        posts: List[Tuple] = []           # deferred posts (Sec. 3.4)

        def emit(st, size, val, mat, col, t_now):
            if flags.early_lock_release:
                posts.append((st, size, val, mat, col))
                return t_now
            return self._post_record(node, t_now, st, size, val, mat,
                                     col)

        for g in self.node_groups[node]:
            me = g.member_pos[node]
            t += host.lock_us + 3 * host.predicate_eval_us

            # ---- receive predicate ----
            if g.n_s:
                counts = g.pub_seen[me]
                fresh = np.maximum(counts - g.recv_counts[me], 0)
                if not flags.batch_receive:
                    fresh = np.minimum(fresh, 1)
                n_new = int(fresh.sum())
                t += host.slot_poll_us * self.poll_mult * (n_new + g.n_s)
                if n_new > 0:
                    did_work = True
                    self.recv_batches.append(n_new)
                    g.recv_counts[me] += fresh
                    new_recv = int(sst.rr_prefix(g.recv_counts[me])) - 1
                    if new_recv > g.recv_seen[me, me]:
                        g.recv_seen[me, me] = new_recv
                        st = self._stream_for(g, me, node)
                        if len(st.dsts):
                            t = emit(st, 64, new_recv, g.recv_seen, me,
                                     t)

            # ---- null-send predicate (Sec. 3.3) ----
            if flags.null_send and node in g.sender_rank and g.n_s > 1:
                s = g.sender_rank[node]
                next_idx = int(g.published[s]) + len(g.queued[s])
                n_nulls = int(nullsend.nulls_needed(
                    s, next_idx, g.recv_counts[me]))
                if n_nulls > 0 and not g.queued[s]:
                    did_work = True
                    self.nulls_sent += n_nulls
                    g.log_append(s, np.full(n_nulls, np.nan))
                    g.published[s] += n_nulls
                    g.pub_seen[me, s] = g.published[s]
                    self._ev_pub.append((g.gid, s, n_nulls, True, t))
                    st = self._stream_for(g, me, node)
                    if len(st.dsts):
                        t = emit(st, 64, int(g.published[s]),
                                 g.pub_seen, s, t)

            # ---- delivery predicate ----
            if flags.wait_stability:
                stable = int(np.min(g.recv_seen[me]))
            else:
                stable = int(g.recv_seen[me, me])
            lo = int(g.deliv_seen[me, me]) + 1
            if stable >= lo:
                n_deliv = (stable - lo + 1) if flags.batch_delivery else 1
                hi = lo + n_deliv - 1
                did_work = True
                self.deliv_batches.append(n_deliv)
                n_app = 0
                for s in range(g.n_s):
                    k0 = max(0, math.ceil((lo - s) / g.n_s))
                    k1 = (hi - s) // g.n_s
                    if k1 < k0:
                        continue
                    seg = g.gen_log[s][k0:k1 + 1]
                    n_app += int((~np.isnan(seg)).sum())
                # latency samples are replayed in phase 2 from this event
                self._ev_deliv.append((g.gid, me, lo, hi, n_app, t))
                g.delivered_app[me] += n_app
                if flags.batched_upcall:
                    t += host.upcall_batch_us + n_app * (
                        0.25 * host.upcall_us + cfg.upcall_extra_us)
                else:
                    t += n_app * (host.upcall_us + cfg.upcall_extra_us)
                if flags.memcpy_delivery:
                    t += n_app * host.memcpy(g.spec.msg_size)
                if flags.disk_append:
                    t += n_app * (1.0 + g.spec.msg_size / (2.5 * 1e3))
                g.deliv_seen[me, me] = hi
                g.last_delivery_time[me] = t
                st = self._stream_for(g, me, node)
                if len(st.dsts):
                    t = emit(st, 64, hi, g.deliv_seen, me, t)

            # ---- send predicate ----
            if node in g.sender_rank:
                s = g.sender_rank[node]
                self._generate(g, node, t)
                if g.queued[s]:
                    cap = self._cap(g, me, s)
                    n_send = int(min(len(g.queued[s]),
                                     cap - int(g.published[s])))
                    if not flags.batch_send:
                        n_send = min(n_send, 1)
                    if n_send > 0:
                        did_work = True
                        self.send_batches.append(n_send)
                        times = np.array([g.queued[s].popleft()
                                          for _ in range(n_send)])
                        g.log_append(s, times)
                        start_slot = int(g.published[s]) % g.spec.window
                        wraps = 2 if start_slot + n_send > g.spec.window \
                            else 1
                        g.published[s] += n_send
                        g.pub_seen[me, s] = g.published[s]
                        pub = int(g.published[s])
                        self._ev_pub.append((g.gid, s, n_send, False, t))
                        st = self._stream_for(g, me, node)
                        if len(st.dsts):
                            if flags.batch_send:
                                sizes = [(n_send - n_send // 2),
                                         n_send // 2] \
                                    if wraps == 2 else [n_send]
                                for nw in sizes:
                                    if nw:
                                        t = emit(st,
                                                 nw * g.smc.slot_bytes,
                                                 pub, g.pub_seen, s, t)
                            else:
                                for _ in range(n_send):
                                    t = emit(st, g.smc.slot_bytes, pub,
                                             g.pub_seen, s, t)
                if (not g.app_done(s) and not g.queued[s]
                        and g.next_ready[s] <= t):
                    self.sender_blocked[node] += max(t - now, 0.0)

        # ---- deferred posts: lock released first (Sec. 3.4) ----
        if flags.early_lock_release:
            self.app_block_until[node] = t
            self.lock_busy[node] += t - now
            for st, size, val, mat, col in posts:
                t = self._post_record(node, t, st, size, val, mat, col)
        else:
            self.app_block_until[node] = t
            self.lock_busy[node] += t - now

        self.pred_time[node] += t - now
        return t - now, did_work

    # -- main loop -----------------------------------------------------------

    def run_graph(self) -> DesGraph:
        """The legacy event loop with the explicit ``(time, node, seq)``
        heap key (DESIGN.md Sec. 12), recording one sweep event per pop."""
        cfg = self.cfg
        seq = itertools.count()
        heap = [(0.0, node, next(seq)) for node in range(cfg.n_nodes)
                if self.node_groups[node]]
        heapq.heapify(heap)
        n_live = len(heap)
        while heap and self.sweeps < cfg.max_sweeps:
            now, node, _ = heapq.heappop(heap)
            if now > cfg.max_time_us:
                break
            self._drain(node, now)
            dur, did_work = self._sweep(node, now)
            self._ev_sweep.append((node, now, dur, did_work))
            self.sweeps += 1
            if did_work:
                self.idle_streak = 0
            else:
                self.idle_streak += 1
            if self._done():
                break
            if (self.idle_streak > 30 * n_live and self.inflight == 0
                    and not self._any_app_pending()):
                break
            if did_work:
                nxt = now + max(dur, 0.05)
            else:
                pend = self._next_arrival(node)
                app = math.inf
                for g in self.node_groups[node]:
                    if node in g.sender_rank and not g.app_done(
                            g.sender_rank[node]):
                        app = min(app, float(
                            g.next_ready[g.sender_rank[node]]))
                nxt = min(pend, app)
                if not math.isfinite(nxt):
                    nxt = now + 50 * cfg.idle_tick_us
                nxt = max(nxt, now + cfg.idle_tick_us)
            heapq.heappush(heap, (nxt, node, next(seq)))
        return self._graph()

    def _graph(self) -> DesGraph:
        ev_s = self._ev_sweep
        ev_d = self._ev_deliv
        ev_p = self._ev_pub
        return DesGraph(
            cfg=self.cfg,
            groups=self.groups,
            node_groups=self.node_groups,
            sweep_node=np.array([e[0] for e in ev_s], np.int32),
            sweep_time=np.array([e[1] for e in ev_s], np.float64),
            sweep_dur=np.array([e[2] for e in ev_s], np.float64),
            sweep_work=np.array([e[3] for e in ev_s], bool),
            deliv_gid=np.array([e[0] for e in ev_d], np.int32),
            deliv_member=np.array([e[1] for e in ev_d], np.int32),
            deliv_lo=np.array([e[2] for e in ev_d], np.int64),
            deliv_hi=np.array([e[3] for e in ev_d], np.int64),
            deliv_napp=np.array([e[4] for e in ev_d], np.int64),
            deliv_time=np.array([e[5] for e in ev_d], np.float64),
            pub_gid=np.array([e[0] for e in ev_p], np.int32),
            pub_rank=np.array([e[1] for e in ev_p], np.int32),
            pub_count=np.array([e[2] for e in ev_p], np.int64),
            pub_is_null=np.array([e[3] for e in ev_p], bool),
            pub_time=np.array([e[4] for e in ev_p], np.float64),
            send_batches=self.send_batches,
            recv_batches=self.recv_batches,
            deliv_batches=self.deliv_batches,
            rdma_writes=self.rdma_writes,
            nulls_sent=self.nulls_sent,
            sweeps=self.sweeps,
            post_time=self.post_time,
            pred_time=self.pred_time,
            sender_blocked=self.sender_blocked,
            lock_busy=self.lock_busy,
            first_gen=self.first_gen,
            stalled=not self._done(),
        )


def simulate(cfg: sim.SimConfig) -> DesGraph:
    """Run phase 1: timestamp the full event timeline and return the
    compact event graph (DESIGN.md Sec. 12)."""
    return Phase1(cfg).run_graph()
