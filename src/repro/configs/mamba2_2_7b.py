"""mamba2-2.7b [ssm] — 64L d_model=2560 attention-free, ssm_state=128,
vocab=50280; SSD (state-space duality).  [arXiv:2405.21060; unverified]

long_500k RUNS for this arch (O(1)-state decode).
"""

from repro.models import registry
from repro.models.config import ModelConfig, SSMConfig


def build() -> ModelConfig:
    return ModelConfig(
        name="mamba2-2.7b", family="ssm",
        n_layers=64, d_model=2560, n_heads=1, n_kv_heads=1,
        d_ff=0, vocab_size=50280, head_dim=64,
        ssm=SSMConfig(d_state=128, head_dim=64, expand=2, chunk=256),
    )


registry.register("mamba2-2.7b", build)
