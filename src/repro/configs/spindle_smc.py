"""The paper's own system configuration: Derecho SMC on the 16-node
100 Gbps testbed (Sec. 4), used by the benchmark harness as defaults."""

import dataclasses

from repro.core import costmodel, simulator


@dataclasses.dataclass(frozen=True)
class PaperSetup:
    n_nodes: int = 16
    msg_size: int = 10240
    window: int = 100
    net: costmodel.NetworkModel = costmodel.RDMA_CX6
    host: costmodel.HostModel = costmodel.HOST_X86

    def config(self, n_nodes=None, *, n_messages=1000, flags=None, **kw
               ) -> simulator.SimConfig:
        return simulator.single_subgroup(
            n_nodes if n_nodes is not None else self.n_nodes,
            msg_size=self.msg_size, window=self.window,
            n_messages=n_messages,
            flags=flags if flags is not None
            else simulator.SpindleFlags.spindle(),
            net=self.net, host=self.host, **kw)


PAPER = PaperSetup()
