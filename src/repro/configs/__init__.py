"""Assigned architecture configs (public literature, exact hyperparams).

Importing this package registers every architecture with
:mod:`repro.models.registry`.  One module per architecture, plus
``spindle_smc`` — the paper's own multicast system configuration used by
the benchmark harness.
"""

from repro.configs import (deepseek_moe_16b, internvl2_26b, mamba2_2_7b,
                           qwen1_5_0_5b, qwen2_1_5b, qwen2_72b,
                           qwen2_moe_a2_7b, qwen3_1_7b, seamless_m4t_medium,
                           spindle_smc, zamba2_2_7b)

__all__ = [
    "internvl2_26b", "qwen2_moe_a2_7b", "deepseek_moe_16b", "qwen3_1_7b",
    "qwen2_1_5b", "qwen1_5_0_5b", "qwen2_72b", "seamless_m4t_medium",
    "zamba2_2_7b", "mamba2_2_7b", "spindle_smc",
]
