"""Shared helpers for architecture configs."""

FULL_ATTN_SKIP = (
    ("long_500k",
     "pure full-attention arch: 524288-token context needs a sub-quadratic "
     "path; run only for ssm/hybrid families (DESIGN.md Sec. 5)"),
)
