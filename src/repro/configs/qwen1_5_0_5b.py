"""qwen1.5-0.5b [dense] — 24L d_model=1024 16H (kv=16) d_ff=2816
vocab=151936; QKV bias.  [hf:Qwen/Qwen1.5-0.5B; hf]"""

from repro.configs._common import FULL_ATTN_SKIP
from repro.models import registry
from repro.models.config import ModelConfig


def build() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-0.5b", family="dense",
        n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
        d_ff=2816, vocab_size=151936, head_dim=64,
        qkv_bias=True, rope_theta=1e4, tie_embeddings=True,
        skip_shapes=FULL_ATTN_SKIP,
    )


registry.register("qwen1.5-0.5b", build)
