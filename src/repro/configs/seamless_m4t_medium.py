"""seamless-m4t-medium [audio] — enc-dec, 12L+12L d_model=1024 16H (kv=16)
d_ff=4096 vocab=256206.  [arXiv:2308.11596; hf]

The audio frontend is a STUB: the encoder consumes precomputed frame
embeddings (B, S_src, d_model).  Deviations noted in DESIGN.md: RoPE +
RMSNorm instead of sinusoidal + LayerNorm.
"""

from repro.configs._common import FULL_ATTN_SKIP
from repro.models import registry
from repro.models.config import EncDecConfig, ModelConfig


def build() -> ModelConfig:
    return ModelConfig(
        name="seamless-m4t-medium", family="encdec",
        n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
        d_ff=4096, vocab_size=256206, head_dim=64,
        rope_theta=1e4,
        encdec=EncDecConfig(n_encoder_layers=12, n_decoder_layers=12),
        skip_shapes=FULL_ATTN_SKIP,
    )


registry.register("seamless-m4t-medium", build)
