"""internvl2-26b [vlm] — InternViT (stub) + InternLM2-20B backbone:
48L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=92553.
[arXiv:2404.16821; hf]

The vision frontend is a STUB per the assignment: input_specs provides
precomputed patch embeddings (InternViT-6B width 3200); the projector and
the LM backbone are real.
"""

from repro.configs._common import FULL_ATTN_SKIP
from repro.models import registry
from repro.models.config import ModelConfig, VLMConfig


def build() -> ModelConfig:
    return ModelConfig(
        name="internvl2-26b", family="vlm",
        n_layers=48, d_model=6144, n_heads=48, n_kv_heads=8,
        d_ff=16384, vocab_size=92553, head_dim=128,
        rope_theta=1e6,
        vlm=VLMConfig(n_patches=256, vision_dim=3200),
        skip_shapes=FULL_ATTN_SKIP,
    )


registry.register("internvl2-26b", build)
