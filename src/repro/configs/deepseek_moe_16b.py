"""deepseek-moe-16b [moe] — 28L d_model=2048 16H (kv=16) d_ff=1408
vocab=102400; 2 shared + 64 routed top-6, fine-grained experts.
[arXiv:2401.06066; hf]

Deviation (DESIGN.md): the HF model uses a dense FFN in layer 0; we make
every layer MoE so the stack scans homogeneously.
"""

from repro.configs._common import FULL_ATTN_SKIP
from repro.models import registry
from repro.models.config import ModelConfig, MoEConfig


def build() -> ModelConfig:
    return ModelConfig(
        name="deepseek-moe-16b", family="moe",
        n_layers=28, d_model=2048, n_heads=16, n_kv_heads=16,
        d_ff=1408, vocab_size=102400, head_dim=128,
        rope_theta=1e4,
        moe=MoEConfig(n_routed=64, top_k=6, n_shared=2, d_ff_expert=1408),
        skip_shapes=FULL_ATTN_SKIP,
    )


registry.register("deepseek-moe-16b", build)
