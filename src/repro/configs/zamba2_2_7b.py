"""zamba2-2.7b [hybrid] — 54 Mamba2 blocks d_model=2560 + ONE shared
attention block (32H, kv=32, d_ff=10240) every 6 blocks; ssm_state=64;
vocab=32000.  [arXiv:2411.15242; hf]

long_500k RUNS for this arch (sub-quadratic decode path).
Simplification noted in DESIGN.md: the shared block consumes the running
hidden state (no embedding concat / per-invocation LoRA).
"""

from repro.models import registry
from repro.models.config import HybridConfig, ModelConfig, SSMConfig


def build() -> ModelConfig:
    return ModelConfig(
        name="zamba2-2.7b", family="hybrid",
        n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32,
        d_ff=10240, vocab_size=32000, head_dim=80,
        rope_theta=1e4,
        ssm=SSMConfig(d_state=64, head_dim=64, expand=2, chunk=256),
        hybrid=HybridConfig(attn_every=6),
    )


registry.register("zamba2-2.7b", build)
