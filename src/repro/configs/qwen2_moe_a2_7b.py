"""qwen2-moe-a2.7b [moe] — 24L d_model=2048 16H (kv=16) d_ff=1408
vocab=151936; 4 shared + 60 routed top-4.  [hf:Qwen/Qwen1.5-MoE-A2.7B; hf]

EP note: 60 routed experts are padded to 64 slots (``ep_pad_to``) so the
expert axis divides the 16-way model/EP mesh axis; pad slots never route.
"""

from repro.configs._common import FULL_ATTN_SKIP
from repro.models import registry
from repro.models.config import ModelConfig, MoEConfig


def build() -> ModelConfig:
    return ModelConfig(
        name="qwen2-moe-a2.7b", family="moe",
        n_layers=24, d_model=2048, n_heads=16, n_kv_heads=16,
        d_ff=1408, vocab_size=151936, head_dim=128,
        qkv_bias=True, rope_theta=1e6,
        moe=MoEConfig(n_routed=60, top_k=4, n_shared=4, d_ff_expert=1408,
                      ep_pad_to=64),
        skip_shapes=FULL_ATTN_SKIP,
    )


registry.register("qwen2-moe-a2.7b", build)
