"""qwen2-1.5b [dense] — 28L d_model=1536 12H (GQA kv=2) d_ff=8960
vocab=151936; GQA, QKV bias.  [arXiv:2407.10671; hf]"""

from repro.configs._common import FULL_ATTN_SKIP
from repro.models import registry
from repro.models.config import ModelConfig


def build() -> ModelConfig:
    return ModelConfig(
        name="qwen2-1.5b", family="dense",
        n_layers=28, d_model=1536, n_heads=12, n_kv_heads=2,
        d_ff=8960, vocab_size=151936, head_dim=128,
        qkv_bias=True, rope_theta=1e6, tie_embeddings=True,
        skip_shapes=FULL_ATTN_SKIP,
    )


registry.register("qwen2-1.5b", build)
