"""qwen3-1.7b [dense] — 28L d_model=2048 16H (GQA kv=8) d_ff=6144
vocab=151936; qk_norm, GQA.  [hf:Qwen/Qwen3-8B family; hf]"""

from repro.configs._common import FULL_ATTN_SKIP
from repro.models import registry
from repro.models.config import ModelConfig


def build() -> ModelConfig:
    return ModelConfig(
        name="qwen3-1.7b", family="dense",
        n_layers=28, d_model=2048, n_heads=16, n_kv_heads=8,
        d_ff=6144, vocab_size=151936, head_dim=128,
        qkv_bias=False, qk_norm=True, rope_theta=1e6,
        tie_embeddings=True,
        skip_shapes=FULL_ATTN_SKIP,
    )


registry.register("qwen3-1.7b", build)
