"""Batched serving engine with continuous batching and round-robin
delivery (the paper's protocol shape, applied to inference).

Mapping (DESIGN.md Sec. 6): requests are messages; the decode loop is the
predicate sweep — every iteration it *opportunistically batches* whatever
is ready (admits new requests into free KV-cache slots = SMC ring slots,
decodes every active slot in one fused step); a slot is freed only after
its response is delivered (slot-reuse rule).  A request that stalls
(client backpressure) occupies its slot but decodes a null step — the
batch round never waits (null-round analogue).  The multicast side of the
mapping — each round's admissions and emitted tokens published on a DDS
topic per replica, swept by ONE stacked program — lives in
:mod:`repro.serve.fanout`.

Single-host reference implementation; the decode step itself is the same
``make_serve_step`` the multi-pod dry-run lowers, so the engine scales to
the production mesh by construction.

Every decode step is validity-masked (:mod:`repro.models.masking`): a
slot that is idle, stalled, or merely a bystander to another slot's
prefill carries its decode state through bit-unchanged instead of taking
a garbage write.  For position-addressed state (KV caches) that is
output-equivalent to the old write-then-overwrite dance; for recurrent
families (ssm/hybrid), whose state mutates cumulatively every step, it
is the unlock — every registry family now serves through the same slot
ring (DESIGN.md Sec. 6).  The masked step is also exactly the round
body the fused device-resident serve plane scans
(:mod:`repro.serve.fused`).
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers, masking, registry
from repro.models.config import ModelConfig, ShapeConfig
from repro.models.runtime import Runtime


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                  # (S_prompt,) int32
    max_new_tokens: int = 16
    submitted_at: float = 0.0
    tokens_out: List[int] = dataclasses.field(default_factory=list)
    finished_at: Optional[float] = None


@dataclasses.dataclass
class EngineConfig:
    max_batch: int = 8                  # KV slots (the ring window w)
    max_len: int = 256
    eos_id: Optional[int] = None
    greedy: bool = True


@dataclasses.dataclass
class EngineRound:
    """What one :meth:`ServeEngine.step` did — the per-round event record
    the serve fan-out publishes as multicast messages (one message per
    admission, one per emitted token; see :mod:`repro.serve.fanout`)."""

    admitted: List[int] = dataclasses.field(default_factory=list)  # slots
    admitted_rids: List[int] = dataclasses.field(default_factory=list)
    emitted: List[int] = dataclasses.field(default_factory=list)   # slots
    finished: List[int] = dataclasses.field(default_factory=list)  # slots
    finished_rids: List[int] = dataclasses.field(default_factory=list)
    stalled: List[int] = dataclasses.field(default_factory=list)   # slots

    def __bool__(self) -> bool:          # truthy = the round made progress
        return bool(self.admitted or self.emitted)


class ServeEngine:
    """Continuous-batching decode engine over a fixed slot ring."""

    def __init__(self, arch_name: str, params, cfg: ModelConfig,
                 ecfg: EngineConfig, rt: Runtime = Runtime()):
        self.arch = registry.get(arch_name)
        self.cfg = cfg
        self.ecfg = ecfg
        self.rt = rt
        self.params = params
        b, s = ecfg.max_batch, ecfg.max_len
        shape = ShapeConfig("engine", s, b, "decode")
        self.cache_specs = registry.cache_specs(cfg, shape,
                                                batch_override=b)
        self.cache = jax.tree.map(
            lambda sp: jnp.zeros(sp.shape, sp.dtype), self.cache_specs,
            is_leaf=lambda x: isinstance(x, layers.ParamSpec))
        decode_fn, specs = self.arch.decode_fn(), self.cache_specs

        def _decode_body(p, c, t, pos, valid):
            """One masked decode step: slots where ``valid`` advance
            their state; the rest carry it through bit-unchanged (the
            null-round no-op — what lets recurrent families serve).
            This pure body is shared verbatim with the fused serve
            program (:mod:`repro.serve.fused`), so the fused scan and
            this per-round loop run the same arithmetic."""
            logits, new_c = decode_fn(p, cfg, c, t, pos, rt)
            return logits, masking.masked_update(specs, c, new_c, valid)

        def _reset_body(c, valid):
            """Admission reset: zero the admitted slots' cache rows (a
            no-op for the rest).  Shared with the fused program, like
            ``_decode_body`` — see :func:`repro.models.masking.reset_rows`
            for why recurrent families require it."""
            return masking.reset_rows(specs, c, valid)

        self._decode_body = _decode_body
        self._reset_body = _reset_body
        self.decode = jax.jit(_decode_body, donate_argnums=(1,))
        self._reset_slots = jax.jit(_reset_body, donate_argnums=(0,))
        # slot state (the SMC ring of the serving plane)
        self.slot_req: List[Optional[Request]] = [None] * b
        self.slot_len = np.zeros(b, dtype=np.int64)
        self.queue: deque = deque()
        self.completed: List[Request] = []
        self.rounds = 0
        self.decode_steps = 0
        # device->host syncs taken inside decode rounds (the logits
        # readback) — the per-round hop the fused serve plane removes
        self.host_syncs = 0

    # -- request plane -------------------------------------------------------

    def submit(self, req: Request):
        req.submitted_at = req.submitted_at or time.time()
        self.queue.append(req)

    def _admit(self, admit_mask: Optional[Sequence[bool]] = None
               ) -> List[int]:
        """Opportunistic admission: fill every free slot that has a ready
        request (never waits to accumulate a batch).  ``admit_mask``
        restricts which slots may admit this round — the serve fan-out
        gates it on the multicast delivery watermark (slot free = last
        response delivered, the SMC slot-reuse rule).  Returns the slots
        admitted into."""
        admitted = []
        for slot in range(self.ecfg.max_batch):
            if (self.slot_req[slot] is None and self.queue
                    and (admit_mask is None or admit_mask[slot])):
                req = self.queue.popleft()
                self._prefill_slot(slot, req)
                admitted.append(slot)
        return admitted

    def _prefill_slot(self, slot: int, req: Request):
        """Sequential prefill through the decode path (single-host
        reference: correctness over speed; the dry-run's prefill step is
        the production path)."""
        self.slot_req[slot] = req
        self.slot_len[slot] = 0
        b = self.ecfg.max_batch
        valid = np.zeros(b, bool)
        valid[slot] = True                # bystander slots: masked no-op
        self.cache = self._reset_slots(self.cache, jnp.asarray(valid))
        for tok in req.prompt:
            tokens = np.zeros((b, 1), dtype=np.int32)
            tokens[slot, 0] = int(tok)
            pos = jnp.asarray(self.slot_len, jnp.int32)
            logits, self.cache = self.decode(self.params, self.cache,
                                             jnp.asarray(tokens), pos,
                                             jnp.asarray(valid))
            self.slot_len[slot] += 1
            self.decode_steps += 1

    # -- the decode sweep ------------------------------------------------------

    def step(self, *, stalled: Optional[Sequence[int]] = None,
             admit_mask: Optional[Sequence[bool]] = None) -> EngineRound:
        """One engine round: admit ready work, decode every active slot.

        ``stalled`` names slots whose client cannot accept output this
        round (backpressure): they keep their slot but make no progress —
        the null-step analogue; the fused decode never waits for them.
        ``admit_mask`` restricts admission (see :meth:`_admit`).  Returns
        the round's :class:`EngineRound` event record (truthy when any
        slot admitted or decoded — the old boolean contract)."""
        self.rounds += 1
        stalled_set = set(stalled or ())
        info = EngineRound(admitted=self._admit(admit_mask))
        info.admitted_rids = [self.slot_req[s].rid for s in info.admitted]
        info.stalled = sorted(stalled_set & {
            i for i, r in enumerate(self.slot_req) if r is not None})
        active = [i for i, r in enumerate(self.slot_req)
                  if r is not None and i not in stalled_set]
        if not active:
            return info
        b = self.ecfg.max_batch
        tokens = np.zeros((b, 1), dtype=np.int32)
        for i in active:
            req = self.slot_req[i]
            last = req.tokens_out[-1] if req.tokens_out else \
                int(req.prompt[-1])
            tokens[i, 0] = last
        # one fused decode for the whole ring with per-slot positions;
        # idle/stalled slots are masked no-ops (state carried through
        # bit-unchanged — safe for KV and recurrent families alike)
        valid = np.zeros(b, bool)
        valid[active] = True
        pos = jnp.asarray(self.slot_len, jnp.int32)
        logits, self.cache = self.decode(self.params, self.cache,
                                         jnp.asarray(tokens), pos,
                                         jnp.asarray(valid))
        self.decode_steps += 1
        self.host_syncs += 1             # logits cross device->host below
        logits = np.asarray(logits.astype(jnp.float32))
        for i in active:
            req = self.slot_req[i]
            nxt = int(np.argmax(logits[i]))
            req.tokens_out.append(nxt)
            info.emitted.append(i)
            self.slot_len[i] += 1
            done = (len(req.tokens_out) >= req.max_new_tokens
                    or (self.ecfg.eos_id is not None
                        and nxt == self.ecfg.eos_id)
                    or self.slot_len[i] >= self.ecfg.max_len - 1)
            if done:
                req.finished_at = time.time()
                self.completed.append(req)
                self.slot_req[i] = None    # slot delivered -> reusable
                self.slot_len[i] = 0
                info.finished.append(i)
                info.finished_rids.append(req.rid)
        return info

    def evict(self, slot: int) -> Optional[Request]:
        """Forcibly clear a slot and void its in-flight decode.

        The serve plane calls this when the slot's NODE dies mid-run
        (DESIGN.md Sec. 7): the request's decoded tokens are discarded —
        its unstable published tail died with the slot, and re-admission
        restarts the decode from the prompt on a surviving slot — and
        the request object is returned to the caller for re-admission or
        shed (the policy lives in the fan-out, DESIGN.md Sec. 9).  Stale
        KV entries are position-overwritten on the next prefill, exactly
        as after :meth:`reset`.  Returns ``None`` if the slot was idle.
        """
        req = self.slot_req[slot]
        self.slot_req[slot] = None
        self.slot_len[slot] = 0
        if req is not None:
            req.tokens_out = []
        return req

    def drained(self) -> bool:
        return not self.queue and all(r is None for r in self.slot_req)

    def run_until_drained(self, max_rounds: int = 10_000):
        while not self.drained() and self.rounds < max_rounds:
            self.step()
        return self.completed

    def reset(self) -> None:
        """Clear all request/slot state, keeping params and the compiled
        decode program (re-running a scenario skips the jit cost; stale
        KV entries are position-overwritten before any read)."""
        self.slot_req = [None] * self.ecfg.max_batch
        self.slot_len[:] = 0
        self.queue.clear()
        self.completed = []
        self.rounds = 0
        self.decode_steps = 0
        self.host_syncs = 0
