"""Fused device-resident serve plane: decode inside the scan body, one
compiled program per serve EPOCH (DESIGN.md Sec. 6).

The paper's core lesson is that small-object replication amplifies every
per-operation overhead until coordination is batched into the data path.
The unfused serve plane still pays that overhead once per engine round:
one jitted decode dispatch, a device->host logits sync, Python
bookkeeping, then one stacked-sweep dispatch
(:meth:`repro.serve.fanout.ReplicatedEngine.run`).  This module removes
the hop entirely: a whole serve run — open-loop arrivals, admission
(queue-cap tail-drop + backlog stalls), prefill, decode, token emission,
multicast publish, watermark-gated slot reuse, the quiescence drain —
executes as ONE compiled ``lax.while_loop`` program whose round body
composes the engine's masked decode step
(:meth:`repro.serve.engine.ServeEngine` ``_decode_body``) with the
multicast round body (:func:`repro.core.sweep.stream_stacked`).  Slot
state, decode caches, SST watermarks, backlogs, slot holds, the arrival
frontier and the admission queue all live in the carry; per-round event
traces land in preallocated device buffers and cross to the host exactly
once, after the loop exits.

Dynamic workloads ride in-graph (this retired the PR 8 fallbacks):

* **open-loop arrivals** — a seeded schedule is a host-precomputed
  per-round arrival-count matrix; the carry tracks the arrival frontier
  (``avail``) and admission gates on it.  Only an *arbitrary*
  ``arrive_fn`` callable still falls back.
* **admission** — ``ServeAdmission``'s queue-cap tail-drop and
  watermark stalls are carry arithmetic: shed requests are marked (with
  their round) in-carry, and a slot stalls when its lane's
  published-undelivered+backlog inflight (read off the previous round's
  in-carry watermarks, exactly what the host loop reads off
  ``StreamView``) exceeds ``stall_backlog``.
* **stall schedules** — a precomputed boolean ``(rounds, G, B)`` mask
  is an operand; scheduled slots decode null steps with no host hop.
  Only callable ``stall_fn``\\ s fall back.
* **view changes (``fail_at``)** — the loop is WEDGE-CAPABLE: it exits
  at the failure round, the host performs the PR 5/PR 7 cut (ragged
  trim, slot compaction, re-pinned holds, head-of-queue re-admission —
  the same :meth:`ReplicatedEngine._fail_nodes`), and a NEW fused
  program runs the next epoch with the ``EpochCarry`` resend as its
  initial backlog.  A serve run with one cut is two device programs,
  not hundreds of host rounds; ``host_hops`` stays 0 between cuts.

Equivalence contract (tested bit-for-bit in tests/test_serve_fused.py):
the same masked decode body runs in both paths; the multicast rounds ARE
:func:`repro.core.sweep.step_backlog` on the same ``ready`` counts,
handed to :meth:`repro.core.group.GroupStream.absorb` so report and
delivery logs come from the identical post-processing; holds pin and
release against the in-carry watermark with the same arithmetic as
:meth:`ReplicatedEngine._sync_holds` / ``app_publish_index``; and the
cut itself is the SAME host code both paths run.

What still falls back to the per-round loop: arbitrary ``arrive_fn`` /
``stall_fn`` host callbacks, ``settle_max`` (the capped host drain),
heterogeneous replicas, and ``fail_at`` cuts that leave replicas with
unequal slot or subscriber counts (the stacked program needs one
homogeneous shape per epoch).  The reason is recorded in
``extras["serve"]["fused_fallback"]``.
"""

from __future__ import annotations

import math
import time
from collections import deque
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import group as group_mod
from repro.core import sweep as sweep_mod
from repro.core.group import TRACE_EVENTS, RunReport, fused_stream_program
from repro.models import masking
from repro.models.layers import ParamSpec


class FusedUnsupported(Exception):
    """The workload needs a feature only the per-round loop has; the
    caller falls back (explicitly, in extras) rather than fail."""


def fused_fallback_reason(rep, *, fail_at=None, arrive_fn=None,
                          arrive_schedule=None, admission=None,
                          settle_max=None) -> Optional[str]:
    """Why this run cannot take the fused path (None = it can).

    ``fail_at`` must already be wave-normalized ({round: [[nodes]]}).
    The fused program handles precomputed arrival schedules, ndarray
    stall masks, ``ServeAdmission`` policies and homogeneous ``fail_at``
    cuts in-graph; only true host callbacks, capped settles, and
    heterogeneity keep the per-round path."""
    if arrive_fn is not None:
        return "arrive_fn: arbitrary open-loop arrivals are host callbacks"
    if rep.stall_fn is not None and not isinstance(rep.stall_fn,
                                                  np.ndarray):
        return "stall_fn: arbitrary client stalls are host callbacks"
    if isinstance(rep.stall_fn, np.ndarray):
        m = rep.stall_fn
        if m.ndim != 3 or m.shape[1] != len(rep.engines) \
                or m.shape[2] != rep.engines[0].ecfg.max_batch:
            return ("stall_fn mask must be (rounds, G, slots) boolean, "
                    f"got {m.shape}")
    if settle_max is not None:
        return "settle_max: capped settle needs the host drain loop"
    e0 = rep.engines[0]
    for eng in rep.engines:
        if (eng.cfg is not e0.cfg and eng.cfg != e0.cfg) \
                or eng.ecfg.max_batch != e0.ecfg.max_batch \
                or eng.ecfg.max_len != e0.ecfg.max_len \
                or eng.ecfg.eos_id != e0.ecfg.eos_id:
            return "heterogeneous replicas (mixed model/engine configs)"
        if eng.params is not e0.params:
            return ("replicas do not share one params tree (the fused "
                    "program folds every replica's slots into one "
                    "decode batch)")
        if any(r is not None for r in eng.slot_req):
            return "engines must start with empty slot rings"
    if fail_at:
        # Every cut must leave the replicas homogeneous — equal live
        # slot counts AND equal live subscriber counts — or the stacked
        # one-shape-per-epoch program cannot express the next epoch.
        sub_to_g = {n: g for g, t in enumerate(rep.topics)
                    for n in t.subscribers}
        dead_slots = [set() for _ in rep.engines]
        dead_subs = [set() for _ in rep.engines]
        for rnd in sorted(fail_at):
            for wave in fail_at[rnd]:
                for n in wave:
                    if n in rep._node_to_slot:
                        g, s = rep._node_to_slot[n]
                        dead_slots[g].add(s)
                    elif n in sub_to_g:
                        dead_subs[sub_to_g[n]].add(n)
                    else:
                        return (f"fail_at names node {n}, which is "
                                "neither a slot node nor a subscriber")
            if len({len(d) for d in dead_slots}) > 1 \
                    or len({len(d) for d in dead_subs}) > 1:
                return ("fail_at cut at round %d leaves heterogeneous "
                        "replicas (unequal live slot/subscriber "
                        "counts); the fused stack needs one shape per "
                        "epoch" % rnd)
    sched_reqs = [q for row in (arrive_schedule or [])
                  for cell in row for q in (cell or ())]
    all_reqs = [q for eng in rep.engines for q in eng.queue] + sched_reqs
    if not all_reqs:
        return "empty workload"
    if any(len(q.prompt) == 0 for q in all_reqs):
        return "empty prompts"
    if any(len(q.prompt) > e0.ecfg.max_len - 2
           or len(q.prompt) + q.max_new_tokens > e0.ecfg.max_len
           for q in all_reqs):
        return "request would overflow max_len mid-run"
    return None


# ---------------------------------------------------------------------------
# The one-program-per-epoch serve run
# ---------------------------------------------------------------------------

def _round_budget(n_reqs: int, slots: int, max_new: int, window: int,
                  n_members: int, max_rounds: int, *,
                  arrive_rounds: int = 0,
                  stall_slack: int = 0) -> Tuple[int, int]:
    """(serve-round cap, total cap incl. settle) — generous analytic
    bounds; a run that overflows them falls back to the unfused loop
    rather than truncate.  Open-loop runs add the arrival horizon and
    scheduled-stall slack (each stalled slot-round delays at most one
    decode round); backlog stalls self-resolve within the window
    throttle already covered per wave, doubled for slack."""
    waves = max(1, math.ceil(n_reqs / max(slots, 1)))
    per_wave = max_new + 8 + 3 * math.ceil((max_new + 1)
                                           / max(window, 1))
    serve = min(max_rounds,
                2 * waves * per_wave + arrive_rounds + stall_slack + 32)
    settle = 2 * n_members + 16 + 3 * math.ceil(
        slots * (max_new + 2) / max(window, 1))
    return serve, serve + settle


def _fold_caches(specs, trees):
    """Concatenate per-replica cache trees along each leaf's batch axis:
    the fused program decodes ALL replicas' slots in ONE masked step
    (batch = G * slots).  Every decode-body op is row-independent along
    the batch axis, so slot (g, s)'s arithmetic — and therefore its
    tokens and state — is bit-identical to the per-replica step."""
    return jax.tree.map(
        lambda sp, *xs: jnp.concatenate(
            xs, axis=masking.batch_axis(sp)),
        specs, *trees, is_leaf=lambda x: isinstance(x, ParamSpec))


def _unfold_caches(specs, tree, n_g, slots):
    """Split a folded cache tree back into per-replica trees."""
    def cut(g):
        return jax.tree.map(
            lambda sp, x: jax.lax.slice_in_dim(
                x, g * slots, (g + 1) * slots,
                axis=masking.batch_axis(sp)),
            specs, tree, is_leaf=lambda x: isinstance(x, ParamSpec))
    return [cut(g) for g in range(n_g)]


def _build_program(decode_body, reset_body, specs, shapes, rank_slot):
    """Trace-once builder for one epoch shape (see
    :func:`repro.core.group.fused_stream_program`).

    ``rank_slot[g]`` maps the epoch's live sender ranks to engine slots
    (identity before any cut; compacted survivors after one) — baked in
    as a static constant, like the shape tuple.  Everything dynamic —
    the arrival matrix, stall mask, admission scalars, requeue list,
    resend backlog, and the epoch's initial engine/queue state — is a
    traced operand, so a repeat run (and every same-shape epoch) reuses
    the compiled program."""
    (n_g, B, S, N, window, backend, R, P, t_serve_cap, t_total,
     eos_id, max_len, T_arr, T_stall, V) = shapes
    win_arr = np.full(n_g, window, np.int32)
    ring = window if backend == "pallas" else 0
    receive_fn = group_mod._kernel_receive(ring) \
        if backend == "pallas" else None
    i32 = jnp.int32
    rank_slot_c = jnp.asarray(rank_slot, jnp.int32)        # (G, S)
    slot_rank = np.zeros((n_g, B), np.int64)
    live_np = np.zeros((n_g, B), bool)
    for g in range(n_g):
        for r, s in enumerate(rank_slot[g]):
            slot_rank[g, s] = r
            live_np[g, s] = True
    slot_rank_c = jnp.asarray(slot_rank, jnp.int32)        # (G, B)
    live_c = jnp.asarray(live_np)                          # (G, B)
    ranks = jnp.arange(S)
    ridx_r = jnp.arange(R)

    def take_rank(x):
        """(G, S) rank-space array -> (G, B) per-slot view (dead slots
        read garbage lane 0 — every consumer masks them out)."""
        return jnp.take_along_axis(x, slot_rank_c, axis=1)

    def program(params, caches, ops):
        TRACE_EVENTS.append(((n_g, N, S), (window,) * n_g,
                             backend + "+decode"))
        t0, t_stop = ops["t0"], ops["t_stop"]
        arrive_rounds = ops["arrive_rounds"]

        def queue_len(c):
            elig = (ridx_r[None, :] < c["avail"][:, None]) \
                & ~c["admitted"] & ~c["shed"]
            pend = ops["n_rq"] - c["rq_head"]
            return pend + jnp.sum(elig.astype(i32), axis=1)

        def serving_now(c):
            live = jnp.any(c["active"]) | jnp.any(queue_len(c) > 0) \
                | (t0 + c["t_serve"] < arrive_rounds)
            return live & (c["t_serve"] < t_serve_cap)

        def body_fn(c):
            serving = serving_now(c)
            t = c["t"]
            t_g = t0 + t

            # ---- open-loop arrivals: the schedule row becomes this
            # round's arrival frontier advance (inert past the horizon
            # and during settle, where the row is zero by construction)
            arr = jnp.where(
                t_g < T_arr,
                jax.lax.dynamic_index_in_dim(
                    ops["arr_counts"],
                    jnp.clip(t_g, 0, max(T_arr, 1) - 1),
                    axis=0, keepdims=False),
                0).astype(i32)                              # (G,)
            avail = c["avail"] + arr
            elig = (ridx_r[None, :] < avail[:, None]) \
                & ~c["admitted"] & ~c["shed"]               # (G, R)
            n_rq_pend = ops["n_rq"] - c["rq_head"]          # (G,)
            qlen = n_rq_pend + jnp.sum(elig.astype(i32), axis=1)

            # ---- admission queue-cap: shed the tail (newest first) —
            # the host loop's `while len(queue) > cap: queue.pop()`.
            # Requeued head-of-queue entries are never shed here: the
            # cut already sheds instead of requeueing at cap, so the
            # overflow never exceeds the eligible-regular count.
            over = jnp.clip(qlen - ops["queue_cap"], 0, None)
            rev = jnp.cumsum(elig[:, ::-1].astype(i32),
                             axis=1)[:, ::-1]               # tail rank 1..
            shed_new = elig & (rev <= over[:, None])
            shed = c["shed"] | shed_new
            shed_round = jnp.where(shed_new, t_g, c["shed_round"])
            elig = elig & ~shed_new
            qlen = qlen - jnp.sum(shed_new.astype(i32), axis=1)
            depth = jnp.sum(qlen).astype(i32)

            # ---- stall mask for this round: the precomputed schedule
            # row plus the watermark stall — a lane whose
            # published-undelivered + backlog inflight (previous round's
            # carry watermarks, what the host reads off StreamView)
            # exceeds stall_backlog decodes a null step.  t > 0 gates
            # the watermark read exactly like the host loop's
            # `_last_view is None` on the first round of an epoch.
            sched_stall = jnp.where(
                t_g < T_stall,
                jax.lax.dynamic_index_in_dim(
                    ops["stall_mask"],
                    jnp.clip(t_g, 0, max(T_stall, 1) - 1),
                    axis=0, keepdims=False),
                False)                                      # (G, B)
            d_prev = jnp.min(c["states"].delivered_num, axis=1)  # (G,)
            sd_prev = jnp.where(
                d_prev[:, None] >= ranks[None, :],
                (d_prev[:, None] - ranks[None, :]) // S + 1, 0)
            inflight = (c["states"].published - sd_prev
                        + c["backlogs"])                    # (G, S)
            wm_stall = (take_rank(inflight) > ops["stall_backlog"]) \
                & (t > 0)

            # ---- engine phase (admission -> prefill -> decode ->
            # finish), skipped entirely on settle rounds --------------
            fields = (c["caches"], c["active"], c["held"],
                      c["hold_target"], c["hold_idx"], c["pos"],
                      c["last_tok"], c["slot_rid"], c["emitted"],
                      c["slot_max_new"], c["apps_enq"], c["admitted"],
                      c["rq_head"], c["stall_ct"])

            def engine_phase(f):
                (caches, active, held, target, hidx, pos, last, rid,
                 emitted, mnew, enq, admitted, rq_head, stall_ct) = f
                # admission: the k-th free live slot (slot order) takes
                # the k-th queued request — requeued head-of-queue
                # entries first, then eligible regulars in arrival
                # order (ServeEngine._admit's popleft loop)
                free = (~active) & (~held) & live_c
                order = jnp.cumsum(free.astype(i32), axis=1) - 1
                admit = free & (order < qlen[:, None])
                from_rq = admit & (order < n_rq_pend[:, None])
                rq_idx = jnp.clip(rq_head[:, None] + order, 0, V - 1)
                rq_ridx = jnp.take_along_axis(ops["requeue"], rq_idx,
                                              axis=1)
                j = order - n_rq_pend[:, None]              # (G, B)
                erank = jnp.cumsum(elig.astype(i32), axis=1) - 1
                sel = (elig[:, None, :]
                       & (erank[:, None, :] == j[:, :, None])
                       & admit[:, :, None]
                       & ~from_rq[:, :, None])              # (G, B, R)
                reg_ridx = jnp.sum(
                    sel * ridx_r[None, None, :], axis=2).astype(i32)
                ridx = jnp.where(from_rq, rq_ridx, reg_ridx)
                safe_r = jnp.where(admit, ridx, 0)
                admitted = admitted | jnp.any(sel, axis=1)
                rq_head = rq_head + jnp.sum(from_rq.astype(i32),
                                            axis=1)
                plen = jnp.take_along_axis(ops["prompt_len"], safe_r,
                                           axis=1)
                amnew = jnp.take_along_axis(ops["max_new"], safe_r,
                                            axis=1)
                pslot = jnp.stack(
                    [jnp.take(ops["prompts"][g], safe_r[g], axis=0)
                     for g in range(n_g)])                  # (G, B, P)
                rid = jnp.where(admit, ridx, rid)
                mnew = jnp.where(admit, amnew, mnew)
                emitted = jnp.where(admit, 0, emitted)

                # prefill: every admitted slot — across ALL replicas,
                # the caches are folded into one (G*B)-row batch —
                # feeds prompt token p at position p; bystanders are
                # masked no-ops.  Rows are independent, so this equals
                # the sequential per-slot prefill of the unfused engine
                # bit-for-bit — including the admission reset.
                def prefill(cs):
                    cs = reset_body(cs, admit.reshape(-1))

                    def pf(p, cs):
                        valid = admit & (p < plen)          # (G, B)
                        tok = jax.lax.dynamic_index_in_dim(
                            pslot, p, axis=2, keepdims=False)
                        tokens = jnp.where(valid, tok, 0).reshape(-1, 1)
                        posv = jnp.where(admit, p, pos).reshape(-1)
                        _, nc = decode_body(params, cs,
                                            tokens.astype(i32),
                                            posv.astype(i32),
                                            valid.reshape(-1))
                        return nc

                    return jax.lax.fori_loop(0, P, pf, cs)

                caches = jax.lax.cond(jnp.any(admit), prefill,
                                      lambda cs: cs, caches)
                pos = jnp.where(admit, plen, pos)
                # first decode input after prefill is the LAST prompt
                # token (fed once more at position P)
                lastp = jnp.take_along_axis(
                    pslot, jnp.maximum(plen - 1, 0)[:, :, None],
                    axis=2)[:, :, 0]
                last = jnp.where(admit, lastp, last)
                active = active | admit

                # stalls bind AFTER admission (a stalled slot still
                # admits and prefills — ServeEngine.step's ordering);
                # stalled occupied slots count, then sit out the decode
                stall_now = (sched_stall | wm_stall) & active
                stall_ct = stall_ct + jnp.sum(
                    stall_now.astype(i32))
                emit = active & ~stall_now

                # main decode: one masked step for every replica's
                # whole ring at once (the folded batch)
                tokens = jnp.where(emit, last, 0).reshape(-1, 1)
                logits, caches = decode_body(params, caches,
                                             tokens.astype(i32),
                                             pos.reshape(-1).astype(i32),
                                             emit.reshape(-1))
                flat = logits.astype(jnp.float32).reshape(n_g * B, -1)
                nxt = jnp.argmax(flat, axis=-1).astype(i32) \
                    .reshape(n_g, B)
                last = jnp.where(emit, nxt, last)
                emitted = emitted + emit.astype(i32)
                pos = pos + emit.astype(i32)
                done = emitted >= mnew
                if eos_id is not None:
                    done = done | (nxt == eos_id)
                fin = emit & (done | (pos >= max_len - 1))
                active = active & ~fin
                pos = jnp.where(fin, 0, pos)

                counts = admit.astype(i32) + emit.astype(i32)
                enq = enq + counts
                # finished slots hold until the delivery watermark
                # passes their last enqueued app (the SMC slot-reuse
                # rule)
                held = held | fin
                target = jnp.where(fin, enq, target)
                hidx = jnp.where(fin, -1, hidx)
                adm_rec = jnp.where(admit, ridx, -1)
                tok_rec = jnp.where(emit, nxt, -1)
                return ((caches, active, held, target, hidx, pos, last,
                         rid, emitted, mnew, enq, admitted, rq_head,
                         stall_ct),
                        (counts, adm_rec, tok_rec, fin))

            def idle_phase(f):
                z = jnp.zeros((n_g, B), i32)
                neg = jnp.full((n_g, B), -1, i32)
                return f, (z, neg, neg, jnp.zeros((n_g, B), bool))

            fields, (counts, adm_rec, tok_rec, fin) = jax.lax.cond(
                serving, engine_phase, idle_phase, fields)
            (caches, active, held, target, hidx, pos, last, rid,
             emitted, mnew, enq, admitted, rq_head, stall_ct) = fields

            # ---- multicast sweep: the SAME round body the stream
            # runs, on the live sender ranks (compacted slot order) ---
            counts_rank = jnp.take_along_axis(counts, rank_slot_c,
                                              axis=1)     # (G, S)
            old = c["states"]
            (states, backlogs), (batch, pub, nulls) = \
                sweep_mod.stream_stacked(
                    old, c["backlogs"], counts_rank, windows=win_arr,
                    null_send=True, receive_fn=receive_fn)

            # ---- holds: pin at the k-th app's publish index, release
            # on the watermark (ReplicatedEngine._sync_holds, in-graph,
            # gathered from rank space into slot space) ---------------
            app_sent_s = take_rank(states.app_sent)
            crossed = held & (hidx < 0) & (target > 0) \
                & (app_sent_s >= target)
            pin = take_rank(old.published) \
                + (target - take_rank(old.app_sent)) - 1
            hidx = jnp.where(crossed, pin, hidx)
            d = jnp.min(states.delivered_num, axis=1)       # (G,)
            sd = jnp.where(d[:, None] >= ranks[None, :],
                           (d[:, None] - ranks[None, :]) // S + 1, 0)
            freed = held & (hidx >= 0) & (take_rank(sd) > hidx)
            held = held & ~freed

            return {
                "t": t + 1,
                "t_serve": c["t_serve"] + serving.astype(i32),
                "states": states, "backlogs": backlogs,
                "caches": caches, "active": active, "held": held,
                "hold_target": target, "hold_idx": hidx, "pos": pos,
                "last_tok": last, "slot_rid": rid, "emitted": emitted,
                "slot_max_new": mnew, "apps_enq": enq,
                "avail": avail, "admitted": admitted, "shed": shed,
                "shed_round": shed_round, "rq_head": rq_head,
                "stall_ct": stall_ct,
                "tb_batch": c["tb_batch"].at[t].set(batch.astype(i32)),
                "tb_pub": c["tb_pub"].at[t].set(pub.astype(i32)),
                "tb_nulls": c["tb_nulls"].at[t].set(nulls.astype(i32)),
                "tb_admit": c["tb_admit"].at[t].set(adm_rec),
                "tb_tok": c["tb_tok"].at[t].set(tok_rec),
                "tb_fin": c["tb_fin"].at[t].set(fin),
                "tb_free": c["tb_free"].at[t].set(freed),
                "tb_backlog": c["tb_backlog"].at[t].set(
                    jnp.sum(backlogs).astype(i32)),
                "tb_depth": c["tb_depth"].at[t].set(depth),
            }

        init = ops["init"]
        c = {
            "t": jnp.asarray(0, i32), "t_serve": jnp.asarray(0, i32),
            "states": sweep_mod.batch_states(N, S, n_g),
            "backlogs": ops["backlogs0"].astype(i32),
            "caches": _fold_caches(specs, caches),
            "active": init["active"], "held": init["held"],
            "hold_target": init["hold_target"].astype(i32),
            "hold_idx": jnp.full((n_g, B), -1, i32),
            "pos": init["pos"].astype(i32),
            "last_tok": init["last_tok"].astype(i32),
            "slot_rid": init["slot_rid"].astype(i32),
            "emitted": init["emitted"].astype(i32),
            "slot_max_new": init["slot_max_new"].astype(i32),
            "apps_enq": init["apps_enq"].astype(i32),
            "avail": init["avail"].astype(i32),
            "admitted": init["admitted"], "shed": init["shed"],
            "shed_round": init["shed_round"].astype(i32),
            "rq_head": jnp.zeros((n_g,), i32),
            "stall_ct": jnp.asarray(0, i32),
            "tb_batch": jnp.zeros((t_total, n_g, N), i32),
            "tb_pub": jnp.zeros((t_total, n_g, S), i32),
            "tb_nulls": jnp.zeros((t_total, n_g, S), i32),
            "tb_admit": jnp.full((t_total, n_g, B), -1, i32),
            "tb_tok": jnp.full((t_total, n_g, B), -1, i32),
            "tb_fin": jnp.zeros((t_total, n_g, B), bool),
            "tb_free": jnp.zeros((t_total, n_g, B), bool),
            "tb_backlog": jnp.zeros((t_total,), i32),
            "tb_depth": jnp.zeros((t_total,), i32),
        }

        def cond(c):
            q = sweep_mod.quiescent_stacked(c["states"], c["backlogs"])
            return (c["t"] < t_total) & (c["t_serve"] < t_stop) \
                & (serving_now(c) | ~q)

        out = jax.lax.while_loop(cond, body_fn, c)
        # hand per-replica cache trees back (sliced in-program: free
        # at trace time, no eager per-leaf dispatches on the host)
        out["caches"] = tuple(
            _unfold_caches(specs, out["caches"], n_g, B))
        return out

    return jax.jit(program)


def _owner_fill(tb_admit: np.ndarray, init_rid: np.ndarray) -> np.ndarray:
    """Per-(round, replica, slot) owning request index: one vectorized
    forward-fill of the last admission at or before each round over the
    whole ``tb_admit`` buffer (replaces the per-(round, slot) O(T)
    column scans of the original reconstruction).  Rounds before a
    slot's first in-epoch admission fall back to ``init_rid`` — the
    request occupying the slot when the epoch began (-1 if idle)."""
    t_n = tb_admit.shape[0]
    if t_n == 0:
        return np.zeros_like(tb_admit)
    idx = np.where(tb_admit >= 0, np.arange(t_n)[:, None, None], -1)
    last = np.maximum.accumulate(idx, axis=0)
    own = np.take_along_axis(tb_admit, np.maximum(last, 0), axis=0)
    return np.where(last >= 0, own, init_rid[None].astype(tb_admit.dtype))


def run_fused(rep, *, max_rounds: int = 10_000, fail_at=None,
              arrive_schedule=None, arrive_rounds: int = 0,
              admission=None) -> Optional[RunReport]:
    """Execute one serve run of ``rep`` (a
    :class:`repro.serve.fanout.ReplicatedEngine`) as one compiled device
    program per membership epoch, then reconstruct the engines' and
    fan-out's host state from the device round traces so callers see
    exactly what the per-round loop would have produced.

    ``fail_at`` must be wave-normalized.  With cuts scheduled, the
    while_loop exits at each failure round, the host performs the PR 5 /
    PR 7 cut through the SAME :meth:`ReplicatedEngine._fail_nodes` the
    unfused loop uses, and the next epoch re-enters a fused program with
    the ``EpochCarry`` resend as its initial backlog.

    Returns None when the FIRST epoch overflows the analytic round
    budget (the caller falls back to the unfused loop — engine state is
    untouched until the first reconstruction, so the fallback restarts
    cleanly).  A later epoch overflowing raises RuntimeError: the run is
    already partially applied and cannot be replayed host-side.  Raises
    :class:`FusedUnsupported` for unsupported workload shapes."""
    from repro.serve.fanout import _SlotHold

    engines = rep.engines
    e0 = engines[0]
    n_g, B = len(engines), e0.ecfg.max_batch
    subs = len(rep.topics[0].subscribers)
    fail_at = dict(fail_at or {})

    # ---- assemble the request universe: initial queues + the truncated
    # arrival schedule, in arrival order (index order == FIFO order) ---
    n_init = [len(eng.queue) for eng in engines]
    reqs = [list(eng.queue) for eng in engines]
    sched = list(arrive_schedule or [])
    if sched and arrive_rounds <= 0:
        arrive_rounds = len(sched)
    t_arr = min(len(sched), arrive_rounds) if sched else 0
    arr_counts = np.zeros((max(t_arr, 1), n_g), np.int32)
    arrive_at: List[Tuple[int, int]] = []    # (rid, round submitted)
    for rnd in range(t_arr):
        row = sched[rnd]
        for g in range(n_g):
            cell = list(row[g]) if row[g] else []
            arr_counts[rnd, g] = len(cell)
            for q in cell:
                reqs[g].append(q)
                arrive_at.append((q.rid, rnd))
    r_max = max(len(r) for r in reqs)
    if r_max == 0:
        raise FusedUnsupported("empty workload")
    p_max = max(len(q.prompt) for r in reqs for q in r)
    m_max = max(q.max_new_tokens for r in reqs for q in r)
    rid_to_idx = [{q.rid: i for i, q in enumerate(reqs[g])}
                  for g in range(n_g)]

    stalls = rep.stall_fn if isinstance(rep.stall_fn, np.ndarray) \
        else None
    t_stall = int(stalls.shape[0]) if stalls is not None else 0
    stall_mask = np.zeros((max(t_stall, 1), n_g, B), bool)
    if stalls is not None:
        stall_mask[:t_stall] = stalls.astype(bool)

    big = np.int32(2 ** 30)
    q_cap = big if admission is None or admission.queue_cap is None \
        else np.int32(admission.queue_cap)
    s_backlog = big if admission is None \
        or admission.stall_backlog is None \
        else np.int32(admission.stall_backlog)

    rep._reset_run_state()
    window = rep.topics[0].window
    t_serve_cap, t_total = _round_budget(
        r_max, B, m_max, window, B + subs, max_rounds,
        arrive_rounds=arrive_rounds, stall_slack=int(stall_mask.sum()))
    wall0 = time.perf_counter()
    now = time.time()
    tok0 = sum(len(r.tokens_out) for eng in engines
               for r in eng.completed)
    req0 = sum(len(eng.completed) for eng in engines)
    steps0 = sum(e.decode_steps for e in engines)

    # ---- host-side run accumulators ----------------------------------
    depth_all: List[int] = []
    backlog_all: List[int] = []
    birth = np.full((n_g, B), -1, np.int64)   # current hold's fin round
    prev_shed = np.zeros((n_g, r_max), bool)
    stall_total = 0
    fused_rounds = 0
    epochs_run = 0

    # epoch-1 initial state: everything idle, identity rank map
    init = {
        "active": np.zeros((n_g, B), bool),
        "held": np.zeros((n_g, B), bool),
        "hold_target": np.zeros((n_g, B), np.int32),
        "pos": np.zeros((n_g, B), np.int32),
        "last_tok": np.zeros((n_g, B), np.int32),
        "slot_rid": np.full((n_g, B), -1, np.int32),
        "emitted": np.zeros((n_g, B), np.int32),
        "slot_max_new": np.zeros((n_g, B), np.int32),
        "apps_enq": np.zeros((n_g, B), np.int32),
        "avail": np.asarray(n_init, np.int32),
        "admitted": np.zeros((n_g, r_max), bool),
        "shed": np.zeros((n_g, r_max), bool),
        "shed_round": np.full((n_g, r_max), -1, np.int32),
    }
    requeue = np.full((n_g, 1), -1, np.int32)
    n_rq = np.zeros(n_g, np.int32)
    caches_dev: Tuple = tuple(eng.cache for eng in engines)
    backlogs0 = np.zeros((n_g, B), np.int32)
    bound = None
    t0 = 0
    pending = deque(sorted(fail_at))

    while True:
        rank_slot = [list(r) for r in rep._rank_slot]
        s_live = len(rank_slot[0])
        if any(len(r) != s_live for r in rank_slot):
            raise FusedUnsupported(
                "cut left replicas with unequal live slot counts")
        if bound is None:
            n_live = B + subs
        else:
            if bound.stream._mask_args:
                raise RuntimeError(
                    "fused epoch after a cut has heterogeneous topic "
                    "shapes; the fallback precheck should have caught "
                    "this")
            n_live = bound.stream.n_members[0]
            s_chk = bound.stream.n_senders[0]
            if s_chk != s_live:
                raise RuntimeError(
                    f"stream sender count {s_chk} disagrees with live "
                    f"slot count {s_live} after the cut")
        nxt_fail = pending[0] if pending else None
        t_stop = (nxt_fail - t0 + 1) if nxt_fail is not None else t_total
        v_cap = max(1, requeue.shape[1])

        shapes = (n_g, B, s_live, n_live, window, rep.backend, r_max,
                  p_max, t_serve_cap, t_total, e0.ecfg.eos_id,
                  e0.ecfg.max_len, t_arr, t_stall, v_cap)
        key = ("serve-fused", repr(e0.cfg), repr(e0.rt), shapes,
               tuple(tuple(r) for r in rank_slot))
        program = fused_stream_program(
            key, lambda: _build_program(e0._decode_body, e0._reset_body,
                                        e0.cache_specs, shapes,
                                        rank_slot))
        ops = {
            "prompts": _pad_prompts(reqs, n_g, r_max, p_max),
            "prompt_len": jnp.asarray(
                _req_field(reqs, n_g, r_max,
                           lambda q: len(q.prompt))),
            "max_new": jnp.asarray(
                _req_field(reqs, n_g, r_max,
                           lambda q: q.max_new_tokens)),
            "arr_counts": jnp.asarray(arr_counts),
            "stall_mask": jnp.asarray(stall_mask),
            "requeue": jnp.asarray(requeue),
            "n_rq": jnp.asarray(n_rq),
            "queue_cap": jnp.asarray(q_cap, jnp.int32),
            "stall_backlog": jnp.asarray(s_backlog, jnp.int32),
            "arrive_rounds": jnp.asarray(arrive_rounds, jnp.int32),
            "t0": jnp.asarray(t0, jnp.int32),
            "t_stop": jnp.asarray(t_stop, jnp.int32),
            "backlogs0": jnp.asarray(backlogs0[:, :s_live]),
            "init": {k: jnp.asarray(v) for k, v in init.items()},
        }
        out = program(e0.params, caches_dev, ops)
        epochs_run += 1

        if bound is None:
            # bind the stream while the device loop runs (dispatch is
            # async; the stream is first needed at absorb time)
            bound = rep.domain.bind(backend=rep.backend)
            stream = bound.stream
            if stream._mask_args:
                raise FusedUnsupported(
                    "heterogeneous topic shapes (padded stack) — fused "
                    "path needs a homogeneous slot ring")
            if not stream.group.cfg.flags.null_send:
                raise FusedUnsupported(
                    "null_send disabled: the in-graph drain may never "
                    "quiesce")
            if stream.windows[0] != window:
                raise FusedUnsupported(
                    "topic window disagrees with the bound stream's "
                    "protocol window")

        host = jax.device_get({k: out[k] for k in (
            "t", "t_serve", "active", "held", "hold_target", "pos",
            "last_tok", "slot_rid", "emitted", "slot_max_new",
            "apps_enq", "avail", "admitted", "shed", "shed_round",
            "rq_head", "stall_ct", "tb_batch", "tb_pub", "tb_nulls",
            "tb_admit", "tb_tok", "tb_fin", "tb_free", "tb_backlog",
            "tb_depth")})
        t_end = int(host["t"])
        t_serve = int(host["t_serve"])
        wedged = nxt_fail is not None and t_serve >= t_stop
        if not wedged:
            qleft = int(n_rq.sum()) - int(host["rq_head"].sum()) + int(
                ((np.arange(r_max)[None, :] < host["avail"][:, None])
                 & ~host["admitted"] & ~host["shed"]).sum())
            live = host["active"].any() or qleft > 0
            overflow = (live and t_serve < max_rounds) or (
                t_end >= t_total and not bool(
                    sweep_mod.quiescent_stacked(out["states"],
                                                out["backlogs"])))
            if overflow:
                if epochs_run == 1:
                    return None      # budget overflow: fall back clean
                raise RuntimeError(
                    "fused epoch %d overflowed its round budget "
                    "mid-run (t=%d, budget=%d); raise max_rounds or "
                    "run unfused" % (epochs_run, t_end, t_total))

        # ---- per-epoch host reconstruction (one crossing per epoch) --
        stall_total += int(host["stall_ct"])
        fused_rounds += t_end
        birth = _apply_epoch(
            rep, reqs, host, out, t0, t_serve, t_end, rank_slot,
            bound.stream, now, birth, prev_shed, depth_all, backlog_all)
        prev_shed = host["shed"].copy()
        _materialize_engines(rep, reqs, host, requeue, n_rq, _SlotHold,
                             birth)
        for g, eng in enumerate(engines):
            eng.cache = out["caches"][g]
        caches_dev = tuple(out["caches"])

        if not wedged:
            break

        # ---- the wedge: host performs the PR 5/PR 7 cut, the next
        # epoch re-enters a fused program with the resend as backlog ---
        pending.popleft()
        bound = rep._fail_nodes(bound, fail_at[nxt_fail], nxt_fail,
                                admission)
        t0 = nxt_fail + 1
        init = _epoch_init(rep, reqs, rid_to_idx, host, n_g, B, r_max)
        requeue, n_rq = _requeue_ops(rep, rid_to_idx,
                                     host["admitted"], n_g)
        backlogs0 = np.asarray(bound.stream._backlogs, np.int32)
        birth = _hold_births(rep, birth, n_g, B)

    # ---- finish: settle already ran in-graph; post-process ----------
    total_serve = t0 + t_serve
    unreached = sorted(r for r in fail_at if r >= total_serve)
    report, logs = bound.finish()
    rep.queue_depth_log = list(depth_all)
    rep.backlog_log = list(backlog_all)
    rep.stall_rounds = stall_total
    wall = time.perf_counter() - wall0
    tokens = sum(len(r.tokens_out) for eng in engines
                 for r in eng.completed) - tok0
    report.extras["delivery_logs"] = logs
    report.extras["serve"] = {
        "replicas": n_g,
        "engine_rounds": total_serve,
        "drained": all(eng.drained() for eng in engines),
        "decode_steps": sum(e.decode_steps
                            for e in engines) - steps0,
        "requests": sum(len(e.completed) for e in engines) - req0,
        "tokens": tokens,
        "tokens_per_s": tokens / wall if wall > 0 else 0.0,
        "stall_rounds": stall_total,
        "held_slots": sum(len(h) for h in rep._holds),
        "view_changes": len(rep.view_log),
        "slot_failures": len(rep.slot_failures),
        "voided_requests": sum(1 for r in rep.slot_failures
                               if r["voided_rid"] is not None),
        "requeued_requests": sum(1 for r in rep.slot_failures
                                 if r["requeued"]),
        "slot_failure_log": list(rep.slot_failures),
        "fail_at_unreached": unreached,
        "shed_requests": len(rep.shed_log),
        # per-RUN maxima over THIS run's logs (not whole-history state:
        # a fused run after a prior run must not report stale maxima)
        "max_queue_depth": max(depth_all, default=0),
        "max_backlog": max(backlog_all, default=0),
        "wall_s": wall,
        "fused": True,
        "host_hops": 0,
        "fused_rounds": fused_rounds,
        "fused_round_budget": t_total,
        "fused_epochs": epochs_run,
    }
    for rid, rnd in arrive_at:
        rep.submit_rounds[rid] = rnd
    rep.last_report = report
    return report


# ---------------------------------------------------------------------------
# Host-side epoch reconstruction
# ---------------------------------------------------------------------------

def _req_field(reqs, n_g, r_max, fn):
    arr = np.zeros((n_g, r_max), np.int32)
    for g, rs in enumerate(reqs):
        for i, q in enumerate(rs):
            arr[g, i] = fn(q)
    return arr


def _pad_prompts(reqs, n_g, r_max, p_max):
    prompts = np.zeros((n_g, r_max, p_max), np.int32)
    for g, rs in enumerate(reqs):
        for i, q in enumerate(rs):
            prompts[g, i, :len(q.prompt)] = np.asarray(q.prompt,
                                                       np.int32)
    return jnp.asarray(prompts)


def _apply_epoch(rep, reqs, host, out, t0, t_serve, t_end, rank_slot,
                 stream, now, birth, prev_shed, depth_all, backlog_all):
    """Replay one epoch's device traces into the fan-out's host state —
    tokens, completions, admission/finish/free/shed bookkeeping, the
    depth/backlog logs — and absorb the multicast rounds into the bound
    stream.  Returns the updated per-slot hold-birth rounds (used to
    keep free_rounds in the host loop's hold-insertion order)."""
    engines = rep.engines
    n_g = len(engines)
    tb = {k: host[k][:t_end] for k in
          ("tb_batch", "tb_pub", "tb_nulls", "tb_admit", "tb_tok",
           "tb_fin", "tb_free", "tb_backlog", "tb_depth")}
    counts = (tb["tb_admit"] >= 0).astype(np.int64) \
        + (tb["tb_tok"] >= 0).astype(np.int64)          # (T, G, B)
    stream.absorb(
        out["states"], out["backlogs"], list(tb["tb_batch"]),
        list(tb["tb_pub"]), list(tb["tb_nulls"]),
        [counts[:, g, :].sum(axis=0)[np.asarray(rank_slot[g])]
         for g in range(n_g)])

    init_rid = np.where(host["slot_rid"] >= 0, host["slot_rid"], -1)
    # the epoch-END slot_rid is not the start state; recover the start
    # owner by rolling admissions back: a slot's pre-epoch owner is only
    # needed for rounds BEFORE its first in-epoch admission, and that
    # owner is exactly the engine's slot_req at epoch entry — which
    # _materialize_engines wrote as reqs indices last epoch.  For the
    # first epoch every slot starts idle (-1).
    start_rid = np.full(init_rid.shape, -1, np.int64)
    for g, eng in enumerate(engines):
        for s, q in enumerate(eng.slot_req):
            if q is not None:
                start_rid[g, s] = _rid_index(reqs, g, q)
    own = _owner_fill(tb["tb_admit"], start_rid)

    # admissions: bookkeeping + prefill decode steps
    for t, g, s in zip(*np.nonzero(tb["tb_admit"] >= 0)):
        i = int(tb["tb_admit"][t, g, s])
        req = reqs[g][i]
        rep.admit_rounds[req.rid] = t0 + int(t)
        rep.admit_slots[req.rid] = (g, int(s))
        engines[g].decode_steps += len(req.prompt)
        req.tokens_out = []     # (re-)admission restarts from prompt

    # tokens, in round order (np.nonzero is t-major)
    for t, g, s in zip(*np.nonzero(tb["tb_tok"] >= 0)):
        reqs[g][own[t, g, s]].tokens_out.append(
            int(tb["tb_tok"][t, g, s]))

    # finishes: completion append order is (round, slot) per replica —
    # the per-round loop's order
    fins = sorted((int(t), int(g), int(s))
                  for t, g, s in zip(*np.nonzero(tb["tb_fin"])))
    for t, g, s in fins:
        req = reqs[g][own[t, g, s]]
        req.finished_at = now
        rep.finish_round_by_rid[req.rid] = t0 + t
        engines[g].completed.append(req)
        rep.finish_rounds.append((g, s, t0 + t))

    # sheds: round ascending, replica ascending, newest (highest
    # arrival index) first — the host loop's tail-pop order
    new_shed = host["shed"] & ~prev_shed
    evs = []
    for g in range(n_g):
        for i in np.nonzero(new_shed[g])[0]:
            evs.append((int(host["shed_round"][g, i]), g, -int(i)))
    for rnd, g, ni in sorted(evs):
        rep.shed_log.append((reqs[g][-ni].rid, rnd))

    # frees: serve-round frees at their round; settle-round frees all
    # land in the single post-finish sync at round t_serve, ordered by
    # hold creation (finish round, slot) per replica
    frees = []
    for t, g, s in zip(*np.nonzero(tb["tb_free"])):
        t, g, s = int(t), int(g), int(s)
        f_ts = [t0 + ft for (ft, gg, ss) in fins
                if gg == g and ss == s and ft <= t]
        b = max(f_ts) if f_ts else int(birth[g, s])
        frees.append((t0 + min(t, t_serve), g, b, s))
    for t, g, _b, s in sorted(frees):
        rep.free_rounds.append((g, s, t))

    # per-engine counters + run logs
    for g, eng in enumerate(engines):
        eng.rounds += t_serve
        eng.decode_steps += int(
            (tb["tb_tok"][:, g] >= 0).any(axis=1).sum())
        rep._apps_enqueued[g][:] = host["apps_enq"][g]
    depth_all.extend(int(x) for x in tb["tb_depth"][:t_serve])
    backlog_all.extend(int(x) for x in tb["tb_backlog"][:t_serve])

    # updated hold births: a held slot's current hold was created at
    # its last finish (this epoch, else carried from before)
    new_birth = birth.copy()
    for t, g, s in fins:
        new_birth[g, s] = t0 + t
    return new_birth


def _rid_index(reqs, g, q) -> int:
    for i, r in enumerate(reqs[g]):
        if r is q:
            return i
    raise KeyError(f"request rid={q.rid} not in replica {g}'s universe")


def _materialize_engines(rep, reqs, host, requeue, n_rq, slot_hold_cls,
                         birth):
    """Install the epoch-end carry as host engine/queue/hold state, so
    the cut (and the caller, after the final epoch) sees exactly what
    the per-round loop would have left behind."""
    n_g = len(rep.engines)
    r_max = host["admitted"].shape[1]
    for g, eng in enumerate(rep.engines):
        b = eng.ecfg.max_batch
        eng.slot_req = [None] * b
        eng.slot_len[:] = 0
        for s in range(b):
            if host["active"][g, s]:
                eng.slot_req[s] = reqs[g][int(host["slot_rid"][g, s])]
                eng.slot_len[s] = int(host["pos"][g, s])
        pend = [int(x) for x in
                requeue[g, int(host["rq_head"][g]):int(n_rq[g])]]
        elig = [i for i in range(int(host["avail"][g]))
                if not host["admitted"][g, i]
                and not host["shed"][g, i]]
        eng.queue = deque(reqs[g][i] for i in pend + elig)
        holds = {}
        order = sorted((int(birth[g, s]), s) for s in range(b)
                       if host["held"][g, s])
        for b_rnd, s in order:     # insertion order = creation order
            holds[s] = slot_hold_cls(
                target_apps=int(host["hold_target"][g, s]),
                last_idx=None, finished_round=max(b_rnd, 0))
        rep._holds[g] = holds


def _epoch_init(rep, reqs, rid_to_idx, host, n_g, b, r_max):
    """Build the next epoch's initial engine/queue carry from the
    post-cut host state (evictions, hold rebasing and re-queueing
    already applied by :meth:`ReplicatedEngine._fail_nodes`)."""
    init = {
        "active": np.zeros((n_g, b), bool),
        "held": np.zeros((n_g, b), bool),
        "hold_target": np.zeros((n_g, b), np.int32),
        "pos": np.zeros((n_g, b), np.int32),
        "last_tok": np.zeros((n_g, b), np.int32),
        "slot_rid": np.full((n_g, b), -1, np.int32),
        "emitted": np.zeros((n_g, b), np.int32),
        "slot_max_new": np.zeros((n_g, b), np.int32),
        "apps_enq": np.zeros((n_g, b), np.int32),
        "avail": host["avail"].astype(np.int32),
        "admitted": host["admitted"].copy(),
        "shed": host["shed"].copy(),
        "shed_round": host["shed_round"].astype(np.int32),
    }
    for g, eng in enumerate(rep.engines):
        for s in range(b):
            q = eng.slot_req[s]
            if q is not None:
                i = rid_to_idx[g][q.rid]
                init["active"][g, s] = True
                init["slot_rid"][g, s] = i
                init["pos"][g, s] = int(eng.slot_len[s])
                init["last_tok"][g, s] = (
                    q.tokens_out[-1] if q.tokens_out
                    else int(q.prompt[-1]))
                init["emitted"][g, s] = len(q.tokens_out)
                init["slot_max_new"][g, s] = q.max_new_tokens
        for s, hold in rep._holds[g].items():
            init["held"][g, s] = True
            init["hold_target"][g, s] = hold.target_apps
        init["apps_enq"][g, :] = rep._apps_enqueued[g]
    return init


def _requeue_ops(rep, rid_to_idx, admitted, n_g):
    """The post-cut head-of-queue re-admission list per replica: the
    queue's leading already-ADMITTED entries (a voided request the cut
    pushed back via ``appendleft``), which must admit before any
    eligible regular — regulars are never marked admitted while still
    queued, so the admitted flag is exactly the requeue marker."""
    rq: List[List[int]] = []
    for g, eng in enumerate(rep.engines):
        lst = []
        for q in eng.queue:
            i = rid_to_idx[g][q.rid]
            if not admitted[g, i]:
                break
            lst.append(i)
        rq.append(lst)
    v = max(1, max((len(r) for r in rq), default=1))
    arr = np.full((n_g, v), -1, np.int32)
    n = np.zeros(n_g, np.int32)
    for g, lst in enumerate(rq):
        arr[g, :len(lst)] = lst
        n[g] = len(lst)
    return arr, n


def _hold_births(rep, birth, n_g, b):
    """Clear birth rounds of slots whose hold the cut dropped/freed."""
    out = birth.copy()
    for g in range(n_g):
        for s in range(b):
            if s not in rep._holds[g]:
                out[g, s] = -1
    return out
