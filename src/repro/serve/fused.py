"""Fused device-resident serve plane: decode inside the scan body, one
compiled program per serve run (DESIGN.md Sec. 6).

The paper's core lesson is that small-object replication amplifies every
per-operation overhead until coordination is batched into the data path.
The unfused serve plane still pays that overhead once per engine round:
one jitted decode dispatch, a device->host logits sync, Python
bookkeeping, then one stacked-sweep dispatch
(:meth:`repro.serve.fanout.ReplicatedEngine.run`).  This module removes
the hop entirely: a whole serve run — admission, prefill, decode, token
emission, multicast publish, watermark-gated slot reuse, the quiescence
drain — executes as ONE compiled ``lax.while_loop`` program whose round
body composes the engine's masked decode step
(:meth:`repro.serve.engine.ServeEngine` ``_decode_body``) with the
multicast round body (:func:`repro.core.sweep.stream_stacked`, i.e.
``step_backlog`` vmapped over replicas).  Slot state, decode caches, SST
watermarks, backlogs, and slot holds all live in the carry; per-round
event traces land in preallocated device buffers and cross to the host
exactly once, after the loop exits.

Equivalence contract (tested bit-for-bit in tests/test_serve_fused.py):

* the same masked decode body runs in both paths, and a slot's decode
  state depends only on its own (token, position) sequence — batch rows
  are computed independently — so fusing admission-round prefills of
  different slots into one masked step reproduces the sequential
  per-slot prefill exactly;
* the multicast rounds ARE :func:`repro.core.sweep.step_backlog` on the
  same ``ready`` counts, so the round traces equal the streamed ones by
  construction; the run hands them to
  :meth:`repro.core.group.GroupStream.absorb` and the report/delivery
  logs come out of the identical :class:`repro.core.group.GraphBackend`
  post-processing;
* holds pin and release against the in-carry watermark with the same
  arithmetic as :meth:`ReplicatedEngine._sync_holds` /
  :meth:`GroupStream.app_publish_index` (apps precede nulls within a
  round), and the loop's serve/settle phase split mirrors the unfused
  ``run`` loop + ``finish`` drain round-for-round
  (:func:`repro.core.sweep.quiescent_stacked` is the same strict
  quiescence test evaluated in-graph).

What the fused path does NOT support — mid-run view changes
(``fail_at``), open-loop arrivals, client stalls, admission policies,
heterogeneous replicas — falls back to the per-round dispatch loop with
the reason recorded in ``extras["serve"]["fused_fallback"]``; the
chaos plane rides the fallback (DESIGN.md Secs. 7, 9).
"""

from __future__ import annotations

import math
import time
from typing import Any, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import group as group_mod
from repro.core import sweep as sweep_mod
from repro.core.group import TRACE_EVENTS, RunReport, fused_stream_program
from repro.models import masking
from repro.models.layers import ParamSpec


class FusedUnsupported(Exception):
    """The workload needs a feature only the per-round loop has; the
    caller falls back (explicitly, in extras) rather than fail."""


def fused_fallback_reason(rep, *, fail_at=None, arrive_fn=None,
                          admission=None,
                          settle_max=None) -> Optional[str]:
    """Why this run cannot take the fused path (None = it can).

    The fused program is shape-static and closed-loop: every dynamic
    feature of the unfused loop that reaches into Python mid-round —
    view changes, open-loop arrival callbacks, stall callbacks,
    admission policies, capped settles — keeps the per-round path."""
    if fail_at:
        return "fail_at: view changes cut through the unfused path"
    if arrive_fn is not None:
        return "arrive_fn: open-loop arrivals are host callbacks"
    if rep.stall_fn is not None:
        return "stall_fn: client stalls are host callbacks"
    if admission is not None:
        return "admission policy gates on host-side watermarks"
    if settle_max is not None:
        return "settle_max: capped settle needs the host drain loop"
    e0 = rep.engines[0]
    for eng in rep.engines:
        if (eng.cfg is not e0.cfg and eng.cfg != e0.cfg) \
                or eng.ecfg.max_batch != e0.ecfg.max_batch \
                or eng.ecfg.max_len != e0.ecfg.max_len \
                or eng.ecfg.eos_id != e0.ecfg.eos_id:
            return "heterogeneous replicas (mixed model/engine configs)"
        if eng.params is not e0.params:
            return ("replicas do not share one params tree (the fused "
                    "program folds every replica's slots into one "
                    "decode batch)")
        if any(r is not None for r in eng.slot_req):
            return "engines must start with empty slot rings"
    if not any(eng.queue for eng in rep.engines):
        return "empty workload"
    if any(len(r.prompt) == 0 for eng in rep.engines for r in eng.queue):
        return "empty prompts"
    if any(len(r.prompt) > e0.ecfg.max_len - 2
           or len(r.prompt) + r.max_new_tokens > e0.ecfg.max_len
           for eng in rep.engines for r in eng.queue):
        return "request would overflow max_len mid-run"
    return None


# ---------------------------------------------------------------------------
# The one-program serve run
# ---------------------------------------------------------------------------

def _round_budget(n_reqs: int, slots: int, max_new: int, window: int,
                  n_members: int, max_rounds: int) -> Tuple[int, int]:
    """(serve-round cap, total cap incl. settle) — generous analytic
    bounds; a run that overflows them falls back to the unfused loop
    rather than truncate."""
    waves = max(1, math.ceil(n_reqs / max(slots, 1)))
    per_wave = max_new + 8 + 3 * math.ceil((max_new + 1)
                                           / max(window, 1))
    serve = min(max_rounds, waves * per_wave + 16)
    settle = 2 * n_members + 16 + 3 * math.ceil(
        slots * (max_new + 2) / max(window, 1))
    return serve, serve + settle


def _fold_caches(specs, trees):
    """Concatenate per-replica cache trees along each leaf's batch axis:
    the fused program decodes ALL replicas' slots in ONE masked step
    (batch = G * slots).  Every decode-body op is row-independent along
    the batch axis, so slot (g, s)'s arithmetic — and therefore its
    tokens and state — is bit-identical to the per-replica step."""
    return jax.tree.map(
        lambda sp, *xs: jnp.concatenate(
            xs, axis=masking.batch_axis(sp)),
        specs, *trees, is_leaf=lambda x: isinstance(x, ParamSpec))


def _unfold_caches(specs, tree, n_g, slots):
    """Split a folded cache tree back into per-replica trees."""
    def cut(g):
        return jax.tree.map(
            lambda sp, x: jax.lax.slice_in_dim(
                x, g * slots, (g + 1) * slots,
                axis=masking.batch_axis(sp)),
            specs, tree, is_leaf=lambda x: isinstance(x, ParamSpec))
    return [cut(g) for g in range(n_g)]


def _build_program(key, decode_body, reset_body, specs, shapes):
    """Trace-once builder for one workload shape (see
    :func:`repro.core.group.fused_stream_program`)."""
    (n_g, slots, n_members, window, null_send, backend, r_max, p_max,
     t_serve_cap, t_total, eos_id, max_len) = shapes
    win_arr = np.full(n_g, window, np.int32)
    ring = window if backend == "pallas" else 0
    receive_fn = group_mod._kernel_receive(ring) \
        if backend == "pallas" else None
    i32 = jnp.int32

    def serving_now(c, n_reqs):
        live = jnp.any(c["active"]) | jnp.any(c["head"] < n_reqs)
        return live & (c["t_serve"] < t_serve_cap)

    def body_fn(c, params, prompts, prompt_len, max_new, n_reqs):
        serving = serving_now(c, n_reqs)
        t = c["t"]
        depth = jnp.sum(n_reqs - c["head"]).astype(i32)

        # ---- engine phase (admission -> prefill -> decode -> finish),
        # skipped entirely on settle rounds ----------------------------
        fields = (c["caches"], c["active"], c["held"], c["hold_target"],
                  c["hold_idx"], c["pos"], c["last_tok"], c["slot_rid"],
                  c["emitted"], c["slot_max_new"], c["apps_enq"],
                  c["head"])

        def engine_phase(f):
            (caches, active, held, target, hidx, pos, last, rid,
             emitted, mnew, enq, head) = f
            # admission: k-th free slot (slot order) takes the k-th
            # queued request — ServeEngine._admit's popleft loop
            free = (~active) & (~held)
            order = jnp.cumsum(free.astype(i32), axis=1) - 1
            admit = free & (order < (n_reqs - head)[:, None])
            ridx = head[:, None] + order
            safe_r = jnp.where(admit, ridx, 0)
            plen = jnp.take_along_axis(prompt_len, safe_r, axis=1)
            amnew = jnp.take_along_axis(max_new, safe_r, axis=1)
            pslot = jnp.stack([jnp.take(prompts[g], safe_r[g], axis=0)
                               for g in range(n_g)])  # (G, B, P_max)
            head = head + jnp.sum(admit, axis=1)
            rid = jnp.where(admit, ridx, rid)
            mnew = jnp.where(admit, amnew, mnew)
            emitted = jnp.where(admit, 0, emitted)

            # prefill: every admitted slot — across ALL replicas, the
            # caches are folded into one (G*B)-row batch — feeds prompt
            # token p at position p; bystanders are masked no-ops.  Rows
            # are independent, so this equals the sequential per-slot
            # prefill of the unfused engine bit-for-bit — including the
            # admission reset (recurrent state must not leak from the
            # slot's previous occupant).
            def prefill(cs):
                cs = reset_body(cs, admit.reshape(-1))

                def pf(p, cs):
                    valid = admit & (p < plen)          # (G, B)
                    tok = jax.lax.dynamic_index_in_dim(
                        pslot, p, axis=2, keepdims=False)
                    tokens = jnp.where(valid, tok, 0).reshape(-1, 1)
                    posv = jnp.where(admit, p, pos).reshape(-1)
                    _, nc = decode_body(params, cs,
                                        tokens.astype(i32),
                                        posv.astype(i32),
                                        valid.reshape(-1))
                    return nc

                return jax.lax.fori_loop(0, p_max, pf, cs)

            caches = jax.lax.cond(jnp.any(admit), prefill,
                                  lambda cs: cs, caches)
            pos = jnp.where(admit, plen, pos)
            # first decode input after prefill is the LAST prompt token
            # (fed once more at position P — the unfused contract)
            lastp = jnp.take_along_axis(
                pslot, jnp.maximum(plen - 1, 0)[:, :, None],
                axis=2)[:, :, 0]
            last = jnp.where(admit, lastp, last)
            active = active | admit

            # main decode: one masked step for every replica's whole
            # ring at once (the folded batch)
            emit = active
            tokens = jnp.where(emit, last, 0).reshape(-1, 1)
            logits, caches = decode_body(params, caches,
                                         tokens.astype(i32),
                                         pos.reshape(-1).astype(i32),
                                         emit.reshape(-1))
            flat = logits.astype(jnp.float32).reshape(n_g * slots, -1)
            nxt = jnp.argmax(flat, axis=-1).astype(i32) \
                .reshape(n_g, slots)                  # (G, B)
            last = jnp.where(emit, nxt, last)
            emitted = emitted + emit.astype(i32)
            pos = pos + emit.astype(i32)
            done = emitted >= mnew
            if eos_id is not None:
                done = done | (nxt == eos_id)
            fin = emit & (done | (pos >= max_len - 1))
            active = active & ~fin
            pos = jnp.where(fin, 0, pos)

            counts = admit.astype(i32) + emit.astype(i32)
            enq = enq + counts
            # finished slots hold until the delivery watermark passes
            # their last enqueued app (the SMC slot-reuse rule)
            held = held | fin
            target = jnp.where(fin, enq, target)
            hidx = jnp.where(fin, -1, hidx)
            adm_rec = jnp.where(admit, ridx, -1)
            tok_rec = jnp.where(emit, nxt, -1)
            return ((caches, active, held, target, hidx, pos, last,
                     rid, emitted, mnew, enq, head),
                    (counts, adm_rec, tok_rec, fin))

        def idle_phase(f):
            z = jnp.zeros((n_g, slots), i32)
            neg = jnp.full((n_g, slots), -1, i32)
            return f, (z, neg, neg, jnp.zeros((n_g, slots), bool))

        fields, (counts, adm_rec, tok_rec, fin) = jax.lax.cond(
            serving, engine_phase, idle_phase, fields)
        (caches, active, held, target, hidx, pos, last, rid, emitted,
         mnew, enq, head) = fields

        # ---- multicast sweep: the SAME round body the stream runs ----
        old = c["states"]
        (states, backlogs), (batch, pub, nulls) = \
            sweep_mod.stream_stacked(
                old, c["backlogs"], counts, windows=win_arr,
                null_send=null_send, receive_fn=receive_fn)

        # ---- holds: pin at the k-th app's publish index, release on
        # the watermark (ReplicatedEngine._sync_holds, in-graph) -------
        crossed = held & (hidx < 0) & (target > 0) \
            & (states.app_sent >= target)
        pin = old.published + (target - old.app_sent) - 1
        hidx = jnp.where(crossed, pin, hidx)
        d = jnp.min(states.delivered_num, axis=1)       # (G,)
        ranks = jnp.arange(slots)
        sd = jnp.where(d[:, None] >= ranks[None, :],
                       (d[:, None] - ranks[None, :]) // slots + 1, 0)
        freed = held & (hidx >= 0) & (sd > hidx)
        held = held & ~freed

        return {
            "t": t + 1,
            "t_serve": c["t_serve"] + serving.astype(i32),
            "states": states, "backlogs": backlogs, "caches": caches,
            "active": active, "held": held, "hold_target": target,
            "hold_idx": hidx, "pos": pos, "last_tok": last,
            "slot_rid": rid, "emitted": emitted, "slot_max_new": mnew,
            "apps_enq": enq, "head": head,
            "tb_batch": c["tb_batch"].at[t].set(batch.astype(i32)),
            "tb_pub": c["tb_pub"].at[t].set(pub.astype(i32)),
            "tb_nulls": c["tb_nulls"].at[t].set(nulls.astype(i32)),
            "tb_admit": c["tb_admit"].at[t].set(adm_rec),
            "tb_tok": c["tb_tok"].at[t].set(tok_rec),
            "tb_fin": c["tb_fin"].at[t].set(fin),
            "tb_free": c["tb_free"].at[t].set(freed),
            "tb_backlog": c["tb_backlog"].at[t].set(
                jnp.sum(backlogs).astype(i32)),
            "tb_depth": c["tb_depth"].at[t].set(depth),
        }

    def program(params, caches, prompts, prompt_len, max_new, n_reqs):
        TRACE_EVENTS.append(((n_g, n_members, slots), (window,) * n_g,
                             backend + "+decode"))
        c = {
            "t": jnp.asarray(0, i32), "t_serve": jnp.asarray(0, i32),
            "states": sweep_mod.batch_states(n_members, slots, n_g),
            "backlogs": jnp.zeros((n_g, slots), i32),
            "caches": _fold_caches(specs, caches),
            "active": jnp.zeros((n_g, slots), bool),
            "held": jnp.zeros((n_g, slots), bool),
            "hold_target": jnp.zeros((n_g, slots), i32),
            "hold_idx": jnp.full((n_g, slots), -1, i32),
            "pos": jnp.zeros((n_g, slots), i32),
            "last_tok": jnp.zeros((n_g, slots), i32),
            "slot_rid": jnp.full((n_g, slots), -1, i32),
            "emitted": jnp.zeros((n_g, slots), i32),
            "slot_max_new": jnp.zeros((n_g, slots), i32),
            "apps_enq": jnp.zeros((n_g, slots), i32),
            "head": jnp.zeros((n_g,), i32),
            "tb_batch": jnp.zeros((t_total, n_g, n_members), i32),
            "tb_pub": jnp.zeros((t_total, n_g, slots), i32),
            "tb_nulls": jnp.zeros((t_total, n_g, slots), i32),
            "tb_admit": jnp.full((t_total, n_g, slots), -1, i32),
            "tb_tok": jnp.full((t_total, n_g, slots), -1, i32),
            "tb_fin": jnp.zeros((t_total, n_g, slots), bool),
            "tb_free": jnp.zeros((t_total, n_g, slots), bool),
            "tb_backlog": jnp.zeros((t_total,), i32),
            "tb_depth": jnp.zeros((t_total,), i32),
        }

        def cond(c):
            q = sweep_mod.quiescent_stacked(c["states"], c["backlogs"])
            return (c["t"] < t_total) & (serving_now(c, n_reqs) | ~q)

        out = jax.lax.while_loop(
            cond, lambda c: body_fn(c, params, prompts, prompt_len,
                                    max_new, n_reqs), c)
        # hand per-replica cache trees back (sliced in-program: free
        # at trace time, no eager per-leaf dispatches on the host)
        out["caches"] = tuple(
            _unfold_caches(specs, out["caches"], n_g, slots))
        return out

    return jax.jit(program)


def run_fused(rep, *, max_rounds: int = 10_000) -> Optional[RunReport]:
    """Execute one serve run of ``rep`` (a
    :class:`repro.serve.fanout.ReplicatedEngine`) as ONE compiled
    program, then reconstruct the engines' and fan-out's host state from
    the device round traces so callers see exactly what the per-round
    loop would have produced.  Returns None when the run overflows the
    analytic round budget (the caller falls back to the unfused loop —
    engine state is untouched until success, so the fallback restarts
    cleanly).  Raises :class:`FusedUnsupported` for unsupported
    workload shapes."""
    engines = rep.engines
    e0 = engines[0]
    n_g, slots = len(engines), e0.ecfg.max_batch
    subs = len(rep.topics[0].subscribers)
    n_members = slots + subs
    reqs = [list(eng.queue) for eng in engines]
    r_max = max(len(r) for r in reqs)
    p_max = max(len(q.prompt) for r in reqs for q in r)
    m_max = max(q.max_new_tokens for r in reqs for q in r)

    rep._reset_run_state()
    window = rep.topics[0].window
    t_serve_cap, t_total = _round_budget(r_max, slots, m_max, window,
                                         n_members, max_rounds)
    wall0 = time.perf_counter()
    tok0 = sum(len(r.tokens_out) for eng in engines
               for r in eng.completed)
    req0 = sum(len(eng.completed) for eng in engines)

    key = (repr(e0.cfg), e0.ecfg.max_batch, e0.ecfg.max_len,
           e0.ecfg.eos_id, repr(e0.rt), n_g, slots, n_members, window,
           rep.backend, r_max, p_max, t_serve_cap, t_total)
    shapes = (n_g, slots, n_members, window, True, rep.backend, r_max,
              p_max, t_serve_cap, t_total, e0.ecfg.eos_id,
              e0.ecfg.max_len)
    program = fused_stream_program(
        key, lambda: _build_program(key, e0._decode_body,
                                    e0._reset_body, e0.cache_specs,
                                    shapes))

    prompts = np.zeros((n_g, r_max, p_max), np.int32)
    prompt_len = np.zeros((n_g, r_max), np.int32)
    max_new = np.zeros((n_g, r_max), np.int32)
    n_reqs = np.asarray([len(r) for r in reqs], np.int32)
    for g, rs in enumerate(reqs):
        for i, q in enumerate(rs):
            prompts[g, i, :len(q.prompt)] = np.asarray(q.prompt,
                                                       np.int32)
            prompt_len[g, i] = len(q.prompt)
            max_new[g, i] = q.max_new_tokens
    out = program(e0.params, tuple(eng.cache for eng in engines),
                  jnp.asarray(prompts), jnp.asarray(prompt_len),
                  jnp.asarray(max_new), jnp.asarray(n_reqs))

    # bind the stream while the device loop runs (dispatch is async;
    # the stream is first needed at absorb time, after the loop exits)
    bound = rep.domain.bind(backend=rep.backend)
    stream = bound.stream
    if stream._mask_args:
        raise FusedUnsupported("heterogeneous topic shapes (padded "
                               "stack) — fused path needs a "
                               "homogeneous slot ring")
    if not stream.group.cfg.flags.null_send:
        raise FusedUnsupported("null_send disabled: the in-graph drain "
                               "may never quiesce")
    if stream.windows[0] != window:
        raise FusedUnsupported("topic window disagrees with the bound "
                               "stream's protocol window")

    # ---- host reconstruction (one device->host crossing, post-loop) --
    host = jax.device_get({k: out[k] for k in
                           ("t", "t_serve", "head", "active", "pos",
                            "slot_rid", "apps_enq", "held", "tb_batch",
                            "tb_pub", "tb_nulls", "tb_admit", "tb_tok",
                            "tb_fin", "tb_free", "tb_backlog",
                            "tb_depth")})
    t_end = int(host["t"])
    t_serve = int(host["t_serve"])
    head = host["head"]
    active = host["active"]
    live = active.any() or (head < n_reqs).any()
    if live and t_serve < max_rounds:
        return None                       # budget overflow: fall back
    if t_end >= t_total and not bool(sweep_mod.quiescent_stacked(
            out["states"], out["backlogs"])):
        return None     # exited on the round cap mid-drain: fall back

    tb = {k: host[k][:t_end] for k in
          ("tb_batch", "tb_pub", "tb_nulls", "tb_admit", "tb_tok",
           "tb_fin", "tb_free", "tb_backlog", "tb_depth")}
    counts = (tb["tb_admit"] >= 0).astype(np.int64) \
        + (tb["tb_tok"] >= 0).astype(np.int64)          # (T, G, B)
    stream.absorb(out["states"], out["backlogs"],
                  list(tb["tb_batch"]), list(tb["tb_pub"]),
                  list(tb["tb_nulls"]),
                  [counts[:, g].sum(axis=0) for g in range(n_g)])

    # engines: consume queues, install tokens/completions/caches
    fins: List[Tuple[int, int, int]] = []   # (t, g, slot)
    for t, g, s in zip(*np.nonzero(tb["tb_fin"])):
        fins.append((int(t), int(g), int(s)))
    fins.sort()
    admit_at: dict = {}                     # (g, ridx) -> (t, slot)
    for t, g, s in zip(*np.nonzero(tb["tb_admit"] >= 0)):
        admit_at[(int(g), int(tb["tb_admit"][t, g, s]))] = \
            (int(t), int(s))
    now = time.time()
    decode_steps0 = sum(e.decode_steps for e in engines)
    for g, eng in enumerate(engines):
        n_admitted = int(head[g])
        for i in range(n_admitted):
            req = reqs[g][i]
            t0_r, s = admit_at[(g, i)]
            rep.admit_rounds[req.rid] = t0_r
            rep.admit_slots[req.rid] = (g, s)
            fin_ts = [t for (t, gg, ss) in fins
                      if gg == g and ss == s and t >= t0_r]
            t_fin = min(fin_ts) if fin_ts else t_end
            toks = tb["tb_tok"][t0_r:t_fin + 1, g, s]
            req.tokens_out = [int(x) for x in toks if x >= 0]
            eng.decode_steps += int(prompt_len[g, i])
            if fin_ts:
                req.finished_at = now
                rep.finish_round_by_rid[req.rid] = t_fin
        # completion order: (finish round, slot) — the per-round loop's
        # append order
        for t, gg, s in fins:
            if gg != g:
                continue
            ridx = _owner_at(tb["tb_admit"], t, g, s)
            eng.completed.append(reqs[g][ridx])
        for _ in range(n_admitted):
            eng.queue.popleft()
        eng.slot_req = [None] * slots
        eng.slot_len[:] = 0
        for s in range(slots):
            if active[g, s]:
                ridx = int(host["slot_rid"][g, s])
                eng.slot_req[s] = reqs[g][ridx]
                eng.slot_len[s] = int(host["pos"][g, s])
        eng.rounds += t_serve
        eng.decode_steps += int(
            (tb["tb_tok"][:, g] >= 0).any(axis=1).sum())
        eng.cache = out["caches"][g]
        rep._apps_enqueued[g][:] = host["apps_enq"][g]
    rep.finish_rounds = [(g, s, t) for (t, g, s) in fins]

    # frees: serve-round frees at their round; settle-round frees all
    # land in the single post-finish sync at round t_serve, ordered by
    # hold creation (finish round, slot) per replica
    frees = []
    for t, g, s in zip(*np.nonzero(tb["tb_free"])):
        t, g, s = int(t), int(g), int(s)
        f_ts = [ft for (ft, gg, ss) in fins
                if gg == g and ss == s and ft <= t]
        frees.append((min(t, t_serve), g, max(f_ts) if f_ts else -1, s))
    frees.sort()
    rep.free_rounds = [(g, s, t) for (t, g, _f, s) in frees]
    rep.queue_depth_log = [int(x) for x in tb["tb_depth"][:t_serve]]
    rep.backlog_log = [int(x) for x in tb["tb_backlog"][:t_serve]]

    report, logs = bound.finish()
    wall = time.perf_counter() - wall0
    tokens = sum(len(r.tokens_out) for eng in engines
                 for r in eng.completed) - tok0
    report.extras["delivery_logs"] = logs
    report.extras["serve"] = {
        "replicas": n_g,
        "engine_rounds": t_serve,
        "drained": all(eng.drained() for eng in engines),
        "decode_steps": sum(e.decode_steps
                            for e in engines) - decode_steps0,
        "requests": sum(len(e.completed) for e in engines) - req0,
        "tokens": tokens,
        "tokens_per_s": tokens / wall if wall > 0 else 0.0,
        "stall_rounds": 0,
        "held_slots": int(host["held"].sum()),
        "view_changes": 0,
        "slot_failures": 0,
        "voided_requests": 0,
        "requeued_requests": 0,
        "slot_failure_log": [],
        "fail_at_unreached": [],
        "shed_requests": 0,
        "max_queue_depth": max(rep.queue_depth_log, default=0),
        "max_backlog": max(rep.backlog_log, default=0),
        "wall_s": wall,
        "fused": True,
        "host_hops": 0,
        "fused_rounds": t_end,
        "fused_round_budget": t_total,
    }
    rep.last_report = report
    return report


def _owner_at(tb_admit: np.ndarray, t: int, g: int, s: int) -> int:
    """Request index occupying slot (g, s) at round t: the latest
    admission into that slot at or before t."""
    col = tb_admit[:t + 1, g, s]
    ts = np.nonzero(col >= 0)[0]
    return int(col[ts[-1]])
